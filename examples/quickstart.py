"""Quickstart: FailLite in 60 seconds (discrete-event simulation).

Builds a 20-server / 2-site edge cluster, deploys a mixed app workload
with heterogeneous variant ladders, injects a server crash, and prints
the two-step failover in action — warm switches for critical apps,
progressive small-first loads for the rest.

    PYTHONPATH=src python examples/quickstart.py
"""

from repro.core.simulation import SimConfig, Simulation


def main():
    cfg = SimConfig(n_sites=4, servers_per_site=5, headroom=0.2,
                    critical_frac=0.5, policy="faillite", seed=0)
    sim = Simulation(cfg).setup()
    print(f"cluster: {len(sim.cluster.servers)} servers, "
          f"{len(sim.apps)} applications "
          f"({sum(a.critical for a in sim.apps)} critical)")
    print(f"warm backups planned: {len(sim.controller.warm)}")

    victim = sim.controller.primaries[sim.apps[0].id]
    n_primaries = sum(1 for i in
                      sim.cluster.servers[victim].instances.values()
                      if i.role == "primary" and i.app_id != "_reserved")
    print(f"\ninjecting crash of {victim} "
          f"({n_primaries} primaries affected)...")
    res = sim.inject_failure(servers=[victim])

    print(f"\nrecovery rate: {res.recovery_rate:.0%}   "
          f"mean controller MTTR: {res.mttr_avg*1e3:.0f} ms   "
          f"accuracy cost: {res.accuracy_reduction:.2%}")
    for app_id, rec in sorted(res.records.items()):
        if rec.recovered:
            extra = (f" -> upgraded to {rec.upgraded_to}"
                     if rec.upgraded_to else "")
            print(f"  {app_id:8s} {rec.mode:17s} {rec.mttr*1e3:7.1f} ms  "
                  f"{rec.variant}{extra}")
        else:
            print(f"  {app_id:8s} NOT RECOVERED")

    # what the CLIENTS saw (request-level traffic plane, paper §5.7)
    t = res.traffic
    if t is not None:
        print(f"\nclient view over {t.n_offered} requests:")
        print(f"  availability: {t.availability:.4%}   "
              f"dropped: {t.n_dropped}   "
              f"degraded: {t.n_degraded}   "
              f"SLO-violated: {t.n_slo_violated}")
        print(f"  client-observed MTTR: {t.client_mttr_avg*1e3:.0f} ms   "
              f"accuracy-weighted goodput: {t.goodput:.4f}")
        print(f"  latency proxy p50/p99: {t.latency_p50*1e3:.1f}/"
              f"{t.latency_p99*1e3:.1f} ms")


if __name__ == "__main__":
    main()
