"""Quickstart: FailLite in 60 seconds (the experiment API, sim backend).

One declarative `ExperimentSpec` describes the whole experiment: a
20-server / 4-site edge cluster, a mixed app workload with heterogeneous
variant ladders, and a crash of the server hosting the first app's
primary. `run_experiment` executes it on the discrete-event simulator
and returns the unified `RunResult` — swap `backend="testbed"` to run
the same spec against live worker threads with real JAX engines.

    PYTHONPATH=src python examples/quickstart.py
"""

from repro.experiment import (ExperimentSpec, primary_kill_scenario,
                              run_experiment)


def main():
    spec = ExperimentSpec(n_sites=4, servers_per_site=5, headroom=0.2,
                          critical_frac=0.5, policy="faillite", seed=0,
                          scenario="primary-kill",
                          scenario_builder=primary_kill_scenario())
    res = run_experiment(spec)

    o = res.overall
    print(f"[{res.backend}] scenario={res.scenario} "
          f"policy={res.policy}")
    print(f"recovery rate: {o['recovery_rate']:.0%}   "
          f"mean controller MTTR: {o['mttr_avg']*1e3:.0f} ms   "
          f"accuracy cost: {o['accuracy_reduction']:.2%}")
    for rec in sorted(res.records, key=lambda r: r.app_id):
        if rec.recovered:
            extra = (f" -> upgraded to {rec.upgraded_to}"
                     if rec.upgraded_to else "")
            print(f"  {rec.app_id:8s} {rec.mode:17s} "
                  f"{rec.mttr*1e3:7.1f} ms  {rec.variant}{extra}")
        else:
            print(f"  {rec.app_id:8s} NOT RECOVERED")

    # what the CLIENTS saw (request-level traffic plane, paper §5.7)
    t = res.traffic
    if t is not None:
        print(f"\nclient view over {t.n_offered} requests:")
        print(f"  availability: {t.availability:.4%}   "
              f"dropped: {t.n_dropped}   "
              f"degraded: {t.n_degraded}   "
              f"SLO-violated: {t.n_slo_violated}")
        print(f"  client-observed MTTR: {t.client_mttr_avg*1e3:.0f} ms   "
              f"accuracy-weighted goodput: {t.goodput:.4f}")
        print(f"  latency proxy p50/p99: {t.latency_p50*1e3:.1f}/"
              f"{t.latency_p99*1e3:.1f} ms")


if __name__ == "__main__":
    main()
