"""End-to-end serving driver: REAL failure injection on the mini-testbed.

Six worker threads host real JAX inference engines (reduced configs of
the assigned architectures) behind the FailLite controller.  Clients
issue batched requests at 10 Hz; one server is crashed mid-flight; the
heartbeat detector fires, the two-step failover re-homes the affected
app, and client-observed downtime is reported next to the controller's
MTTR accounting.

    PYTHONPATH=src python examples/edge_failover.py [--policy full-cold]
"""

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--policy", default="faillite",
                    choices=["faillite", "full-warm", "full-cold",
                             "full-warm-k"])
    ap.add_argument("--observe", type=float, default=30.0)
    args = ap.parse_args()

    from repro.serving.testbed import MiniTestbed
    print(f"deploying mini-testbed (policy={args.policy}) — real model "
          f"loads, takes ~1 min on CPU...")
    tb = MiniTestbed(apps_per_arch=1,
                     archs=["qwen2.5-3b", "rwkv6-3b",
                            "recurrentgemma-2b"],
                     seed=1, headroom=0.3, policy=args.policy)
    tb.deploy()
    print(f"  apps: {[a.id for a in tb.apps]}")
    print(f"  warm backups: "
          f"{{k: v[1] for k, v in tb.controller.warm.items()}}")

    res = tb.run_failure_experiment(observe_s=args.observe, client_hz=10.0)
    print(f"\nvictim: {res['victim']}  "
          f"detected in {res['detect_latency_s']*1e3:.0f} ms")
    s = res["summary"]
    print(f"recovery: {s['recovery_rate']:.0%}  "
          f"MTTR {s['mttr_avg']*1e3:.0f} ms  "
          f"accuracy cost {s['accuracy_reduction']:.2%}")
    for app_id, rec in res["records"].items():
        print(f"  {app_id:28s} {rec.mode:17s} "
              f"{rec.mttr*1e3 if rec.recovered else float('nan'):8.0f} ms "
              f"-> {rec.variant}")
    print("\nclient view:")
    for app_id, st in res["client_stats"].items():
        down = f"{st.downtime*1e3:.0f} ms" if st.downtime else "none"
        print(f"  {app_id:28s} ok={st.ok:4d} failed={st.failed:4d} "
              f"downtime={down}")
    tb.shutdown()


if __name__ == "__main__":
    main()
