"""End-to-end serving driver: REAL failure injection on the mini-testbed.

Worker threads host real JAX inference engines (reduced configs of the
assigned architectures) behind the FailLite controller.  Clients issue
batched requests; one server is crashed mid-flight; the heartbeat
detector fires, the two-step failover re-homes the affected app, and
client-observed downtime is reported next to the controller's MTTR
accounting — all through the same `ExperimentSpec` API the simulator
uses (`--backend sim` runs the identical experiment there).

    PYTHONPATH=src python examples/edge_failover.py [--policy full-cold]
"""

import argparse
import math


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--policy", default="faillite",
                    choices=["faillite", "full-warm", "full-cold",
                             "full-warm-k"])
    ap.add_argument("--backend", default="testbed",
                    choices=["sim", "testbed"])
    ap.add_argument("--settle", type=float, default=20.0)
    args = ap.parse_args()

    from repro.experiment import (ExperimentSpec, primary_kill_scenario,
                                  run_experiment)
    spec = ExperimentSpec(
        backend=args.backend, policy=args.policy, app_mix="arch",
        archs=["qwen2.5-3b", "rwkv6-3b", "recurrentgemma-2b"],
        n_sites=3, servers_per_site=2, headroom=0.3, seed=1,
        client_hz=10.0, time_scale=0.25, settle_s=args.settle,
        scenario="primary-kill",
        scenario_builder=primary_kill_scenario())
    if args.backend == "testbed":
        print(f"deploying mini-testbed (policy={args.policy}) — real "
              f"model loads, takes ~1 min on CPU...")
    res = run_experiment(spec)

    if math.isfinite(res.detect_latency_s):
        print(f"\ndetected in {res.detect_latency_s*1e3:.0f} ms")
    s = res.overall
    print(f"recovery: {s['recovery_rate']:.0%}  "
          f"MTTR {s['mttr_avg']*1e3:.0f} ms  "
          f"accuracy cost {s['accuracy_reduction']:.2%}")
    for rec in sorted(res.records, key=lambda r: r.app_id):
        print(f"  {rec.app_id:28s} {rec.mode:17s} "
              f"{rec.mttr*1e3 if rec.recovered else float('nan'):8.0f} ms "
              f"-> {rec.upgraded_to or rec.variant}")

    print("\nclient view:")
    t = res.traffic
    print(f"  {t.n_offered} requests, availability {t.availability:.2%},"
          f" dropped {t.n_dropped}, degraded {t.n_degraded}")
    cli = (f"{t.client_mttr_avg*1e3:.0f} ms"
           if math.isfinite(t.client_mttr_avg) else "inf")
    print(f"  client-observed MTTR: {cli}   "
          f"goodput {t.goodput:.4f}")
    for app_id, st in sorted(res.extras.get("client_stats", {}).items()):
        down = f"{st.downtime*1e3:.0f} ms" if st.downtime else "none"
        print(f"  {app_id:28s} ok={st.ok:4d} failed={st.failed:4d} "
              f"downtime={down}")


if __name__ == "__main__":
    main()
