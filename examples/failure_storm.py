"""Failure storm: the scenario engine end-to-end, via the experiment API.

Replays a rolling outage with rejoins, then a correlated cascade under
workload churn, on the same 20-server cluster — showing per-epoch
recovery, nodes rejoining empty and being re-filled, and the continuous
re-protection loop restoring warm coverage between failure waves. Each
run is one `ExperimentSpec`; add `backend="testbed"` to replay the same
event streams against live workers.

    PYTHONPATH=src python examples/failure_storm.py
"""

from repro.experiment import ExperimentSpec, run_experiment


def show(res):
    print(f"  epochs: {res.n_epochs}")
    for ep, s in enumerate(res.per_epoch):
        mttr = (f"{s['mttr_avg']*1e3:6.0f} ms"
                if s["mttr_avg"] != float("inf") else "   inf")
        print(f"    epoch {ep}: {s['n']:3d} affected  "
              f"recovered {s['recovery_rate']:6.1%}  MTTR {mttr}  "
              f"accuracy cost {s['accuracy_reduction']:.2%}")
    print(f"  overall: {res.overall['recovery_rate']:.1%} of "
          f"{res.overall['n']} recoveries, warm coverage at end "
          f"{res.warm_coverage:.0%}, {res.n_apps_final} apps serving")


def main():
    for name in ("rolling-with-rejoin", "cascade", "churn-under-failure"):
        spec = ExperimentSpec(scenario=name, n_sites=4,
                              servers_per_site=5, headroom=0.2,
                              critical_frac=0.5, policy="faillite",
                              seed=0)
        print(f"\n=== {name} ===")
        show(run_experiment(spec))


if __name__ == "__main__":
    main()
