"""Geo-correlated failures at scale: 100 servers, 10 sites, kill half.

Reproduces the paper's §5.6 scenario with the site-independence
constraint and FailLite's warm-backup reclamation (beyond-paper): the
controller evicts stranded warm replicas of unaffected apps to make room
for progressive failover of the ~50% of applications that lost their
primaries.

    PYTHONPATH=src python examples/site_failure_sim.py
"""

from repro.core.simulation import SimConfig, Simulation


def main():
    for policy in ("faillite", "full-cold"):
        cfg = SimConfig(n_sites=10, servers_per_site=10, headroom=0.2,
                        policy=policy, site_independence=True, seed=0)
        sim = Simulation(cfg).setup()
        sites = list(sim.cluster.sites)[:5]
        print(f"\n[{policy}] {len(sim.apps)} apps on "
              f"{len(sim.cluster.servers)} servers; "
              f"failing sites: {sites}")
        res = sim.inject_failure(sites=sites)
        print(f"  affected: {res.n_affected}  "
              f"recovered: {res.recovery_rate:.1%}  "
              f"MTTR: {res.mttr_avg*1e3:.0f} ms  "
              f"accuracy cost: {res.accuracy_reduction:.2%}")
        modes = {}
        for r in res.records.values():
            modes[r.mode] = modes.get(r.mode, 0) + 1
        print(f"  recovery modes: {modes}")


if __name__ == "__main__":
    main()
