"""Train a ~100M-parameter LM for a few hundred steps with the full
fault-tolerance path: periodic sharded checkpoints, a simulated crash,
and an automatic elastic restart that resumes bit-identically.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""

import argparse
import tempfile

from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--scale", default="20m", choices=["toy", "20m", "100m"])
    args = ap.parse_args()

    ckpt = tempfile.mkdtemp(prefix="faillite_train_")
    crash_at = args.steps // 2
    print(f"=== phase 1: train to step {crash_at}, then crash ===")
    train(arch="qwen2.5-3b", scale=args.scale, steps=args.steps,
          batch=8, seq=128, ckpt_every=25, ckpt_dir=ckpt,
          simulate_failure_at=crash_at)

    print("\n=== phase 2: elastic restart from the latest checkpoint ===")
    out = train(arch="qwen2.5-3b", scale=args.scale, steps=args.steps,
                batch=8, seq=128, ckpt_every=25, ckpt_dir=ckpt,
                resume=True)
    print(f"\nfinal loss: {out['final_loss']:.4f}")


if __name__ == "__main__":
    main()
