"""Compatibility shim — the warm-backup ILP (Eq. 1-7) now lives in
`core/planner/ilp.py`, with sparse constraint assembly built from the
planner's array state. See docs/PLANNER.md."""

from repro.core.planner.ilp import (PlacementResult, build_constraints,
                                    enumerate_vars, solve_warm_placement)

__all__ = ["PlacementResult", "build_constraints", "enumerate_vars",
           "solve_warm_placement"]
