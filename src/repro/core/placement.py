"""Warm-backup model selection & placement — the paper's ILP (Eq. 1-7).

max  Σ_{i∈K} Σ_j Σ_k  a_ij · q_i · x_ijk
s.t. per-server capacity (2), α cold-reserve (3), primary anti-affinity
(4, optionally extended to site anti-affinity, §3.4), one backup per app
(5), latency SLO (6, encoded by filtering variables), binary x (7).

The paper solves this with Gurobi; no solver ships offline, so this is
an exact branch-and-bound over the scipy/HiGHS LP relaxation, with the
paper's own heuristic as the incumbent/warm start and as the fallback at
scale (the paper does the same in its large-scale simulation, §5.1).
Eq. 5 is relaxed from == 1 to <= 1 so low-headroom instances stay
feasible; maximization makes them equal whenever the paper's form is
feasible.
"""

from __future__ import annotations

import heapq
import itertools
import math
import time
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.cluster import Cluster, RESOURCES, Server
from repro.core.variants import Application, Variant


@dataclass
class PlacementResult:
    assignment: Dict[str, Tuple[Variant, str]]   # app -> (variant, server)
    objective: float
    optimal: bool
    nodes: int
    wall_s: float


def _latency_ok(app: Application, variant: Variant, server: Server,
                latency_fn) -> bool:
    if latency_fn is None:
        return True
    return latency_fn(app, variant, server) <= app.latency_slo


def enumerate_vars(apps: List[Application], cluster: Cluster,
                   primaries: Dict[str, str], *,
                   site_independence: bool = False,
                   latency_fn=None):
    """Filtered (app, variant, server) triples honoring Eq. 4 and 6."""
    triples = []
    for app in apps:
        p_srv = primaries.get(app.id)
        p_site = cluster.servers[p_srv].site if p_srv else None
        for v in app.variants:
            for srv in cluster.alive_servers():
                if srv.id == p_srv:
                    continue                      # Eq. 4
                if site_independence and p_site and srv.site == p_site:
                    continue                      # §3.4 extension
                if not _latency_ok(app, v, srv, latency_fn):
                    continue                      # Eq. 6
                triples.append((app, v, srv))
    return triples


def solve_warm_placement(apps: List[Application], cluster: Cluster,
                         primaries: Dict[str, str], *,
                         alpha: float = 0.1,
                         site_independence: bool = False,
                         latency_fn=None,
                         node_limit: int = 500,
                         time_limit_s: float = 10.0) -> PlacementResult:
    """Exact B&B over the LP relaxation (falls back to heuristic bound)."""
    from scipy.optimize import linprog

    t0 = time.time()
    triples = enumerate_vars(apps, cluster, primaries,
                             site_independence=site_independence,
                             latency_fn=latency_fn)
    if not triples:
        return PlacementResult({}, 0.0, True, 0, time.time() - t0)

    nvar = len(triples)
    servers = cluster.alive_servers()
    sidx = {s.id: n for n, s in enumerate(servers)}
    aidx = {a.id: n for n, a in enumerate(apps)}

    # Eq. 1 (negated: linprog minimizes)
    c = np.array([-(t[1].accuracy * t[0].request_rate) for t in triples])

    rows, cols, vals, b_ub = [], [], [], []
    row = 0
    # Eq. 2: per-server, per-resource capacity
    for s in servers:
        for r in RESOURCES:
            for n, (app, v, srv) in enumerate(triples):
                if srv.id == s.id:
                    rows.append(row), cols.append(n), vals.append(v.demand[r])
            b_ub.append(s.free(r))
            row += 1
    # Eq. 3: α cold-reserve on total free capacity
    total_free = cluster.total_free()
    for r in RESOURCES:
        for n, (app, v, srv) in enumerate(triples):
            rows.append(row), cols.append(n), vals.append(v.demand[r])
        b_ub.append((1.0 - alpha) * total_free[r])
        row += 1
    # Eq. 5 (relaxed to <= 1)
    for a in apps:
        for n, (app, v, srv) in enumerate(triples):
            if app.id == a.id:
                rows.append(row), cols.append(n), vals.append(1.0)
        b_ub.append(1.0)
        row += 1

    from scipy.sparse import coo_matrix
    A = coo_matrix((vals, (rows, cols)), shape=(row, nvar)).tocsr()
    b = np.array(b_ub)

    def lp(lo, hi):
        res = linprog(c, A_ub=A, b_ub=b, bounds=np.stack([lo, hi], axis=1),
                      method="highs")
        if not res.success:
            return None, None
        return res.fun, res.x

    # incumbent from the paper's heuristic (greedy)
    from repro.core.heuristic import faillite_heuristic
    greedy = faillite_heuristic(
        apps, cluster, exclude={a.id: {primaries.get(a.id)} for a in apps},
        site_exclude={a.id: ({cluster.servers[primaries[a.id]].site}
                             if site_independence and a.id in primaries
                             else set()) for a in apps},
        alpha=alpha, latency_fn=latency_fn)
    inc_obj = -sum(v.accuracy * next(a for a in apps if a.id == i).request_rate
                   for i, (v, s) in greedy.assignment.items())
    incumbent = greedy.assignment

    lo0 = np.zeros(nvar)
    hi0 = np.ones(nvar)
    nodes = 0
    heap = []
    root_obj, root_x = lp(lo0, hi0)
    if root_obj is None:
        return PlacementResult(incumbent, -inc_obj, False, 0,
                               time.time() - t0)
    counter = itertools.count()
    heapq.heappush(heap, (root_obj, next(counter), lo0, hi0, root_x))
    best_obj, best_x = inc_obj, None
    optimal = True

    while heap:
        bound, _, lo, hi, x = heapq.heappop(heap)
        if bound >= best_obj - 1e-9:
            continue
        nodes += 1
        if nodes > node_limit or time.time() - t0 > time_limit_s:
            optimal = False
            break
        frac = np.abs(x - np.round(x))
        j = int(np.argmax(frac))
        if frac[j] < 1e-6:
            if bound < best_obj - 1e-9:
                best_obj, best_x = bound, x
            continue
        for fix in (0.0, 1.0):
            lo2, hi2 = lo.copy(), hi.copy()
            lo2[j] = hi2[j] = fix
            obj2, x2 = lp(lo2, hi2)
            if obj2 is None or obj2 >= best_obj - 1e-9:
                continue
            frac2 = np.abs(x2 - np.round(x2))
            if frac2.max() < 1e-6:
                best_obj, best_x = obj2, x2
            else:
                heapq.heappush(heap, (obj2, next(counter), lo2, hi2, x2))

    if best_x is None:
        return PlacementResult(incumbent, -inc_obj, optimal, nodes,
                               time.time() - t0)
    assignment = {}
    for n, (app, v, srv) in enumerate(triples):
        if best_x[n] > 0.5:
            assignment[app.id] = (v, srv.id)
    return PlacementResult(assignment, -best_obj, optimal, nodes,
                           time.time() - t0)
