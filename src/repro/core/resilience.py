"""Request-plane resilience toolkit: hedging, circuit breakers,
bulkheads, retry budgets, and drain-gated admission control.

FailLite's contribution is fast *recovery* (175.5 ms MTTR); this layer
shapes the request plane *while* the controller recovers, so failover
storms cannot erase the MTTR win:

  * **hedged requests** — after a configurable delay a pending request
    is re-issued to the app's warm backup and the first success wins
    (the loser is cancelled). Clients of warm-protected apps bridge the
    detection gap instead of timing out against a dead primary.
  * **circuit breakers** — per-app closed/open/half-open state machines
    over a rolling failure window. An open breaker fails fast to the
    degraded (warm backup) variant instead of queueing on a dead
    primary; half-open probes detect recovery.
  * **bulkheads** — per-server bounded in-flight slots, so one app's
    failover storm cannot starve co-located apps of worker capacity.
  * **retry-with-budget** — retries are paid from a token budget that
    accrues per fresh request, bounding retry amplification.
  * **drain-gated admission** — while the `RecoveryScheduler` is
    draining recovery loads, offered load above ``admit_util`` is
    rate-limited (deterministic token-bucket thinning): draining
    servers shed excess load instead of absorbing it into a
    metastable queueing collapse.

Both execution backends enforce the same config: the mini-testbed
(serving/testbed.py) applies the primitives live on real worker
threads, while the simulator applies the equivalent *vectorized*
outcome shaping (`shape_app_log`) to the classified request arrays —
a pure function of the recorded timelines and the config, with **no
new RNG draws**, so runs stay bit-deterministic and the off-path
(``enabled=False`` or no config at all) is bit-exact with the
pre-resilience behavior (pinned by tests/test_resilience.py).

New outcome classes (core/metrics.py): hedged-win, fast-failed, shed,
retried — every request is still classified exactly once
(tests/test_properties.py pins the conservation invariant).
"""

from __future__ import annotations

import math
import threading
import time
from dataclasses import asdict, dataclass, fields
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.metrics import UP, AppLog, DowntimeWindow


# ---------------------------------------------------------------------------
# config
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ResilienceConfig:
    """Knobs of the request-plane resilience layer.

    ``enabled=False`` (the default) keeps every request path
    bit-exactly on the historical behavior; a spec/SimConfig carries
    the config as a plain dict (JSON round-trip), coerced here.
    """
    enabled: bool = False
    # hedging: delay before the backup is engaged; the testbed scales a
    # live latency percentile, the simulator the backup's service time
    hedge_delay_factor: float = 2.0
    hedge_min_delay_s: float = 0.02
    # circuit breaker: rolling outcome window + failure-rate trip rule
    breaker_window: int = 8
    breaker_failure_rate: float = 0.5
    breaker_min_failures: int = 4
    breaker_open_s: float = 0.5
    breaker_probes: int = 1
    # bulkhead: bounded in-flight submissions per server
    bulkhead_slots: int = 4
    # retry budget: tokens accrued per fresh request / spent per retry
    retry_budget: float = 0.2
    retry_backoff_s: float = 0.02
    # admission during recovery drain: offered utilization ceiling
    admit_util: float = 0.75

    def to_dict(self) -> dict:
        return asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "ResilienceConfig":
        known = {f.name for f in fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(f"unknown ResilienceConfig fields: "
                             f"{sorted(unknown)}")
        return cls(**d)

    @classmethod
    def coerce(cls, value) -> Optional["ResilienceConfig"]:
        """None | dict | ResilienceConfig -> config or None.

        A dict without an explicit ``enabled`` key means "turn it on"
        (passing a config at all expresses intent); ``None`` and
        ``enabled=False`` both mean the off-path.
        """
        if value is None:
            return None
        if isinstance(value, cls):
            return value
        if isinstance(value, dict):
            d = dict(value)
            d.setdefault("enabled", True)
            return cls.from_dict(d)
        raise TypeError(f"cannot coerce {type(value).__name__} "
                        f"to ResilienceConfig")


def active(value) -> Optional[ResilienceConfig]:
    """Coerce + gate: the config when enabled, else None."""
    cfg = ResilienceConfig.coerce(value)
    return cfg if (cfg is not None and cfg.enabled) else None


# ---------------------------------------------------------------------------
# circuit breaker
# ---------------------------------------------------------------------------

CLOSED, OPEN, HALF_OPEN = "closed", "open", "half-open"


class CircuitBreaker:
    """Per-app failure-rate breaker (closed -> open -> half-open).

    Closed: outcomes fold into a rolling window; the breaker trips when
    the window holds at least ``breaker_min_failures`` failures AND the
    window failure rate reaches ``breaker_failure_rate``. Open: every
    request fails fast (to the degraded variant, if the caller has one)
    until ``breaker_open_s`` elapses, then half-open grants
    ``breaker_probes`` probe requests — one success closes the breaker,
    one failure re-opens it. Thread-safe; the clock is injectable so
    the state machine is unit-testable without sleeping.
    """

    def __init__(self, cfg: ResilienceConfig,
                 clock: Callable[[], float] = time.monotonic):
        self.cfg = cfg
        self.clock = clock
        self._lock = threading.Lock()
        self.state = CLOSED
        self._window: List[bool] = []       # True = failure
        self._opened_at = 0.0
        self._probes_left = 0

    def allow(self) -> bool:
        """May the next request go to the primary?"""
        with self._lock:
            if self.state == OPEN:
                if self.clock() - self._opened_at >= self.cfg.breaker_open_s:
                    self.state = HALF_OPEN
                    self._probes_left = self.cfg.breaker_probes
                else:
                    return False
            if self.state == HALF_OPEN:
                if self._probes_left <= 0:
                    return False
                self._probes_left -= 1
                return True
            return True

    def record(self, ok: bool):
        with self._lock:
            if self.state == HALF_OPEN:
                if ok:
                    self.state = CLOSED
                    self._window = []
                else:
                    self._trip()
                return
            if self.state == OPEN:
                return
            self._window.append(not ok)
            if len(self._window) > self.cfg.breaker_window:
                self._window.pop(0)
            fails = sum(self._window)
            if (fails >= self.cfg.breaker_min_failures
                    and fails >= self.cfg.breaker_failure_rate
                    * len(self._window)):
                self._trip()

    def _trip(self):
        self.state = OPEN
        self._opened_at = self.clock()
        self._window = []


# ---------------------------------------------------------------------------
# bulkhead
# ---------------------------------------------------------------------------

class Bulkhead:
    """Bounded in-flight slots (per server): acquire-or-reject."""

    def __init__(self, slots: int):
        self.slots = max(1, int(slots))
        self._lock = threading.Lock()
        self._in_flight = 0

    def try_acquire(self) -> bool:
        with self._lock:
            if self._in_flight >= self.slots:
                return False
            self._in_flight += 1
            return True

    def release(self):
        with self._lock:
            self._in_flight = max(0, self._in_flight - 1)

    @property
    def in_flight(self) -> int:
        with self._lock:
            return self._in_flight


# ---------------------------------------------------------------------------
# retry budget
# ---------------------------------------------------------------------------

class RetryBudget:
    """Token bucket bounding retry amplification: each fresh request
    accrues ``retry_budget`` tokens (capped), each retry spends one —
    so the retry rate can never exceed ``retry_budget`` times the
    offered rate, no matter how long the outage lasts."""

    def __init__(self, cfg: ResilienceConfig, cap: float = 8.0):
        self.rate = cfg.retry_budget
        self.cap = cap
        self._lock = threading.Lock()
        self._tokens = 0.0

    def on_request(self):
        with self._lock:
            self._tokens = min(self.cap, self._tokens + self.rate)

    def try_spend(self) -> bool:
        with self._lock:
            if self._tokens < 1.0:
                return False
            self._tokens -= 1.0
            return True

    @property
    def tokens(self) -> float:
        with self._lock:
            return self._tokens


# ---------------------------------------------------------------------------
# hedged call (testbed live path)
# ---------------------------------------------------------------------------

def hedged_call(primary: Callable[[threading.Event], object],
                backup: Optional[Callable[[threading.Event], object]],
                delay_s: float,
                timeout_s: float = 10.0) -> Tuple[object, Optional[str]]:
    """First-success-wins hedge between two attempts.

    ``primary`` starts immediately; ``backup`` starts after ``delay_s``
    — or as soon as the primary *fails* (returns None / raises), since
    waiting out the hedge delay against a known-dead primary is wasted
    time. Each callable receives a cancel `threading.Event`; when the
    other side wins, the loser's event is set (cooperative
    cancellation) and its eventual result is discarded.

    Returns ``(value, winner)`` with winner in {"primary", "backup"},
    or ``(None, None)`` when both fail (or the timeout expires).
    """
    settled = threading.Event()
    state = {"failed": 0}
    state_lock = threading.Lock()
    cancels = {"primary": threading.Event(), "backup": threading.Event()}
    n_arms = 1 if backup is None else 2

    def arm(name, fn):
        try:
            out = fn(cancels[name])
        except Exception:                  # noqa: BLE001
            out = None
        with state_lock:
            if out is not None and "winner" not in state:
                state["winner"] = name
                state["value"] = out
                for other, ev in cancels.items():
                    if other != name:
                        ev.set()
                settled.set()
            elif out is None:
                state["failed"] += 1
                if state["failed"] >= n_arms:
                    settled.set()

    t_primary = threading.Thread(target=arm, args=("primary", primary),
                                 daemon=True)
    t_primary.start()
    if backup is not None:
        # wake early on primary success OR failure; fall through to the
        # hedge on the delay either way
        deadline = time.monotonic() + max(0.0, delay_s)
        while not settled.is_set() and time.monotonic() < deadline:
            if not t_primary.is_alive():
                break
            settled.wait(min(0.005, max(0.0,
                                        deadline - time.monotonic())))
        with state_lock:
            won = "winner" in state
        if not won:
            threading.Thread(target=arm, args=("backup", backup),
                             daemon=True).start()
    settled.wait(timeout_s)
    with state_lock:
        return state.get("value"), state.get("winner")


# ---------------------------------------------------------------------------
# vectorized outcome shaping (simulator path)
# ---------------------------------------------------------------------------

def admit_mask(p: np.ndarray) -> np.ndarray:
    """Deterministic token-bucket thinning: keep request i iff the
    cumulative admission credit crosses an integer at i. Admits a
    ``mean(p)`` fraction with maximal spacing — no RNG draws."""
    c = np.cumsum(p)
    return np.floor(c) > np.floor(c - p)


def shape_app_log(log: AppLog, rates: np.ndarray, *,
                  times: np.ndarray, states: np.ndarray,
                  accs: np.ndarray, svcs: np.ndarray,
                  windows: Sequence[DowntimeWindow],
                  drains: Sequence[Tuple[float, float]],
                  full_accuracy: float, slo: float,
                  util_k: float, util_cap: float,
                  rcfg: ResilienceConfig) -> AppLog:
    """Apply the resilience policies to one app's classified arrays.

    A pure, vectorized function of the recorded serving timeline, the
    downtime windows (with their warm-backup annotations), and the
    recovery-drain intervals — deterministic, no RNG:

      * dropped arrivals inside a window whose ``backup`` is known
        become **hedged** wins: served by the backup variant after the
        hedge delay (first-success-wins against a dead primary);
      * in windows with no backup, failures beyond the breaker's trip
        threshold become **fast-failed** (the open breaker answers
        immediately instead of queueing on the dead primary);
      * the last ``retry_budget`` fraction of a recovered window's
        failures are **retried** successfully once the route is
        restored (latency honestly spans the remaining outage);
      * while a recovery drain is active, served load whose offered
        utilization exceeds ``admit_util`` is thinned: rejected
        requests are **shed**, admitted ones see queueing latency
        capped at the admission ceiling.
    """
    n = log.arrivals.size
    hedged = np.zeros(n, bool)
    fast_failed = np.zeros(n, bool)
    shed = np.zeros(n, bool)
    retried = np.zeros(n, bool)
    if n == 0:
        return AppLog(log.app_id, log.arrivals, log.served, log.dropped,
                      log.offered, log.degraded, log.slo_violated,
                      log.accuracy, log.latency, hedged=hedged,
                      fast_failed=fast_failed, shed=shed, retried=retried)

    arrivals = log.arrivals
    served = log.served.copy()
    dropped = log.dropped.copy()
    degraded = log.degraded.copy()
    slo_v = log.slo_violated.copy()
    accuracy = log.accuracy.copy()
    latency = log.latency.copy()
    # per-request service time from the timeline (what classify_app saw)
    tl_idx = np.clip(np.searchsorted(times, arrivals, side="right") - 1,
                     0, len(times) - 1)
    svc_req = svcs[tl_idx]

    for w in windows:
        if w.app_id != log.app_id:
            continue
        lo = np.searchsorted(arrivals, w.t_start, side="left")
        hi = (np.searchsorted(arrivals, w.t_end, side="left")
              if w.recovered else n)
        idx = lo + np.nonzero(dropped[lo:hi])[0]
        if idx.size == 0:
            continue
        if w.backup is not None:
            # hedge: the warm backup answers after the hedge delay
            b_acc, b_svc = w.backup
            delay = max(rcfg.hedge_min_delay_s,
                        rcfg.hedge_delay_factor * b_svc)
            util_b = np.clip(rates[idx] * b_svc * util_k, 0.0, util_cap)
            lat = delay + b_svc / (1.0 - util_b)
            served[idx] = True
            dropped[idx] = False
            hedged[idx] = True
            accuracy[idx] = b_acc
            latency[idx] = lat
            degraded[idx] = b_acc < full_accuracy - 1e-12
            slo_v[idx] = lat > slo
            continue
        # no backup: retry the budgeted tail once the route restores...
        n_retry = 0
        if w.recovered:
            j = int(np.searchsorted(times, w.t_end, side="right")) - 1
            if 0 <= j < len(times) and states[j] == UP:
                n_retry = int(rcfg.retry_budget * idx.size)
            if n_retry:
                rid = idx[-n_retry:]
                lat_r = (w.t_end + rcfg.retry_backoff_s) - arrivals[rid]
                served[rid] = True
                dropped[rid] = False
                retried[rid] = True
                accuracy[rid] = accs[j]
                latency[rid] = lat_r
                degraded[rid] = accs[j] < full_accuracy - 1e-12
                slo_v[rid] = lat_r > slo
        # ...and fail the rest fast once the breaker trips
        rest = idx[:idx.size - n_retry]
        if rest.size > rcfg.breaker_min_failures:
            ff = rest[rcfg.breaker_min_failures:]
            dropped[ff] = False
            fast_failed[ff] = True

    # admission control while a recovery drain is active
    for t0, t1 in drains:
        lo = np.searchsorted(arrivals, t0, side="left")
        hi = np.searchsorted(arrivals, t1, side="left")
        if hi <= lo:
            continue
        raw = rates[lo:hi] * svc_req[lo:hi] * util_k
        over = (served[lo:hi] & ~hedged[lo:hi] & ~retried[lo:hi]
                & (raw > rcfg.admit_util))
        oidx = lo + np.nonzero(over)[0]
        if oidx.size == 0:
            continue
        raw_o = raw[oidx - lo]
        keep = admit_mask(rcfg.admit_util / raw_o)
        rej = oidx[~keep]
        adm = oidx[keep]
        if rej.size:
            served[rej] = False
            shed[rej] = True
            degraded[rej] = False
            slo_v[rej] = False
            accuracy[rej] = math.nan
            latency[rej] = math.nan
        if adm.size:
            # thinned to the ceiling: queueing factor re-priced from
            # the original utilization down to admit_util
            util_o = np.clip(raw_o[keep], 0.0, util_cap)
            latency[adm] *= (1.0 - util_o) / (1.0 - rcfg.admit_util)
            slo_v[adm] = latency[adm] > slo

    return AppLog(log.app_id, arrivals, served, dropped, log.offered,
                  degraded, slo_v, accuracy, latency, hedged=hedged,
                  fast_failed=fast_failed, shed=shed, retried=retried)
