"""Declarative failure scenarios — trace-driven fault injection.

The paper evaluates one-shot server/site crashes; real edge deployments
see *sequences* of correlated faults: cascades that spill across racks,
rolling maintenance with rejoins, flaky nodes that crash repeatedly, and
workload churn arriving mid-outage. A `Scenario` is a list of timed
events the simulator replays deterministically from a seed, exercising
the controller's re-entrant failure handling and the continuous
re-protection loop.

Event types:
    ServerFail / SiteFail      crash one server / a whole failure domain
    ShardFail                  crash one server hosting a shard of a
                               tensor-parallel group (physically a
                               server crash; the controller's shard
                               plane decides degrade/reshard/fallback)
    ServerRejoin               failed node returns (empty, gets refilled)
    AppArrival / AppDeparture  workload churn
    LoadSpike                  temporary request-rate multiplier
    LinkDegrade                temporary bandwidth cut on a storage link
                               ("cloud", "nic:<sid>", or "disk:<sid>")

Named library (`SCENARIOS`): single-server, site-outage, cascade,
rolling-with-rejoin, churn-under-failure, flaky-node, plus
cold-load-storm (a site outage under a degraded cloud uplink — the
model-state plane's worst case: every surviving server cold-loads at
once and the fetch paths contend; pair it with the "edge" storage
preset), three resilience storms — retry-amplification,
thundering-herd-rejoin, metastable-overload (crash + spike compositions
stressing the request-plane toolkit, core/resilience.py) — and chaos
(a seeded randomized churn stream from
core/chaos.py — the soak harness's always-on scenario). Generators
(`cascade_failures`, `rolling_failures`, `flaky_server`) compose into
custom scenarios.

Every scenario replay is also measured at the *request* level: while the
events above drive the control plane, the simulator's traffic plane
(core/traffic.py + core/metrics.py) streams per-app requests through the
epoch-versioned routing table, so each `ScenarioResult` carries
client-observed availability, MTTR, and accuracy-weighted goodput next
to the per-epoch controller records. `LoadSpike` is therefore no longer
cosmetic: the multiplied rates generate real extra requests (and
queueing-latency pressure) for the spike's duration.

Determinism guarantee: the scenario RNG is seeded from (name, seed)
independently of the workload RNG, and all request-level randomness
derives from the simulation seed — the same (name, seed, cluster)
yields the same event trace AND the same per-request trace; see
`ScenarioResult.fingerprint()`.
"""

from __future__ import annotations

import random
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.cluster import Cluster
from repro.core.variants import Application, synthetic_family


# ---------------------------------------------------------------------------
# events
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ScenarioEvent:
    t: float


@dataclass(frozen=True)
class ServerFail(ScenarioEvent):
    server: str = ""


@dataclass(frozen=True)
class SiteFail(ScenarioEvent):
    site: str = ""


@dataclass(frozen=True)
class ShardFail(ScenarioEvent):
    """Kill one server hosting a member of a tensor-parallel shard
    group. Physically identical to `ServerFail` (the whole host dies);
    the distinct event type marks the *intent* — stressing the shard
    plane's recovery ladder (degraded-TP continuation, reshard onto
    survivors, monolith fallback) — and keeps traces self-describing.
    With `tp_degree=1` (no groups) it behaves exactly like ServerFail."""
    server: str = ""


@dataclass(frozen=True)
class ServerRejoin(ScenarioEvent):
    server: str = ""


@dataclass(frozen=True)
class AppArrival(ScenarioEvent):
    app: Optional[Application] = None


@dataclass(frozen=True)
class AppDeparture(ScenarioEvent):
    app_id: str = ""


@dataclass(frozen=True)
class LoadSpike(ScenarioEvent):
    factor: float = 2.0
    duration: float = 5.0
    app_ids: Optional[Tuple[str, ...]] = None     # None = every app


@dataclass(frozen=True)
class LinkDegrade(ScenarioEvent):
    """Cut a storage link's bandwidth to `factor`x for `duration`
    seconds. `link` uses the model-state plane's link names
    (core/modelstate.py): "cloud", "nic:<server>", "disk:<server>"."""
    link: str = "cloud"
    factor: float = 0.5
    duration: float = 10.0


FAILURE_EVENTS = (ServerFail, SiteFail, ShardFail)


@dataclass
class Scenario:
    """A named, deterministic event trace."""
    name: str
    events: List[ScenarioEvent]
    horizon: float                 # sim runs until horizon (post-settle)
    description: str = ""

    def sorted_events(self) -> List[ScenarioEvent]:
        return sorted(self.events, key=lambda e: e.t)

    @property
    def n_failure_events(self) -> int:
        return sum(1 for e in self.events
                   if isinstance(e, FAILURE_EVENTS))

    def validate(self, cluster: Cluster) -> None:
        for e in self.events:
            if e.t < 0:
                raise ValueError(f"negative event time: {e}")
            if isinstance(e, (ServerFail, ServerRejoin, ShardFail)) \
                    and e.server not in cluster.servers:
                raise ValueError(f"unknown server in {e}")
            if isinstance(e, SiteFail) and e.site not in cluster.sites:
                raise ValueError(f"unknown site in {e}")
            if isinstance(e, LinkDegrade):
                if e.factor <= 0:
                    raise ValueError(f"non-positive degrade factor: {e}")
                if ":" in e.link:
                    kind, sid = e.link.split(":", 1)
                    if kind not in ("disk", "nic") \
                            or sid not in cluster.servers:
                        raise ValueError(f"unknown link in {e}")
                elif e.link != "cloud":
                    raise ValueError(f"unknown link in {e}")


# ---------------------------------------------------------------------------
# generators (compose into custom scenarios)
# ---------------------------------------------------------------------------

def _pick_servers(cluster: Cluster, rng: random.Random, n: int,
                  site: Optional[str] = None) -> List[str]:
    pool = (list(cluster.sites[site]) if site
            else sorted(s.id for s in cluster.alive_servers()))
    return rng.sample(pool, min(n, len(pool)))


def cascade_failures(cluster: Cluster, rng: random.Random, *,
                     t0: float = 1.0, waves: int = 3, per_wave: int = 2,
                     gap: float = 4.0) -> List[ScenarioEvent]:
    """Correlated cascade: failure waves every `gap` seconds, each wave
    hitting servers co-located with the previous wave when possible
    (overload/thermal spill inside a failure domain)."""
    events: List[ScenarioEvent] = []
    chosen: List[str] = []
    site: Optional[str] = None
    for w in range(waves):
        pool = [sid for sid in
                (cluster.sites[site] if site
                 else sorted(cluster.servers))
                if sid not in chosen]
        if not pool:           # domain exhausted: spill to a new site
            site = None
            pool = [sid for sid in sorted(cluster.servers)
                    if sid not in chosen]
            if not pool:
                break
        hit = rng.sample(pool, min(per_wave, len(pool)))
        chosen.extend(hit)
        site = cluster.servers[hit[0]].site
        events.extend(ServerFail(t=t0 + w * gap, server=sid)
                      for sid in hit)
    return events


def rolling_failures(cluster: Cluster, rng: random.Random, *,
                     n: int = 4, t0: float = 1.0, period: float = 4.0,
                     downtime: float = 6.0,
                     rejoin: bool = True) -> List[ScenarioEvent]:
    """Rolling outage (maintenance-style): one server down every
    `period` seconds, each rejoining `downtime` seconds later."""
    events: List[ScenarioEvent] = []
    for i, sid in enumerate(_pick_servers(cluster, rng, n)):
        t_fail = t0 + i * period
        events.append(ServerFail(t=t_fail, server=sid))
        if rejoin:
            events.append(ServerRejoin(t=t_fail + downtime, server=sid))
    return events


def flaky_server(cluster: Cluster, rng: random.Random, *,
                 cycles: int = 3, t0: float = 1.0, up: float = 4.0,
                 down: float = 2.0,
                 server: Optional[str] = None) -> List[ScenarioEvent]:
    """One node crash-looping: fails, rejoins, fails again."""
    sid = server or _pick_servers(cluster, rng, 1)[0]
    events: List[ScenarioEvent] = []
    t = t0
    for _ in range(cycles):
        events.append(ServerFail(t=t, server=sid))
        events.append(ServerRejoin(t=t + down, server=sid))
        t += down + up
    return events


def churn_apps(rng: random.Random, *, n: int = 3, t0: float = 0.5,
               spacing: float = 2.0, mem: float = 1.0e9,
               spread: float = 5.0,
               prefix: str = "late") -> List[ScenarioEvent]:
    """A stream of app arrivals with fresh synthetic ladders."""
    events: List[ScenarioEvent] = []
    for i in range(n):
        ladder = synthetic_family(f"{prefix}{i}", mem, n_variants=4,
                                  spread=spread)
        app = Application(id=f"{prefix}{i}", family=ladder[0].family,
                          variants=ladder,
                          request_rate=rng.uniform(0.5, 2.0),
                          # same finite SLO rule as setup-time apps
                          # (simulation.synthetic_apps), so churned
                          # apps are SLO-gated like everyone else
                          latency_slo=ladder[0].compute * 4.0,
                          critical=(i % 2 == 0))
        events.append(AppArrival(t=t0 + i * spacing, app=app))
    return events


# ---------------------------------------------------------------------------
# named scenario library
# ---------------------------------------------------------------------------

def _single_server(cluster, apps, rng) -> Scenario:
    sid = _pick_servers(cluster, rng, 1)[0]
    return Scenario(
        name="single-server",
        events=[ServerFail(t=1.0, server=sid)],
        horizon=30.0,
        description="the paper's base case: one server crash")


def _site_outage(cluster, apps, rng) -> Scenario:
    site = rng.choice(sorted(cluster.sites))
    return Scenario(
        name="site-outage",
        events=[SiteFail(t=1.0, site=site)],
        horizon=40.0,
        description="a whole failure domain (rack/pod) goes dark")


def _cascade(cluster, apps, rng) -> Scenario:
    events = cascade_failures(cluster, rng, t0=1.0, waves=3,
                              per_wave=2, gap=4.0)
    return Scenario(
        name="cascade",
        events=events,
        horizon=45.0,
        description="correlated cascade: three failure waves spilling "
                    "through co-located servers")


def _rolling_with_rejoin(cluster, apps, rng) -> Scenario:
    events = rolling_failures(cluster, rng, n=4, t0=1.0, period=4.0,
                              downtime=6.0, rejoin=True)
    return Scenario(
        name="rolling-with-rejoin",
        events=events,
        horizon=45.0,
        description="rolling outage; every node rejoins empty and is "
                    "re-filled by the re-protection loop")


def _churn_under_failure(cluster, apps, rng) -> Scenario:
    events: List[ScenarioEvent] = []
    events += churn_apps(rng, n=3, t0=0.5, spacing=2.0)
    # departures of existing apps (deterministic choice from the seed)
    if apps:
        leave = rng.sample(sorted(a.id for a in apps),
                           min(2, len(apps)))
        events += [AppDeparture(t=3.0 + i * 2.0, app_id=aid)
                   for i, aid in enumerate(leave)]
    events.append(LoadSpike(t=1.5, factor=3.0, duration=6.0))
    events.append(ServerFail(t=2.5,
                             server=_pick_servers(cluster, rng, 1)[0]))
    return Scenario(
        name="churn-under-failure",
        events=events,
        horizon=40.0,
        description="arrivals, departures, and a load spike around a "
                    "mid-churn server crash")


def _flaky_node(cluster, apps, rng) -> Scenario:
    events = flaky_server(cluster, rng, cycles=3, t0=1.0, up=5.0,
                          down=2.0)
    return Scenario(
        name="flaky-node",
        events=events,
        horizon=40.0,
        description="one node crash-looping three times; bookkeeping "
                    "must not double-count repeated failures")


def _cold_load_storm(cluster, apps, rng) -> Scenario:
    """The model-state plane's stress case: a whole site goes dark while
    the cloud uplink is degraded, so every affected app cold-loads at
    once and the fetch paths (peer NICs, shared uplink) contend. With
    the default local-everything storage this degenerates into a plain
    site outage; run it with the "edge" storage preset to see the
    contention (tools/bench_mttr.py does exactly that)."""
    site = rng.choice(sorted(cluster.sites))
    events: List[ScenarioEvent] = [
        SiteFail(t=1.0, site=site),
        LinkDegrade(t=1.0, link="cloud", factor=0.5, duration=30.0),
    ]
    return Scenario(
        name="cold-load-storm",
        events=events,
        horizon=45.0,
        description="site outage under a degraded cloud uplink: a storm "
                    "of simultaneous cold loads contending for fetch "
                    "bandwidth")


def _retry_amplification(cluster, apps, rng) -> Scenario:
    """The resilience layer's headline storm: a server crash immediately
    followed by a cluster-wide 3x load spike — the client-side retry
    wave a blackout triggers. Without the toolkit every spiked request
    against the dead primary is lost (and survivors drown in queueing);
    with it, hedges bridge to warm backups, breakers fail fast, and
    admission thins the spike during the recovery drain
    (tools/bench_resilience.py gates on-beats-off here)."""
    sid = _pick_servers(cluster, rng, 1)[0]
    events: List[ScenarioEvent] = [
        ServerFail(t=1.0, server=sid),
        LoadSpike(t=1.2, factor=3.0, duration=10.0),
    ]
    return Scenario(
        name="retry-amplification",
        events=events,
        horizon=35.0,
        description="server crash + immediate 3x retry wave: the storm "
                    "that erases MTTR wins without request shaping")


def _thundering_herd_rejoin(cluster, apps, rng) -> Scenario:
    """A whole site blacks out, then every one of its servers rejoins
    at the same instant while pent-up demand (2.5x spike) slams the
    cluster — rejoin refill and the spike contend for the same recovery
    drain."""
    site = rng.choice(sorted(cluster.sites))
    sids = sorted(cluster.sites[site])
    events: List[ScenarioEvent] = [SiteFail(t=1.0, site=site)]
    events += [ServerRejoin(t=9.0, server=s) for s in sids]
    events.append(LoadSpike(t=9.0, factor=2.5, duration=8.0))
    return Scenario(
        name="thundering-herd-rejoin",
        events=events,
        horizon=40.0,
        description="site outage, then all its servers rejoin at once "
                    "under a pent-up 2.5x demand wave")


def _metastable_overload(cluster, apps, rng) -> Scenario:
    """The metastable failure mode: a sustained (20 s) 2x overload with
    a crash at its start and a second crash mid-overload — the system
    must recover while queueing pressure never lets up, the regime
    where uncontrolled retries keep a healthy-capacity cluster
    saturated indefinitely."""
    sids = _pick_servers(cluster, rng, 2)
    events: List[ScenarioEvent] = [
        ServerFail(t=1.0, server=sids[0]),
        LoadSpike(t=1.5, factor=2.0, duration=20.0),
    ]
    if len(sids) > 1:
        events.append(ServerFail(t=7.0, server=sids[1]))
    return Scenario(
        name="metastable-overload",
        events=events,
        horizon=40.0,
        description="sustained 2x overload with two crashes inside it: "
                    "recovery under never-relenting queueing pressure")


def _tp_shard_storm(cluster, apps, rng) -> Scenario:
    """The shard plane's stress case: three staggered `ShardFail`
    kills against distinct servers with a load spike between them, so
    several tensor-parallel groups lose a member while demand is up.
    With `tp_degree=1` (the default) no groups exist and every kill is
    an ordinary server crash — the scenario still builds, validates,
    and replays deterministically. Pair it with `tp_degree>=2` and a
    `shard_policy` (degrade / reshard / monolith) to exercise the
    recovery ladder; `tools/bench_shardfail.py` sweeps exactly that."""
    sids = _pick_servers(cluster, rng, 3)
    events: List[ScenarioEvent] = [
        ShardFail(t=1.0 + 6.0 * i, server=sid)
        for i, sid in enumerate(sids)
    ]
    events.append(LoadSpike(t=2.0, factor=2.0, duration=8.0))
    return Scenario(
        name="tp-shard-storm",
        events=events,
        horizon=45.0,
        description="staggered shard-host kills under a 2x spike: "
                    "tensor-parallel groups lose members while demand "
                    "is elevated")


def _chaos(cluster, apps, rng) -> Scenario:
    """Seeded randomized churn stream (core/chaos.py): crashes with
    staggered rejoins, site blackouts, load spikes, and link degrades
    drawn from a marked Poisson process — the soak harness's scenario.
    Imported lazily: chaos.py composes the event vocabulary above."""
    from repro.core.chaos import build_chaos
    return build_chaos(cluster, rng)


ScenarioBuilder = Callable[[Cluster, Sequence[Application],
                            random.Random], Scenario]

SCENARIOS: Dict[str, ScenarioBuilder] = {
    "single-server": _single_server,
    "site-outage": _site_outage,
    "cascade": _cascade,
    "rolling-with-rejoin": _rolling_with_rejoin,
    "churn-under-failure": _churn_under_failure,
    "flaky-node": _flaky_node,
    "cold-load-storm": _cold_load_storm,
    "retry-amplification": _retry_amplification,
    "thundering-herd-rejoin": _thundering_herd_rejoin,
    "metastable-overload": _metastable_overload,
    "tp-shard-storm": _tp_shard_storm,
    "chaos": _chaos,
}


def build_scenario(name: str, cluster: Cluster,
                   apps: Sequence[Application],
                   seed: int = 0) -> Scenario:
    """Materialize a named scenario deterministically from `seed`.

    The scenario RNG is independent of the simulator's workload RNG, so
    the same (name, seed, cluster) always yields the same event trace.
    """
    if name not in SCENARIOS:
        raise KeyError(f"unknown scenario {name!r}; "
                       f"have {sorted(SCENARIOS)}")
    rng = random.Random(f"{name}:{seed}")
    sc = SCENARIOS[name](cluster, list(apps), rng)
    sc.validate(cluster)
    return sc
