"""SLO autopilot — adaptive protection from the live metrics plane.

The controller's protection knobs (which apps hold a warm backup, the
checkpoint replication factor, the recovery-drain order) are static per
run: `_warm_candidates()` reads the apps' `critical` flags and nothing
else. Real edge deployments must adapt them to what the deployment
actually observes — EdgeSight's argument (PAPERS.md): spend the minimum
headroom that meets the SLO, and move it to where the traffic is.

`AutopilotPolicy` closes that loop. Once per re-protection sweep the
controller hands it an `AutopilotView` of the live metrics plane —
per-app observed arrival rates and SLO margins from the traffic plane,
the empirical failure-hazard from the run's own epoch history, and the
diurnal phase — and gets back an `AutopilotDecisions`:

  * **warm set** — protect the top-K apps by *observed* request rate
    (EWMA-smoothed), where K never exceeds the static policy's budget
    (the number of critical apps), so autopilot headroom is equal or
    lower by construction. A hysteresis margin + per-sweep move cap
    prevent protection flip-flop on noisy rates.
  * **predictive pre-warming** — in a diurnal trough with no recent
    failures the budget shrinks to `calm_frac`; as the modeled peak
    approaches (`lead_s` ahead) the budget snaps back and the normal
    re-protection sweep pre-warms the set *before* the rates climb.
  * **replication retune** — recent failure epochs raise the
    checkpoint replication target above the storage preset's base (the
    PR 5 `executor.replicate()` path then fans copies out), so the
    next failure finds a nearby copy instead of paying the uplink.
  * **drain boosts** — per-app priority boosts handed to the
    `RecoveryScheduler` so criticality-mode drains follow observed
    rates, not the static configured ones.

Everything is pure data-in/data-out and deterministic (sorted
iteration, no wall clock, no RNG): the same view stream yields the
same decisions, preserving the simulator's same-seed reproducibility.
The default off-path (no `AutopilotPolicy` attached) is untouched —
the six named-scenario golden fingerprints stay bit-exact.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set

from repro.core.traffic import diurnal_factor
from repro.core.variants import Application


@dataclass(frozen=True)
class AutopilotConfig:
    """Knobs of the adaptive-protection loop."""
    rate_ewma: float = 0.4        # weight of the newest rate observation
    swap_margin: float = 1.15     # challenger must beat incumbent by 15%
    max_moves: int = 2            # protection swaps per sweep (anti-thrash)
    lookback_s: float = 30.0      # failure-hazard estimation window
    hazard_hi: int = 3            # epochs in window -> max replication bump
    lead_s: float = 10.0          # predictive pre-warm lead before a peak
    calm_frac: float = 0.5        # warm-budget fraction in a calm trough
    trough_eps: float = 0.05      # diurnal factor below 1-eps = trough
    # diurnal model shared with the traffic plane (0 amplitude = none)
    diurnal_amplitude: float = 0.0
    diurnal_period: float = 240.0


@dataclass(frozen=True)
class AppSignal:
    """One app's slice of the live metrics plane at sweep time."""
    rate: float                   # observed logical request rate q_i
    slo_margin: float = math.inf  # SLO minus modeled latency (s)
    down: bool = False            # currently awaiting recovery
    recent_downtime_s: float = 0.0


@dataclass(frozen=True)
class AutopilotView:
    """What the controller shows the policy each sweep."""
    now: float
    apps: Dict[str, Application]
    warm_ids: Set[str]            # apps currently holding a warm backup
    signals: Dict[str, AppSignal]
    fail_times: List[float]       # t_fail of every epoch so far
    base_replication: int = 2
    unrecovered: Set[str] = field(default_factory=set)


@dataclass
class AutopilotDecisions:
    """One sweep's protection decisions."""
    protected: List[str]          # the full warm-eligible set, ranked
    promote: List[str]            # newly protected this sweep
    demote: List[str]             # lost protection this sweep
    replication: Optional[int]    # checkpoint residency target (or None)
    boosts: Dict[str, float]      # recovery-drain priority boosts
    budget: int                   # warm slots this sweep (<= static K)
    hazard: int                   # failure epochs inside the lookback


class AutopilotPolicy:
    """Stateful decision engine; one instance per controller."""

    def __init__(self, cfg: Optional[AutopilotConfig] = None):
        self.cfg = cfg or AutopilotConfig()
        # None until the first decide(): the controller's static
        # criticality rule applies at setup time, so deploy-time warm
        # planning is identical with and without the autopilot
        self.protected: Optional[Set[str]] = None
        self.last: Optional[AutopilotDecisions] = None
        self._rate: Dict[str, float] = {}
        self._base_repl: Optional[int] = None

    # -- diurnal model ------------------------------------------------------
    def _factor(self, t: float) -> float:
        cfg = self.cfg
        if cfg.diurnal_amplitude <= 0.0:
            return 1.0
        return diurnal_factor(t, period=cfg.diurnal_period,
                              amplitude=cfg.diurnal_amplitude)

    def in_trough(self, now: float) -> bool:
        """Below-average traffic now AND `lead_s` ahead — i.e. the next
        peak is not imminent, so shrinking the warm budget is safe and
        the restore path has time to pre-warm before rates climb."""
        cfg = self.cfg
        if cfg.diurnal_amplitude <= 0.0:
            return False
        lo = 1.0 - cfg.trough_eps
        return (self._factor(now) < lo
                and self._factor(now + cfg.lead_s) < lo)

    # -- main loop ----------------------------------------------------------
    def hazard(self, view: AutopilotView) -> int:
        return sum(1 for t in view.fail_times
                   if view.now - t <= self.cfg.lookback_s)

    def _observe(self, view: AutopilotView) -> Dict[str, float]:
        """EWMA-smoothed observed rates (configured rate as the prior)."""
        a = self.cfg.rate_ewma
        for aid in sorted(view.apps):
            sig = view.signals.get(aid)
            obs = sig.rate if sig is not None \
                else view.apps[aid].request_rate
            prev = self._rate.get(aid)
            self._rate[aid] = obs if prev is None \
                else (1.0 - a) * prev + a * obs
        self._rate = {aid: r for aid, r in self._rate.items()
                      if aid in view.apps}
        return dict(self._rate)

    def decide(self, view: AutopilotView) -> AutopilotDecisions:
        cfg = self.cfg
        score = self._observe(view)
        n_hazard = self.hazard(view)

        # warm budget: the static policy's slot count, shrunk in a calm
        # diurnal trough (predictive pre-warm = the budget snapping back
        # lead_s before the peak, refilled by the re-protection sweep)
        k_static = sum(1 for a in view.apps.values() if a.critical)
        budget = k_static
        if n_hazard == 0 and self.in_trough(view.now):
            budget = int(math.ceil(k_static * cfg.calm_frac))

        incumbents = (set(self.protected) if self.protected is not None
                      else {aid for aid, a in view.apps.items()
                            if a.critical}) & set(view.apps)
        ranked = sorted(view.apps, key=lambda aid: (-score[aid], aid))
        inc = [aid for aid in ranked if aid in incumbents]
        new = [aid for aid in ranked if aid not in incumbents]

        # merge: incumbents keep their slot unless a challenger beats
        # them by the hysteresis margin, at most max_moves swaps/sweep
        sel: List[str] = []
        moves = i = j = 0
        while len(sel) < budget and (i < len(inc) or j < len(new)):
            challenger_wins = (
                j < len(new) and moves < cfg.max_moves
                and (i >= len(inc)
                     or score[new[j]] > score[inc[i]] * cfg.swap_margin))
            if challenger_wins:
                sel.append(new[j])
                j += 1
                moves += 1
            elif i < len(inc):
                sel.append(inc[i])
                i += 1
            else:
                break            # move cap hit and no incumbents left
        prot = set(sel)

        # replication retune: hazard in the lookback raises the
        # checkpoint residency target above the preset's base
        if self._base_repl is None:
            self._base_repl = view.base_replication
        bump = 0 if n_hazard == 0 else (1 if n_hazard < cfg.hazard_hi
                                        else 2)
        replication = self._base_repl + bump

        dec = AutopilotDecisions(
            protected=[aid for aid in ranked if aid in prot],
            promote=sorted(prot - incumbents),
            demote=sorted(incumbents - prot),
            replication=replication,
            boosts=score,
            budget=budget,
            hazard=n_hazard)
        self.protected = prot
        self.last = dec
        return dec
