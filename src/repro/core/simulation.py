"""Discrete-event simulation platform (paper §4/§5: 100 servers, 640+
apps, model profiles + MTTR constants taken from the testbed).

Events: failure injections, detector sweeps, model-load completions, and
*traffic chunks*. The simulator provides the SimClock + SimLoadExecutor
the controller runs against; per-LINK FIFO queues (disk/PCIe channel,
NIC, shared cloud uplink — the model-state plane, core/modelstate.py)
serialize transfers along each cold load's fetch path, which with the
default local-everything storage reduces to the historical per-server
serialization.

Request-event model: client requests are not individual heap events.
Every `traffic_chunk_s` of sim time a chunk event (interleaved with
failure/detector/load events in the same queue) bulk-generates each live
app's arrivals for the next window with one vectorized Poisson draw,
reading the rates in effect at that instant — so `LoadSpike` windows and
app churn are honored at chunk granularity while millions of requests
per run stay cheap. Routing-table epoch bumps and crash instants are
timestamped into per-app serving timelines (`core/traffic.py`); after
the run, every request is classified against those timelines into
served / dropped / degraded / SLO-violated and folded into availability,
latency percentiles, accuracy-weighted goodput, and client-observed MTTR
(`core/metrics.py`).

Determinism guarantee: all randomness (workload synthesis, scenario
materialization, arrival generation, latency jitter) derives from
`SimConfig.seed` through independent named streams, and the event queue
breaks time ties by insertion order — the same seed yields the same
per-request trace and byte-identical summaries (`ScenarioResult
.fingerprint()` covers both the control plane and the traffic plane).
"""

from __future__ import annotations

import heapq
import itertools
import random
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.cluster import make_cluster
from repro.core.controller import FailLiteController, LoadExecutor
from repro.core.heartbeat import FailureDetector, SimClock
from repro.core.metrics import TrafficSummary
from repro.core.modelstate import (CLOUD_LINK, LOCAL, LinkScale,
                                   LoadTicket, ModelRegistry, disk_link,
                                   nic_link, storage_preset)
from repro.core.resilience import active as resilience_active
from repro.core.scenario import (AppArrival, AppDeparture, LinkDegrade,
                                 LoadSpike, Scenario, ServerFail,
                                 ServerRejoin, ShardFail, SiteFail,
                                 build_scenario)
from repro.core.shardgroup import ShardGroupManager
from repro.core.traffic import TrafficConfig, TrafficPlane
from repro.core.variants import (
    Application, Variant, synthetic_family, LOAD_BW, WARMUP_S)

DETECT_SWEEP_S = 0.100        # controller sweep period (paper §5.1)
HEARTBEAT_S = 0.020
REPROTECT_SWEEP_S = 1.0       # continuous re-protection loop period


class EventQueue:
    def __init__(self, clock: SimClock):
        self.clock = clock
        self._q: List[Tuple[float, int, Callable[[], None]]] = []
        self._c = itertools.count()
        # events drained so far (bench_scale's events/sec numerator)
        self.n_processed = 0

    def at(self, t: float, fn: Callable[[], None]):
        heapq.heappush(self._q, (t, next(self._c), fn))

    def after(self, dt: float, fn: Callable[[], None]):
        self.at(self.clock.now() + dt, fn)

    def next_time(self) -> Optional[float]:
        """Earliest pending event time, or None when the heap is empty
        (the epoch folder peeks at this to size event-free spans)."""
        return self._q[0][0] if self._q else None

    def run_until(self, t_end: float):
        while self._q and self._q[0][0] <= t_end:
            t, _, fn = heapq.heappop(self._q)
            self.clock.t = max(self.clock.t, t)
            self.n_processed += 1
            fn()
        self.clock.t = max(self.clock.t, t_end)


class SimLoadExecutor(LoadExecutor):
    """Contention-aware load engine: per-link FIFO queues + fetch-path
    selection through the `ModelRegistry` (local ≫ peer ≫ cloud).

    A transfer serializes on EVERY link of its fetch path: it starts
    when the latest of its links frees up and occupies each of them
    until it completes — so N simultaneous cold loads through the one
    shared cloud uplink drain back-to-back (the Nth pays N-1 transfer
    times of queueing), while loads on disjoint links overlap freely.
    `LinkDegrade` scenario events scale a link's bandwidth for a
    window; costs are priced through the registry's `LoadCostModel`,
    so a testbed-measured calibration applies here too.

    With the default local-everything storage every load is a single
    local disk hit, which reduces bit-exactly to the historical model:
    serialized per server at `bw`, each load costing
    ``bytes / bw + warmup``.
    """

    def __init__(self, events: EventQueue, bw: float = LOAD_BW,
                 registry: Optional[ModelRegistry] = None):
        self.events = events
        self.bw = bw                       # registry-less fallback
        self.registry = registry
        self.busy_until: Dict[str, float] = {}    # link -> free time
        self._scales = LinkScale()                # LinkDegrade windows
        # bumped by reset_server: transfers severed by a crash must not
        # stage phantom checkpoint residency when their event fires
        self._reset_gen: Dict[str, int] = {}

    # -- link model ----------------------------------------------------------
    def _base_bw(self, link: str) -> float:
        if self.registry is None:
            return self.bw
        st = self.registry.storage
        if link == CLOUD_LINK:
            return st.cloud_bw
        if link.startswith("disk:"):
            return st.disk_bw
        return st.nic_bw

    def degrade_link(self, link: str, factor: float, duration: float):
        """Scale `link`'s bandwidth by `factor` for `duration` sim
        seconds (multiplicative, so overlapping windows compose)."""
        self.events.after(duration, self._scales.degrade(link, factor))

    def _path(self, variant, server_id):
        """(links, bottleneck_bw, warmup_s, source) for one load."""
        if self.registry is not None:
            plan = self.registry.fetch_plan(variant.name, server_id)
            links = plan.links
            bw = min(self._base_bw(l) for l in links)
            bw = self.registry.calibration.effective_bw(plan.source, bw)
            warm = self.registry.storage.warmup_s
            source = plan.source
        else:
            links = (disk_link(server_id),)
            bw, warm, source = self.bw, WARMUP_S, LOCAL
        return links, bw * self._scales.min_over(links), warm, source

    def _occupy(self, links, now: float, duration: float):
        """FIFO-claim every link of a fetch path: the transfer starts
        when the latest link frees up and occupies all of them until it
        completes. Returns (start, done)."""
        start = max(now, max(self.busy_until.get(l, now) for l in links))
        done = start + duration
        for l in links:
            self.busy_until[l] = done
        return start, done

    def idle(self) -> bool:
        """No transfer in flight on any link at the current sim time."""
        now = self.events.clock.now()
        return all(t <= now for t in self.busy_until.values())

    # -- LoadExecutor --------------------------------------------------------
    def load(self, app, variant, server_id, on_ready) -> LoadTicket:
        now = self.events.clock.now()
        links, bw, warm, source = self._path(variant, server_id)
        fetch = variant.mem_bytes / bw
        start, done = self._occupy(links, now, fetch + warm)
        ticket = LoadTicket(source=source, queue_s=start - now,
                            fetch_s=fetch, warmup_s=warm)
        gen = self._reset_gen.get(server_id, 0)

        def fire():
            ticket.done = True
            if (self.registry is not None
                    and self._reset_gen.get(server_id, 0) == gen):
                # the fetched bytes are now on this server's disk;
                # severed transfers (server crashed mid-stream) must
                # not claim residency
                self.registry.stage(variant.name, server_id)
            on_ready(done)

        self.events.at(done, fire)
        return ticket

    def activate(self, app, variant, server_id):
        pass  # warm: already resident

    def prepare_warm(self, app, variant, server_id):
        """Proactive warm placement ships the checkpoint bytes along
        (background, not MTTR-critical — modeled as instant)."""
        if self.registry is not None:
            self.registry.stage(variant.name, server_id)

    def replicate(self, app, variant, server_id, on_done=None):
        """Background checkpoint copy onto `server_id`'s disk: occupies
        the fetch-path links (no warmup — nothing is compiled), then
        stages residency."""
        now = self.events.clock.now()
        if self.registry is None:
            if on_done is not None:
                on_done(now)
            return
        plan = self.registry.fetch_plan(variant.name, server_id)
        if plan.source == LOCAL:
            if on_done is not None:
                on_done(now)
            return
        links = plan.links
        bw = min(self._base_bw(l) for l in links) \
            * self._scales.min_over(links)
        _start, done = self._occupy(links, now, variant.mem_bytes / bw)
        gen = self._reset_gen.get(server_id, 0)

        def fire():
            if self._reset_gen.get(server_id, 0) == gen:
                self.registry.stage(variant.name, server_id)
            if on_done is not None:
                on_done(done)

        self.events.at(done, fire)

    def reset_server(self, server_id):
        """Crash/rejoin wipes the server's own link queues (disk + NIC)
        and severs its in-flight transfers (they will not stage
        residency); shared links keep their backlog."""
        self._reset_gen[server_id] = \
            self._reset_gen.get(server_id, 0) + 1
        self.busy_until.pop(disk_link(server_id), None)
        self.busy_until.pop(nic_link(server_id), None)
        # registry-less fallback keyed the queue by bare server id
        self.busy_until.pop(server_id, None)


@dataclass
class SimConfig:
    """Paper §5.1 semantics: primaries fill ~`primary_util` of the
    cluster; `headroom` is the fraction of each server usable for
    failover backups (controlled 10%-50%); the remainder is blocked
    (other tenants)."""
    n_sites: int = 10
    servers_per_site: int = 10
    server_mem: float = 16e9
    server_compute: float = 1.0
    primary_util: float = 0.5
    headroom: float = 0.2          # usable free fraction per server
    critical_frac: float = 0.5     # |K| / N
    alpha: float = 0.1
    policy: str = "faillite"
    site_independence: bool = False
    use_ilp: bool = False
    # placement policy by registry name (docs/PLANNER.md): "greedy",
    # "ilp", "load-aware", "legacy-greedy", "locality"; None =
    # use_ilp-derived default
    planner: Optional[str] = None
    seed: int = 0
    # request-level traffic plane: requests/s generated per unit app
    # rate q_i (0 disables the plane) and the bulk-generation window
    traffic_rate_scale: float = 20.0
    traffic_chunk_s: float = 0.5
    # diurnal rate modulation (0 amplitude = plain Poisson, the
    # historical default); shared with the autopilot's trough/peak model
    traffic_diurnal_amplitude: float = 0.0
    traffic_diurnal_period: float = 240.0
    # model-state plane (core/modelstate.py): storage preset by name
    # ("local" = every checkpoint on every disk, the exact historical
    # behavior; "edge" = paper-faithful constrained topology), the
    # Fig. 2b load-cost constants (previously the module-level
    # LOAD_BW/WARMUP_S), optional per-preset bandwidth overrides, and
    # the recovery-drain scheduler ("fifo" | "criticality")
    storage: str = "local"
    load_bw: float = LOAD_BW       # bytes/s disk->HBM (Fig. 2b slope)
    warmup_s: float = WARMUP_S     # per-instance compile/alloc warmup
    nic_bw: Optional[float] = None
    cloud_bw: Optional[float] = None
    replication: Optional[int] = None
    scheduler: str = "fifo"
    # adaptive protection (core/autopilot.py): False = the static
    # criticality rule, bit-exact historical behavior
    autopilot: bool = False
    # request-plane resilience toolkit (core/resilience.py): a
    # ResilienceConfig as a plain dict (JSON round-trip through
    # ExperimentSpec). None/enabled=False = bit-exact historical
    # request plane (golden fingerprints pinned)
    resilience: Optional[dict] = None
    # event-loop drain strategy (docs/SCALE.md): "epoch" folds
    # event-free spans of traffic chunks into vectorized bulk
    # generation (bit-exact with per-event, proven by
    # tests/test_scale.py); "per-event" is the historical
    # one-callback-per-chunk compat path and the bench baseline
    event_mode: str = "epoch"
    # planner array dtype: "float64" (bit-exact default) or "float32"
    # (halves PlannerState memory at 10k servers; NOT fingerprint-
    # preserving — scale runs only)
    planner_dtype: str = "float64"
    # planner compute backend: "numpy" (bit-exact default, golden
    # fingerprints pinned) or "jax" (compiled chunk kernels,
    # planner/jax_backend.py — bit-identical assignments, property-
    # tested); "jax" requires jax importable. Only the greedy family
    # ("greedy"/"sharded") honors it. `planner_coordinators` >= 2
    # plans sharded rounds with that many concurrent site-slice
    # coordinators (numpy sharded path only)
    planner_backend: str = "numpy"
    planner_coordinators: int = 0
    # shard plane (core/shardgroup.py): tp_degree >= 2 deploys every
    # app as a tensor-parallel group spanning tp_degree servers and
    # attaches the shard recovery ladder; 1 (the default) keeps the
    # historical monolith path bit-exact. `shard_policy` picks the
    # ladder rung: "auto" (critical -> degrade, rest -> reshard),
    # "degrade", "reshard", or "monolith" (immediate fallback)
    tp_degree: int = 1
    shard_policy: str = "auto"


def synthetic_apps(cfg: SimConfig, rng: random.Random,
                   family_class: Optional[str] = None) -> List[Application]:
    """App mix reproducing the paper's family spread classes.

    Small/Medium/Large = max demand diff between largest/smallest variant
    (paper §5.5: Mobilenet 12MB diff vs Convnext 648MB diff); scaled here
    to LLM serving-cell sizes.
    """
    # spreads calibrated to the paper's TorchVision families: Mobilenet
    # (small, ~1.5x), EfficientNet/RegNet (medium), ConvNeXt/ResNet
    # (large, order-of-magnitude member spread).
    classes = {
        "small": (0.4e9, 1.5),
        "medium": (1.5e9, 5.0),
        "large": (5.0e9, 24.0),
    }
    if family_class:
        fams = [(f"{family_class}{i}", *classes[family_class])
                for i in range(5)]
    else:
        fams = [(f"{cls}{i}", *classes[cls])
                for cls in classes for i in range(3)]
    total_mem = cfg.n_sites * cfg.servers_per_site * cfg.server_mem
    budget = total_mem * cfg.primary_util
    apps: List[Application] = []
    used = 0.0
    i = 0
    while True:
        name, mem, spread = fams[i % len(fams)]
        ladder = synthetic_family(f"{name}-a{i}", mem, n_variants=6,
                                  spread=spread)
        need = ladder[0].mem_bytes
        if used + need > budget:
            break
        apps.append(Application(
            id=f"app{i}", family=ladder[0].family, variants=ladder,
            request_rate=rng.uniform(0.5, 2.0),
            # finite SLO so the traffic plane can flag late requests:
            # ~4x the full model's service-time proxy leaves room for
            # jitter but not for a queueing blow-up under a LoadSpike
            latency_slo=ladder[0].compute * 4.0,
            critical=(rng.random() < cfg.critical_frac)))
        used += need
        i += 1
    return apps


@dataclass
class SimResult:
    recovery_rate: float
    mttr_avg: float
    accuracy_reduction: float
    n_affected: int
    records: dict
    traffic: Optional[TrafficSummary] = None   # request-level view


@dataclass
class ScenarioResult:
    """Outcome of one deterministic scenario replay."""
    name: str
    n_epochs: int                       # handle_failures invocations
    per_epoch: List[dict]               # summary per failure epoch
    overall: dict                       # summary over ALL epoch records
    warm_coverage: float                # critical apps warm-protected at end
    unplaced_arrivals: int
    n_apps_final: int
    records: List[object]               # flat per-epoch RecoveryRecords
    traffic: Optional[TrafficSummary] = None   # request-level view

    def fingerprint(self) -> tuple:
        """Deterministic digest used by the determinism tests; covers
        both the control plane and the per-request traffic plane."""
        base = tuple(sorted(
            (r.epoch, r.app_id, r.recovered, round(r.mttr, 9)
             if r.mttr != float("inf") else -1.0, r.variant, r.mode)
            for r in self.records))
        if self.traffic is not None:
            return (base, self.traffic.fingerprint())
        return base


class Simulation:
    def __init__(self, cfg: SimConfig,
                 apps: Optional[List[Application]] = None):
        self.cfg = cfg
        self.rng = random.Random(cfg.seed)
        self.clock = SimClock()
        self.events = EventQueue(self.clock)
        self.cluster = make_cluster(cfg.n_sites, cfg.servers_per_site,
                                    mem=cfg.server_mem,
                                    compute=cfg.server_compute)
        # model-state plane: storage topology + checkpoint registry
        self.cluster.storage = storage_preset(
            cfg.storage, disk_bw=cfg.load_bw, warmup_s=cfg.warmup_s,
            nic_bw=cfg.nic_bw, cloud_bw=cfg.cloud_bw,
            replication=cfg.replication)
        self.registry = ModelRegistry(self.cluster, self.cluster.storage)
        self.executor = SimLoadExecutor(self.events, bw=cfg.load_bw,
                                        registry=self.registry)
        self.detector = FailureDetector(self.clock, interval=HEARTBEAT_S)
        pilot = None
        if cfg.autopilot:
            from repro.core.autopilot import (AutopilotConfig,
                                              AutopilotPolicy)
            pilot = AutopilotPolicy(AutopilotConfig(
                diurnal_amplitude=cfg.traffic_diurnal_amplitude,
                diurnal_period=cfg.traffic_diurnal_period))
        if cfg.event_mode not in ("epoch", "per-event"):
            raise ValueError(f"unknown event_mode: {cfg.event_mode!r}")
        self.controller = FailLiteController(
            self.cluster, self.clock, self.executor,
            policy=cfg.policy, alpha=cfg.alpha,
            site_independence=cfg.site_independence, use_ilp=cfg.use_ilp,
            planner=cfg.planner, detector=self.detector,
            registry=self.registry, scheduler=cfg.scheduler,
            autopilot=pilot, planner_dtype=cfg.planner_dtype,
            planner_backend=cfg.planner_backend,
            planner_coordinators=cfg.planner_coordinators)
        # shard plane: only constructed at tp_degree >= 2 (off-path
        # bit-exactness — no manager, no shard branch anywhere)
        self.shards: Optional[ShardGroupManager] = None
        if cfg.tp_degree > 1:
            self.shards = ShardGroupManager(
                self.controller, tp_degree=cfg.tp_degree,
                policy=cfg.shard_policy, defer=self.events.after)
        self.apps = apps if apps is not None else synthetic_apps(
            cfg, self.rng)
        # per-server "other tenants" reservation, recorded at setup so a
        # rejoining (empty) server gets the same share re-blocked
        self._blockers: Dict[str, float] = {}
        # request-level traffic plane: observes routing-table pushes and
        # crash instants; injections are numbered so downtime windows
        # carry the same epoch index as the controller's records
        self._injection_seq = 0
        self.resilience = resilience_active(cfg.resilience)
        self.traffic: Optional[TrafficPlane] = None
        if cfg.traffic_rate_scale > 0:
            self.traffic = TrafficPlane(
                seed=cfg.seed,
                cfg=TrafficConfig(
                    rate_scale=cfg.traffic_rate_scale,
                    chunk_s=cfg.traffic_chunk_s,
                    diurnal_amplitude=cfg.traffic_diurnal_amplitude,
                    diurnal_period=cfg.traffic_diurnal_period),
                resilience=self.resilience,
                batch=(cfg.event_mode == "epoch"))
            self.controller.routing.observer = self._on_route_set
            self.controller.routing.drop_observer = self._on_route_drop
            if self.resilience is not None:
                # admission control needs the recovery-drain intervals;
                # the observer hook is a no-op when unset (off-path)
                self.controller.scheduler.drain_observer = \
                    self.traffic.record_drain
        if cfg.autopilot:
            self.controller.metrics_feed = self._autopilot_feed
        # warm-headroom observation: (bytes, count) sampled once per
        # re-protection sweep (pure measurement — no events, no RNG)
        self._warm_samples: List[Tuple[float, int]] = []

    # ------------------------------------------------------------------
    # traffic plane hooks
    # ------------------------------------------------------------------
    def _on_route_set(self, app_id: str, server_id: str,
                      variant_name: str):
        app = self.controller.apps.get(app_id)
        if app is None:
            return
        try:
            v = app.variant_by_name(variant_name)
        except KeyError:
            # synthesized shard variants (degraded-TP continuation) live
            # in the shard manager's side table, never in app.variants
            v = (self.shards.lookup_variant(variant_name)
                 if self.shards is not None else None)
            if v is None:
                raise
        self.traffic.mark_up(app_id, self.clock.now(),
                             accuracy=v.accuracy, service_time=v.compute,
                             full_accuracy=app.full.accuracy,
                             slo=app.latency_slo)

    def _on_route_drop(self, app_id: str):
        self.traffic.mark_gone(app_id, self.clock.now())

    def _autopilot_feed(self):
        """Live metrics-plane view for the autopilot: observed arrival
        rates and recent client downtime from the traffic plane, plus a
        modeled SLO margin for the variant each route currently serves.
        Pure observation — reading it perturbs no event or RNG state."""
        from repro.core.autopilot import AppSignal

        now = self.clock.now()
        ctl = self.controller
        rates = (self.traffic.current_rates()
                 if self.traffic is not None else {})
        downs = (self.traffic.downtime_since(now - 30.0, now)
                 if self.traffic is not None else {})
        tcfg = self.traffic.cfg if self.traffic is not None \
            else TrafficConfig()
        out = {}
        for app_id, app in ctl.apps.items():
            q = rates.get(app_id, app.request_rate)
            route = ctl.routing.routes.get(app_id)
            try:
                v = app.variant_by_name(route[1]) if route else app.full
            except KeyError:
                v = app.full
            util = min(q * v.compute * tcfg.util_k, tcfg.util_cap)
            latency = v.compute / (1.0 - util)
            out[app_id] = AppSignal(
                rate=q,
                slo_margin=app.latency_slo - latency,
                down=app_id in ctl._unrecovered,
                recent_downtime_s=downs.get(app_id, 0.0))
        return out

    def shard_summary(self) -> Optional[Dict]:
        """Shard-plane report (None when tp_degree == 1): group states,
        ladder actions taken, and per-action MTTR averages."""
        return self.shards.summary() if self.shards is not None else None

    def protection_summary(self) -> Dict[str, float]:
        """Warm-replica headroom actually spent over the run: mean and
        final warm bytes / instance counts from the per-sweep samples —
        the soak harness's equal-or-lower-headroom check."""
        warm = self.controller.warm.values()
        final_bytes = float(sum(v.mem_bytes for v, _, _ in warm))
        if not self._warm_samples:
            return {"warm_bytes_mean": final_bytes,
                    "warm_bytes_final": final_bytes,
                    "n_warm_mean": float(len(self.controller.warm)),
                    "n_warm_final": len(self.controller.warm)}
        return {
            "warm_bytes_mean": (sum(b for b, _ in self._warm_samples)
                                / len(self._warm_samples)),
            "warm_bytes_final": final_bytes,
            "n_warm_mean": (sum(n for _, n in self._warm_samples)
                            / len(self._warm_samples)),
            "n_warm_final": len(self.controller.warm),
        }

    def _start_traffic(self, t_end: float):
        """Schedule the chunked bulk-generation loop up to t_end.

        Per-event mode fires one heap callback per chunk window (the
        historical path, kept verbatim as the bench baseline). Epoch
        mode folds runs of event-free chunk windows into one
        `generate_chunks` call: a fold extends while the next pending
        heap event lies STRICTLY past the window end — any event at
        exactly t1 (or any state change at all inside the span) stops
        the fold, so rates/eligibility are constant across folded
        windows and the drain is bit-exact with per-event mode (no seq
        numbers are consumed inside an event-free span, so the
        stop-tick rescheduled at t1 orders identically to the
        per-event reschedule; proven by tests/test_scale.py)."""
        if self.traffic is None:
            return
        chunk = self.traffic.cfg.chunk_s

        if self.cfg.event_mode == "per-event":
            def tick():
                t0 = self.clock.now()
                t1 = min(t0 + chunk, t_end)
                self.traffic.generate_chunk(self.apps, t0, t1)
                if t1 < t_end - 1e-12:
                    self.events.at(t1, tick)

            self.events.at(self.clock.now(), tick)
            return

        def epoch_tick():
            tc = self.clock.now()
            spans = []
            while True:
                t1 = min(tc + chunk, t_end)
                spans.append((tc, t1))
                if t1 >= t_end - 1e-12:
                    break
                nxt = self.events.next_time()
                if nxt is not None and nxt <= t1:
                    self.events.at(t1, epoch_tick)
                    break
                tc = t1
            self.traffic.generate_chunks(self.apps, spans)

        self.events.at(self.clock.now(), epoch_tick)

    def setup(self):
        """Place primaries, block non-headroom capacity, plan warm backups.

        Fragmentation can make the last few generated apps unplaceable;
        they are dropped (the paper fixes the app count per setting, we
        fix the target utilization)."""
        placed = []
        for app in self.apps:
            try:
                if self.shards is not None:
                    self.shards.deploy_group(app)
                else:
                    self.controller.deploy_primary(app)
                placed.append(app)
            except ValueError:
                continue
        self.apps = placed

        # block everything beyond `headroom` per server (other tenants)
        for srv in self.cluster.alive_servers():
            excess = srv.free("mem") - self.cfg.headroom * srv.capacity["mem"]
            if excess > 0:
                self._blockers[srv.id] = excess
                self._place_blocker(srv.id, excess)
        self.controller.plan_warm_backups()
        return self

    def _place_blocker(self, server_id: str, mem: float):
        blocker = Variant(name="blocked", family="_reserved",
                          mem_bytes=mem, compute=0.0, accuracy=0.0)
        self.cluster.place("_reserved", blocker, server_id, "primary")

    def _schedule_failure(self, server_ids: List[str], t_fail: float):
        """Crash at t_fail (instances die NOW); the controller reacts
        after the detection latency (2 missed heartbeats + sweep
        alignment, §5.7: ~65ms). Collecting the lost instances at crash
        time keeps a rejoin inside the detection window consistent."""
        def do_fail():
            lost = []
            for sid in server_ids:
                lost.extend(self.cluster.fail_server(sid))
                self.detector.mark_failed(sid)
                self.executor.reset_server(sid)
            # clients see the blackout from the crash instant, well
            # before detection; windows are tagged with the epoch index
            # this injection will occupy (injections are handled in
            # scheduling order, so the sequence number matches). An app
            # goes dark iff its ROUTE pointed at the crashed server —
            # that covers primaries and also progressive recoveries
            # that were already serving while their selected variant
            # was still loading (instance role "loading").
            epoch = self._injection_seq
            self._injection_seq += 1
            if self.traffic is not None:
                routes = self.controller.routing.routes
                # shard plane: a group member loss can black out an app
                # whose route points at a SURVIVING lead (reshard /
                # monolith fallback pause serving); a seamless degrade
                # of a non-lead member keeps serving and is excluded
                shard_dark = (self.shards.darkened_by(set(server_ids))
                              if self.shards is not None else set())
                marked = set()
                for inst in lost:
                    if (inst.app_id in self.controller.apps
                            and routes.get(inst.app_id, (None,))[0]
                            == inst.server_id):
                        marked.add(inst.app_id)
                        backup = None
                        if self.resilience is not None:
                            # hedged requests go to the app's warm
                            # backup, valid only if its host survived
                            # this injection
                            warm = self.controller.warm.get(inst.app_id)
                            if warm is not None:
                                v, wsid, _key = warm
                                srv = self.cluster.servers.get(wsid)
                                if srv is not None and srv.alive:
                                    backup = (v.accuracy, v.compute)
                        self.traffic.mark_down(inst.app_id, t_fail,
                                               epoch, backup=backup)
                for app_id in sorted(shard_dark - marked):
                    if app_id in self.controller.apps:
                        self.traffic.mark_down(app_id, t_fail, epoch)
            t_detect = (self.detector.detection_latency_bound()
                        + DETECT_SWEEP_S / 4)
            self.events.after(t_detect, lambda: self.controller
                              .handle_failures(list(server_ids), t_fail,
                                               lost=lost))

        self.events.at(t_fail, do_fail)

    def inject_failure(self, *, servers: Optional[List[str]] = None,
                       sites: Optional[List[str]] = None,
                       t_fail: float = 1.0,
                       run_for: float = 60.0) -> SimResult:
        """Crash servers/sites at t_fail; run the recovery to completion."""
        failed: List[str] = list(servers or [])
        for site in (sites or []):
            failed.extend(self.cluster.sites[site])

        t_end = t_fail + run_for
        self._schedule_failure(failed, t_fail)
        self._start_traffic(t_end)
        self.events.run_until(t_end)

        recs = self.controller.records
        summary = self.controller.summarize(recs)
        return SimResult(
            recovery_rate=summary["recovery_rate"],
            mttr_avg=summary["mttr_avg"],
            accuracy_reduction=summary["accuracy_reduction"],
            n_affected=summary["n"],
            records=recs,
            traffic=(self.traffic.summarize(t_end)
                     if self.traffic is not None else None))

    # ------------------------------------------------------------------
    # scenario replay
    # ------------------------------------------------------------------
    def _on_rejoin(self, server_id: str):
        srv = self.cluster.servers[server_id]
        if srv.alive:
            return
        self.controller.handle_rejoin(server_id)
        # the node returns empty; re-block the other-tenant share so only
        # (former primary share + headroom) is available for refilling
        mem = self._blockers.get(server_id, 0.0)
        if mem > 0:
            self._place_blocker(server_id, mem)

    def _traffic_dirty(self):
        """App set or rates changed: invalidate the traffic plane's
        epoch-mode eligibility snapshot."""
        if self.traffic is not None:
            self.traffic.snapshot_gen += 1

    def _on_arrival(self, app: Application, stats: dict):
        try:
            self.controller.deploy_primary(app)
            self.apps.append(app)
            self._traffic_dirty()
        except ValueError:
            stats["unplaced_arrivals"] += 1

    def _on_departure(self, app_id: str):
        self.controller.handle_departure(app_id)
        self.apps = [a for a in self.apps if a.id != app_id]
        self._traffic_dirty()

    def _on_spike(self, ev: LoadSpike):
        ids = set(ev.app_ids) if ev.app_ids is not None else None
        targets = [a for a in self.apps
                   if ids is None or a.id in ids]
        saved = [(a, a.request_rate) for a in targets]
        for a in targets:
            a.request_rate *= ev.factor
        self._traffic_dirty()

        def restore():
            for a, r in saved:
                a.request_rate = r
            self._traffic_dirty()
        self.events.after(ev.duration, restore)

    def run_scenario(self, scenario: Scenario, *,
                     reprotect_every: float = REPROTECT_SWEEP_S,
                     settle: float = 20.0) -> ScenarioResult:
        """Replay a Scenario deterministically.

        Failures go through detection latency; rejoining servers return
        empty and are refilled; `controller.reprotect()` runs as a
        periodic event-queue loop (continuous re-protection), replacing
        the manual `replan_lost_backups` call."""
        scenario.validate(self.cluster)
        stats = {"unplaced_arrivals": 0}
        for ev in scenario.sorted_events():
            if isinstance(ev, ServerFail):
                self._schedule_failure([ev.server], ev.t)
            elif isinstance(ev, ShardFail):
                # physically a server crash; the controller's shard
                # plane (when attached) walks hit groups through the
                # degrade/reshard/fallback ladder at detection time
                self._schedule_failure([ev.server], ev.t)
            elif isinstance(ev, SiteFail):
                self._schedule_failure(list(self.cluster.sites[ev.site]),
                                       ev.t)
            elif isinstance(ev, ServerRejoin):
                self.events.at(ev.t, (lambda s=ev.server:
                                      self._on_rejoin(s)))
            elif isinstance(ev, AppArrival):
                self.events.at(ev.t, (lambda a=ev.app:
                                      self._on_arrival(a, stats)))
            elif isinstance(ev, AppDeparture):
                self.events.at(ev.t, (lambda a=ev.app_id:
                                      self._on_departure(a)))
            elif isinstance(ev, LoadSpike):
                self.events.at(ev.t, (lambda e=ev: self._on_spike(e)))
            elif isinstance(ev, LinkDegrade):
                self.events.at(ev.t, (lambda e=ev: self.executor
                                      .degrade_link(e.link, e.factor,
                                                    e.duration)))
            else:
                raise TypeError(f"unhandled scenario event: {ev}")

        t_end = scenario.horizon + settle

        # memoized warm-bytes fold: the warm set only changes when the
        # controller says so (warm_gen), so sweeps between mutations
        # reuse the previous sum bit-for-bit instead of re-scanning
        # every warm entry (a per-sweep O(apps) loop at 100k apps)
        warm_cache = [-1, (0.0, 0)]

        def reprotect_tick():
            self.controller.reprotect()
            # pure observation for the headroom trend; no event/RNG state
            if warm_cache[0] != self.controller.warm_gen:
                warm_cache[0] = self.controller.warm_gen
                warm_cache[1] = (
                    float(sum(v.mem_bytes for v, _, _
                              in self.controller.warm.values())),
                    len(self.controller.warm))
            self._warm_samples.append(warm_cache[1])
            if self.clock.now() + reprotect_every <= t_end:
                self.events.after(reprotect_every, reprotect_tick)

        self.events.after(reprotect_every, reprotect_tick)
        self._start_traffic(t_end)
        self.events.run_until(t_end)

        ctl = self.controller
        flat = ctl.flat_records()
        return ScenarioResult(
            name=scenario.name,
            n_epochs=len(ctl.epoch_records),
            per_epoch=ctl.summarize_epochs(),
            overall=ctl.overall_summary(),
            warm_coverage=ctl.warm_coverage(),
            unplaced_arrivals=stats["unplaced_arrivals"],
            n_apps_final=len(ctl.apps),
            records=flat,
            traffic=(self.traffic.summarize(t_end)
                     if self.traffic is not None else None))

    def run_named_scenario(self, name: str, **kw) -> ScenarioResult:
        sc = build_scenario(name, self.cluster, self.apps,
                            seed=self.cfg.seed)
        return self.run_scenario(sc, **kw)


def run_policy_comparison(cfg: SimConfig, fail_servers: int = 1,
                          fail_sites: int = 0, seeds=(0, 1, 2)):
    """Convenience: same workload, all four policies, averaged."""
    out = {}
    for policy in ("faillite", "full-warm", "full-cold", "full-warm-k"):
        agg = {"recovery_rate": 0.0, "mttr_avg": 0.0,
               "accuracy_reduction": 0.0}
        n = 0
        for seed in seeds:
            # only the three aggregate recovery numbers are returned, so
            # skip the (otherwise-discarded) traffic plane
            c = SimConfig(**{**cfg.__dict__, "policy": policy,
                             "seed": seed, "traffic_rate_scale": 0.0})
            sim = Simulation(c).setup()
            if fail_sites:
                sites = list(sim.cluster.sites)[:fail_sites]
                res = sim.inject_failure(sites=sites)
            else:
                servers = [s.id for s in
                           sim.rng.sample(sim.cluster.alive_servers(),
                                          fail_servers)]
                res = sim.inject_failure(servers=servers)
            if res.n_affected == 0:
                continue
            agg["recovery_rate"] += res.recovery_rate
            if res.recovery_rate > 0:
                agg["mttr_avg"] += res.mttr_avg
            agg["accuracy_reduction"] += res.accuracy_reduction
            n += 1
        out[policy] = {k: v / max(n, 1) for k, v in agg.items()}
    return out


def run_scenario_suite(cfg: SimConfig,
                       names: Optional[List[str]] = None,
                       policies=("faillite", "full-warm", "full-cold",
                                 "full-warm-k")):
    """Sweep every policy over the named scenario library. Each cell is
    a fresh Simulation (same cfg+seed => same workload & event trace),
    so policies are compared on identical fault sequences."""
    from repro.core.scenario import SCENARIOS
    names = list(names) if names is not None else sorted(SCENARIOS)
    out: Dict[str, Dict[str, ScenarioResult]] = {}
    for name in names:
        out[name] = {}
        for policy in policies:
            c = SimConfig(**{**cfg.__dict__, "policy": policy})
            sim = Simulation(c).setup()
            out[name][policy] = sim.run_named_scenario(name)
    return out
