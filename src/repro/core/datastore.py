"""Controller data store (paper §4: Redis with replication + periodic
checkpoints).  In-memory KV with versioning, snapshot/restore, and
synchronous replication to follower stores — the controller fail-over
path restores from the freshest follower.
"""

from __future__ import annotations

import copy
import json
import threading
from pathlib import Path
from typing import Any, Dict, List


class DataStore:
    def __init__(self, name: str = "primary"):
        self.name = name
        self._data: Dict[str, Any] = {}
        self._version = 0
        self._lock = threading.RLock()
        self._replicas: List["DataStore"] = []

    # -- kv -------------------------------------------------------------
    def put(self, key: str, value: Any):
        with self._lock:
            self._data[key] = copy.deepcopy(value)
            self._version += 1
            for r in self._replicas:
                r._apply(key, value, self._version)

    def get(self, key: str, default=None):
        with self._lock:
            return copy.deepcopy(self._data.get(key, default))

    def keys(self, prefix: str = ""):
        with self._lock:
            return [k for k in self._data if k.startswith(prefix)]

    def delete(self, key: str):
        with self._lock:
            self._data.pop(key, None)
            self._version += 1
            for r in self._replicas:
                r._apply(key, None, self._version, delete=True)

    @property
    def version(self) -> int:
        return self._version

    # -- replication ------------------------------------------------------
    def add_replica(self, replica: "DataStore"):
        with self._lock:
            replica._data = copy.deepcopy(self._data)
            replica._version = self._version
            self._replicas.append(replica)

    def _apply(self, key, value, version, delete=False):
        with self._lock:
            if delete:
                self._data.pop(key, None)
            else:
                self._data[key] = copy.deepcopy(value)
            self._version = version

    # -- checkpoints --------------------------------------------------------
    def snapshot(self) -> Dict[str, Any]:
        with self._lock:
            return {"version": self._version,
                    "data": copy.deepcopy(self._data)}

    def restore(self, snap: Dict[str, Any]):
        with self._lock:
            self._data = copy.deepcopy(snap["data"])
            self._version = snap["version"]

    def checkpoint_to(self, path: Path):
        snap = self.snapshot()
        Path(path).write_text(json.dumps(snap, default=str))

    @classmethod
    def from_checkpoint(cls, path: Path) -> "DataStore":
        ds = cls()
        snap = json.loads(Path(path).read_text())
        ds._data = snap["data"]
        ds._version = snap["version"]
        return ds
