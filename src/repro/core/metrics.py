"""Request-level outcome metrics — the aggregation half of the traffic
plane (paper §5.7: client-observed MTTR and accuracy loss).

Every generated request is classified, vectorized with numpy, against
its application's serving timeline (recorded by `core/traffic.py` from
routing-table epoch bumps and crash instants) into one of:

  * **served** — a live replica answered; carries that replica variant's
    accuracy and a latency proxy,
  * **served-degraded** — served, but by a smaller-than-full variant
    (progressive failover in flight, or a heterogeneous warm backup),
  * **SLO-violated** — served, but the latency proxy exceeded the app's
    ``latency_slo`` (queueing blow-up under a LoadSpike, for example),
  * **dropped** — arrived inside a downtime window: the serving replica
    was dead and no re-route had reached the client yet.

The latency proxy is ``service_time / (1 - utilization)`` with lognormal
jitter — an M/M/1-shaped stand-in that responds to the variant size
(smaller variants are faster) and to the instantaneous request rate
(spikes push p99 and SLO violations), without simulating queues
per-request.

Aggregates reported per run and per failure epoch:

  * **availability** — served / offered (departed-app residue excluded),
  * **accuracy-weighted goodput** — Σ accuracy over requests served
    within SLO, / offered: one number folding drops, degradation, and
    SLO misses together (1.0 = every request answered at full quality),
  * **latency p50/p99** of the proxy over served requests,
  * **downtime windows** — per (app, failure-epoch) blackout intervals,
    with the number of requests they swallowed, and
  * **client-observed MTTR** — per window, first *served* request after
    the route was restored minus the crash instant. This is the
    request-level analogue of the paper's 175.5 ms: it upper-bounds the
    controller's own MTTR (detection + load + notify) because clients
    also pay route propagation and arrival discretization.

Determinism guarantees: classification is a pure function of the
recorded timelines, the arrival arrays, and a PCG64 jitter stream seeded
from (simulation seed, stable app index) — same seed ⇒ identical
per-request trace and identical summary, regardless of wall clock or
dict iteration order (apps are processed in sorted-id order).
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

# serving-timeline states (core/traffic.py appends transitions)
UP, DOWN, GONE = 0, 1, 2


@dataclass
class DowntimeWindow:
    """One client-visible blackout: [crash instant, route restored)."""
    app_id: str
    epoch: int                     # failure epoch that opened the window
    t_start: float                 # the serving replica's crash instant
    t_end: float = math.inf        # route restored (+notify); inf = never
    n_dropped: int = 0             # requests that arrived inside
    t_first_served: float = math.inf   # first served request after t_end
    # warm backup serving this app during the window, as
    # (accuracy, service_time) — set only when the resilience layer is
    # on; hedged requests inside the window are served by it
    backup: Optional[Tuple[float, float]] = None

    @property
    def recovered(self) -> bool:
        return math.isfinite(self.t_end)

    @property
    def duration(self) -> float:
        """Control-plane view: route-outage length."""
        return self.t_end - self.t_start

    @property
    def client_downtime(self) -> float:
        """Request-level view: gap until a request actually succeeded."""
        if not self.recovered:
            return math.inf
        if math.isfinite(self.t_first_served):
            return self.t_first_served - self.t_start
        return self.duration          # no arrivals after restore


@dataclass
class AppLog:
    """Classified per-request arrays for one application."""
    app_id: str
    arrivals: np.ndarray           # sorted arrival times
    served: np.ndarray             # bool
    dropped: np.ndarray            # bool (downtime)
    offered: np.ndarray            # bool (False = pre-deploy / departed)
    degraded: np.ndarray           # bool (served below full accuracy)
    slo_violated: np.ndarray       # bool (served but proxy > SLO)
    accuracy: np.ndarray           # serving accuracy (nan if not served)
    latency: np.ndarray            # latency proxy (nan if not served)
    # resilience-layer outcomes (core/resilience.py); None on the
    # historical off-path. hedged/retried are subsets of served;
    # fast_failed/shed are terminal non-served classes disjoint from
    # dropped — every offered request lands in exactly one of
    # {served&~hedged&~retried, hedged, retried, dropped, fast_failed,
    # shed} (pinned by tests/test_properties.py)
    hedged: Optional[np.ndarray] = None       # served via warm backup
    fast_failed: Optional[np.ndarray] = None  # breaker answered instantly
    shed: Optional[np.ndarray] = None         # admission/bulkhead reject
    retried: Optional[np.ndarray] = None      # served on post-restore retry


def classify_app(app_id: str, arrivals: np.ndarray, rates: np.ndarray,
                 times: np.ndarray, states: np.ndarray,
                 accs: np.ndarray, svcs: np.ndarray, *,
                 full_accuracy: float, slo: float,
                 jitter_rng: np.random.Generator,
                 jitter_sigma: float = 0.25,
                 util_k: float = 2.0, util_cap: float = 0.9) -> AppLog:
    """Vectorized request classification against one app's timeline.

    ``times/states/accs/svcs`` are the app's serving transitions;
    ``rates`` holds the logical request rate q_i in effect when each
    request was generated (so spikes raise utilization → latency).
    """
    n = arrivals.size
    idx = np.searchsorted(times, arrivals, side="right") - 1
    pre = idx < 0                          # before first deploy
    idx = np.clip(idx, 0, len(times) - 1)
    state = states[idx]
    served = (~pre) & (state == UP)
    dropped = (~pre) & (state == DOWN)
    offered = ~(pre | (state == GONE))

    accuracy = np.where(served, accs[idx], np.nan)
    svc = np.where(served, svcs[idx], np.nan)
    util = np.clip(rates * svc * util_k, 0.0, util_cap) if n else svc
    jitter = (np.exp(jitter_rng.normal(-0.5 * jitter_sigma ** 2,
                                       jitter_sigma, n))
              if n else np.empty(0))
    with np.errstate(invalid="ignore"):
        latency = svc / (1.0 - util) * jitter
        degraded = served & (accuracy < full_accuracy - 1e-12)
        slo_violated = served & (latency > slo)
    return AppLog(app_id, arrivals, served, dropped, offered,
                  degraded, slo_violated, accuracy, latency)


def classify_apps(items: List[tuple], *, jitter_sigma: float = 0.25,
                  util_k: float = 2.0,
                  util_cap: float = 0.9) -> List["AppLog"]:
    """Batched `classify_app` over many apps in one vectorized pass
    (epoch-mode summarize; see docs/SCALE.md).

    ``items`` is a list of ``(app_id, arrivals, rates, times, states,
    accs, svcs, full_accuracy, slo, jitter_rng)`` tuples — the exact
    arguments `classify_app` takes, one tuple per app.

    Bit-exact with calling `classify_app` per item:

    * the timeline interval lookup is reformulated per app as an
      integer ``np.repeat`` over ``searchsorted(arrivals, times,
      "left")`` boundaries — provably equal to
      ``searchsorted(times, arrivals, "right") - 1`` for sorted inputs
      (duplicate timeline times collapse to zero-length intervals in
      both forms), with no float offset tricks that could flip
      near-tie comparisons;
    * jitter is drawn from each app's own generator, with the same
      single ``normal(mu, sigma, n)`` call per app;
    * every remaining operation is elementwise, so grouping apps into
      one flat array changes no float result.
    """
    if not items:
        return []
    ns = np.array([it[1].size for it in items], np.int64)
    ms = np.array([it[3].size for it in items], np.int64)
    offs = np.zeros(len(items) + 1, np.int64)
    np.cumsum(ns, out=offs[1:])
    toffs = np.zeros(len(items) + 1, np.int64)
    np.cumsum(ms, out=toffs[1:])
    total = int(offs[-1])
    g_idx = np.zeros(total, np.int64)       # per-request timeline row
    pre = np.zeros(total, bool)             # before the app's first deploy
    gnorm = np.empty(total, np.float64)     # per-request jitter draws
    mu = -0.5 * jitter_sigma ** 2
    for k, it in enumerate(items):
        arrivals, times, jitter_rng = it[1], it[3], it[9]
        n = arrivals.size
        if n == 0:
            continue
        m = times.size
        lo, hi = int(offs[k]), int(offs[k + 1])
        # method calls + direct integer subtraction: same values as
        # np.searchsorted / np.diff(np.concatenate(...)) / np.clip with
        # ~3 fewer dispatch wrappers per app (hot at 100k apps)
        bb = np.empty(m + 2, np.int64)
        bb[0] = 0
        bb[1:-1] = arrivals.searchsorted(times, side="left")
        bb[-1] = n
        il = np.repeat(np.arange(-1, m), bb[1:] - bb[:-1])
        pre[lo:hi] = il < 0
        g_idx[lo:hi] = il.clip(0, m - 1) + toffs[k]
        gnorm[lo:hi] = jitter_rng.normal(mu, jitter_sigma, n)
    t_states = np.concatenate([it[4] for it in items])
    t_accs = np.concatenate([it[5] for it in items])
    t_svcs = np.concatenate([it[6] for it in items])
    rates = (np.concatenate([it[2] for it in items]) if total
             else np.empty(0, np.float64))
    state = t_states[g_idx]
    served = (~pre) & (state == UP)
    dropped = (~pre) & (state == DOWN)
    offered = ~(pre | (state == GONE))
    accuracy = np.where(served, t_accs[g_idx], np.nan)
    svc = np.where(served, t_svcs[g_idx], np.nan)
    util = np.clip(rates * svc * util_k, 0.0, util_cap)
    jitter = np.exp(gnorm)
    full_acc = np.repeat(np.array([it[7] for it in items], np.float64), ns)
    slo = np.repeat(np.array([it[8] for it in items], np.float64), ns)
    with np.errstate(invalid="ignore"):
        latency = svc / (1.0 - util) * jitter
        degraded = served & (accuracy < full_acc - 1e-12)
        slo_violated = served & (latency > slo)
    out: List[AppLog] = []
    for k, it in enumerate(items):
        lo, hi = int(offs[k]), int(offs[k + 1])
        out.append(AppLog(it[0], it[1], served[lo:hi], dropped[lo:hi],
                          offered[lo:hi], degraded[lo:hi],
                          slo_violated[lo:hi], accuracy[lo:hi],
                          latency[lo:hi]))
    return out


@dataclass
class TrafficSummary:
    """Run-level fold of every request outcome + downtime window."""
    n_offered: int = 0
    n_served: int = 0
    n_dropped: int = 0
    n_degraded: int = 0
    n_slo_violated: int = 0
    availability: float = 1.0
    goodput: float = 1.0           # accuracy-weighted, SLO-gated
    latency_p50: float = 0.0
    latency_p99: float = 0.0
    # mean client_downtime over recovered windows; inf when windows
    # exist but none recovered (permanent blackout ≠ zero downtime);
    # 0.0 only when there were no downtime windows at all
    client_mttr_avg: float = 0.0
    # Σ route-outage durations; unrecovered windows are censored at the
    # run horizon (they count as dark from crash to end of run)
    downtime_total_s: float = 0.0
    n_windows: int = 0
    n_unrecovered_windows: int = 0
    # resilience-layer outcome counters (all zero on the off-path)
    n_hedged_win: int = 0
    n_fast_failed: int = 0
    n_shed: int = 0
    n_retried: int = 0
    per_epoch: List[dict] = field(default_factory=list)
    windows: List[DowntimeWindow] = field(default_factory=list)

    def to_dict(self) -> Dict[str, float]:
        return {k: getattr(self, k) for k in (
            "n_offered", "n_served", "n_dropped", "n_degraded",
            "n_slo_violated", "availability", "goodput", "latency_p50",
            "latency_p99", "client_mttr_avg", "downtime_total_s",
            "n_windows", "n_unrecovered_windows", "n_hedged_win",
            "n_fast_failed", "n_shed", "n_retried")}

    def fingerprint(self) -> tuple:
        """Deterministic digest for same-seed replay tests."""
        def r(x):
            return -1.0 if not math.isfinite(x) else round(float(x), 9)
        base = (self.n_offered, self.n_served, self.n_dropped,
                self.n_degraded, self.n_slo_violated,
                r(self.availability), r(self.goodput),
                r(self.latency_p50), r(self.latency_p99),
                r(self.client_mttr_avg), r(self.downtime_total_s),
                self.n_windows, self.n_unrecovered_windows,
                tuple(tuple(sorted(e.items())) for e in self.per_epoch))
        res = (self.n_hedged_win, self.n_fast_failed, self.n_shed,
               self.n_retried)
        # resilience-off runs keep the historical fingerprint shape
        # bit-exact (golden pinning in tests/test_modelstate.py)
        return base if res == (0, 0, 0, 0) else base + (res,)

    def epoch_row(self, epoch: int) -> dict:
        for e in self.per_epoch:
            if e["epoch"] == epoch:
                return e
        return {"epoch": epoch, "n_windows": 0, "n_dropped": 0,
                "client_mttr_avg": 0.0, "n_unrecovered": 0}


def aggregate(logs: List[AppLog], windows: List[DowntimeWindow],
              t_end: float) -> TrafficSummary:
    """Fold per-app logs + downtime windows into one summary.

    Also back-fills each window's ``n_dropped`` and ``t_first_served``
    from the request arrays (the windows themselves only carry the
    control-plane interval).
    """
    by_app: Dict[str, AppLog] = {l.app_id: l for l in logs}
    for w in windows:
        log = by_app.get(w.app_id)
        if log is None or log.arrivals.size == 0:
            continue
        lo = np.searchsorted(log.arrivals, w.t_start, side="left")
        hi = (np.searchsorted(log.arrivals, w.t_end, side="left")
              if w.recovered else log.arrivals.size)
        w.n_dropped = int(np.count_nonzero(log.dropped[lo:hi]))
        if w.recovered:
            cand: List[float] = []
            after = np.nonzero(log.served & (log.arrivals >= w.t_end))[0]
            if after.size:
                cand.append(float(log.arrivals[after[0]]))
            # resilience wins *inside* the window (hedged to the warm
            # backup, or retried at restore) end the client-visible
            # blackout at their completion instant, not at the first
            # organic post-restore arrival
            for name in ("hedged", "retried"):
                mask = getattr(log, name)
                if mask is None:
                    continue
                in_w = np.nonzero(mask[lo:hi])[0]
                if in_w.size:
                    i = lo + in_w
                    cand.append(float(np.min(log.arrivals[i]
                                             + log.latency[i])))
            if cand:
                w.t_first_served = min(cand)

    # integer counts are order-free — concatenate once and count in C
    # instead of a 5x per-app Python genexpr sweep (hot at 100k apps)
    def _cat_count(name: str) -> int:
        arrs = [a for l in logs
                if (a := getattr(l, name)) is not None and a.size]
        return int(np.count_nonzero(np.concatenate(arrs))) if arrs else 0

    n_offered = _cat_count("offered")
    n_served = _cat_count("served")
    n_dropped = _cat_count("dropped")
    n_degraded = _cat_count("degraded")
    n_slo = _cat_count("slo_violated")
    _count = _cat_count

    n_hedged = _count("hedged")
    n_fast_failed = _count("fast_failed")
    n_shed = _count("shed")
    n_retried = _count("retried")

    good = 0.0
    lat_all: List[np.ndarray] = []
    for l in logs:
        ok = l.served & ~l.slo_violated
        if ok.any():
            # np.sum is bitwise nansum when no NaN is present (same
            # pairwise reduce), and NaN always propagates through it —
            # so sum first and fall back to the (much slower) masking
            # nansum only on an actual NaN (testbed in-flight requests)
            s = float(np.sum(l.accuracy[ok]))
            good += float(np.nansum(l.accuracy[ok])) if math.isnan(s) \
                else s
        if l.served.any():
            lat_all.append(l.latency[l.served])
    lats = np.concatenate(lat_all) if lat_all else np.empty(0)
    # the testbed leaves nan latencies on requests still in flight at
    # run end; the sim path never produces them (no-op there)
    lats = lats[np.isfinite(lats)]

    recovered = [w for w in windows if w.recovered]
    client_downs = [w.client_downtime for w in recovered]
    summary = TrafficSummary(
        n_offered=n_offered, n_served=n_served, n_dropped=n_dropped,
        n_degraded=n_degraded, n_slo_violated=n_slo,
        availability=(n_served / n_offered if n_offered else 1.0),
        goodput=(good / n_offered if n_offered else 1.0),
        latency_p50=float(np.percentile(lats, 50)) if lats.size else 0.0,
        latency_p99=float(np.percentile(lats, 99)) if lats.size else 0.0,
        client_mttr_avg=(sum(client_downs) / len(client_downs)
                         if client_downs
                         else (math.inf if windows else 0.0)),
        downtime_total_s=(sum(w.duration for w in recovered)
                          + sum(t_end - w.t_start for w in windows
                                if not w.recovered)),
        n_windows=len(windows),
        n_unrecovered_windows=sum(1 for w in windows if not w.recovered),
        n_hedged_win=n_hedged, n_fast_failed=n_fast_failed,
        n_shed=n_shed, n_retried=n_retried,
        windows=sorted(windows, key=lambda w: (w.epoch, w.t_start,
                                               w.app_id)))

    epochs = sorted({w.epoch for w in windows})
    for ep in epochs:
        ws = [w for w in windows if w.epoch == ep]
        downs = [w.client_downtime for w in ws if w.recovered]
        summary.per_epoch.append({
            "epoch": ep,
            "n_windows": len(ws),
            "n_dropped": sum(w.n_dropped for w in ws),
            "client_mttr_avg": (sum(downs) / len(downs)
                                if downs else math.inf),
            "n_unrecovered": sum(1 for w in ws if not w.recovered)})
    return summary
