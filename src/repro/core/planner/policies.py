"""Registered placement policies.

    greedy         vectorized Algorithm 1 (the paper's heuristic)
    legacy-greedy  the original loop implementation (oracle/baseline)
    ilp            exact B&B over Eq. 1-7 (proactive-only: realtime=False)
    load-aware     worst-fit ranked by rate-weighted compute headroom
    locality       worst-fit with checkpoint-locality tie-breaking
                   (model-state plane: prefer servers that can fetch
                   the failover variant fastest — local ≫ peer ≫ cloud)
    sharded        site-sharded worst-fit selection (planner/sharded.py):
                   bit-identical to greedy, sublinear per attempt —
                   the planet-scale option

Select by name: `get_planner("greedy")`, or through the controller /
simulator via `FailLiteController(planner="load-aware")` /
`SimConfig(planner="load-aware")`.
"""

from __future__ import annotations

import numpy as np

from repro.core.cluster import RESOURCES
from repro.core.planner.base import (PlanRequest, PlanResult, Planner,
                                     register_planner)
from repro.core.planner.ilp import solve_warm_placement
from repro.core.planner.kernels import resolve_backend
from repro.core.planner.legacy import faillite_heuristic_legacy
from repro.core.planner.vectorized import plan_greedy


@register_planner("greedy")
class GreedyPlanner(Planner):
    """Algorithm 1, vectorized — the MTTR-critical default.

    ``backend="jax"`` routes rounds through the compiled chunk kernels
    (planner/jax_backend.py): bit-identical assignments and objective,
    compiled inner loops. Requests carrying a `latency_fn` need the
    dense (V, S) mask layout and fall back to the numpy path (counted
    in `stats["fallback_numpy"]`).
    """

    realtime = True

    def __init__(self, backend: str = "numpy"):
        self.backend = resolve_backend(backend)
        self.stats = {"backend": self.backend, "jax_rounds": 0,
                      "numpy_rounds": 0, "fallback_numpy": 0}
        self._ctx = None

    def plan(self, req: PlanRequest) -> PlanResult:
        exclude, site_exclude = req.exclusions()
        if self.backend == "jax":
            if req.latency_fn is None:
                from repro.core.planner.jax_backend import (JaxPlanContext,
                                                            plan_greedy_jax)
                if self._ctx is None:
                    self._ctx = JaxPlanContext()
                self.stats["jax_rounds"] += 1
                return plan_greedy_jax(req.apps, req.cluster,
                                       state=req.state, exclude=exclude,
                                       site_exclude=site_exclude,
                                       alpha=req.alpha, ctx=self._ctx)
            self.stats["fallback_numpy"] += 1
        self.stats["numpy_rounds"] += 1
        return plan_greedy(req.apps, req.cluster, state=req.state,
                           exclude=exclude, site_exclude=site_exclude,
                           alpha=req.alpha, latency_fn=req.latency_fn)


@register_planner("legacy-greedy")
class LegacyGreedyPlanner(Planner):
    """Algorithm 1, original pure-Python loops (parity oracle)."""

    realtime = True

    def plan(self, req: PlanRequest) -> PlanResult:
        exclude, site_exclude = req.exclusions()
        return faillite_heuristic_legacy(
            req.apps, req.cluster, exclude=exclude,
            site_exclude=site_exclude, alpha=req.alpha,
            latency_fn=req.latency_fn)


@register_planner("ilp")
class IlpPlanner(Planner):
    """Eq. 1-7 exact B&B; proactive planning only (the controller uses a
    realtime planner on the failover hot path, as the paper does)."""

    realtime = False

    def __init__(self, node_limit: int = 500, time_limit_s: float = 10.0):
        self.node_limit = node_limit
        self.time_limit_s = time_limit_s

    def plan(self, req: PlanRequest) -> PlanResult:
        return solve_warm_placement(
            req.apps, req.cluster, req.primaries, alpha=req.alpha,
            site_independence=req.site_independence,
            latency_fn=req.latency_fn, state=req.state,
            node_limit=self.node_limit, time_limit_s=self.time_limit_s)


@register_planner("load-aware")
class LoadAwarePlanner(Planner):
    """Worst-fit ranked by *projected* headroom under traffic load.

    The paper's rule ranks servers by current normalized free fraction;
    this policy instead ranks by the headroom REMAINING after placement,
    with the candidate's compute demand amplified by the app's request
    rate (`core/traffic.py` rates, optionally modulated by the diurnal
    profile at plan time) — so high-traffic apps land on compute-rich
    servers and low-traffic apps soak up memory-rich ones. Feasibility
    (Eq. 2/3/4/6) is unchanged; only the ranking differs.
    """

    realtime = True

    def __init__(self, diurnal: bool = False):
        self.diurnal = diurnal

    def plan(self, req: PlanRequest) -> PlanResult:
        # lazy import: traffic -> controller -> planner would otherwise
        # cycle at module-import time
        from repro.core.traffic import diurnal_factor
        mod = diurnal_factor(req.now) if self.diurnal else 1.0
        ci = RESOURCES.index("compute")

        def score(free, cap, d, app):
            eff = d.copy()
            eff[ci] *= 1.0 + mod * max(app.request_rate, 0.0)
            return ((free - eff[None, :]) / cap).min(axis=1)

        exclude, site_exclude = req.exclusions()
        return plan_greedy(req.apps, req.cluster, state=req.state,
                           exclude=exclude, site_exclude=site_exclude,
                           alpha=req.alpha, latency_fn=req.latency_fn,
                           score_fn=score)


@register_planner("locality")
class LocalityPlanner(Planner):
    """Worst-fit with checkpoint-locality tie-breaking (model-state
    plane, `core/modelstate.py`).

    Algorithm 1's worst-fit ranks servers by normalized free fraction;
    under a constrained storage topology that rule happily places a
    failover onto a server that must stream the checkpoint over the
    shared cloud uplink while an equally-roomy server holds the bytes
    on local disk. This policy quantizes the headroom rank into bands
    of `band` (so "equally roomy" means within one band, not bit-equal
    floats) and, inside a band, prefers the server with the SMALLEST
    uncontended fetch time for the candidate variant — local hit ≫
    same-site peer ≫ cloud. Feasibility (Eq. 2/3/4/6) is unchanged.

    Needs a `ModelRegistry` attached to the planner state
    (`PlannerState.attach_registry`); without one it degrades to plain
    vectorized Algorithm 1.
    """

    realtime = True

    def __init__(self, band: float = 0.05):
        self.band = band

    def plan(self, req: PlanRequest) -> PlanResult:
        exclude, site_exclude = req.exclusions()
        registry = getattr(req.state, "registry", None) \
            if req.state is not None else None
        if registry is None:
            return plan_greedy(req.apps, req.cluster, state=req.state,
                               exclude=exclude, site_exclude=site_exclude,
                               alpha=req.alpha, latency_fn=req.latency_fn)
        band = self.band

        def score(free, cap, d, app):
            return np.floor((free / cap).min(axis=1) / band)

        def tiebreak(app, variant, server_ids):
            return [registry.fetch_seconds(variant, sid)
                    for sid in server_ids]

        return plan_greedy(req.apps, req.cluster, state=req.state,
                           exclude=exclude, site_exclude=site_exclude,
                           alpha=req.alpha, latency_fn=req.latency_fn,
                           score_fn=score, tiebreak_fn=tiebreak)


__all__ = ["GreedyPlanner", "LegacyGreedyPlanner", "IlpPlanner",
           "LoadAwarePlanner", "LocalityPlanner"]
