"""Pluggable planner subsystem — array-backed placement core.

Public surface (see docs/PLANNER.md):

  * `PlannerState` / `ScratchView` — persistent S x R capacity arrays,
    incrementally synced from `Cluster` change notifications;
  * `Planner` / `PlanRequest` / registry (`get_planner`,
    `register_planner`, `available_planners`) — policy selection by
    name: "greedy", "legacy-greedy", "ilp", "load-aware";
  * `faillite_heuristic` (vectorized Algorithm 1), `plan_greedy`,
    `solve_warm_placement` (Eq. 1-7 B&B), and the legacy oracle.

This package IS the placement API — the old `core/heuristic.py` /
`core/placement.py` compat shims are gone; import from here.
"""

from repro.core.planner.base import (HeuristicResult, PlanRequest,
                                     PlanResult, Planner,
                                     available_planners, eq1_objective,
                                     get_planner, register_planner)
from repro.core.planner.ilp import (PlacementResult, build_constraints,
                                    enumerate_vars, solve_warm_placement)
from repro.core.planner.legacy import (faillite_heuristic_legacy, match,
                                       worst_fit)
from repro.core.planner.kernels import have_jax, resolve_backend
from repro.core.planner.state import PlannerState, ScratchView
from repro.core.planner.vectorized import faillite_heuristic, plan_greedy
from repro.core.planner.sharded import CoordinatedSiteIndex, SiteIndex
from repro.core.planner import policies as _policies  # noqa: F401  (registers planners)
from repro.core.planner import sharded as _sharded  # noqa: F401  (registers "sharded")

__all__ = [
    "CoordinatedSiteIndex", "HeuristicResult", "PlacementResult",
    "PlanRequest", "PlanResult", "Planner", "PlannerState",
    "ScratchView", "SiteIndex", "available_planners",
    "build_constraints", "enumerate_vars", "eq1_objective",
    "faillite_heuristic", "faillite_heuristic_legacy", "get_planner",
    "have_jax", "match", "plan_greedy", "register_planner",
    "resolve_backend", "solve_warm_placement", "worst_fit",
]
