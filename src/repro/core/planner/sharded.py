"""Site-sharded worst-fit selection — planet-scale Algorithm 1.

The vectorized planner answers every worst-fit query with a full
(S, R) feasibility broadcast plus a length-S masked argmax. At 10k
servers that is ~20k float compares *per placement attempt*, and a
100k-app planning round does hundreds of thousands of attempts.

This module shards the selection by site: a `SiteIndex` groups the
alive rows per site and maintains each site's maximum headroom
(updated in O(site size) after every tentative take). A query then
scans sites in descending max-headroom order, runs feasibility only on
the rows of sites still able to beat the best feasible row found, and
stops as soon as the next site's ceiling falls below it. On realistic
edge topologies (10-100 servers/site, headroom spread across sites)
a query touches a handful of sites instead of all S rows.

Bit-exactness with the dense path (asserted row-for-row by
tests/test_scale.py): the dense argmax returns the FIRST maximum in
ascending row order, i.e. the minimum row index among rows of maximal
headroom. `select` examines every site whose ceiling is >= the current
best feasible headroom — a skipped site satisfies
``row_head <= site_max < best`` for all its rows, so it can neither
beat nor tie the best — and resolves cross-site ties by minimum global
row index, within-site ties by within-site argmax (rows ascending).
Budget checks, δ-derived start variants, and the upgrade pass are the
shared `plan_greedy` code, so everything except the selection is the
same code path.

Registered as planner "sharded" (realtime): opt in with
``SimConfig(planner="sharded")`` / ``--planner sharded``. Custom
rank/tiebreak/latency hooks need the dense rank vector, so requests
carrying a `latency_fn` fall back to the dense path; each such
fallback is counted in ``stats["fallback_dense"]`` and warned once.

Two scale knobs compose with the sharding: ``backend="jax"`` routes
whole planning rounds through the compiled chunked kernels
(jax_backend.py — bit-identical to numpy, see docs/PLANNER.md), and
``coordinators=N`` plans independent site groups on a thread pool
(`CoordinatedSiteIndex`) with a deterministic single-coordinator
merge.
"""

from __future__ import annotations

import logging
from concurrent.futures import ThreadPoolExecutor

import numpy as np

from repro.core.planner.base import (PlanRequest, PlanResult, Planner,
                                     register_planner)
from repro.core.planner.kernels import resolve_backend
from repro.core.planner.vectorized import plan_greedy

_EPS = 1e-9

logger = logging.getLogger("repro.planner.sharded")


class SiteIndex:
    """Per-site headroom ceilings over the alive rows of one planning
    round (see module docstring). Built by `plan_greedy` when a
    `site_index` factory is passed; row indices here are positions in
    the round's alive-row arrays, not global cluster rows."""

    def __init__(self, site_of_rows: np.ndarray, headroom: np.ndarray):
        order = np.argsort(site_of_rows, kind="stable")
        sids = site_of_rows[order]
        if sids.size:
            starts = np.flatnonzero(
                np.concatenate(([True], sids[1:] != sids[:-1])))
            ends = np.concatenate((starts[1:], [sids.size]))
        else:
            starts = ends = np.empty(0, np.int64)
        # members[g]: the g-th site's row positions, ascending (stable
        # argsort of an ascending range preserves input order)
        self.members = [order[s:e] for s, e in zip(starts, ends)]
        self.group_of = np.empty(site_of_rows.size, np.int64)
        for g, m in enumerate(self.members):
            self.group_of[m] = g
        self.site_max = np.array(
            [headroom[m].max() for m in self.members], np.float64)
        # group-order min rows; when they ascend (contiguous per-site
        # row blocks — the cluster layout), a losing ceiling TIE ends
        # the scan: every later tied group starts at a larger row
        mins = np.array([m[0] for m in self.members], np.int64)
        self._rows_ascend = bool(np.all(mins[1:] > mins[:-1]))

    def update(self, k: int, headroom: np.ndarray):
        """Row k's headroom changed (take/give): refresh its site's
        ceiling — O(site size)."""
        g = int(self.group_of[k])
        self.site_max[g] = float(headroom[self.members[g]].max())

    def _excl_mask(self, excl_rows):
        if excl_rows is None:
            return None
        # membership mask once per query instead of np.isin per
        # examined site — same rows excluded, no sort per site
        excl_mask = np.zeros(self.group_of.size, bool)
        excl_mask[excl_rows] = True
        return excl_mask

    def _scan_groups(self, groups: np.ndarray, free: np.ndarray,
                     headroom: np.ndarray, d: np.ndarray, excl_mask):
        """Descending-ceiling scan restricted to `groups`: the feasible
        row of maximal headroom among those sites, minimal row index on
        ties; (-inf, -1) when nothing fits. Over all groups this is the
        dense argmax; over a slice it is that slice's exact winner, so
        per-slice results merge deterministically (max h, then min
        row)."""
        best_h = -np.inf
        best_k = -1
        for g in groups[np.argsort(-self.site_max[groups],
                                   kind="stable")]:
            sm = float(self.site_max[g])
            if sm < best_h:
                break               # no later site can beat or tie best
            rows = self.members[g]
            # a site whose ceiling only TIES the best cannot win unless
            # it holds a smaller global row: rows are ascending per
            # site, so rows[0] > best_k rules the whole site out
            # without touching feasibility (homogeneous fleets tie
            # almost everywhere — this skips nearly the entire scan)
            if best_k >= 0 and sm == best_h and rows[0] > best_k:
                if self._rows_ascend:
                    break       # ties scan ascending: all later tied
                continue        # groups lose on row index too
            feas = (free[rows] >= d - _EPS).all(axis=1)
            if excl_mask is not None:
                feas &= ~excl_mask[rows]
            if not feas.any():
                continue
            hh = np.where(feas, headroom[rows], -np.inf)
            j = int(np.argmax(hh))          # first max, rows ascending
            h = float(hh[j])
            r = int(rows[j])
            if h > best_h or (h == best_h and r < best_k):
                best_h, best_k = h, r
        return best_h, best_k

    def select(self, free: np.ndarray, headroom: np.ndarray,
               d: np.ndarray, excl_rows) -> int:
        """Dense-argmax-equivalent worst-fit query: the feasible row of
        maximal headroom, minimal row index on ties; -1 when nothing
        fits. Scans sites in descending ceiling order and stops once no
        remaining site can reach the best feasible headroom found."""
        _h, k = self._scan_groups(np.arange(len(self.members)), free,
                                  headroom, d, self._excl_mask(excl_rows))
        return k


class CoordinatedSiteIndex(SiteIndex):
    """Multi-coordinator site-sharded selection.

    The site groups are partitioned into `coordinators` contiguous
    slices ("row groups"); every worst-fit query scans the slices
    concurrently on a thread pool and merges the per-slice winners with
    a deterministic rule — maximal headroom, then minimal global row —
    so the answer is the dense argmax winner regardless of thread
    scheduling (fuzz-asserted by tests/test_planner.py). Per-slice
    scans reuse `SiteIndex._scan_groups`, so each coordinator keeps the
    descending-ceiling early exit within its slice."""

    def __init__(self, site_of_rows: np.ndarray, headroom: np.ndarray,
                 *, coordinators: int = 2, pool=None):
        super().__init__(site_of_rows, headroom)
        G = len(self.members)
        c = max(1, min(int(coordinators), max(G, 1)))
        bounds = np.linspace(0, G, c + 1).astype(np.int64)
        self._slices = [np.arange(bounds[i], bounds[i + 1])
                        for i in range(c) if bounds[i + 1] > bounds[i]]
        self._pool = pool

    def select(self, free: np.ndarray, headroom: np.ndarray,
               d: np.ndarray, excl_rows) -> int:
        excl_mask = self._excl_mask(excl_rows)
        if self._pool is None or len(self._slices) <= 1:
            parts = [self._scan_groups(s, free, headroom, d, excl_mask)
                     for s in self._slices]
        else:
            parts = list(self._pool.map(
                lambda s: self._scan_groups(s, free, headroom, d,
                                            excl_mask), self._slices))
        best_h, best_k = -np.inf, -1
        for h, k in parts:
            if k >= 0 and (h > best_h
                           or (h == best_h and (best_k < 0 or k < best_k))):
                best_h, best_k = h, k
        return best_k


@register_planner("sharded")
class ShardedGreedyPlanner(Planner):
    """Algorithm 1 with site-sharded worst-fit selection (realtime).

    Identical assignments to the "greedy" planner bit-for-bit; chosen
    for planet-scale clusters where the dense per-attempt scan
    dominates failover planning wall time.

    ``backend="jax"`` routes latency-free rounds through the compiled
    chunk kernels instead of the site-sharded Python scan — same bits,
    compiled inner loops. ``coordinators=N`` (numpy path) plans with N
    concurrent site-slice coordinators (`CoordinatedSiteIndex`).
    Requests carrying a `latency_fn` fall back to the dense vectorized
    path either way — logged once per planner instance and counted in
    ``stats["fallback_dense"]`` (surfaced via `RunResult.extras`)."""

    realtime = True

    def __init__(self, backend: str = "numpy", coordinators: int = 0):
        self.backend = resolve_backend(backend)
        self.coordinators = int(coordinators)
        self.stats = {"backend": self.backend,
                      "coordinators": self.coordinators,
                      "jax_rounds": 0, "sharded_rounds": 0,
                      "fallback_dense": 0}
        self._warned_dense = False
        self._ctx = None
        self._pool = None

    def plan(self, req: PlanRequest) -> PlanResult:
        exclude, site_exclude = req.exclusions()
        if req.latency_fn is not None:
            # latency masks need the dense (V, S) layout; correctness
            # over speed for the rare latency-constrained request
            if not self._warned_dense:
                logger.warning(
                    "sharded planner: request carries a latency_fn; "
                    "falling back to the DENSE selection path "
                    "(warning logged once per planner instance; see "
                    "stats['fallback_dense'] for the running count)")
                self._warned_dense = True
            self.stats["fallback_dense"] += 1
            return plan_greedy(req.apps, req.cluster, state=req.state,
                               exclude=exclude, site_exclude=site_exclude,
                               alpha=req.alpha, latency_fn=req.latency_fn)
        if self.backend == "jax":
            from repro.core.planner.jax_backend import (JaxPlanContext,
                                                        plan_greedy_jax)
            if self._ctx is None:
                self._ctx = JaxPlanContext()
            self.stats["jax_rounds"] += 1
            return plan_greedy_jax(req.apps, req.cluster, state=req.state,
                                   exclude=exclude,
                                   site_exclude=site_exclude,
                                   alpha=req.alpha, ctx=self._ctx)
        factory = SiteIndex
        if self.coordinators > 1:
            if self._pool is None:
                self._pool = ThreadPoolExecutor(
                    max_workers=self.coordinators,
                    thread_name_prefix="planner-coord")
            c, pool = self.coordinators, self._pool

            def factory(site_of_rows, headroom):
                return CoordinatedSiteIndex(site_of_rows, headroom,
                                            coordinators=c, pool=pool)
        self.stats["sharded_rounds"] += 1
        return plan_greedy(req.apps, req.cluster, state=req.state,
                           exclude=exclude, site_exclude=site_exclude,
                           alpha=req.alpha, site_index=factory)


__all__ = ["CoordinatedSiteIndex", "SiteIndex", "ShardedGreedyPlanner"]
