"""Warm-backup model selection & placement — the paper's ILP (Eq. 1-7),
with constraint assembly built directly from the planner's array state.

max  Σ_{i∈K} Σ_j Σ_k  a_ij · q_i · x_ijk
s.t. per-server capacity (2), α cold-reserve (3), primary anti-affinity
(4, optionally extended to site anti-affinity, §3.4), one backup per app
(5), latency SLO (6, encoded by filtering variables), binary x (7).

The paper solves this with Gurobi; no solver ships offline, so this is
an exact branch-and-bound over the scipy/HiGHS LP relaxation, with the
paper's own heuristic as the incumbent/warm start and as the fallback at
scale (the paper does the same in its large-scale simulation, §5.1).
Eq. 5 is relaxed from == 1 to <= 1 so low-headroom instances stay
feasible; maximization makes them equal whenever the paper's form is
feasible.

The A_ub matrix is assembled as three `scipy.sparse` COO blocks built
from flat (variable -> app/server/demand) index arrays — no Python
row loops — so constraint construction scales with nnz, not with
rows x variables.
"""

from __future__ import annotations

import heapq
import itertools
import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.cluster import Cluster, RESOURCES
from repro.core.planner.state import PlannerState
from repro.core.variants import Application, Variant


def _branch_frac(x) -> np.ndarray:
    """Per-variable fractionality |x - round(x)|, pinned to float64.

    Branching-variable selection argmaxes this vector; a relaxation
    vector that arrives in a narrower dtype (e.g. float32 from a
    future solver backend) would round 0.49999999-style values to 0.5
    and flip which variable the argmax picks, changing the search tree.
    Casting here makes the branching order a function of the VALUES,
    not of the dtype they were handed over in (regression-tested by
    tests/test_planner.py)."""
    x = np.asarray(x, dtype=np.float64)
    return np.abs(x - np.round(x))


@dataclass
class PlacementResult:
    assignment: Dict[str, Tuple[Variant, str]]   # app -> (variant, server)
    objective: float
    optimal: bool
    nodes: int
    wall_s: float


def enumerate_vars(apps: List[Application], cluster: Cluster,
                   primaries: Dict[str, str], *,
                   site_independence: bool = False,
                   latency_fn=None):
    """Filtered (app, variant, server) triples honoring Eq. 4 and 6.

    Compatibility helper, materialized from the same flat index arrays
    the solver plans with (`_build_variables`), so the two can never
    diverge."""
    state = PlannerState(cluster, subscribe=False)
    ids, (col_app, col_var, col_srv), _, _, _ = _build_variables(
        apps, cluster, primaries, state,
        site_independence=site_independence, latency_fn=latency_fn)
    return [(apps[int(a)], apps[int(a)].variants[int(v)],
             cluster.servers[ids[int(s)]])
            for a, v, s in zip(col_app, col_var, col_srv)]


def _build_variables(apps, cluster, primaries, state, *,
                     site_independence, latency_fn):
    """Flat variable arrays over filtered (app, variant, server) triples.

    Returns (ids, col_app, col_var_local, col_srv, dem, cost, free_alive)
    where columns follow the legacy app -> variant -> server order and
    `dem` is the per-variable demand matrix (nvar, R)."""
    state.sync()
    rows = state.alive_rows()
    S = int(rows.size)
    ids = [state.server_ids[int(i)] for i in rows]
    servers = [cluster.servers[sid] for sid in ids]
    free_alive = state.free[rows]
    site_row = state.site_of[rows]
    pos = {sid: k for k, sid in enumerate(ids)}

    col_app: List[np.ndarray] = []
    col_var: List[np.ndarray] = []
    col_srv: List[np.ndarray] = []
    dem_blocks: List[np.ndarray] = []
    cost_blocks: List[np.ndarray] = []
    for a_idx, app in enumerate(apps):
        base = np.ones(S, dtype=bool)
        p_srv = primaries.get(app.id)
        if p_srv is not None and p_srv in pos:
            base[pos[p_srv]] = False                           # Eq. 4
        if site_independence and p_srv is not None \
                and p_srv in state.sidx:
            p_site = state.site_of[state.sidx[p_srv]]
            base &= site_row != p_site                         # §3.4
        V = len(app.variants)
        if latency_fn is None:
            mask = np.broadcast_to(base, (V, S))
        else:
            lt = np.array([[latency_fn(app, v, srv) for srv in servers]
                           for v in app.variants], dtype=np.float64)
            mask = base[None, :] & (lt <= app.latency_slo)     # Eq. 6
        vi, si = np.nonzero(mask)          # variant-major: legacy order
        if vi.size == 0:
            continue
        col_app.append(np.full(vi.size, a_idx, dtype=np.int64))
        col_var.append(vi.astype(np.int64))
        col_srv.append(si.astype(np.int64))
        vdem = np.array([[v.demand[r] for r in RESOURCES]
                         for v in app.variants], dtype=np.float64)
        dem_blocks.append(vdem[vi])
        acc = np.array([v.accuracy for v in app.variants])
        cost_blocks.append(-(acc[vi] * app.request_rate))      # Eq. 1
    if not col_app:
        return ids, (np.empty(0, np.int64),) * 3, \
            np.empty((0, len(RESOURCES))), np.empty(0), free_alive
    return (ids,
            (np.concatenate(col_app), np.concatenate(col_var),
             np.concatenate(col_srv)),
            np.concatenate(dem_blocks), np.concatenate(cost_blocks),
            free_alive)


def build_constraints(apps, cluster, primaries, *,
                      alpha: float = 0.1,
                      site_independence: bool = False,
                      latency_fn=None,
                      state: Optional[PlannerState] = None):
    """Assemble (c, A_ub, b_ub, columns) via sparse block construction.

    Row layout: S·R per-server capacity rows (Eq. 2), R α-reserve rows
    (Eq. 3), then one <=1 row per app (Eq. 5)."""
    from scipy.sparse import coo_matrix

    if state is None:
        state = PlannerState(cluster, subscribe=False)
    ids, (col_app, col_var, col_srv), dem, c, free_alive = \
        _build_variables(apps, cluster, primaries, state,
                         site_independence=site_independence,
                         latency_fn=latency_fn)
    S, R = free_alive.shape
    nvar = int(col_app.size)
    n_rows = S * R + R + len(apps)
    if nvar == 0:
        A = coo_matrix((n_rows, 0)).tocsr()
        return c, A, np.zeros(n_rows), (ids, col_app, col_var, col_srv)

    cols_rep = np.repeat(np.arange(nvar), R)
    r_idx = np.arange(R)
    # Eq. 2: row = server_row * R + resource
    rows_cap = (col_srv[:, None] * R + r_idx[None, :]).ravel()
    # Eq. 3: R dense rows after the capacity block
    rows_res = np.tile(r_idx, nvar) + S * R
    # Eq. 5: one row per app after that
    rows_one = S * R + R + col_app

    rows = np.concatenate([rows_cap, rows_res, rows_one])
    cols = np.concatenate([cols_rep, cols_rep, np.arange(nvar)])
    vals = np.concatenate([dem.ravel(), dem.ravel(), np.ones(nvar)])
    A = coo_matrix((vals, (rows, cols)), shape=(n_rows, nvar)).tocsr()

    total_free = cluster.total_free()
    b = np.concatenate([
        free_alive.ravel(),
        np.array([(1.0 - alpha) * total_free[r] for r in RESOURCES]),
        np.ones(len(apps)),
    ])
    return c, A, b, (ids, col_app, col_var, col_srv)


def solve_warm_placement(apps: List[Application], cluster: Cluster,
                         primaries: Dict[str, str], *,
                         alpha: float = 0.1,
                         site_independence: bool = False,
                         latency_fn=None,
                         node_limit: int = 500,
                         time_limit_s: float = 10.0,
                         state: Optional[PlannerState] = None,
                         ) -> PlacementResult:
    """Exact B&B over the LP relaxation (falls back to heuristic bound)."""
    from scipy.optimize import linprog

    t0 = time.time()
    c, A, b, (ids, col_app, col_var, col_srv) = build_constraints(
        apps, cluster, primaries, alpha=alpha,
        site_independence=site_independence, latency_fn=latency_fn,
        state=state)
    nvar = int(col_app.size)
    if nvar == 0:
        return PlacementResult({}, 0.0, True, 0, time.time() - t0)

    def lp(lo, hi):
        res = linprog(c, A_ub=A, b_ub=b, bounds=np.stack([lo, hi], axis=1),
                      method="highs")
        if not res.success:
            return None, None
        return res.fun, res.x

    # incumbent from the paper's heuristic (vectorized greedy)
    from repro.core.planner.vectorized import plan_greedy
    greedy = plan_greedy(
        apps, cluster, state=state,
        exclude={a.id: {primaries.get(a.id)} for a in apps},
        site_exclude={a.id: ({cluster.servers[primaries[a.id]].site}
                             if site_independence and a.id in primaries
                             else set()) for a in apps},
        alpha=alpha, latency_fn=latency_fn)
    inc_obj = -greedy.objective
    incumbent = greedy.assignment

    lo0 = np.zeros(nvar)
    hi0 = np.ones(nvar)
    nodes = 0
    heap = []
    root_obj, root_x = lp(lo0, hi0)
    if root_obj is None:
        return PlacementResult(incumbent, -inc_obj, False, 0,
                               time.time() - t0)
    counter = itertools.count()
    heapq.heappush(heap, (root_obj, next(counter), lo0, hi0, root_x))
    best_obj, best_x = inc_obj, None
    optimal = True

    while heap:
        bound, _, lo, hi, x = heapq.heappop(heap)
        if bound >= best_obj - 1e-9:
            continue
        nodes += 1
        if nodes > node_limit or time.time() - t0 > time_limit_s:
            optimal = False
            break
        frac = _branch_frac(x)
        j = int(np.argmax(frac))
        if frac[j] < 1e-6:
            if bound < best_obj - 1e-9:
                best_obj, best_x = bound, x
            continue
        for fix in (0.0, 1.0):
            lo2, hi2 = lo.copy(), hi.copy()
            lo2[j] = hi2[j] = fix
            obj2, x2 = lp(lo2, hi2)
            if obj2 is None or obj2 >= best_obj - 1e-9:
                continue
            frac2 = _branch_frac(x2)
            if frac2.max() < 1e-6:
                best_obj, best_x = obj2, x2
            else:
                heapq.heappush(heap, (obj2, next(counter), lo2, hi2, x2))

    if best_x is None:
        return PlacementResult(incumbent, -inc_obj, optimal, nodes,
                               time.time() - t0)
    assignment: Dict[str, Tuple[Variant, str]] = {}
    sel = np.flatnonzero(best_x > 0.5)
    for n in sel:
        app = apps[int(col_app[n])]
        assignment[app.id] = (app.variants[int(col_var[n])],
                              ids[int(col_srv[n])])
    return PlacementResult(assignment, -best_obj, optimal, nodes,
                           time.time() - t0)
