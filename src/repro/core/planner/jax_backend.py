"""JAX planner backend — compiled Algorithm 1, bit-identical to numpy.

`plan_greedy_jax` is a transliteration of `vectorized.plan_greedy`
(default rank, no latency/score/tiebreak hooks) whose inner loops run
as the jitted chunk kernels in planner/kernels.py instead of a Python
loop over apps. The host side is byte-for-byte the numpy prologue —
ordering, ordered-sum δ and α-budget, per-app exclusion rows — so the
compiled path and the numpy path consume identical inputs; the device
side replays every comparison, argmax, and state update as the same
IEEE ops in the same order (see kernels.py for the contract). The
property tests in tests/test_planner.py assert assignment AND
objective bits match across random clusters, exclusions, dtypes, and
dirty-sync sequences.

Two pieces of persistent state make repeated rounds cheap:

  * `DeviceMirror` — device-resident (S, R) free / (S,) head / alive
    copies of a `PlannerState`, registered via
    `PlannerState.attach_mirror` so `sync()` forwards its dirty rows;
    a refresh scatters O(dirty) rows through the donated-buffer kernel
    instead of re-uploading the matrices.
  * `AppMatrixCache` — padded per-app variant-demand tensors, gathered
    per round by row index (apps are immutable, so rows never go
    stale).

Chunking (kernels.CHUNK_MAIN / CHUNK_TAIL) keeps the set of compiled
scan shapes at two per cluster signature: big proactive rounds compile
both, MTTR-critical failover rounds only ever hit the jit cache.
"""

from __future__ import annotations

import time
from typing import Dict, List, Optional, Set

import numpy as np

from repro.core.cluster import RESOURCES
from repro.core.planner.base import HeuristicResult, eq1_objective
from repro.core.planner.kernels import (build_kernels, build_scatter,
                                        chunk_sizes, have_jax)
from repro.core.planner.state import PlannerState, _ordered_sum
from repro.core.variants import Application

_EPS = 1e-9

# padded-variant floor: every app catalog in the repo is <= 8 variants,
# so V is almost always one compiled value; exclusion-row padding gets
# a floor of 8 so proactive rounds (1 primary row) and failover rounds
# (primary + site peers) share one compiled E
_V_MIN = 4
_E_MIN = 8


def _bucket(n: int, floor: int) -> int:
    b = floor
    while b < n:
        b *= 2
    return b


def _cmp_thresholds(dm: np.ndarray, dtype) -> np.ndarray:
    """Feasibility thresholds in the state dtype, exactly equivalent to
    numpy's f64 comparison.

    numpy decides `free >= d - eps` in f64 (f32 state rows promote
    losslessly). For an f32 x and real t, `x >= t` iff `x >= c` where
    c is the smallest f32 with c >= t — so rounding t = d - eps UP to
    the state dtype lets the kernel compare in pure f32, halving the
    (S, R) memory traffic of its hottest loop with zero behavior
    change. For f64 state the threshold is t itself."""
    t = dm - _EPS
    if np.dtype(dtype) == np.float64:
        return t
    c = t.astype(np.float32)
    low = c.astype(np.float64) < t
    return np.where(low, np.nextafter(c, np.float32(np.inf)),
                    c).astype(np.float32)


class DeviceMirror:
    """Device-resident mirror of a `PlannerState` (free/head/alive/cap).

    Attach once per state; `PlannerState.sync()` forwards dirty rows to
    `mark_dirty`, structural rebuilds call `invalidate`. `arrays()`
    returns current device buffers, pushing only the pending rows
    through the donated scatter kernel (bucket-padded index vector so
    the jit cache stays small)."""

    def __init__(self, state: PlannerState):
        self.state = state
        self._pending: set = set()
        self._bufs = None                  # (free, head, alive) on device
        self._cap = None
        self.full_uploads = 0
        self.rows_scattered = 0
        state.attach_mirror(self)

    def mark_dirty(self, rows) -> None:
        if self._bufs is not None:
            self._pending.update(int(r) for r in rows)

    def invalidate(self) -> None:
        self._bufs = None
        self._cap = None
        self._pending.clear()

    def _prewarm_scatter(self) -> None:
        """Compile the donated scatter for every index-bucket size up
        front (k = 16, 32, ... until >= S) with pad-only no-op calls:
        an MTTR-critical failover round must never pay an XLA compile
        inside the measured plan wall just because its dirty-row count
        landed in a bucket no earlier round had used."""
        import jax.numpy as jnp
        S = self.state.alive.size
        k = 16
        while True:
            idx = jnp.full((k,), S, jnp.int32)      # pad rows: no-op
            frows = jnp.zeros((k, len(RESOURCES)),
                              self._bufs[0].dtype)
            hrows = jnp.zeros((k,), self._bufs[1].dtype)
            arows = jnp.zeros((k,), bool)
            self._bufs = build_scatter()(*self._bufs, idx, frows,
                                         hrows, arows)
            if k >= S:
                break
            k *= 2

    def arrays(self):
        """(free, head, alive, cap) device arrays, synced to the state.
        Caller must hold the x64 scope and have called `state.sync()`."""
        import jax.numpy as jnp
        st = self.state
        if self._bufs is None:
            self._bufs = (jnp.asarray(st.free), jnp.asarray(st.head),
                          jnp.asarray(st.alive))
            self._cap = jnp.asarray(st.capacity)
            self._pending.clear()
            self.full_uploads += 1
            self._prewarm_scatter()
        elif self._pending:
            idx = np.fromiter(sorted(self._pending), np.int32,
                              len(self._pending))
            S = st.alive.size
            k = _bucket(idx.size, 16)
            pidx = np.full(k, S, np.int32)          # pad rows drop out
            pidx[:idx.size] = idx
            frows = np.zeros((k, len(RESOURCES)), st.free.dtype)
            hrows = np.zeros(k, st.head.dtype)
            arows = np.zeros(k, bool)
            frows[:idx.size] = st.free[idx]
            hrows[:idx.size] = st.head[idx]
            arows[:idx.size] = st.alive[idx]
            free, head, alive = build_scatter()(
                *self._bufs, jnp.asarray(pidx), jnp.asarray(frows),
                jnp.asarray(hrows), jnp.asarray(arows))
            self._bufs = (free, head, alive)
            self._pending.clear()
            self.rows_scattered += int(idx.size)
        return (*self._bufs, self._cap)


class AppMatrixCache:
    """Padded (V, R) demand tensors per app, gathered per round.

    Apps and their variant ladders are immutable, so a cached row never
    goes stale; the cache grows (and re-pads) only when an app with
    more variants than the current pad width appears."""

    def __init__(self):
        self.V = _V_MIN
        self._row: Dict[str, int] = {}
        self._dm = np.zeros((0, self.V, len(RESOURCES)), np.float64)
        self._vmask = np.zeros((0, self.V), bool)
        self._full = np.zeros((0, len(RESOURCES)), np.float64)

    def _grow_v(self, V: int) -> None:
        n = self._dm.shape[0]
        dm = np.full((n, V, len(RESOURCES)), np.inf, np.float64)
        dm[:, :self.V] = self._dm
        vm = np.zeros((n, V), bool)
        vm[:, :self.V] = self._vmask
        self._dm, self._vmask, self.V = dm, vm, V

    def rows(self, apps: List[Application]) -> np.ndarray:
        """Row indices for `apps`, adding unseen apps to the cache."""
        new = [a for a in apps if a.id not in self._row]
        if new:
            maxv = max(len(a.variants) for a in new)
            if maxv > self.V:
                self._grow_v(_bucket(maxv, _V_MIN))
            n0 = self._dm.shape[0]
            dm = np.full((len(new), self.V, len(RESOURCES)), np.inf,
                         np.float64)
            vm = np.zeros((len(new), self.V), bool)
            fd = np.zeros((len(new), len(RESOURCES)), np.float64)
            for i, a in enumerate(new):
                m = a.demand_matrix()
                dm[i, :m.shape[0]] = m
                vm[i, :m.shape[0]] = True
                fd[i] = a.full.demand_vec
                self._row[a.id] = n0 + i
            self._dm = np.concatenate([self._dm, dm])
            self._vmask = np.concatenate([self._vmask, vm])
            self._full = np.concatenate([self._full, fd])
        return np.array([self._row[a.id] for a in apps], np.int64)

    def gather(self, rows: np.ndarray):
        return self._dm[rows], self._vmask[rows], self._full[rows]


class JaxPlanContext:
    """Per-planner-instance persistent caches: one `DeviceMirror` per
    `PlannerState` identity plus the shared `AppMatrixCache`."""

    def __init__(self):
        self.apps = AppMatrixCache()
        self._mirrors: Dict[int, DeviceMirror] = {}

    def mirror(self, state: PlannerState) -> DeviceMirror:
        m = self._mirrors.get(id(state))
        if m is None or m.state is not state:
            m = DeviceMirror(state)
            self._mirrors[id(state)] = m
        return m


def plan_greedy_jax(apps: List[Application], cluster=None, *,
                    state: Optional[PlannerState] = None,
                    exclude: Optional[Dict[str, Set[str]]] = None,
                    site_exclude: Optional[Dict[str, Set[str]]] = None,
                    alpha: float = 0.0,
                    ctx: Optional[JaxPlanContext] = None,
                    ) -> HeuristicResult:
    """Compiled Algorithm 1 — same contract (and same bits) as
    `vectorized.plan_greedy` with the default worst-fit rank.

    Unsupported hooks (latency_fn / score_fn / tiebreak_fn /
    site_index) are the caller's responsibility: the planner policies
    route such requests to the numpy path."""
    assert have_jax(), "jax backend requested but jax is not importable"
    from jax.experimental import enable_x64

    t0 = time.time()
    exclude = exclude or {}
    site_exclude = site_exclude or {}
    if state is None:
        assert cluster is not None, "need a cluster or a PlannerState"
        state = PlannerState(cluster, subscribe=False)
    if cluster is None:
        cluster = state.cluster
    if ctx is None:
        ctx = JaxPlanContext()
    state.sync()

    order = sorted(apps, key=lambda a: (not a.critical, -a.request_rate))
    rows = state.alive_rows()
    if not apps or rows.size == 0:
        assignment: Dict[str, tuple] = {}
        return HeuristicResult(assignment, [a.id for a in order],
                               time.time() - t0,
                               eq1_objective(assignment, apps))

    S = int(state.alive.size)                    # full rows; dead masked
    R = len(RESOURCES)

    # host prologue — the numpy path's exact code over the gathered
    # alive rows: ordered sums seed δ and the α-budget bit-identically
    arows = ctx.apps.rows(order)
    dm_all, vmask_all, full_order = ctx.apps.gather(arows)
    gfree = state.free[rows]
    C = [_ordered_sum(gfree[:, j]) for j in range(R)]
    # δ's demand total is accumulated in `apps` order (not placement
    # order), matching plan_greedy's full_dem construction
    full_apps = np.array([a.full.demand_vec for a in apps],
                         dtype=np.float64).reshape(len(apps), R)
    D = [_ordered_sum(full_apps[:, j]) for j in range(R)]
    delta = min((C[j] / D[j]) if D[j] > 0 else 1.0 for j in range(R))
    budget0 = np.array([(1.0 - alpha) * C[j] for j in range(R)],
                       dtype=np.float64)

    if delta >= 1.0:
        thr_all = np.full((len(order), R), np.inf, np.float64)
    else:
        thr_all = delta * full_order + _EPS

    # sparse per-app exclusion rows as GLOBAL row indices (the kernel
    # masks the full alive vector, so dead rows are harmless to list)
    excl_lists: List[List[int]] = []
    for app in order:
        er: List[int] = []
        for sid in exclude.get(app.id, ()):
            if sid:
                i = state.sidx.get(sid)
                if i is not None:
                    er.append(i)
        for site in site_exclude.get(app.id, ()):
            for sid in cluster.sites.get(site, ()):
                i = state.sidx.get(sid)
                if i is not None:
                    er.append(i)
        excl_lists.append(er)
    E = _bucket(max((len(e) for e in excl_lists), default=0), _E_MIN)
    excl_all = np.full((len(order), E), S, np.int32)     # pad drops out
    for i, er in enumerate(excl_lists):
        if er:
            u = sorted(set(er))
            excl_all[i, :len(u)] = u

    dmc_all = _cmp_thresholds(dm_all, state.dtype)

    with enable_x64():
        import jax.numpy as jnp
        kern = build_kernels(S, R, ctx.apps.V, E, str(state.dtype))
        free, head, alive, cap = ctx.mirror(state).arrays()
        budget = jnp.asarray(budget0)

        chunks = chunk_sizes(len(order))
        dev_chunks = []                    # (dm, vmask) kept for upgrade
        j_parts, k_parts = [], []
        off = 0
        for n in chunks:
            lo, hi = off, off + n
            na = min(hi, len(order)) - lo              # active rows
            dm = np.full((n, ctx.apps.V, R), np.inf, np.float64)
            dc = np.full((n, ctx.apps.V, R), np.inf, state.dtype)
            vm = np.zeros((n, ctx.apps.V), bool)
            th = np.full((n, R), np.inf, np.float64)
            ex = np.full((n, E), S, np.int32)
            ac = np.zeros(n, bool)
            dm[:na] = dm_all[lo:lo + na]
            dc[:na] = dmc_all[lo:lo + na]
            vm[:na] = vmask_all[lo:lo + na]
            th[:na] = thr_all[lo:lo + na]
            ex[:na] = excl_all[lo:lo + na]
            ac[:na] = True
            dmj, vmj = jnp.asarray(dm), jnp.asarray(vm)
            free, head, budget, j, k = kern["place_chunk"](
                free, head, budget, alive, cap, dmj, jnp.asarray(dc),
                vmj, jnp.asarray(th), jnp.asarray(ex), jnp.asarray(ac))
            dev_chunks.append((dmj, vmj))
            j_parts.append(j)
            k_parts.append(k)
            off = hi

        # upgrade pass over the SAME order once every app is placed —
        # matching the numpy path's two sequential sweeps
        up_parts = []
        for (dmj, vmj), j, k in zip(dev_chunks, j_parts, k_parts):
            free, head, budget, j_up = kern["upgrade_chunk"](
                free, head, budget, cap, dmj, vmj, j, k)
            up_parts.append(j_up)

        A = len(order)
        jj = np.concatenate([np.asarray(p) for p in j_parts])[:A]
        kk = np.concatenate([np.asarray(p) for p in k_parts])[:A]
        ju = np.concatenate([np.asarray(p) for p in up_parts])[:A]

    assignment = {}
    unplaced: List[str] = []
    for i, app in enumerate(order):
        k = int(kk[i])
        if k < 0:
            unplaced.append(app.id)
            continue
        j = int(ju[i]) if int(ju[i]) >= 0 else int(jj[i])
        assignment[app.id] = (app.variants[j], state.server_ids[k])

    return HeuristicResult(assignment, unplaced, time.time() - t0,
                           eq1_objective(assignment, apps))


__all__ = ["AppMatrixCache", "DeviceMirror", "JaxPlanContext",
           "plan_greedy_jax"]
