"""Planner subsystem primitives: result types, Eq. 1 objective, protocol
and registry.

Every placement policy in the repo — the paper's Algorithm 1 (greedy),
the warm-backup ILP (Eq. 1-7), and beyond-paper policies — implements
the same `Planner` interface and is selected by *name* through the
registry, so the controller never imports planner internals.

The shared objective is the paper's Eq. 1:

    max  Σ_{i} Σ_j Σ_k  a_ij · q_i · x_ijk

i.e. accuracy weighted by request rate. Both the heuristic and the ILP
report it, so `benchmarks/ilp_vs_heuristic.py` compares like with like.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Set, Tuple, TYPE_CHECKING

if TYPE_CHECKING:                                   # pragma: no cover
    from repro.core.cluster import Cluster
    from repro.core.planner.state import PlannerState
    from repro.core.variants import Application, Variant


def eq1_objective(assignment: Dict[str, Tuple["Variant", str]],
                  apps: List["Application"]) -> float:
    """Paper Eq. 1: Σ accuracy · request_rate over the assignment.

    Summed in assignment insertion order so that two behavior-equivalent
    planners producing the same assignment report the *bit-identical*
    float (the parity tests rely on this).
    """
    rate = {a.id: a.request_rate for a in apps}
    return sum(v.accuracy * rate[app_id]
               for app_id, (v, _) in assignment.items())


@dataclass
class HeuristicResult:
    """Outcome of a greedy-family planner run.

    `objective` is the Eq. 1 value of `assignment` (NOT the raw accuracy
    sum an earlier revision used) so heuristic and ILP results are
    directly comparable.
    """
    assignment: Dict[str, Tuple["Variant", str]]
    unplaced: List[str] = field(default_factory=list)
    wall_s: float = 0.0
    objective: float = 0.0


# alias: the registry-facing name for "whatever a planner returns";
# duck-typed — the ILP returns its own PlacementResult which also has
# .assignment / .objective / .wall_s
PlanResult = HeuristicResult


@dataclass
class PlanRequest:
    """Everything a planner may need for one placement round.

    `state` is the persistent array-backed view (see
    planner/state.py); planners fall back to building a throwaway one
    from `cluster` when it is None. `exclude`/`site_exclude` override
    the anti-affinity sets derived from `primaries` (Eq. 4 / §3.4).
    """
    apps: List["Application"]
    cluster: "Cluster"
    state: Optional["PlannerState"] = None
    primaries: Dict[str, str] = field(default_factory=dict)
    alpha: float = 0.0
    site_independence: bool = False
    latency_fn: Optional[Callable] = None
    exclude: Optional[Dict[str, Set[str]]] = None
    site_exclude: Optional[Dict[str, Set[str]]] = None
    now: float = 0.0               # sim time, for load/diurnal-aware policies

    def exclusions(self):
        """(exclude, site_exclude) honoring Eq. 4 and §3.4 defaults."""
        excl = self.exclude
        if excl is None:
            excl = {a.id: {self.primaries.get(a.id)} for a in self.apps}
        site_excl = self.site_exclude
        if site_excl is None:
            site_excl = {}
            if self.site_independence:
                for a in self.apps:
                    p = self.primaries.get(a.id)
                    site_excl[a.id] = ({self.cluster.servers[p].site}
                                       if p else set())
        return excl, site_excl


class Planner:
    """Base class every placement policy implements.

    `realtime` marks policies cheap enough for the MTTR-critical
    failover path; the controller falls back to a realtime planner for
    `handle_failures`/`reprotect` when the configured one is not
    (the paper runs the ILP proactively only, §3.3).
    """

    name: str = "?"
    realtime: bool = True

    def plan(self, req: PlanRequest) -> PlanResult:
        raise NotImplementedError


_REGISTRY: Dict[str, Callable[..., Planner]] = {}


def register_planner(name: str):
    """Class decorator: `@register_planner("greedy")`."""
    def deco(factory):
        factory.name = name
        _REGISTRY[name] = factory
        return factory
    return deco


def get_planner(name: str, **kwargs) -> Planner:
    """Instantiate a registered planner by name."""
    try:
        factory = _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown planner {name!r}; available: "
                       f"{', '.join(sorted(_REGISTRY))}") from None
    return factory(**kwargs)


def available_planners() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))
