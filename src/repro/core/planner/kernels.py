"""Jitted JAX kernels for the planner's inner loops (jax backend).

Three compiled primitives, built per (S, R, Vmax, E, dtype) signature
and cached process-wide so a planning round never recompiles:

  * ``place_chunk`` — the fused feasibility-match + masked-argmax
    worst-fit: one `lax.scan` step per app runs Algorithm 1's
    match (Line 6, δ-threshold variant selection), degradation loop
    (Lines 7-12, lazily testing one (S,) feasibility column per tried
    variant), and worst-fit reduction (Line 9, the
    `kernels/planner_argmax` masked argmax — first-maximum tie rule)
    against carried (S, R) free / (S,) headroom / (R,) α-budget
    device arrays;
  * ``upgrade_chunk`` — the fused upgrade pass (Lines 13-14): per
    placed app, first feasible larger variant on its chosen row, with
    the legacy give-then-take two-step replayed op-for-op;
  * ``scatter_rows`` — donated-buffer dirty-row update powering the
    incremental `PlannerState` device mirror: the old free/head/alive
    buffers are donated to XLA, so a sync touches O(dirty) rows and
    never re-materializes the (S, R) arrays.

Bit-exactness contract (the property tests in tests/test_planner.py
assert it end-to-end): every arithmetic op here is an elementary IEEE
op in the same dtype and the same order as the numpy path — the (S, R)
feasibility compare runs in the state dtype against precomputed
round-up thresholds proven equal to numpy's f64 `free >= d - eps`
(jax_backend._cmp_thresholds), small f64 compares promote f32 state
losslessly, in-place f32 updates replay numpy's
compute-in-f64-then-cast semantics via an explicit astype round-trip,
and every argmax keeps numpy's first-maximum rule. All public entry points run under
`jax.experimental.enable_x64` so f64 stays f64 without flipping the
global x64 flag for the rest of the process.

Chunking: callers drive whole rounds through fixed chunk shapes
(`CHUNK_MAIN` then `CHUNK_TAIL` for the remainder, padded with inactive
apps) so only two scan shapes ever compile per cluster signature — the
proactive setup round pays the compile; MTTR-critical failover rounds
hit the cache.
"""

from __future__ import annotations

from functools import lru_cache, partial

_EPS = 1e-9

CHUNK_MAIN = 4096       # bulk chunk (large proactive rounds)
CHUNK_TAIL = 256        # remainder chunk (failover-round scale)


def have_jax() -> bool:
    try:
        import jax  # noqa: F401
        return True
    except ImportError:                             # pragma: no cover
        return False


def resolve_backend(backend: str) -> str:
    """Validate a planner backend name at construction time, so a bad
    config fails loudly instead of at the first failover round."""
    if backend not in ("numpy", "jax"):
        raise ValueError(f"unknown planner backend {backend!r}; "
                         "expected 'numpy' or 'jax'")
    if backend == "jax" and not have_jax():
        raise RuntimeError("planner backend 'jax' requires jax, which is "
                           "not importable here; use backend='numpy'")
    return backend


def chunk_sizes(n: int):
    """Decompose a round of n apps into fixed-shape chunks: as many
    CHUNK_MAIN as fit, then CHUNK_TAIL chunks for the remainder (the
    last one padded) — exactly two compiled shapes per signature."""
    out = []
    while n >= CHUNK_MAIN:
        out.append(CHUNK_MAIN)
        n -= CHUNK_MAIN
    while n > 0:
        out.append(CHUNK_TAIL)
        n -= CHUNK_TAIL
    return out


@lru_cache(maxsize=None)
def build_kernels(S: int, R: int, V: int, E: int, dtype_str: str):
    """Compile-cached kernel set for one cluster/round signature.

    S/R: state matrix shape; V: padded variants per app; E: padded
    exclusion rows per app (pad index = S, dropped by scatter mode);
    dtype_str: the PlannerState dtype ("float64" | "float32")."""
    import jax
    import jax.numpy as jnp
    from jax.experimental import enable_x64

    from repro.kernels.planner_argmax.ops import masked_argmax

    with enable_x64():
        f64 = jnp.float64
        st_dtype = jnp.dtype(dtype_str)

        def _place_step(carry, x):
            free, head, budget, alive, cap = carry
            dm, dmc, vmask, thr, excl, active = x
            # (S,) allowed mask: alive minus this app's excluded rows
            # (Eq. 4 / §3.4) — pad index S drops out
            allowed = alive.at[excl].set(False, mode="drop")

            # fused match (Line 6): first variant under the δ threshold,
            # else the smallest variant — bit-equal to the numpy
            # segment scan (thr rows are +inf when δ >= 1)
            okv = (dm <= thr[None, :]).all(axis=1) & vmask
            nvar = jnp.maximum(vmask.sum(), 1).astype(jnp.int32)
            start = jnp.where(okv.any(), jnp.argmax(okv),
                              nvar - 1).astype(jnp.int32)

            # degradation loop (Lines 7-12): lazily test one (S,)
            # feasibility column per tried variant
            def cond(s):
                j, k, done = s
                return (~done) & (j < V)

            def body(s):
                j, _, _ = s
                bok = (budget >= dm[j] - _EPS).all() & vmask[j]

                def attempt(_):
                    # pure-dtype compares against the precomputed
                    # per-variant thresholds (jax_backend._cmp_thresholds
                    # proves them equal to numpy's f64 `free >= d - eps`),
                    # unrolled over R — XLA:CPU vectorizes the unrolled
                    # compares but not an (S, R) `.all(axis=1)` reduce
                    feas = allowed
                    for r in range(R):
                        feas = feas & (free[:, r] >= dmc[j, r])
                    k, _val = masked_argmax(head, feas)
                    return k

                k = jax.lax.cond(bok, attempt,
                                 lambda _: jnp.int32(-1), None)
                return (j + 1, k, k >= 0)

            j_end, k, done = jax.lax.while_loop(
                cond, body, (start, jnp.int32(-1), ~active))
            placed = active & (k >= 0)
            j = jnp.where(placed, j_end - 1, -1).astype(jnp.int32)
            ku = jnp.where(placed, k, 0)
            d = dm[jnp.where(placed, j, 0)]
            # numpy in-place `free[k] -= d` computes in f64, casts back
            newrow = (free[ku].astype(f64) - d).astype(st_dtype)
            free2 = free.at[ku].set(jnp.where(placed, newrow, free[ku]))
            budget2 = jnp.where(placed, budget - d, budget)
            newhead = (free2[ku] / cap[ku]).min()
            head2 = head.at[ku].set(jnp.where(placed, newhead, head[ku]))
            return ((free2, head2, budget2, alive, cap),
                    (j, jnp.where(placed, k, -1).astype(jnp.int32)))

        @jax.jit
        def place_chunk(free, head, budget, alive, cap,
                        dm, dmc, vmask, thr, excl, active):
            (free, head, budget, alive, cap), (j, k) = jax.lax.scan(
                _place_step, (free, head, budget, alive, cap),
                (dm, dmc, vmask, thr, excl, active))
            return free, head, budget, j, k

        def _upgrade_step(carry, x):
            free, head, budget, cap = carry
            dm, vmask, jcur, k = x
            active = (k >= 0) & (jcur > 0)
            ku = jnp.where(active, k, 0)
            d_cur = dm[jnp.where(active, jcur, 0)]
            row = free[ku]

            # first feasible larger variant (Lines 13-14): extras =
            # d[j] - d[jcur], fits row k AND the α-budget
            def cond(s):
                j, up, done = s
                return (~done) & (j < jcur)

            def body(s):
                j, _, _ = s
                extras = dm[j] - d_cur                      # f64 exact
                ok = vmask[j] \
                    & (row >= extras - _EPS).all() \
                    & (budget >= extras - _EPS).all()
                return (j + 1, jnp.where(ok, j, -1).astype(jnp.int32),
                        ok)

            _j_end, j_up, found = jax.lax.while_loop(
                cond, body, (jnp.int32(0), jnp.int32(-1), ~active))
            take = active & (j_up >= 0)
            d_up = dm[jnp.where(take, j_up, 0)]
            # give(current) then take(upgrade), two casts, NOT one
            # fused delta — replays the legacy float rounding exactly
            row1 = (row.astype(f64) + d_cur).astype(st_dtype)
            row2 = (row1.astype(f64) - d_up).astype(st_dtype)
            free2 = free.at[ku].set(jnp.where(take, row2, row))
            budget2 = jnp.where(take, (budget + d_cur) - d_up, budget)
            newhead = (free2[ku] / cap[ku]).min()
            head2 = head.at[ku].set(jnp.where(take, newhead, head[ku]))
            return ((free2, head2, budget2, cap),
                    jnp.where(take, j_up, -1).astype(jnp.int32))

        @jax.jit
        def upgrade_chunk(free, head, budget, cap, dm, vmask, jcur, k):
            (free, head, budget, cap), j_up = jax.lax.scan(
                _upgrade_step, (free, head, budget, cap),
                (dm, vmask, jcur, k))
            return free, head, budget, j_up

        return {"place_chunk": place_chunk,
                "upgrade_chunk": upgrade_chunk}


@lru_cache(maxsize=None)
def build_scatter():
    """Donated dirty-row scatter for the `PlannerState` device mirror:
    the stale free/head/alive buffers are donated to XLA so the update
    writes in place — O(dirty) work, no (S, R) re-materialization.
    Row indices >= S (the bucket padding) drop out."""
    import jax
    from jax.experimental import enable_x64

    with enable_x64():
        @partial(jax.jit, donate_argnums=(0, 1, 2))
        def scatter_rows(free, head, alive, idx, frows, hrows, arows):
            free = free.at[idx].set(frows, mode="drop")
            head = head.at[idx].set(hrows, mode="drop")
            alive = alive.at[idx].set(arows, mode="drop")
            return free, head, alive

        return scatter_rows


__all__ = ["CHUNK_MAIN", "CHUNK_TAIL", "build_kernels", "build_scatter",
           "chunk_sizes", "have_jax", "resolve_backend"]
