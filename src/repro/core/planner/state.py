"""Array-backed placement core: the persistent, incrementally-updated
free-capacity view every planner plans against.

Layout (S servers x R resources, row order = `Cluster.servers` order):

    capacity  (S, R) float64   static per-server capacity
    free      (S, R) float64   capacity - Σ non-cold instance demand
    alive     (S,)   bool      liveness mask
    site_of   (S,)   int       row -> site index (anti-affinity, §3.4)

Incremental-update contract: the state subscribes to `Cluster` change
notifications (place/remove/fail/revive), marking the touched server
*dirty*; `sync()` re-derives only the dirty rows from the cluster —
exact (each row is recomputed with `Server.free`, so there is no
floating-point drift from accumulated deltas) and O(dirty) instead of
O(S·instances) per planning call. `handle_failures`/`handle_rejoin`/
`reprotect` therefore feed server-granular deltas into one persistent
state rather than rebuilding a view per call.

`ScratchView` is the public successor of the old `_FreeView`: tentative
take/give accounting over a copy of the free matrix, with the α-budget
(Eq. 3) held back, used for multi-placement rounds before committing to
the cluster.
"""

from __future__ import annotations

from typing import Dict, Iterable, List, Optional

import numpy as np

from repro.core.cluster import Cluster, RESOURCES

_EPS = 1e-9


def _ordered_sum(values) -> float:
    """Left-to-right float sum, matching Python's builtin `sum` over the
    same sequence (bit-parity with the legacy dict-based planner)."""
    total = 0.0
    for v in values:
        total += float(v)
    return total


class PlannerState:
    """Persistent array view of a `Cluster` (see module docstring)."""

    def __init__(self, cluster: Cluster, *, subscribe: bool = True,
                 dtype="float64"):
        self.cluster = cluster
        # array dtype: float64 is the bit-exact default; float32 halves
        # the (S, R) matrices' footprint for planet-scale runs (ulp at
        # 16 GB is ~1 KB — placement-equivalent in practice but NOT
        # fingerprint-preserving, see docs/SCALE.md)
        self.dtype = np.dtype(dtype)
        # model-state plane attachment (checkpoint residency columns):
        # locality-aware policies read per-server residency and fetch
        # costs through this; None = no registry attached
        self.registry = None
        # attached device mirrors (planner/jax_backend.DeviceMirror):
        # sync() forwards dirty rows, structural rebuilds invalidate
        self._mirrors: List = []
        self._rebuild()
        if subscribe:
            cluster.subscribe(self._on_change)

    # -- construction / sync ------------------------------------------------
    def _rebuild(self):
        servers = list(self.cluster.servers.values())
        self.server_ids: List[str] = [s.id for s in servers]
        self.sidx: Dict[str, int] = {sid: i for i, sid
                                     in enumerate(self.server_ids)}
        S, R = len(servers), len(RESOURCES)
        self.capacity = np.array(
            [[s.capacity[r] for r in RESOURCES] for s in servers],
            dtype=self.dtype).reshape(S, R)
        self.free = np.zeros((S, R), dtype=self.dtype)
        self.alive = np.zeros(S, dtype=bool)
        # maintained per-row normalized headroom (min over resources):
        # recomputed for dirty rows in sync() so worst_fit never
        # re-divides the full (S, R) matrices per placement attempt
        self.head = np.zeros(S, dtype=self.dtype)
        self._alive_cache: Optional[np.ndarray] = None
        sites = []
        site_idx: Dict[str, int] = {}
        for s in servers:
            if s.site not in site_idx:
                site_idx[s.site] = len(sites)
                sites.append(s.site)
        self.site_names = sites
        self.site_of = np.array([site_idx[s.site] for s in servers],
                                dtype=np.int64)
        self._dirty = set(range(S))
        self._structure_stale = False
        self._alive_cache = None
        # _rebuild also runs from __init__, before _mirrors exists
        for m in getattr(self, "_mirrors", ()):
            m.invalidate()

    def _on_change(self, server_id: str):
        i = self.sidx.get(server_id)
        if i is None:                 # server set changed out-of-band
            self._structure_stale = True
        else:
            self._dirty.add(i)

    def sync(self) -> int:
        """Re-derive dirty rows from the cluster; returns rows touched."""
        if self._structure_stale:
            self._rebuild()
            self._structure_stale = False
        if not self._dirty:
            return 0
        n = len(self._dirty)
        R = len(RESOURCES)
        idx = np.fromiter(self._dirty, np.int64, n)
        rows = np.empty((n, R), np.float64)
        for t in range(n):
            i = int(idx[t])
            srv = self.cluster.servers[self.server_ids[i]]
            # accumulate cached per-variant demand vectors instead of
            # Server.free's per-resource dict-building genexpr: same
            # instances, same iteration order, same left-to-right
            # float64 adds per component — bit-identical row values
            used = np.zeros(R, np.float64)
            for inst in srv.instances.values():
                if inst.role != "cold":
                    used += inst.variant.demand_vec
            rows[t] = [srv.capacity[r] for r in RESOURCES]
            rows[t] -= used
            if self.alive[i] != srv.alive:
                self.alive[i] = srv.alive
                self._alive_cache = None
        self.free[idx] = rows
        # same per-row math worst_fit used to run over the full matrix:
        # min over resources of free/capacity, divided in the state
        # dtype (batched over dirty rows — elementwise, so each row is
        # bit-identical to the former one-row-at-a-time computation)
        self.head[idx] = (self.free[idx] / self.capacity[idx]).min(axis=1)
        for m in self._mirrors:
            m.mark_dirty(self._dirty)
        self._dirty.clear()
        return n

    # -- queries ------------------------------------------------------------
    @property
    def n_dirty(self) -> int:
        return len(self._dirty)

    def alive_rows(self) -> np.ndarray:
        """Row indices of alive servers, in cluster order (the legacy
        `alive_servers()` iteration order). Cached; invalidated when a
        sync flips any row's liveness."""
        if self._alive_cache is None:
            self._alive_cache = np.flatnonzero(self.alive)
        return self._alive_cache

    def mask_of(self, server_ids: Iterable[str], rows: np.ndarray,
                ) -> np.ndarray:
        """Bool mask (len(rows),) — True where the row's server is in
        `server_ids` (unknown/dead ids are ignored)."""
        pos = {int(i): k for k, i in enumerate(rows)}
        out = np.zeros(len(rows), dtype=bool)
        for sid in server_ids:
            i = self.sidx.get(sid) if sid else None
            if i is not None and i in pos:
                out[pos[i]] = True
        return out

    def worst_fit(self, demand, excluded: Iterable[str] = ()
                  ) -> Optional[str]:
        """Most-headroom alive server fitting `demand` (Alg. 1 line 9);
        first-maximum tie-break, matching the legacy loop.

        `demand` is a resource dict or a prebuilt `RESOURCES`-ordered
        vector (`Variant.demand_vec` — the hot failover path passes the
        cached array). Runs one fused feasibility pass over the full
        matrix against the maintained headroom column: no row gather,
        no per-call division, no per-call demand-vector rebuild. The
        old defensive total-free budget check is gone — free is
        non-negative, so the sum can never bind when any per-server fit
        passes."""
        self.sync()
        d = (demand if isinstance(demand, np.ndarray)
             else np.array([demand[r] for r in RESOURCES],
                           dtype=np.float64))
        feas = self.alive & (self.free >= d - _EPS).all(axis=1)
        for sid in excluded:
            i = self.sidx.get(sid) if sid else None
            if i is not None:
                feas[i] = False
        if not feas.any():
            return None
        # full-row masked argmax: first maximum among feasible rows in
        # ascending row order — the same winner the gathered sub-array
        # argmax picked
        i = int(np.argmax(np.where(feas, self.head, -np.inf)))
        return self.server_ids[i]

    def place_group(self, demand, k: int, excluded: Iterable[str] = ()
                    ) -> Optional[List[str]]:
        """Pick k *distinct* alive servers each fitting `demand` — the
        shard-group placement primitive. Co-placement: prefer the site
        holding the most-headroom feasible server with >= k feasible
        members (TP traffic stays on the site fabric); fall back to
        cluster-wide spread when no single site can host the group.
        Anti-affinity (one shard per server) is inherent: rows are
        distinct servers. Deterministic: headroom-descending with
        row-order tie-break, like `worst_fit`."""
        self.sync()
        d = (demand if isinstance(demand, np.ndarray)
             else np.array([demand[r] for r in RESOURCES],
                           dtype=np.float64))
        feas = self.alive & (self.free >= d - _EPS).all(axis=1)
        for sid in excluded:
            i = self.sidx.get(sid) if sid else None
            if i is not None:
                feas[i] = False
        if int(feas.sum()) < k:
            return None
        head = np.where(feas, self.head, -np.inf)
        best_site, best_key = None, None
        for s in range(len(self.site_names)):
            rows = np.flatnonzero(feas & (self.site_of == s))
            if len(rows) >= k:
                key = float(head[rows].max())
                if best_key is None or key > best_key:
                    best_site, best_key = s, key
        if best_site is not None:
            rows = np.flatnonzero(feas & (self.site_of == best_site))
        else:
            rows = np.flatnonzero(feas)
        order = sorted(rows.tolist(), key=lambda i: (-head[i], i))[:k]
        return [self.server_ids[i] for i in order]

    def scratch(self, reserve_frac: float = 0.0) -> "ScratchView":
        return ScratchView(self, reserve_frac=reserve_frac)

    # -- device mirrors ------------------------------------------------------
    def attach_mirror(self, mirror) -> None:
        """Register a device-side mirror of the free/head/alive arrays
        (the jax backend's `DeviceMirror`). `sync()` forwards the dirty
        row set to `mirror.mark_dirty` before clearing it, and a
        structural `_rebuild` calls `mirror.invalidate` — so the mirror
        can stay incremental (O(dirty) scatter) without re-deriving
        anything from the cluster itself."""
        self._mirrors.append(mirror)

    # -- model-state columns -------------------------------------------------
    def attach_registry(self, registry) -> None:
        """Attach a `core.modelstate.ModelRegistry` so locality-aware
        policies can read checkpoint residency per server (the
        `locality` planner's tie-break reads `registry.fetch_seconds`
        through this attachment)."""
        self.registry = registry

    def residency_mask(self, variant_name: str) -> np.ndarray:
        """(S,) bool column — True where the server holds the variant's
        checkpoint on local disk."""
        assert self.registry is not None, "no ModelRegistry attached"
        mask = np.zeros(len(self.server_ids), dtype=bool)
        for sid in self.registry.resident_servers(variant_name):
            i = self.sidx.get(sid)
            if i is not None:
                mask[i] = True
        return mask


class ScratchView:
    """Tentative free-capacity accounting over the alive rows of a
    `PlannerState` — array-backed replacement for the old `_FreeView`."""

    def __init__(self, state: PlannerState, reserve_frac: float = 0.0):
        state.sync()
        self.state = state
        self.rows = state.alive_rows()
        self.ids = [state.server_ids[int(i)] for i in self.rows]
        self.pos = {sid: k for k, sid in enumerate(self.ids)}
        self.free = state.free[self.rows].copy()
        self.cap = state.capacity[self.rows].copy()
        # α-reserve (Eq. 3): hold back a fraction of TOTAL free capacity;
        # ordered sums keep bit-parity with the legacy implementation
        self.budget = np.array(
            [(1.0 - reserve_frac) * _ordered_sum(self.free[:, j])
             for j in range(len(RESOURCES))], dtype=np.float64)

    def _vec(self, demand) -> np.ndarray:
        """Demand dict -> vector; prebuilt vectors (`Variant.demand_vec`)
        pass straight through."""
        if isinstance(demand, np.ndarray):
            return demand
        return np.array([demand[r] for r in RESOURCES], dtype=np.float64)

    def fits(self, sid: str, demand: Dict[str, float]) -> bool:
        d = self._vec(demand)
        k = self.pos[sid]
        return (bool((self.free[k] >= d - _EPS).all())
                and bool((self.budget >= d - _EPS).all()))

    def take(self, sid: str, demand: Dict[str, float]):
        d = self._vec(demand)
        self.free[self.pos[sid]] -= d
        self.budget -= d

    def give(self, sid: str, demand: Dict[str, float]):
        d = self._vec(demand)
        self.free[self.pos[sid]] += d
        self.budget += d

    def headroom(self, sid: str) -> float:
        k = self.pos[sid]
        return float((self.free[k] / self.cap[k]).min())

    def worst_fit(self, demand: Dict[str, float],
                  excluded: Iterable[str] = ()) -> Optional[str]:
        d = self._vec(demand)
        if not (self.budget >= d - _EPS).all():
            return None
        feas = (self.free >= d - _EPS).all(axis=1)
        for sid in excluded:
            k = self.pos.get(sid)
            if k is not None:
                feas[k] = False
        if not feas.any():
            return None
        head = (self.free / self.cap).min(axis=1)
        k = int(np.argmax(np.where(feas, head, -np.inf)))
        return self.ids[k]
