"""Algorithm 1, reference implementation — the original pure-Python
triple loop (O(N·V·S) dict arithmetic).

Kept verbatim as the behavioral oracle: `tests/test_planner.py` asserts
the vectorized planner (planner/vectorized.py) produces identical
assignments and Eq. 1 objective on seeded random instances, and
`tools/bench_planner.py` measures the old-vs-new speedup. New code
should use the vectorized `faillite_heuristic` instead.

    δ = available_capacity / max_demand        (per resource, take min)
    X[i] = match(n_i, δ)                       variant sized ≈ δ × full
    for each app: worst-fit place X[i], degrading to smaller variants
    upgrade_model(): grow placed variants where headroom remains
"""

from __future__ import annotations

import time
from typing import Dict, List, Optional, Set

from repro.core.cluster import Cluster, RESOURCES, Server
from repro.core.planner.base import HeuristicResult, eq1_objective
from repro.core.variants import Application, Variant


class _FreeView:
    """Tentative free-capacity accounting over alive servers.

    Deprecated: use `PlannerState.scratch()` (planner/state.py) — kept
    only so the legacy oracle stays byte-for-byte the old algorithm.
    """

    def __init__(self, servers: List[Server], reserve_frac: float = 0.0):
        self.cap = {s.id: dict(s.capacity) for s in servers}
        self.free = {s.id: {r: s.free(r) for r in RESOURCES}
                     for s in servers}
        self.servers = {s.id: s for s in servers}
        # α-reserve: hold back a fraction of the *total* free capacity
        self.budget = {r: (1.0 - reserve_frac) *
                       sum(f[r] for f in self.free.values())
                       for r in RESOURCES}

    def fits(self, sid: str, demand: Dict[str, float]) -> bool:
        return (all(self.free[sid][r] >= demand[r] - 1e-9 for r in RESOURCES)
                and all(self.budget[r] >= demand[r] - 1e-9
                        for r in RESOURCES))

    def take(self, sid: str, demand: Dict[str, float]):
        for r in RESOURCES:
            self.free[sid][r] -= demand[r]
            self.budget[r] -= demand[r]

    def give(self, sid: str, demand: Dict[str, float]):
        for r in RESOURCES:
            self.free[sid][r] += demand[r]
            self.budget[r] += demand[r]

    def headroom(self, sid: str) -> float:
        return min(self.free[sid][r] / self.cap[sid][r] for r in RESOURCES)


def match(variants: List[Variant], delta: float) -> int:
    """Index of the variant whose demand ≈ δ × full demand (Line 6)."""
    if delta >= 1.0:
        return 0
    full = variants[0]
    for j, v in enumerate(variants):
        if all(v.demand[r] <= delta * full.demand[r] + 1e-9
               for r in RESOURCES):
            return j
    return len(variants) - 1


def worst_fit(view: _FreeView, demand: Dict[str, float],
              excluded: Set[str], app=None, variant=None,
              latency_fn=None, slo=float("inf")) -> Optional[str]:
    """Most-headroom alive server that fits demand + SLO (Line 9)."""
    best, best_h = None, -1.0
    for sid, srv in view.servers.items():
        if sid in excluded:
            continue
        if latency_fn is not None and app is not None and \
                latency_fn(app, variant, srv) > slo:
            continue
        if not view.fits(sid, demand):
            continue
        h = view.headroom(sid)
        if h > best_h:
            best, best_h = sid, h
    return best


def faillite_heuristic_legacy(apps: List[Application], cluster: Cluster, *,
                              exclude: Optional[Dict[str, Set[str]]] = None,
                              site_exclude: Optional[Dict[str, Set[str]]]
                              = None,
                              alpha: float = 0.0,
                              latency_fn=None) -> HeuristicResult:
    """Algorithm 1 (loop oracle). `exclude[app]` = servers the app may
    not use (its primary, Eq. 4); `site_exclude[app]` = forbidden sites
    (§3.4)."""
    t0 = time.time()
    exclude = exclude or {}
    site_exclude = site_exclude or {}
    servers = cluster.alive_servers()
    view = _FreeView(servers, reserve_frac=alpha)

    # Lines 2-4: capacity ratio δ
    C = {r: sum(view.free[s.id][r] for s in servers) for r in RESOURCES}
    D = {r: sum(a.full.demand[r] for a in apps) for r in RESOURCES}
    delta = min((C[r] / D[r]) if D[r] > 0 else 1.0 for r in RESOURCES)

    def excluded_for(app: Application) -> Set[str]:
        out = {s for s in exclude.get(app.id, set()) if s}
        for site in site_exclude.get(app.id, set()):
            out |= set(cluster.sites.get(site, ()))
        return out

    assignment = {}
    unplaced: List[str] = []

    # Lines 5-6: variant pre-selection; Lines 7-12: degrade + worst-fit.
    # Apps are visited critical-first, then by request rate (ties in the
    # paper are unspecified; this ordering favors the objective).
    order = sorted(apps, key=lambda a: (not a.critical, -a.request_rate))
    start = {a.id: match(a.variants, delta) for a in apps}
    for app in order:
        placed = False
        for j in range(start[app.id], len(app.variants)):
            v = app.variants[j]
            sid = worst_fit(view, v.demand, excluded_for(app), app, v,
                            latency_fn, app.latency_slo)
            if sid is not None:
                view.take(sid, v.demand)
                assignment[app.id] = (v, sid)
                placed = True
                break
        if not placed:
            unplaced.append(app.id)

    # Lines 13-14: upgrade_model — grow where the chosen server fits more.
    for app in order:
        if app.id not in assignment:
            continue
        v_cur, sid = assignment[app.id]
        j_cur = next(n for n, v in enumerate(app.variants)
                     if v.name == v_cur.name)
        for j in range(j_cur):
            v_up = app.variants[j]
            extra = {r: v_up.demand[r] - v_cur.demand[r] for r in RESOURCES}
            if latency_fn is not None and latency_fn(
                    app, v_up, cluster.servers[sid]) > app.latency_slo:
                continue
            if all(view.free[sid][r] >= extra[r] - 1e-9 and
                   view.budget[r] >= extra[r] - 1e-9 for r in RESOURCES):
                view.give(sid, v_cur.demand)
                view.take(sid, v_up.demand)
                assignment[app.id] = (v_up, sid)
                break

    return HeuristicResult(assignment, unplaced, time.time() - t0,
                           eq1_objective(assignment, apps))
