"""Vectorized Algorithm 1 — array-backed progressive model selection.

Behavior-equivalent to the legacy loop (planner/legacy.py), asserted
bit-exactly by tests/test_planner.py, but the O(V·S) inner work per app
runs as numpy broadcasts instead of Python dict arithmetic:

  * `match` (Line 6) is one broadcast comparison over the flattened
    (A·V) x R variant-demand matrix;
  * worst-fit (Line 9) is a masked argmax over the maintained headroom
    vector (argmax's first-maximum rule reproduces the legacy loop's
    strict-improvement tie-break);
  * the upgrade pass (Lines 13-14) is one vectorized feasibility test
    per app over its larger variants.

Floating-point parity notes: totals that seed δ and the α-budget are
accumulated left-to-right in legacy order (`_ordered_sum`), tentative
takes replay the legacy give-then-take two-step, and all comparisons
use the same 1e-9 epsilon — so identical instances produce identical
assignments AND identical objective bits.

`latency_fn` (Eq. 6) is an arbitrary Python callable, so when present
its (V, S) feasibility mask is materialized once per app up front; the
placement sweep itself stays vectorized.
"""

from __future__ import annotations

import time
from typing import Dict, List, Optional, Set

import numpy as np

from repro.core.cluster import Cluster, RESOURCES
from repro.core.planner.base import HeuristicResult, eq1_objective
from repro.core.planner.state import PlannerState, _ordered_sum
from repro.core.variants import Application

_EPS = 1e-9


def _demand_matrix(app: Application) -> np.ndarray:
    # delegates to the per-app cache; kept as the module-level helper
    # other planners import
    return app.demand_matrix()


def plan_greedy(apps: List[Application], cluster: Optional[Cluster] = None,
                *,
                state: Optional[PlannerState] = None,
                exclude: Optional[Dict[str, Set[str]]] = None,
                site_exclude: Optional[Dict[str, Set[str]]] = None,
                alpha: float = 0.0,
                latency_fn=None,
                score_fn=None,
                tiebreak_fn=None,
                site_index=None) -> HeuristicResult:
    """Vectorized Algorithm 1 over a (persistent or throwaway)
    `PlannerState`.

    `score_fn(free, cap, demand, app) -> (S,)` customizes the worst-fit
    ranking (used by the load-aware policy); None means the paper's
    normalized-headroom rule. `tiebreak_fn(app, variant, server_ids) ->
    array` supplies a secondary key (lower = better, first-minimum on
    equal keys) applied among servers whose primary rank ties exactly —
    the locality policy ranks quantized headroom and tie-breaks on
    checkpoint fetch time. None (the default) keeps argmax's
    first-maximum rule, i.e. the legacy bit-exact behavior.

    `site_index` is a factory (e.g. `sharded.SiteIndex`) building a
    site-hierarchical selection structure over the alive rows; when
    given, the worst-fit argmax is answered by `index.select` (scanning
    only the top sites by maintained per-site headroom) instead of the
    full-matrix masked argmax — bit-identical winners, sublinear
    per-attempt work (see planner/sharded.py). Only valid with the
    default rank (no score/tiebreak/latency customization).
    """
    t0 = time.time()
    exclude = exclude or {}
    site_exclude = site_exclude or {}
    if state is None:
        assert cluster is not None, "need a cluster or a PlannerState"
        state = PlannerState(cluster, subscribe=False)
    if cluster is None:
        cluster = state.cluster
    state.sync()

    order = sorted(apps, key=lambda a: (not a.critical, -a.request_rate))
    rows = state.alive_rows()
    S = int(rows.size)
    if not apps or S == 0:
        assignment: Dict[str, tuple] = {}
        return HeuristicResult(assignment, [a.id for a in order],
                               time.time() - t0,
                               eq1_objective(assignment, apps))

    ids = [state.server_ids[int(i)] for i in rows]
    free = state.free[rows].copy()               # (S, R) working copy
    cap = state.capacity[rows]
    R = len(RESOURCES)

    # Lines 2-4: capacity ratio δ (ordered sums = legacy bit-parity);
    # full-size demands come from the cached per-variant vectors, and
    # _ordered_sum replays builtin sum()'s left-to-right accumulation
    full_dem = np.array([a.full.demand_vec for a in apps],
                        dtype=np.float64).reshape(len(apps), R)
    C = [_ordered_sum(free[:, j]) for j in range(R)]
    D = [_ordered_sum(full_dem[:, j]) for j in range(R)]
    delta = min((C[j] / D[j]) if D[j] > 0 else 1.0 for j in range(R))
    budget = np.array([(1.0 - alpha) * C[j] for j in range(R)],
                      dtype=np.float64)

    # per-app arrays: variant demands (cached on the Application),
    # sparse excluded-row lists (a dense (A, S) bool mask is ~1 GB at
    # 100k apps x 10k servers; exclusions are a handful of rows per
    # app), and the optional latency mask
    dm = {a.id: _demand_matrix(a) for a in apps}
    excl_rows: Dict[str, np.ndarray] = {}
    lat: Dict[str, Optional[np.ndarray]] = {}
    pos = {sid: k for k, sid in enumerate(ids)}
    servers = ([cluster.servers[sid] for sid in ids]
               if latency_fn is not None else None)
    for app in apps:
        er: List[int] = []
        for sid in exclude.get(app.id, ()):
            if sid and sid in pos:
                er.append(pos[sid])
        for site in site_exclude.get(app.id, ()):
            for sid in cluster.sites.get(site, ()):
                if sid in pos:
                    er.append(pos[sid])
        if er:
            excl_rows[app.id] = np.array(sorted(set(er)), dtype=np.int64)
        if latency_fn is None:
            lat[app.id] = None
        else:
            lt = np.array([[latency_fn(app, v, srv) for srv in servers]
                           for v in app.variants], dtype=np.float64)
            # mirror the legacy skip condition `lat > slo` exactly
            # (NaN compares False there, i.e. allowed)
            lat[app.id] = np.logical_not(lt > app.latency_slo)

    # Lines 5-6: match as ONE broadcast comparison over all variants
    start: Dict[str, int] = {}
    if delta >= 1.0:
        for app in apps:
            start[app.id] = 0
    else:
        counts = [len(a.variants) for a in apps]
        offs = np.concatenate([[0], np.cumsum(counts)])
        all_dem = np.concatenate([dm[a.id] for a in apps])     # (T, R)
        thr = np.repeat(delta * full_dem + _EPS, counts, axis=0)
        okv = (all_dem <= thr).all(axis=1)
        for k, app in enumerate(apps):
            seg = np.flatnonzero(okv[offs[k]:offs[k + 1]])
            start[app.id] = (int(seg[0]) if seg.size
                             else len(app.variants) - 1)

    assignment = {}
    chosen: Dict[str, tuple] = {}     # app -> (variant idx, server row)
    unplaced: List[str] = []
    headroom = (free / cap).min(axis=1)          # maintained per take
    sindex = None
    if site_index is not None:
        assert score_fn is None and tiebreak_fn is None \
            and latency_fn is None, \
            "site-sharded selection requires the default worst-fit rank"
        sindex = site_index(state.site_of[rows], headroom)

    # Lines 7-12: degrade + worst-fit, vectorized over servers
    for app in order:
        d_app = dm[app.id]
        er = excl_rows.get(app.id)
        lm = lat[app.id]
        placed = False
        for j in range(start[app.id], len(app.variants)):
            d = d_app[j]
            if not (budget >= d - _EPS).all():
                continue              # α-budget binds every server alike
            if sindex is not None:
                k = sindex.select(free, headroom, d, er)
                if k < 0:
                    continue
            else:
                feas = (free >= d - _EPS).all(axis=1)
                if er is not None:
                    feas[er] = False
                if lm is not None:
                    feas &= lm[j]
                if not feas.any():
                    continue
                if score_fn is None:
                    rank = headroom
                else:
                    rank = score_fn(free, cap, d, app)
                masked = np.where(feas, rank, -np.inf)
                k = int(np.argmax(masked))
                if tiebreak_fn is not None:
                    ties = np.flatnonzero(masked == masked[k])
                    if ties.size > 1:
                        tb = np.asarray(
                            tiebreak_fn(app, app.variants[j],
                                        [ids[int(t)] for t in ties]),
                            dtype=np.float64)
                        k = int(ties[int(np.argmin(tb))])
            free[k] -= d
            budget -= d
            headroom[k] = (free[k] / cap[k]).min()
            if sindex is not None:
                sindex.update(k, headroom)
            assignment[app.id] = (app.variants[j], ids[k])
            chosen[app.id] = (j, k)
            placed = True
            break
        if not placed:
            unplaced.append(app.id)

    # Lines 13-14: upgrade_model — one feasibility broadcast per app
    for app in order:
        if app.id not in assignment:
            continue
        j_cur, k = chosen[app.id]
        if j_cur == 0:
            continue
        d_app = dm[app.id]
        extras = d_app[:j_cur] - d_app[j_cur]            # (j_cur, R)
        feas = ((free[k] >= extras - _EPS).all(axis=1)
                & (budget >= extras - _EPS).all(axis=1))
        lm = lat[app.id]
        if lm is not None:
            feas &= lm[:j_cur, k]
        ups = np.flatnonzero(feas)
        if ups.size:
            j_up = int(ups[0])
            # give(current) then take(upgrade), NOT one fused delta —
            # replays the legacy float rounding exactly
            free[k] += d_app[j_cur]
            budget += d_app[j_cur]
            free[k] -= d_app[j_up]
            budget -= d_app[j_up]
            headroom[k] = (free[k] / cap[k]).min()
            if sindex is not None:
                sindex.update(k, headroom)
            assignment[app.id] = (app.variants[j_up], ids[k])
            chosen[app.id] = (j_up, k)

    return HeuristicResult(assignment, unplaced, time.time() - t0,
                           eq1_objective(assignment, apps))


def faillite_heuristic(apps: List[Application], cluster: Cluster, *,
                       exclude: Optional[Dict[str, Set[str]]] = None,
                       site_exclude: Optional[Dict[str, Set[str]]] = None,
                       alpha: float = 0.0,
                       latency_fn=None,
                       state: Optional[PlannerState] = None,
                       ) -> HeuristicResult:
    """Algorithm 1 — drop-in replacement of the legacy entry point,
    now vectorized (optionally reusing a persistent `PlannerState`)."""
    return plan_greedy(apps, cluster, state=state, exclude=exclude,
                       site_exclude=site_exclude, alpha=alpha,
                       latency_fn=latency_fn)
