"""Vectorized request workload layer — the traffic half of the
request-level traffic plane (paper §5.7: client-observed metrics).

The paper's headline numbers (175.5 ms MTTR, 0.6 % accuracy loss) are
measured at the *request* level: what clients experienced, not what the
controller recorded. This module generates per-app request streams and
tracks, for every application, the piecewise-constant serving timeline
(which variant was serving when, and when the app was blacked out), so
`core/metrics.py` can classify millions of requests after the fact.

Design for scale ("millions of users"): arrivals are generated
**per-epoch in bulk**, not per-request. A homogeneous Poisson process on
a window [t0, t1) is sampled as one `N ~ Poisson(rate * dt)` draw plus
`N` uniform order statistics — a single numpy call instead of `N`
sequential exponentials — so requests never enter the discrete-event
heap individually. The simulator schedules one *chunk* event per
`chunk_s` of sim time; each chunk reads the apps' request rates at that
instant (so `LoadSpike` multipliers and diurnal modulation are honored)
and appends one numpy array per app.

Serving timelines come from the control plane, not from the workload:
`RoutingTable` epoch bumps (observed via its `observer`/`drop_observer`
hooks) mark when a client-visible route changed, and the simulator marks
apps down at the instant their serving primary's host crashed. The
interval between those two is exactly the window a failure blacks out.

Determinism guarantee: all draws come from one `numpy` PCG64 generator
seeded from the simulation seed, and chunk events fire in deterministic
event-queue order — same seed ⇒ byte-identical per-request trace,
which `tests/test_traffic.py` asserts.

`serving/workload.py` shares this layer: its `poisson_arrivals` is a
thin wrapper over `poisson_arrival_times` for the thread-based testbed.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np

# the same notify constant the controller folds into its MTTR: the two
# metric planes must agree on it or client windows would close before
# (or after) the controller claims recovery
from repro.core.controller import NOTIFY_OVERHEAD_S
from repro.core.metrics import (AppLog, DowntimeWindow, TrafficSummary,
                                UP, DOWN, GONE, aggregate, classify_app,
                                classify_apps)
from repro.core.resilience import ResilienceConfig, shape_app_log


# ---------------------------------------------------------------------------
# vectorized arrival generation (shared with serving/workload.py)
# ---------------------------------------------------------------------------

def poisson_arrival_times(rng: np.random.Generator, rate_hz: float,
                          t0: float, t1: float) -> np.ndarray:
    """Exact homogeneous Poisson process on [t0, t1), batched.

    Draws ``N ~ Poisson(rate * (t1 - t0))`` then ``N`` uniform order
    statistics — distributionally identical to summing exponential gaps,
    but one vectorized call regardless of N.
    """
    dt = t1 - t0
    if dt <= 0.0 or rate_hz <= 0.0:
        return np.empty(0, np.float64)
    n = int(rng.poisson(rate_hz * dt))
    if n == 0:
        return np.empty(0, np.float64)
    return np.sort(rng.uniform(t0, t1, n))


def diurnal_factor(t: float, *, period: float = 240.0,
                   amplitude: float = 0.5, phase: float = 0.0) -> float:
    """Sinusoidal day/night rate modulation, >= 0."""
    return max(0.0, 1.0 + amplitude
               * math.sin(2.0 * math.pi * t / period + phase))


def diurnal_arrival_times(rng: np.random.Generator, base_rate: float,
                          t0: float, t1: float, *, period: float = 240.0,
                          amplitude: float = 0.5, phase: float = 0.0,
                          bin_s: float = 1.0) -> np.ndarray:
    """Non-homogeneous Poisson arrivals via piecewise-constant bins.

    Each bin uses the diurnal rate at its midpoint; bins are generated
    with the same batched order-statistics trick as the homogeneous case.
    """
    out: List[np.ndarray] = []
    t = t0
    while t < t1:
        te = min(t + bin_s, t1)
        rate = base_rate * diurnal_factor(0.5 * (t + te), period=period,
                                          amplitude=amplitude, phase=phase)
        out.append(poisson_arrival_times(rng, rate, t, te))
        t = te
    if not out:
        return np.empty(0, np.float64)
    return np.concatenate(out)


# ---------------------------------------------------------------------------
# traffic plane
# ---------------------------------------------------------------------------

# Registered-app count above which epoch-mode generation abandons
# per-pair RNG-stream parity for one-call bulk draws (generate_chunks).
BULK_STREAM_MIN_APPS = 4096


@dataclass(frozen=True)
class TrafficConfig:
    """Knobs of the request plane.

    ``rate_scale`` converts the paper's abstract per-app rate q_i into
    requests/s of actual traffic (sampling density); utilization and
    latency use the *logical* q_i, so scaling traffic up for tighter
    confidence intervals does not change the physics.
    """
    rate_scale: float = 20.0      # requests/s generated per unit q_i
    chunk_s: float = 0.5          # bulk-generation window (sim seconds)
    util_k: float = 2.0           # q_i * service_time -> utilization
    util_cap: float = 0.9         # clamp for the M/M/1-style factor
    jitter_sigma: float = 0.25    # lognormal service jitter
    diurnal_amplitude: float = 0.0  # 0 = plain Poisson
    diurnal_period: float = 240.0


class TrafficPlane:
    """Per-app request streams + serving timelines for one simulation.

    The simulator owns the chunk schedule and the crash hooks; the
    controller's `RoutingTable` observers feed route transitions. At the
    end of a run `summarize()` classifies every generated request
    against the recorded timelines (vectorized, in `core/metrics.py`).
    """

    def __init__(self, seed: int = 0,
                 cfg: Optional[TrafficConfig] = None,
                 resilience: Optional[ResilienceConfig] = None,
                 batch: bool = False):
        self.cfg = cfg or TrafficConfig()
        self.resilience = resilience
        self.batch = batch
        self.rng = np.random.default_rng([0x7AFF1C, seed])
        self._jitter_seed = seed
        self.n_generated = 0            # total requests drawn (bench metric)
        # epoch mode switches from the RNG-stream-exact scalar loop to
        # bulk vectorized draws above this many registered apps (see
        # generate_chunks / docs/SCALE.md); golden + parity configs are
        # far below it
        self.bulk_min_apps = BULK_STREAM_MIN_APPS
        # epoch-mode eligibility snapshot cache: bumped by the
        # simulation on app arrival/departure/spike (generate_chunks)
        self.snapshot_gen = 0
        self._snap: Optional[tuple] = None
        # per-app chunked arrival buffers + the logical rate per chunk
        # (per-event compat mode — `batch=False`)
        self._arrivals: Dict[str, List[np.ndarray]] = {}
        self._chunk_rates: Dict[str, List[Tuple[int, float]]] = {}
        # epoch mode (`batch=True`) stores requests columnar instead:
        # one (app_row, count, rate, sorted_times) quadruple per chunk,
        # where app_row indexes the registration-ordered `_reg_ids`.
        # Per-app python-list appends are the per-event path's second
        # hot loop (after RNG draws); this layout kills them.
        self._reg_ids: List[str] = []
        self._reg_idx: Dict[str, int] = {}
        self._chunks: List[Tuple[np.ndarray, np.ndarray,
                                 np.ndarray, np.ndarray]] = []
        self._last_q = np.empty(0, np.float64)   # latest rate per reg row
        self._has_q = np.empty(0, bool)
        self._ubuf = np.empty(1 << 16, np.float64)   # raw-uniform scratch
        # per-app serving timeline: (t, state, accuracy, service_time)
        self._timeline: Dict[str, List[Tuple[float, int, float, float]]] = {}
        self._full_acc: Dict[str, float] = {}
        self._slo: Dict[str, float] = {}
        self.windows: List[DowntimeWindow] = []
        self._open: Dict[str, DowntimeWindow] = {}
        # recovery-drain intervals (RecoveryScheduler.drain_observer):
        # closed [t0, t1] pairs + the currently-open drain start
        self._drains: List[Tuple[float, float]] = []
        self._drain_open: Optional[float] = None
        self._drain_depth = 0

    # -- timeline recording (control-plane hooks) ---------------------------
    def _last_t(self, app_id: str) -> float:
        tl = self._timeline.get(app_id)
        return tl[-1][0] if tl else 0.0

    def mark_up(self, app_id: str, t: float, *, accuracy: float,
                service_time: float, full_accuracy: float,
                slo: float = math.inf):
        """Route now points at a live replica serving `accuracy`.

        The first sighting registers the app (its deploy); later calls
        are failovers or progressive upgrades. Route pushes after the
        first are delayed by the client-notify overhead.
        """
        first = app_id not in self._timeline
        if first:
            self._timeline[app_id] = []
            self._arrivals[app_id] = []
            self._chunk_rates[app_id] = []
            self._full_acc[app_id] = full_accuracy
            self._slo[app_id] = slo
            self._reg_idx[app_id] = len(self._reg_ids)
            self._reg_ids.append(app_id)
        else:
            t += NOTIFY_OVERHEAD_S
        t = max(t, self._last_t(app_id))
        self._timeline[app_id].append((t, UP, accuracy, service_time))
        w = self._open.pop(app_id, None)
        if w is not None:
            w.t_end = t
            self.windows.append(w)

    def mark_down(self, app_id: str, t: float, epoch: int,
                  backup: Optional[Tuple[float, float]] = None):
        """The app's serving replica just died (crash instant, *before*
        detection): requests fail from here until the next route push.

        ``backup`` is the app's warm backup (accuracy, service_time) at
        the crash instant, when one exists and the resilience layer is
        on — hedged requests inside the window are served by it.
        """
        tl = self._timeline.get(app_id)
        if tl is None or tl[-1][1] != UP:
            return                      # unknown or already down
        t = max(t, self._last_t(app_id))
        tl.append((t, DOWN, math.nan, math.nan))
        self._open[app_id] = DowntimeWindow(app_id=app_id, epoch=epoch,
                                            t_start=t, backup=backup)

    def record_drain(self, kind: str, t: float):
        """RecoveryScheduler drain-activity hook ("start"/"end").

        Folds possibly-nested start/end pairs into flat non-overlapping
        [t0, t1] intervals; admission control thins served load inside
        them (see core/resilience.py).
        """
        if kind == "start":
            if self._drain_depth == 0:
                self._drain_open = t
            self._drain_depth += 1
        elif kind == "end":
            self._drain_depth = max(0, self._drain_depth - 1)
            if self._drain_depth == 0 and self._drain_open is not None:
                if t > self._drain_open:
                    self._drains.append((self._drain_open, t))
                self._drain_open = None

    def mark_gone(self, app_id: str, t: float):
        """App departed: requests after this instant are not offered."""
        tl = self._timeline.get(app_id)
        if tl is None or tl[-1][1] == GONE:
            return
        t = max(t, self._last_t(app_id))
        tl.append((t, GONE, math.nan, math.nan))
        w = self._open.pop(app_id, None)
        if w is not None:
            self.windows.append(w)      # never recovered (censored)

    # -- bulk generation ----------------------------------------------------
    def generate_chunk(self, apps: Iterable, t0: float, t1: float):
        """Generate [t0, t1) arrivals for every live app in one pass.

        Reads each app's *current* request_rate, so LoadSpike windows
        (which multiply the rate in place) are honored at chunk
        granularity.
        """
        cfg = self.cfg
        for app in apps:
            if app.id not in self._timeline:
                continue                # not deployed (or not routed) yet
            q = app.request_rate
            if cfg.diurnal_amplitude > 0.0:
                q *= diurnal_factor(0.5 * (t0 + t1),
                                    period=cfg.diurnal_period,
                                    amplitude=cfg.diurnal_amplitude)
            arr = poisson_arrival_times(self.rng, q * cfg.rate_scale,
                                        t0, t1)
            if arr.size:
                self._arrivals[app.id].append(arr)
                self._chunk_rates[app.id].append((arr.size, q))
                self.n_generated += arr.size

    def generate_chunks(self, apps: Iterable, spans: List[Tuple[float, float]]):
        """Epoch-mode bulk generation: fold several consecutive chunk
        windows (an event-free span between two heap events) into one
        vectorized pass. Bit-exact with calling `generate_chunk` once
        per span, proven by `tests/test_scale.py`.

        RNG-stream parity is the whole trick. The per-event path draws,
        per (chunk, app) pair, one scalar Poisson count followed
        immediately by that many uniforms — an interleaved consumption
        pattern on ONE generator that a batched poisson-array /
        uniform-array rewrite would not reproduce. The loop below keeps
        the exact per-pair draw order (scalar ``poisson``, then ``n``
        raw doubles written straight into a scratch buffer:
        ``Generator.random(out=view)`` consumes the stream identically
        to ``uniform(t0, t1, n)`` because
        ``uniform(a, b, n) == a + (b - a) * random(n)`` bitwise), and
        defers the affine [t0, t1) scaling and the per-pair sort to two
        vectorized passes per chunk — sorted values do not depend on
        which sort produced them, so one segment-keyed ``lexsort``
        replaces per-app ``np.sort`` calls.

        Rates and eligibility only change through heap events, which by
        construction never fire inside a fold, so one snapshot per call
        is safe.

        Above ``bulk_min_apps`` registered apps the per-pair scalar
        loop itself becomes the hot spot (~1 µs of mandatory Generator
        calls per (chunk, app) pair), so the plane switches to a
        bulk-stream draw: ONE vectorized ``poisson(lam_vector)`` plus
        ONE uniform block per chunk. That consumes the RNG stream in a
        different order — still the exact same Poisson-process law,
        still fully deterministic per seed, but not bitwise
        stream-compatible with the per-event drain. The control plane
        never reads the traffic plane (resilience off), so recovery
        records are unaffected either way; golden/parity configs sit
        far below the threshold and keep bit-exactness
        (docs/SCALE.md).
        """
        cfg = self.cfg
        # (rows, base) only change when an app arrives/departs/respikes
        # (simulation bumps snapshot_gen) or a new app is first routed
        # (timeline gains a key) — cache the snapshot across epochs
        key = (self.snapshot_gen, len(self._timeline))
        if self._snap is not None and self._snap[0] == key:
            rows, base = self._snap[1], self._snap[2]
        else:
            elig = [a for a in apps if a.id in self._timeline]
            rows = np.array([self._reg_idx[a.id] for a in elig], np.int64)
            base = np.array([a.request_rate for a in elig], np.float64)
            self._snap = (key, rows, base)
        if not rows.size:
            return
        m = len(self._reg_ids)
        if self._last_q.shape[0] < m:
            grow = max(m, 2 * self._last_q.shape[0])
            nq = np.zeros(grow, np.float64)
            nq[:self._last_q.shape[0]] = self._last_q
            nh = np.zeros(grow, bool)
            nh[:self._has_q.shape[0]] = self._has_q
            self._last_q, self._has_q = nq, nh
        poisson = self.rng.poisson
        draw = self.rng.random
        for t0, t1 in spans:
            dt = t1 - t0
            if dt <= 0.0:
                continue                # per-app early return: no draws
            q = base
            if cfg.diurnal_amplitude > 0.0:
                q = base * diurnal_factor(0.5 * (t0 + t1),
                                          period=cfg.diurnal_period,
                                          amplitude=cfg.diurnal_amplitude)
            # same association order as the scalar path:
            # (q * rate_scale) first, then * dt
            rate_hz = q * cfg.rate_scale
            lam = rate_hz * dt
            if rows.shape[0] >= self.bulk_min_apps:
                lam = np.where(rate_hz > 0.0, lam, 0.0)
                ns_all = poisson(lam)
                sel_a = np.flatnonzero(ns_all)
                if not sel_a.size:
                    continue
                ns = ns_all[sel_a]
                total = int(ns.sum())
                times = t0 + (t1 - t0) * draw(total)
                seg = np.repeat(np.arange(sel_a.shape[0]), ns)
                times = times[np.lexsort((times, seg))]
                kk = rows[sel_a]
                qs = q[sel_a]
                self._chunks.append((kk, ns, qs, times))
                self._last_q[kk] = qs
                self._has_q[kk] = True
                self.n_generated += total
                continue
            lam_l = lam.tolist()
            rh_l = rate_hz.tolist()
            buf = self._ubuf
            cap = buf.shape[0]
            pos = 0
            sel: List[int] = []
            cnt: List[int] = []
            for i, l in enumerate(lam_l):
                if rh_l[i] <= 0.0:
                    continue            # rate<=0: no poisson draw at all
                n = int(poisson(l))
                if n == 0:
                    continue
                end = pos + n
                if end > cap:
                    cap = max(2 * cap, end)
                    nb = np.empty(cap, np.float64)
                    nb[:pos] = buf[:pos]
                    self._ubuf = buf = nb
                draw(out=buf[pos:end])
                pos = end
                sel.append(i)
                cnt.append(n)
            if not sel:
                continue
            sel_a = np.array(sel, np.int64)
            ns = np.array(cnt, np.int64)
            times = t0 + (t1 - t0) * buf[:pos]
            seg = np.repeat(np.arange(sel_a.shape[0]), ns)
            times = times[np.lexsort((times, seg))]
            kk = rows[sel_a]
            qs = q[sel_a]
            self._chunks.append((kk, ns, qs, times))
            self._last_q[kk] = qs
            self._has_q[kk] = True
            self.n_generated += pos

    # -- live introspection (autopilot feed) --------------------------------
    def current_rates(self) -> Dict[str, float]:
        """Latest observed logical rate q_i per app (the rate the most
        recent chunk was generated at, diurnal/spike modulation
        included) — the autopilot's arrival-rate signal. Apps whose
        last chunk drew zero arrivals keep their previous observation."""
        if self.batch:
            # _reg_ids is registration order == _chunk_rates insertion
            # order, so the dict iterates identically to the dict path
            return {self._reg_ids[i]: float(self._last_q[i])
                    for i in np.flatnonzero(self._has_q[:len(self._reg_ids)])}
        return {app_id: chunks[-1][1]
                for app_id, chunks in self._chunk_rates.items() if chunks}

    def downtime_since(self, t0: float, now: float) -> Dict[str, float]:
        """Per-app client-observed downtime seconds overlapping
        [t0, now] — closed windows clipped to the horizon plus any
        still-open blackout."""
        out: Dict[str, float] = {}
        for w in self.windows:
            end = w.t_end if math.isfinite(w.t_end) else now
            overlap = min(end, now) - max(w.t_start, t0)
            if overlap > 0:
                out[w.app_id] = out.get(w.app_id, 0.0) + overlap
        for app_id, w in self._open.items():
            overlap = now - max(w.t_start, t0)
            if overlap > 0:
                out[app_id] = out.get(app_id, 0.0) + overlap
        return out

    # -- aggregation --------------------------------------------------------
    def _assemble_columnar(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Epoch-mode request store -> (reg-row, arrival, rate) triples
        sorted stably by reg-row: one concatenation plus one stable
        argsort instead of per-app list-of-chunks bookkeeping. Stability
        preserves chunk order inside each app, so the per-app slices are
        bit-identical to the per-event path's concatenations."""
        if not self._chunks:
            z = np.empty(0, np.float64)
            return np.empty(0, np.int64), z, z
        seg = np.concatenate(
            [np.repeat(kk, ns) for kk, ns, _, _ in self._chunks])
        tt = np.concatenate([t for _, _, _, t in self._chunks])
        qq = np.concatenate(
            [np.repeat(qs, ns) for _, ns, qs, _ in self._chunks])
        order = np.argsort(seg, kind="stable")
        return seg[order], tt[order], qq[order]

    def _summarize_batched(self, t_end: float,
                           windows: List[DowntimeWindow]) -> TrafficSummary:
        """Epoch-mode summarize: single vectorized classification pass
        (`classify_apps`) over all apps instead of one `classify_app`
        call per app. Per-app jitter generators and iteration order are
        identical to the per-event path, so outcomes are bit-exact."""
        seg, tt, qq = self._assemble_columnar()
        bounds = np.searchsorted(seg, np.arange(len(self._reg_ids) + 1))
        items = []
        for idx, app_id in enumerate(sorted(self._timeline)):
            k = self._reg_idx[app_id]
            lo, hi = bounds[k], bounds[k + 1]
            tl = self._timeline[app_id]
            # one (m, 4) conversion instead of four per-app listcomps;
            # states round-trip float64 exactly (small ints)
            ta = np.array(tl, np.float64)
            items.append((
                app_id, tt[lo:hi], qq[lo:hi],
                ta[:, 0], ta[:, 1].astype(np.int8), ta[:, 2], ta[:, 3],
                self._full_acc[app_id], self._slo[app_id],
                np.random.default_rng([0x1A7E, self._jitter_seed, idx])))
        logs = classify_apps(items, jitter_sigma=self.cfg.jitter_sigma,
                             util_k=self.cfg.util_k,
                             util_cap=self.cfg.util_cap)
        if self.resilience is not None:
            drains = list(self._drains)
            if self._drain_open is not None and t_end > self._drain_open:
                drains.append((self._drain_open, t_end))
            logs = [shape_app_log(
                        log, it[2], times=it[3], states=it[4], accs=it[5],
                        svcs=it[6], windows=windows, drains=drains,
                        full_accuracy=it[7], slo=it[8],
                        util_k=self.cfg.util_k, util_cap=self.cfg.util_cap,
                        rcfg=self.resilience)
                    for log, it in zip(logs, items)]
        return aggregate(logs, windows, t_end)

    def summarize(self, t_end: float) -> TrafficSummary:
        """Classify every request against its app's timeline and fold
        the outcomes into a `TrafficSummary` (see core/metrics.py)."""
        logs: List[AppLog] = []
        windows = list(self.windows) + list(self._open.values())
        if self.batch:
            return self._summarize_batched(t_end, windows)
        for idx, app_id in enumerate(sorted(self._timeline)):
            chunks = self._arrivals[app_id]
            arrivals = (np.concatenate(chunks) if chunks
                        else np.empty(0, np.float64))
            rates = (np.concatenate(
                [np.full(n, q) for n, q in self._chunk_rates[app_id]])
                if chunks else np.empty(0, np.float64))
            tl = self._timeline[app_id]
            times = np.array([e[0] for e in tl])
            states = np.array([e[1] for e in tl], np.int8)
            accs = np.array([e[2] for e in tl])
            svcs = np.array([e[3] for e in tl])
            jitter_rng = np.random.default_rng(
                [0x1A7E, self._jitter_seed, idx])
            log = classify_app(
                app_id, arrivals, rates, times, states, accs, svcs,
                full_accuracy=self._full_acc[app_id],
                slo=self._slo[app_id],
                jitter_rng=jitter_rng,
                jitter_sigma=self.cfg.jitter_sigma,
                util_k=self.cfg.util_k, util_cap=self.cfg.util_cap)
            if self.resilience is not None:
                drains = list(self._drains)
                if self._drain_open is not None and t_end > self._drain_open:
                    drains.append((self._drain_open, t_end))
                log = shape_app_log(
                    log, rates, times=times, states=states, accs=accs,
                    svcs=svcs, windows=windows, drains=drains,
                    full_accuracy=self._full_acc[app_id],
                    slo=self._slo[app_id], util_k=self.cfg.util_k,
                    util_cap=self.cfg.util_cap, rcfg=self.resilience)
            logs.append(log)
        return aggregate(logs, windows, t_end)
