"""Shard-aware failure plane: tensor-parallel deployments as
first-class failure-domain objects.

FailLite's failure model (and this repo's reproduction of it through
PR 8) treats a model instance as atomic: a server dies, the whole
replica dies, recovery means loading a (smaller) variant elsewhere.
Modern LLM serving is tensor-parallel: one deployment spans k servers,
each holding 1/k of the weights, and one host failing kills only a
*shard* of a live group. This module makes that first-class:

* **`ShardGroup`** — one app deployed TP-k across k distinct servers
  (co-site preferred, `PlannerState.place_group`). Each member holds a
  *slice variant* (`<full>::shard<r>of<k>`: 1/k of the bytes and
  FLOPs) whose checkpoint slice has its own residency and fetch path
  in the model-state plane, so a reshard refetch is priced as slice
  bytes — not the whole monolith.
* **`ShardGroupManager`** — the controller-side plane. On a member
  loss (a `ShardFail` or any crash of a member host) it walks a
  recovery ladder chosen per-app by criticality:

    (a) degraded-TP continuation (KevlarFlow-style): the surviving
        k-1 shards keep serving immediately at reduced throughput and
        slightly reduced accuracy — a synthetic degraded variant
        (`<full>::tp<k-1>of<k>`) is synthesized from the group and
        routed without any blackout for the clients;
    (b) reshard onto survivors (FailSafe-style): a replacement server
        refetches the lost slice through the RecoveryScheduler and
        the contention-aware load engine, then pays an explicit
        *repartition* phase (survivors re-shuffle their partitions),
        restoring full TP-k;
    (c) monolith fallback: the group dissolves and the app takes
        today's progressive-failover path (smallest variant first).

  Every action lands in the controller's normal `RecoveryRecord`
  stream (modes ``shard-degrade`` / ``shard-reshard``; fallback keeps
  the cold/cold-progressive modes) with the standard MTTR phase
  decomposition plus a new ``repartition`` phase.

The plane is strictly additive: with ``tp_degree=1`` (the default) no
manager is constructed, no code path below runs, and every pinned
golden fingerprint is bit-exact.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Set

from repro.core.controller import NOTIFY_OVERHEAD_S, RecoveryRecord
from repro.core.variants import Application, LOAD_BW, Variant

SHARD_POLICIES = ("auto", "degrade", "reshard", "monolith")

# degraded-TP continuation: re-planning the parallelism over the
# survivors (no bytes move — KevlarFlow skips the lost partition)
DEGRADE_REPARTITION_S = 0.025
# accuracy discount per lost-shard fraction: serving with k-1 of k
# partitions drops quality a little, far less than a smaller monolith
DEGRADE_ACC_PENALTY = 0.04
# reshard repartition: survivors re-shuffle ~this fraction of the
# replaced slice's bytes through the disk path (all-gather style),
# plus a fixed re-plan cost
RESHARD_REPARTITION_FRAC = 0.5
REPARTITION_BASE_S = 0.010


def slice_name(variant: Variant, rank: int, k: int) -> str:
    return f"{variant.name}::shard{rank}of{k}"


def degraded_name(variant: Variant, k_alive: int, k: int) -> str:
    return f"{variant.name}::tp{k_alive}of{k}"


@dataclass
class Member:
    """One shard-group member: a slice instance on one server."""
    rank: int
    server_id: str
    key: str                      # cluster instance key


@dataclass
class ShardGroup:
    """One TP-k deployment. `state` is the group lifecycle:

        live        exactly k members, serving the full variant
        degraded    k-1 members continue serving (synthetic variant)
        resharding  k-1 members + one replacement slice in flight
        fallen-back dissolved; the app is an ordinary monolith again
    """
    app_id: str
    tp_degree: int
    base: Variant                          # the full variant sharded
    policy: str                            # degrade|reshard|monolith
    members: Dict[int, Member] = field(default_factory=dict)
    state: str = "live"
    pending: Optional[Member] = None       # reshard target in flight

    @property
    def lead(self) -> Member:
        return self.members[min(self.members)]


class ShardGroupManager:
    """Controller-side shard plane (see module docstring).

    `defer(dt, fn)` schedules work `dt` sim-seconds ahead (the
    simulator wires its event queue; the testbed wires a timer); when
    None, deferred work applies immediately and only the recorded MTTR
    carries the repartition time.
    """

    def __init__(self, controller, *, tp_degree: int,
                 policy: str = "auto",
                 defer: Optional[Callable[[float, Callable], None]] = None):
        assert tp_degree >= 2, tp_degree
        assert policy in SHARD_POLICIES, policy
        self.controller = controller
        self.tp_degree = tp_degree
        self.policy = policy
        self.defer = defer
        self.groups: Dict[str, ShardGroup] = {}
        # synthesized (degraded) variants by name: these are routing
        # objects only — never appended to app.variants, which would
        # corrupt `app.smallest` and the cached demand matrices
        self._synth: Dict[str, Variant] = {}
        # (action, RecoveryRecord) pairs; records fill in async, so
        # summary() reads them lazily at end of run
        self._log: List[tuple] = []
        # reshard repartition calibration (testbed-measured scale on
        # the modeled byte-shuffle cost)
        self.repartition_scale = 1.0
        controller.attach_shard_manager(self)

    # -- variant synthesis ---------------------------------------------------
    def slice_variant(self, base: Variant, rank: int) -> Variant:
        k = self.tp_degree
        return Variant(name=slice_name(base, rank, k), family=base.family,
                       mem_bytes=base.mem_bytes / k,
                       compute=base.compute / k,
                       accuracy=base.accuracy,
                       quant_bits=base.quant_bits)

    def degraded_variant(self, base: Variant, k_alive: int) -> Variant:
        """KevlarFlow-style continuation variant: the surviving k_alive
        of k partitions serve with proportionally less parallelism
        (service time scales k/k_alive) and a small accuracy discount
        for the skipped partition."""
        k = self.tp_degree
        name = degraded_name(base, k_alive, k)
        v = self._synth.get(name)
        if v is None:
            lost_frac = (k - k_alive) / k
            v = Variant(name=name, family=base.family,
                        mem_bytes=base.mem_bytes * k_alive / k,
                        compute=base.compute * k / k_alive,
                        accuracy=base.accuracy
                        * (1.0 - DEGRADE_ACC_PENALTY * lost_frac),
                        quant_bits=base.quant_bits)
            self._synth[name] = v
        return v

    def lookup_variant(self, name: str) -> Optional[Variant]:
        """Side-table lookup for synthesized variant names (the traffic
        plane's route observer falls back to this when
        `app.variant_by_name` misses)."""
        return self._synth.get(name)

    # -- queries -------------------------------------------------------------
    def is_grouped(self, app_id: str) -> bool:
        """True while the app is shard-protected (a fallen-back group
        is an ordinary monolith again and re-enters warm planning)."""
        g = self.groups.get(app_id)
        return g is not None and g.state != "fallen-back"

    def _resolve_policy(self, app: Application) -> str:
        if self.policy != "auto":
            return self.policy
        # criticality ladder: critical apps must not go dark -> degrade
        # and keep serving; the rest restore full quality via reshard
        return "degrade" if app.critical else "reshard"

    def _can_degrade(self, g: ShardGroup, lost_ranks: List[int],
                     pending_dead: bool) -> bool:
        """Single member lost from a live degrade-policy group: the
        survivors continue (KevlarFlow tolerates one missing
        partition; a second loss falls through to monolith)."""
        return (g.state == "live" and g.policy == "degrade"
                and len(lost_ranks) == 1 and not pending_dead
                and len(g.members) - 1 >= 1)

    def _seamless(self, g: ShardGroup, lost_ranks: List[int],
                  pending_dead: bool) -> bool:
        """Does this loss continue serving with zero client blackout?
        Degraded continuation of a NON-lead member: the routed lead
        survives and keeps answering. A lead loss still degrades, but
        clients see the gap until the route flips to a survivor. Must
        be decidable at crash time — `darkened_by` and `handle_lost`
        agree through this."""
        return (self._can_degrade(g, lost_ranks, pending_dead)
                and min(g.members) not in lost_ranks)

    def darkened_by(self, failed_set: Set[str]) -> Set[str]:
        """App ids that go dark for clients when `failed_set` crashes:
        every affected group EXCEPT a seamless degrade of a non-lead
        member (survivors keep answering on the routed lead). The
        simulator calls this at the crash instant to open downtime
        windows for shard losses whose route still points at a live
        lead."""
        out: Set[str] = set()
        for gid, g in self.groups.items():
            if g.state == "fallen-back":
                continue
            lost = [r for r, m in g.members.items()
                    if m.server_id in failed_set]
            pending_dead = (g.pending is not None
                            and g.pending.server_id in failed_set)
            if not lost and not pending_dead:
                continue
            if not self._seamless(g, lost, pending_dead):
                out.add(gid)
        return out

    # -- deployment ----------------------------------------------------------
    def deploy_group(self, app: Application) -> List[str]:
        """Deploy `app` as a TP-k group: k distinct servers (co-site
        preferred), one slice instance each, slice checkpoints staged,
        route on the rank-0 lead. Raises ValueError when no k-server
        placement exists (mirrors `deploy_primary`)."""
        ctl = self.controller
        k = self.tp_degree
        probe = self.slice_variant(app.full, 0)
        sids = ctl.state.place_group(probe.demand_vec, k)
        if sids is None:
            raise ValueError(f"no {k}-server placement for group "
                             f"of {app.id}")
        members: Dict[int, Member] = {}
        for rank, sid in enumerate(sids):
            sv = self.slice_variant(app.full, rank)
            key = ctl.cluster.place(app.id, sv, sid, "shard")
            members[rank] = Member(rank, sid, key)
            if ctl.registry is not None:
                ctl.registry.stage(sv.name, sid)
        # register only after every slice placed (mirror deploy_primary)
        ctl.apps[app.id] = app
        ctl._reg_seq[app.id] = next(ctl._reg_counter)
        ctl.primaries[app.id] = sids[0]
        ctl.routing.set(app.id, sids[0], app.full.name)
        ctl.ds.put(f"primary/{app.id}",
                   {"server": sids[0], "variant": app.full.name,
                    "tp_degree": k, "members": list(sids)})
        self.groups[app.id] = ShardGroup(
            app_id=app.id, tp_degree=k, base=app.full,
            policy=self._resolve_policy(app), members=members)
        return sids

    def forget(self, app_id: str):
        """App departed: drop its group (instances are released by
        `cluster.remove_app`)."""
        self.groups.pop(app_id, None)

    # -- failure handling ----------------------------------------------------
    def handle_lost(self, failed_set: Set[str], t_fail: float,
                    t_detect: float) -> Dict[str, RecoveryRecord]:
        """Walk every group hit by this epoch's crashed servers through
        the recovery ladder. Called by `handle_failures` before the
        warm/cold split; returns the grouped apps' records."""
        ctl = self.controller
        records: Dict[str, RecoveryRecord] = {}
        for gid, g in self.groups.items():
            if g.state == "fallen-back":
                continue
            lost_ranks = [r for r, m in g.members.items()
                          if m.server_id in failed_set]
            pending_dead = (g.pending is not None
                            and g.pending.server_id in failed_set)
            if not lost_ranks and not pending_dead:
                continue
            app = ctl.apps.get(gid)
            if app is None:
                continue
            can_degrade = self._can_degrade(g, lost_ranks, pending_dead)
            ctl._bump(gid)                 # void stale load callbacks
            ctl._unrecovered.pop(gid, None)
            for r in lost_ranks:
                del g.members[r]
            if pending_dead:
                g.pending = None
            can_reshard = (g.state == "live" and g.policy == "reshard"
                           and len(lost_ranks) == 1 and not pending_dead
                           and len(g.members) >= 1)
            if can_degrade:
                records[gid] = self._degrade(g, app, t_fail, t_detect)
            elif can_reshard:
                records[gid] = self._reshard(g, app, lost_ranks[0],
                                             failed_set, t_fail, t_detect)
            else:
                records[gid] = self._fallback(g, app, t_fail, t_detect)
        return records

    # -- ladder rung (a): degraded-TP continuation ---------------------------
    def _degrade(self, g: ShardGroup, app: Application, t_fail: float,
                 t_detect: float) -> RecoveryRecord:
        ctl = self.controller
        dv = self.degraded_variant(g.base, len(g.members))
        lead = g.lead
        ctl.primaries[app.id] = lead.server_id
        ctl.routing.set(app.id, lead.server_id, dv.name)
        ctl.ds.put(f"primary/{app.id}",
                   {"server": lead.server_id, "variant": dv.name,
                    "tp_degree": g.tp_degree,
                    "members": [m.server_id
                                for m in g.members.values()]})
        g.state = "degraded"
        mttr = ((t_detect - t_fail) + DEGRADE_REPARTITION_S
                + NOTIFY_OVERHEAD_S)
        rec = RecoveryRecord(app.id, True, mttr, dv.name, dv.accuracy,
                             "shard-degrade")
        rec.phases = {"detect": t_detect - t_fail,
                      "repartition": DEGRADE_REPARTITION_S,
                      "route": NOTIFY_OVERHEAD_S}
        self._log.append(("shard-degrade", rec))
        return rec

    # -- ladder rung (b): reshard onto survivors -----------------------------
    def _disk_bw(self) -> float:
        reg = self.controller.registry
        return reg.storage.disk_bw if reg is not None else LOAD_BW

    def repartition_seconds(self, sv: Variant, k_alive: int) -> float:
        """Reshard repartition cost: survivors re-shuffle a fraction of
        the replaced slice's bytes (all-gather style) through the disk
        path, scaled by the testbed-calibrated factor."""
        del k_alive
        return (REPARTITION_BASE_S + self.repartition_scale
                * RESHARD_REPARTITION_FRAC * sv.mem_bytes
                / self._disk_bw())

    def calibrate_repartition(self, measured_s: float,
                              slice_bytes: float, ewma: float = 0.3):
        """Fold one testbed-measured repartition wall time into the
        modeled cost (EWMA on the scale factor, like LoadCostModel)."""
        modeled = (RESHARD_REPARTITION_FRAC * slice_bytes
                   / self._disk_bw())
        if modeled <= 0 or measured_s <= 0:
            return
        obs = max(measured_s - REPARTITION_BASE_S, 0.0) / modeled
        self.repartition_scale = ((1 - ewma) * self.repartition_scale
                                  + ewma * obs)

    def _after_repartition(self, g: ShardGroup, sv: Variant,
                           repart_s: float, finish: Callable[[], None]):
        """Apply the repartition phase then commit the reshard. The sim
        defers `finish` by the MODELED cost; the testbed subclass
        overrides this to do the real work (re-gather the slices and
        rebuild the serving engine) and commit when it actually
        finishes, feeding the measured wall time back into
        `calibrate_repartition`."""
        del g, sv
        if self.defer is not None and repart_s > 0:
            self.defer(repart_s, finish)
        else:
            finish()

    def _reshard(self, g: ShardGroup, app: Application, rank: int,
                 failed_set: Set[str], t_fail: float,
                 t_detect: float) -> RecoveryRecord:
        ctl = self.controller
        sv = self.slice_variant(g.base, rank)
        excl = ({m.server_id for m in g.members.values()}
                | set(failed_set))
        sid = ctl.state.worst_fit(sv.demand_vec, excluded=excl)
        if sid is None:
            return self._fallback(g, app, t_fail, t_detect)
        try:
            key = ctl.cluster.place(app.id, sv, sid, "loading",
                                    ready=False)
        except ValueError:
            return self._fallback(g, app, t_fail, t_detect)
        g.state = "resharding"
        g.pending = Member(rank, sid, key)
        rec = RecoveryRecord(app.id, False)
        gen = ctl._gen.get(app.id, 0)
        plan_s = ctl._last_plan_wall

        def _stale() -> bool:
            return (ctl._gen.get(app.id, 0) != gen
                    or app.id not in ctl.apps
                    or not ctl.cluster.servers[sid].alive
                    or g.pending is None or g.pending.key != key)

        def on_slice_ready(t_ready: float):
            if _stale():
                return
            repart = self.repartition_seconds(sv, len(g.members))

            def finish():
                if _stale():
                    return
                inst = ctl.cluster.servers[sid].instances.get(key)
                if inst is not None:
                    inst.role = "shard"
                    inst.ready = True
                g.members[rank] = g.pending
                g.pending = None
                g.state = "live"
                lead = g.lead
                ctl.primaries[app.id] = lead.server_id
                ctl.routing.set(app.id, lead.server_id, g.base.name)
                rec.recovered = True
                rec.mttr = ((t_detect - t_fail) + (t_ready - t_detect)
                            + repart + NOTIFY_OVERHEAD_S)
                rec.variant = g.base.name
                rec.accuracy = g.base.accuracy
                rec.mode = "shard-reshard"
                rec.phases = {"detect": t_detect - t_fail,
                              "plan": plan_s,
                              "repartition": repart,
                              "route": NOTIFY_OVERHEAD_S}
                ticket = handle.ticket
                if ticket is not None:
                    rec.source = ticket.source
                    rec.phases.update(queue=ticket.queue_s,
                                      fetch=ticket.fetch_s,
                                      warmup=ticket.warmup_s)
                ctl.ds.put(f"primary/{app.id}",
                           {"server": lead.server_id,
                            "variant": g.base.name,
                            "tp_degree": g.tp_degree,
                            "members": [m.server_id
                                        for m in g.members.values()]})

            self._after_repartition(g, sv, repart, finish)

        handle = ctl.scheduler.submit(app, sv, sid, on_slice_ready)
        self._log.append(("shard-reshard", rec))
        return rec

    # -- ladder rung (c): monolith fallback ----------------------------------
    def _fallback(self, g: ShardGroup, app: Application, t_fail: float,
                  t_detect: float) -> RecoveryRecord:
        """Dissolve the group and take today's progressive path. The
        app re-enters normal (warm-backup) protection from here on."""
        ctl = self.controller
        for m in list(g.members.values()):
            srv = ctl.cluster.servers.get(m.server_id)
            if (srv is not None and srv.alive
                    and m.key in srv.instances):
                ctl.cluster.remove(m.key, m.server_id)
        g.members.clear()
        if g.pending is not None:
            srv = ctl.cluster.servers.get(g.pending.server_id)
            if (srv is not None and srv.alive
                    and g.pending.key in srv.instances):
                ctl.cluster.remove(g.pending.key, g.pending.server_id)
            g.pending = None
        g.state = "fallen-back"
        # The dissolved group has no serving primary anymore (the lead's
        # gathered engine is gone); a stale entry would make the planner
        # anti-affinity exclude the surviving lead's server — fatal when
        # it is the only capacity left (mirrors handle_failures).
        ctl.primaries.pop(app.id, None)
        if ctl._is_warm_candidate(app):
            ctl._warm_missing.add(app.id)
        recs = ctl._progressive([app], t_fail, t_detect)
        rec = recs[app.id]
        self._log.append(("shard-monolith", rec))
        return rec

    # -- invariants + reporting ----------------------------------------------
    def check_conservation(self):
        """Shard-group conservation invariant (the property test's
        oracle): every group is in exactly one lifecycle state and its
        member count matches that state."""
        k = self.tp_degree
        for gid, g in self.groups.items():
            assert g.state in ("live", "degraded", "resharding",
                               "fallen-back"), (gid, g.state)
            n = len(g.members)
            if g.state == "live":
                assert n == k and g.pending is None, (gid, n)
            elif g.state == "degraded":
                assert 1 <= n < k and g.pending is None, (gid, n)
            elif g.state == "resharding":
                assert 1 <= n < k and g.pending is not None, (gid, n)
            else:                                    # fallen-back
                assert n == 0 and g.pending is None, (gid, n)

    def summary(self) -> dict:
        states: Dict[str, int] = {}
        for g in self.groups.values():
            states[g.state] = states.get(g.state, 0) + 1
        actions: Dict[str, int] = {}
        mttrs: Dict[str, List[float]] = {}
        for action, rec in self._log:
            actions[action] = actions.get(action, 0) + 1
            if rec.recovered and math.isfinite(rec.mttr):
                mttrs.setdefault(action, []).append(rec.mttr)
        return {
            "tp_degree": self.tp_degree,
            "policy": self.policy,
            "n_groups": len(self.groups),
            "states": states,
            "actions": actions,
            "mttr_avg_s": {a: sum(v) / len(v)
                           for a, v in mttrs.items() if v},
            "repartition_scale": self.repartition_scale,
        }
