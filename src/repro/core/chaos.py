"""Seeded randomized chaos streams — always-on failure churn.

The named scenario library (core/scenario.py) replays *curated* fault
sequences; the soak harness (tools/soak.py) needs *generated* ones:
long randomized churn streams that compose the whole event vocabulary —
server crashes with staggered rejoins, site blackouts, load spikes, and
link degrades — so the adaptive-protection loop is exercised against
faults nobody hand-picked.

`chaos_events()` draws a marked Poisson process over the stream
duration: event epochs arrive with exponential gaps, each epoch rolls
one event kind from `ChaosConfig`'s mixture weights. The generator
tracks which servers are down (every crash schedules its own rejoin)
and refuses to take the cluster below `1 - max_down_frac` alive — a
chaos stream must stress recovery, not make recovery impossible.

Everything derives from the `random.Random` handed in, so the same
(cluster, seed) yields the same stream — `Scenario` determinism and
`ScenarioResult.fingerprint()` reproducibility hold exactly as for the
curated library. The stream registers as the named scenario
``"chaos"`` (excluded from the pre-model-state golden-fingerprint set,
like ``cold-load-storm``).
"""

from __future__ import annotations

import random
from dataclasses import dataclass
from typing import List

from repro.core.cluster import Cluster
from repro.core.scenario import (LinkDegrade, LoadSpike, Scenario,
                                 ScenarioEvent, ServerFail, ServerRejoin,
                                 ShardFail, SiteFail)


@dataclass(frozen=True)
class ChaosConfig:
    """Shape of one chaos stream. The four kind weights form a mixture
    (they need not sum to 1; they are normalized)."""
    duration: float = 90.0        # event-injection window (sim s)
    t0: float = 1.0               # first possible event time
    mean_gap_s: float = 7.0       # exponential gap between event epochs
    w_server_fail: float = 0.45
    w_site_fail: float = 0.08
    w_spike: float = 0.22
    w_link_degrade: float = 0.25
    # shard-host kills (ShardFail). 0.0 by default so every existing
    # chaos stream is bit-identical; raise it on tp_degree>=2 configs
    # to fold shard failures into the soak mixture.
    w_shard_fail: float = 0.0
    rejoin_min_s: float = 6.0     # crash downtime bounds
    rejoin_max_s: float = 18.0
    site_stagger_s: float = 2.0   # extra rejoin delay per site member
    spike_lo: float = 2.0         # LoadSpike factor bounds
    spike_hi: float = 4.0
    spike_duration_s: float = 6.0
    degrade_lo: float = 0.3       # LinkDegrade factor bounds
    degrade_hi: float = 0.7
    degrade_duration_s: float = 12.0
    max_down_frac: float = 0.4    # never take > this fraction down


def chaos_events(cluster: Cluster, rng: random.Random,
                 cfg: ChaosConfig = ChaosConfig()) -> List[ScenarioEvent]:
    """One randomized churn stream over `cluster`, seeded by `rng`."""
    weights = (cfg.w_server_fail, cfg.w_site_fail, cfg.w_spike,
               cfg.w_link_degrade, cfg.w_shard_fail)
    total_w = sum(weights)
    events: List[ScenarioEvent] = []
    down_until = {sid: 0.0 for sid in cluster.servers}
    n_servers = len(cluster.servers)
    max_down = cfg.max_down_frac * n_servers
    t = cfg.t0
    while True:
        t += rng.expovariate(1.0 / cfg.mean_gap_s)
        if t >= cfg.t0 + cfg.duration:
            break
        alive = [sid for sid in sorted(cluster.servers)
                 if down_until[sid] <= t]
        n_down = n_servers - len(alive)
        roll = rng.random() * total_w
        if roll < weights[0]:                          # server crash
            if not alive or n_down + 1 > max_down:
                continue
            sid = rng.choice(alive)
            dt = rng.uniform(cfg.rejoin_min_s, cfg.rejoin_max_s)
            events.append(ServerFail(t=t, server=sid))
            events.append(ServerRejoin(t=t + dt, server=sid))
            down_until[sid] = t + dt
        elif roll < weights[0] + weights[1]:           # site blackout
            site = rng.choice(sorted(cluster.sites))
            members = [sid for sid in cluster.sites[site]
                       if down_until[sid] <= t]
            if not members or n_down + len(members) > max_down:
                continue
            events.append(SiteFail(t=t, site=site))
            base = rng.uniform(cfg.rejoin_min_s, cfg.rejoin_max_s)
            for k, sid in enumerate(members):
                dt = base + k * cfg.site_stagger_s
                events.append(ServerRejoin(t=t + dt, server=sid))
                down_until[sid] = t + dt
        elif roll < weights[0] + weights[1] + weights[2]:   # load spike
            events.append(LoadSpike(
                t=t, factor=rng.uniform(cfg.spike_lo, cfg.spike_hi),
                duration=cfg.spike_duration_s))
        elif roll < (weights[0] + weights[1] + weights[2]
                     + weights[3]):                    # link degrade
            if rng.random() < 0.5:
                link = "cloud"
            else:
                link = f"nic:{rng.choice(sorted(cluster.servers))}"
            events.append(LinkDegrade(
                t=t, link=link,
                factor=rng.uniform(cfg.degrade_lo, cfg.degrade_hi),
                duration=cfg.degrade_duration_s))
        else:                                          # shard-host kill
            # only reachable when w_shard_fail > 0 (roll < total_w);
            # same crash/rejoin bookkeeping as a server crash
            if not alive or n_down + 1 > max_down:
                continue
            sid = rng.choice(alive)
            dt = rng.uniform(cfg.rejoin_min_s, cfg.rejoin_max_s)
            events.append(ShardFail(t=t, server=sid))
            events.append(ServerRejoin(t=t + dt, server=sid))
            down_until[sid] = t + dt
    return events


def build_chaos(cluster: Cluster, rng: random.Random,
                cfg: ChaosConfig = ChaosConfig(),
                name: str = "chaos") -> Scenario:
    """A chaos stream as a `Scenario`, with at least one failure: a
    stream that happened to roll only spikes/degrades would make the
    soak's recovery metrics vacuous, so a deterministic fallback crash
    is injected."""
    events = chaos_events(cluster, rng, cfg)
    if not any(isinstance(e, (ServerFail, SiteFail, ShardFail))
               for e in events):
        sid = sorted(cluster.servers)[0]
        events.append(ServerFail(t=cfg.t0, server=sid))
        events.append(ServerRejoin(t=cfg.t0 + cfg.rejoin_min_s,
                                   server=sid))
    horizon = max(e.t + getattr(e, "duration", 0.0) for e in events) + 5.0
    return Scenario(
        name=name, events=events, horizon=horizon,
        description="seeded randomized churn: crashes with staggered "
                    "rejoins, site blackouts, load spikes, and link "
                    "degrades drawn from a marked Poisson process")
