"""Heterogeneous variant ladders — FailLite's core object.

Every served architecture derives a ladder of smaller variants (width-
scaled, depth-scaled, weight-only int8) with profiled memory, compute
cost, normalized accuracy, and load time.  The accuracy proxy is
calibrated to the paper's Fig. 2a shape: accuracy falls very slowly as
capacity shrinks (ConvNeXt-T is 5.1x smaller than -L for -1.89%:
a = ratio^k with k ≈ 0.012); quantization adds a small constant penalty
(int8 ≈ -0.3%, cf. the quantization literature the paper cites).

Load time follows Fig. 2b: bytes / (host->HBM bandwidth) + warmup.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Optional

import numpy as np

from repro.models.config import ModelConfig

ACC_EXP = 0.012          # Fig 2a calibration: acc = capacity_ratio ** k
INT8_PENALTY = 0.003
LOAD_BW = 8e9            # bytes/s host->HBM (profiled on testbed, see fig2)
WARMUP_S = 0.040         # per-instance compile/alloc warmup


@dataclass(frozen=True)
class Variant:
    name: str
    family: str                   # app/model family id (arch name)
    mem_bytes: float              # accelerator-resident bytes
    compute: float                # fraction of a cell's compute at rate q=1
    accuracy: float               # normalized to the family's full model
    quant_bits: int = 16
    width_mult: float = 1.0
    depth_mult: float = 1.0
    config: Optional[ModelConfig] = None

    @property
    def demand(self) -> Dict[str, float]:
        return {"mem": self.mem_bytes, "compute": self.compute}

    @property
    def demand_vec(self):
        """Cached demand vector in `cluster.RESOURCES` order
        (("mem", "compute") — asserted by tests/test_scale.py).

        `demand` builds a fresh dict per access, and the planner's
        worst-fit rebuilt an array from it once per placement attempt
        on the failover hot path; this caches the array on the frozen
        instance instead. Variants are immutable, so the cache can
        never go stale — treat the returned array as read-only."""
        v = self.__dict__.get("_demand_vec")
        if v is None:
            v = np.array([self.mem_bytes, self.compute], np.float64)
            object.__setattr__(self, "_demand_vec", v)
        return v

    def load_time(self, bw: float = LOAD_BW) -> float:
        return self.mem_bytes / bw + WARMUP_S


def _scaled_config(cfg: ModelConfig, width: float, depth: float,
                   bits: int) -> ModelConfig:
    def r8(x, m):     # round to multiple of m, >= m
        return max(m, int(round(x / m)) * m)

    d = r8(cfg.d_model * width, 64)
    heads = max(1, int(round(cfg.num_heads * width))) if cfg.num_heads else 0
    kvh = max(1, min(cfg.num_kv_heads, heads)) if cfg.num_kv_heads else 0
    if heads and cfg.num_kv_heads:
        kvh = max(1, int(round(cfg.num_kv_heads * width)))
    plen = len(cfg.block_pattern)
    layers = max(plen, int(round(cfg.num_layers * depth / plen)) * plen)
    kw = dict(
        num_layers=layers,
        d_model=d,
        num_heads=heads,
        num_kv_heads=kvh,
        d_ff=r8(cfg.d_ff * width, 64),
        rnn_width=r8(cfg.rnn_width * width, cfg.rnn_blocks * 8)
        if cfg.rnn_width else 0,
        quant_bits=bits,
        width_mult=width,
        depth_mult=depth,
    )
    if cfg.num_experts:
        kw["moe_d_ff"] = r8(cfg.moe_d_ff * width, 64)
        kw["num_experts"] = max(cfg.top_k,
                                int(round(cfg.num_experts * width)))
    if cfg.dense_residual_d_ff:
        kw["dense_residual_d_ff"] = r8(cfg.dense_residual_d_ff * width, 64)
    if cfg.is_encoder_decoder:
        kw["num_encoder_layers"] = max(1, int(round(
            cfg.num_encoder_layers * depth)))
        kw["num_decoder_layers"] = max(1, int(round(
            cfg.num_decoder_layers * depth)))
        kw["num_layers"] = kw["num_encoder_layers"] + kw["num_decoder_layers"]
    return cfg.replace(**kw)


# ladder steps: (tag, width, depth, bits)
LADDER_STEPS = [
    ("full", 1.0, 1.0, 16),
    ("w075", 0.75, 1.0, 16),
    ("w050", 0.5, 1.0, 16),
    ("d050", 1.0, 0.5, 16),
    ("int8", 1.0, 1.0, 8),
    ("w050-int8", 0.5, 1.0, 8),
    ("w025", 0.25, 1.0, 16),
]


def build_ladder(cfg: ModelConfig, *, cell_mem: float = 16e9,
                 cell_flops: float = 197e12) -> List[Variant]:
    """Variant ladder for one architecture, largest to smallest."""
    full_active = None
    out = []
    for tag, w, dpt, bits in LADDER_STEPS:
        vcfg = _scaled_config(cfg, w, dpt, bits)
        mem = vcfg.param_bytes() * 1.15          # +15% runtime buffers
        active = vcfg.active_param_count()
        if full_active is None:
            full_active = active
        ratio = active / full_active
        acc = ratio ** ACC_EXP
        if bits == 8:
            acc -= INT8_PENALTY
        compute = 2.0 * active / cell_flops      # cell-seconds per token
        out.append(Variant(
            name=f"{cfg.name}:{tag}", family=cfg.name, mem_bytes=mem,
            compute=compute * 1e3,               # per 1k req/s unit rate
            accuracy=acc, quant_bits=bits, width_mult=w, depth_mult=dpt,
            config=vcfg))
    out.sort(key=lambda v: -v.mem_bytes)
    return out


@dataclass
class Application:
    """One served model = the paper's 'application'."""
    id: str
    family: str
    variants: List[Variant]          # sorted large -> small
    request_rate: float = 1.0        # q_i
    latency_slo: float = math.inf    # L_i (seconds)
    critical: bool = False           # i in K

    @property
    def full(self) -> Variant:
        return self.variants[0]

    @property
    def smallest(self) -> Variant:
        return self.variants[-1]

    def variant_by_name(self, name: str) -> Variant:
        for v in self.variants:
            if v.name == name:
                return v
        raise KeyError(name)

    def demand_matrix(self) -> np.ndarray:
        """Cached (n_variants, len(RESOURCES)) demand matrix, rows
        large -> small — the planner's per-round `_demand_matrix`
        rebuilt this on every call. The variants list is never mutated
        after construction; treat the array as read-only."""
        dm = self.__dict__.get("_demand_matrix")
        if dm is None:
            dm = np.array([[v.mem_bytes, v.compute] for v in self.variants],
                          np.float64)
            self.__dict__["_demand_matrix"] = dm
        return dm


def synthetic_family(name: str, full_mem: float, n_variants: int = 4,
                     spread: float = 4.0) -> List[Variant]:
    """Profile-only ladder (no ModelConfig) for large-scale simulation.

    `spread` = mem ratio between largest and smallest (the paper's
    Small/Medium/Large family classes differ exactly in this spread).
    """
    out = []
    for i in range(n_variants):
        ratio = spread ** (-i / max(1, n_variants - 1))
        mem = full_mem * ratio
        acc = ratio ** ACC_EXP
        out.append(Variant(
            name=f"{name}:v{i}", family=name, mem_bytes=mem,
            compute=mem / 32e9, accuracy=acc))   # ~50% compute at 50% mem
    return out
