"""Compatibility shim — Algorithm 1 now lives in `core/planner/`.

`faillite_heuristic` is the vectorized implementation
(planner/vectorized.py), behavior-equivalent to the original loop
(kept as `faillite_heuristic_legacy` in planner/legacy.py and asserted
identical by tests/test_planner.py). `_FreeView` remains importable for
old callers; new code should use `PlannerState`/`ScratchView`.
"""

from repro.core.planner.base import HeuristicResult, eq1_objective
from repro.core.planner.legacy import (_FreeView, faillite_heuristic_legacy,
                                       match, worst_fit)
from repro.core.planner.state import PlannerState, ScratchView
from repro.core.planner.vectorized import faillite_heuristic, plan_greedy

__all__ = [
    "HeuristicResult", "PlannerState", "ScratchView", "_FreeView",
    "eq1_objective", "faillite_heuristic", "faillite_heuristic_legacy",
    "match", "plan_greedy", "worst_fit",
]
