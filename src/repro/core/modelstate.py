"""Model-state plane: checkpoint residency, storage topology, and the
calibrated load-cost model shared by the simulator and the testbed.

The paper's MTTR story is dominated by model loading (Fig. 2b): a cold
replica must stream checkpoint bytes before it can serve. Where those
bytes live decides how expensive that stream is. This module makes the
byte-location a first-class object:

  * `StorageConfig` — the storage topology attached to a `Cluster`:
    per-server disk->HBM bandwidth, per-server NIC bandwidth, and ONE
    shared cloud-origin uplink for the whole cluster, plus the
    checkpoint replication policy. The default (`"local"` preset)
    reproduces the repo's historical flat model exactly: every
    checkpoint is on every disk and every load costs
    ``bytes / disk_bw + warmup`` — bit-identical to the old
    ``Variant.load_time`` path.
  * `ModelRegistry` — tracks, per variant, WHICH servers hold the
    checkpoint on local disk (the cloud origin always has a copy), and
    selects the fetch path for a load: local disk hit ≫ peer server
    (same site preferred) ≫ cloud origin. Residency survives crashes
    (disk outlives the process, as on the testbed, where `stage_cold`
    content survives a worker kill) and can be persisted through the
    controller `DataStore` for controller-failover restores.
  * `LoadCostModel` — the Fig. 2b cost ``bytes / effective_bw(source)
    + warmup``, with per-source effective bandwidths that the testbed
    CALIBRATES from real measured load wall-times (`observe`). The
    simulator prices loads through the same class, so feeding a
    testbed calibration into a sim spec reproduces measured costs.

The per-link *queueing* (N concurrent cold loads on one uplink each
slow down) lives in the execution engines — `core/simulation.py`'s
`SimLoadExecutor` keys FIFO queues by the link names produced here.

Link naming convention (shared with the load engines and the
`LinkDegrade` scenario event):

    disk:<server_id>    the server's disk/PCIe->HBM channel
    nic:<server_id>     the server's NIC
    cloud               the shared cloud-origin uplink
"""

from __future__ import annotations

import math
import threading
from dataclasses import dataclass, replace
from typing import Dict, Iterable, List, Optional, Set, Tuple

from repro.core.variants import LOAD_BW, WARMUP_S, Variant

# fetch-path sources, fastest to slowest
LOCAL, PEER, CLOUD = "local", "peer", "cloud"


def disk_link(server_id: str) -> str:
    return f"disk:{server_id}"


def nic_link(server_id: str) -> str:
    return f"nic:{server_id}"


CLOUD_LINK = "cloud"


@dataclass(frozen=True)
class StorageConfig:
    """Storage topology + replication policy of one cluster.

    ``replicate_all=True`` is the historical flat model: every variant
    checkpoint resident on every server's disk, so every load is a
    local hit at ``disk_bw`` — with the default bandwidths this reduces
    bit-exactly to the pre-model-state behavior. ``replicate_all=False``
    is the paper-faithful edge story: checkpoints live on ``replication``
    servers (primary's site excluded for the extras when possible) and
    everyone else fetches from a peer NIC or the shared cloud uplink.
    """
    disk_bw: float = LOAD_BW          # bytes/s, per-server disk->HBM
    nic_bw: float = math.inf          # bytes/s, per-server NIC
    cloud_bw: float = math.inf        # bytes/s, SHARED cloud-origin uplink
    warmup_s: float = WARMUP_S        # per-instance compile/alloc warmup
    replicate_all: bool = True        # every checkpoint on every disk
    replication: int = 2              # residency target otherwise
    name: str = "local"

    def with_(self, **kw) -> "StorageConfig":
        return replace(self, **kw)


#: Named presets, surfaced through `SimConfig.storage` /
#: `ExperimentSpec.storage`. "local" is the default (exact historical
#: behavior). "edge" is the paper-faithful constrained topology:
#: 10 GbE peer NICs, a 5 Gb/s shared cloud uplink (half a 10 Gb WAN
#: pipe, as edge sites typically see), checkpoints on 2 servers.
STORAGE_PRESETS: Dict[str, StorageConfig] = {
    "local": StorageConfig(name="local"),
    "edge": StorageConfig(nic_bw=1.25e9, cloud_bw=0.625e9,
                          replicate_all=False, replication=2,
                          name="edge"),
}


def storage_preset(name: str, **overrides) -> StorageConfig:
    """Look up a preset by name, applying non-None overrides."""
    try:
        cfg = STORAGE_PRESETS[name]
    except KeyError:
        raise KeyError(f"unknown storage preset {name!r}; "
                       f"have {sorted(STORAGE_PRESETS)}") from None
    kw = {k: v for k, v in overrides.items() if v is not None}
    return cfg.with_(**kw) if kw else cfg


@dataclass(frozen=True)
class FetchPlan:
    """How one checkpoint reaches one server: the source class, the
    links the transfer serializes on, and the bottleneck bandwidth."""
    source: str                        # LOCAL | PEER | CLOUD
    links: Tuple[str, ...]
    bw: float
    src_server: Optional[str] = None   # peer fetches only


class LinkScale:
    """Multiplicative per-link bandwidth-scale windows — the shared
    `LinkDegrade` bookkeeping of both execution engines. `degrade`
    applies a factor and returns the matching restore callable; the
    caller schedules the restore on its own clock (event queue on the
    simulator, a timer thread on the testbed). Overlapping windows
    compose multiplicatively."""

    def __init__(self):
        self._scale: Dict[str, float] = {}

    def get(self, link: str) -> float:
        return self._scale.get(link, 1.0)

    def min_over(self, links: Iterable[str]) -> float:
        return min((self.get(l) for l in links), default=1.0)

    def degrade(self, link: str, factor: float):
        self._scale[link] = self.get(link) * factor

        def restore():
            s = self.get(link) / factor
            if abs(s - 1.0) < 1e-12:
                self._scale.pop(link, None)
            else:
                self._scale[link] = s

        return restore


@dataclass
class LoadTicket:
    """Per-load receipt an execution engine fills in: where the bytes
    came from and how the wall time decomposed. The controller folds
    this into `RecoveryRecord.phases` for the MTTR breakdown."""
    source: str = LOCAL
    queue_s: float = 0.0               # waited behind earlier transfers
    fetch_s: float = 0.0               # byte-transfer time
    warmup_s: float = 0.0              # compile/alloc warmup
    done: bool = False


class LoadCostModel:
    """Fig. 2b load-cost model with per-source calibration.

    ``seconds(variant, source, bw)`` prices a load as
    ``bytes / effective_bw + warmup``; the effective bandwidth is the
    topology's bottleneck unless a calibration observation exists for
    that source class. The testbed `observe()`s every real load it
    executes (measured wall seconds), maintaining an EWMA effective
    bandwidth per source — `to_dict()` of that calibration can be fed
    into a simulator run so both backends price loads identically.
    """

    def __init__(self, storage: StorageConfig,
                 calibration: Optional[Dict[str, float]] = None):
        self.storage = storage
        self._eff_bw: Dict[str, float] = dict(calibration or {})
        self.n_obs = 0
        # the testbed observes from worker threads while the
        # controller thread prices loads
        self._lock = threading.Lock()

    def effective_bw(self, source: str, topo_bw: float) -> float:
        with self._lock:
            return self._eff_bw.get(source, topo_bw)

    def seconds(self, variant: Variant, source: str, topo_bw: float,
                ) -> float:
        bw = self.effective_bw(source, topo_bw)
        return variant.mem_bytes / bw + self.storage.warmup_s

    def observe(self, variant: Variant, source: str, measured_s: float,
                *, ewma: float = 0.3) -> float:
        """Fold one measured load wall-time into the calibration;
        returns the updated effective bandwidth for `source`."""
        transfer = max(measured_s - self.storage.warmup_s, 1e-6)
        bw = variant.mem_bytes / transfer
        with self._lock:
            prev = self._eff_bw.get(source)
            self._eff_bw[source] = (bw if prev is None
                                    else (1.0 - ewma) * prev + ewma * bw)
            self.n_obs += 1
            return self._eff_bw[source]

    def to_dict(self) -> Dict[str, float]:
        with self._lock:
            return dict(self._eff_bw)


class ModelRegistry:
    """Where every variant's checkpoint bytes are resident, per server.

    Replaces the old implicit assumption ("weights are wherever a load
    needs them") with explicit residency sets + fetch-path selection.
    A `version` counter bumps on every residency change so array views
    (`PlannerState`) can cache per-server residency masks.
    """

    def __init__(self, cluster, storage: Optional[StorageConfig] = None,
                 datastore=None):
        self.cluster = cluster
        self.storage = storage or getattr(cluster, "storage", None) \
            or STORAGE_PRESETS["local"]
        self.ds = datastore                      # optional durability
        self.calibration = LoadCostModel(self.storage)
        self._resident: Dict[str, Set[str]] = {}   # variant -> server ids
        self._seed_i = 0                           # deterministic spreading
        self.version = 0
        # the testbed stages/observes from worker threads while the
        # controller thread reads fetch plans
        self._lock = threading.RLock()

    # -- residency ----------------------------------------------------------
    def stage(self, variant_name: str, server_id: str) -> None:
        """Checkpoint bytes land on `server_id`'s disk."""
        if self.storage.replicate_all:
            return                               # trivially everywhere
        with self._lock:
            servers = self._resident.setdefault(variant_name, set())
            if server_id not in servers:
                servers.add(server_id)
                self.version += 1
                if self.ds is not None:
                    self.ds.put(f"ckpt/{variant_name}",
                                {"servers": sorted(servers)})

    def evict(self, variant_name: str, server_id: str) -> None:
        with self._lock:
            servers = self._resident.get(variant_name)
            if servers and server_id in servers:
                servers.discard(server_id)
                self.version += 1
                if self.ds is not None:
                    self.ds.put(f"ckpt/{variant_name}",
                                {"servers": sorted(servers)})

    def forget_app(self, app, in_use: Iterable[str] = ()) -> None:
        """App departed: garbage-collect its checkpoints — EXCEPT
        variants named in `in_use` (arch-mix apps of one architecture
        share variant names, so a surviving sibling keeps the bytes)."""
        keep = set(in_use)
        with self._lock:
            for v in app.variants:
                if v.name in keep:
                    continue
                if self._resident.pop(v.name, None) is not None:
                    self.version += 1
                    if self.ds is not None:
                        self.ds.delete(f"ckpt/{v.name}")

    def is_local(self, variant_name: str, server_id: str) -> bool:
        if self.storage.replicate_all:
            return True
        with self._lock:
            return server_id in self._resident.get(variant_name, ())

    def resident_servers(self, variant_name: str) -> Set[str]:
        if self.storage.replicate_all:
            return set(self.cluster.servers)
        with self._lock:
            return set(self._resident.get(variant_name, ()))

    def alive_resident(self, variant_name: str) -> List[str]:
        """Alive servers holding the checkpoint, sorted for determinism."""
        return sorted(sid for sid in self.resident_servers(variant_name)
                      if self.cluster.servers[sid].alive)

    def ensure_app(self, app, primary_sid: str) -> None:
        """Seed an arriving app's checkpoint replicas: the whole ladder
        on the primary's disk, plus ``replication - 1`` extra servers
        spread deterministically across OTHER sites (site-independent
        replicas, §3.4) so a site outage never strands every copy."""
        if self.storage.replicate_all:
            return
        extras = self._pick_replica_targets(primary_sid,
                                            self.storage.replication - 1)
        for v in app.variants:
            self.stage(v.name, primary_sid)
            for sid in extras:
                self.stage(v.name, sid)

    def _pick_replica_targets(self, primary_sid: str, n: int) -> List[str]:
        """`n` deterministic targets, rotating through the server list
        (so replicas spread), preferring sites != the primary's."""
        if n <= 0:
            return []
        ids = sorted(self.cluster.servers)
        p_site = self.cluster.servers[primary_sid].site
        off = self._seed_i
        self._seed_i += 1
        ranked = sorted(
            (sid for sid in ids if sid != primary_sid),
            key=lambda sid: (self.cluster.servers[sid].site == p_site,
                             (ids.index(sid) - off) % len(ids)))
        return ranked[:n]

    # -- fetch-path selection ----------------------------------------------
    def fetch_plan(self, variant_name: str, server_id: str) -> FetchPlan:
        """local disk hit ≫ peer server (same site first) ≫ cloud."""
        st = self.storage
        if self.is_local(variant_name, server_id):
            return FetchPlan(LOCAL, (disk_link(server_id),), st.disk_bw)
        peers = self.alive_resident(variant_name)
        peers = [p for p in peers if p != server_id]
        if peers:
            my_site = self.cluster.servers[server_id].site
            same = [p for p in peers
                    if self.cluster.servers[p].site == my_site]
            src = (same or peers)[0]
            return FetchPlan(PEER, (nic_link(src), nic_link(server_id)),
                             st.nic_bw, src_server=src)
        return FetchPlan(CLOUD, (CLOUD_LINK, nic_link(server_id)),
                         min(st.cloud_bw, st.nic_bw))

    def fetch_seconds(self, variant: Variant, server_id: str) -> float:
        """Uncontended fetch-time estimate (no queueing) — the planner's
        locality signal."""
        plan = self.fetch_plan(variant.name, server_id)
        bw = self.calibration.effective_bw(plan.source, plan.bw)
        if not math.isfinite(bw) or bw <= 0:
            return 0.0
        return variant.mem_bytes / bw

    def load_seconds(self, variant: Variant, server_id: str) -> float:
        """Uncontended end-to-end load estimate (fetch + warmup)."""
        plan = self.fetch_plan(variant.name, server_id)
        return self.calibration.seconds(variant, plan.source, plan.bw)

    # -- protection view ----------------------------------------------------
    def under_replicated(self, apps: Iterable, *,
                         variant_of=lambda a: a.smallest) -> List[tuple]:
        """(app, variant, n_alive_copies) for apps whose failover entry
        variant has fewer alive disk copies than the replication target.
        Empty under ``replicate_all`` (trivially everywhere)."""
        if self.storage.replicate_all:
            return []
        out = []
        for app in apps:
            v = variant_of(app)
            n = len(self.alive_resident(v.name))
            if n < self.storage.replication:
                out.append((app, v, n))
        return out

    def replication_target(self, variant_name: str) -> Optional[str]:
        """Best alive server to receive a new copy: most free memory,
        deterministic first-max — None if every alive server holds it."""
        have = self.resident_servers(variant_name)
        best, best_free = None, -1.0
        for sid in sorted(self.cluster.servers):
            srv = self.cluster.servers[sid]
            if not srv.alive or sid in have:
                continue
            f = srv.free("mem")
            if f > best_free:
                best, best_free = sid, f
        return best
