"""FailLite controller: two-step proactive + progressive failover (§3).

Workflow (paper Fig. 4):
  (1) app arrival -> place primary, proactive warm-backup planning (ILP)
  (2) agents load models per policy
  (3) heartbeat failure detection -> progressive failover (Algorithm 1)
  (4) progressive loading: smallest variant first, hot-swap to selected
      — dispatched through the RecoveryScheduler drain queue ("fifo" =
      historical order; "criticality" = restore-before-upgrade,
      critical apps first, preemptive)
  (5) clients re-routed via routing-epoch push

The model-state plane (core/modelstate.py) threads through: the
controller seeds checkpoint replicas at deploy, records each
recovery's MTTR phase breakdown from the executor's LoadTickets, and
proactively re-replicates under-protected checkpoints in idle
re-protection rounds.

The same controller frame runs the paper's three baselines
(Full-Size-Warm / -Cold / -Warm(K)) via `policy=`, and runs against
either the discrete-event simulator or the thread-based mini-testbed via
the LoadExecutor interface.
"""

from __future__ import annotations

import itertools
import math
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Set, Tuple

from repro.core.cluster import Cluster, Instance
from repro.core.datastore import DataStore
from repro.core.heartbeat import Clock, FailureDetector
from repro.core.modelstate import ModelRegistry
from repro.core.planner import PlanRequest, PlannerState, get_planner
from repro.core.variants import Application, Variant

POLICIES = ("faillite", "full-warm", "full-cold", "full-warm-k")
SCHEDULERS = ("fifo", "criticality")

NOTIFY_OVERHEAD_S = 0.010      # client push notification (paper §5.7)


class LoadExecutor:
    """Backend that actually loads/activates model instances."""

    def load(self, app: Application, variant: Variant, server_id: str,
             on_ready: Callable[[float], None]):
        """Asynchronously load; call on_ready(completion_time)."""
        raise NotImplementedError

    def unload(self, key: str, server_id: str):
        pass

    def activate(self, app: Application, variant: Variant, server_id: str):
        """Warm instance starts serving (instant)."""
        pass

    def prepare_warm(self, app: Application, variant: Variant,
                     server_id: str):
        """A warm backup was planned onto `server_id`: materialize it on
        the backend (no-op for the simulator, where warm means already
        resident; a real background model load on the testbed)."""
        pass

    def replicate(self, app: Application, variant: Variant,
                  server_id: str, on_done: Optional[Callable] = None):
        """Background checkpoint copy onto `server_id`'s disk (no HBM
        residency) — the re-protection loop's proactive re-replication.
        Backends with a ModelRegistry stage the bytes when the transfer
        completes; the base class is a no-op."""
        if on_done is not None:
            on_done(0.0)

    def reset_server(self, server_id: str):
        """Server crashed or rejoined empty: drop its pending load queue."""
        pass


@dataclass
class RecoveryRecord:
    app_id: str
    recovered: bool
    mttr: float = math.inf
    variant: Optional[str] = None
    accuracy: float = 0.0
    mode: str = "none"            # warm | cold | cold-progressive
    upgraded_to: Optional[str] = None
    epoch: int = 0                # failure epoch this record belongs to
    t_fail: float = 0.0
    # MTTR phase decomposition (seconds): detect / plan / queue / fetch /
    # warmup / route, plus the fetch source ("local"|"peer"|"cloud").
    # Filled on recovery when the backend reports a LoadTicket;
    # benchmarks/fig_mttr_breakdown.py aggregates it. NOT part of the
    # scenario fingerprint.
    phases: Dict[str, float] = field(default_factory=dict)
    source: Optional[str] = None


@dataclass
class RoutingTable:
    """Epoch-versioned client routes (the paper's websocket push, §4).

    Every `set`/`drop` bumps `epoch` and fires the corresponding
    observer, so the bump sequence defines exactly which in-flight
    request window a failure blacks out: the traffic plane
    (core/traffic.py) subscribes via `observer`/`drop_observer` to
    timestamp those transitions into per-app serving timelines.
    """
    epoch: int = 0
    routes: Dict[str, Tuple[str, str]] = field(default_factory=dict)
    observer: Optional[Callable[[str, str, str], None]] = None
    drop_observer: Optional[Callable[[str], None]] = None

    def set(self, app_id: str, server_id: str, variant_name: str):
        self.routes[app_id] = (server_id, variant_name)
        self.epoch += 1
        if self.observer is not None:
            self.observer(app_id, server_id, variant_name)

    def drop(self, app_id: str):
        if self.routes.pop(app_id, None) is not None:
            self.epoch += 1
            if self.drop_observer is not None:
                self.drop_observer(app_id)


@dataclass
class _PendingLoad:
    """One queued recovery load awaiting dispatch."""
    prio: tuple                # (stage, -boost, not critical, -rate, seq)
    app: Application
    variant: Variant
    server_id: str
    on_ready: Callable[[float], None]
    ticket: object = None          # LoadTicket once dispatched
    t_submit: Optional[float] = None


class RecoveryScheduler:
    """Explicit recovery-drain scheduler in front of the LoadExecutor.

    Progressive failover used to be an ordering convention: loads were
    handed to the executor in whatever order the affected apps were
    discovered, and the executor's per-server FIFO implicitly decided
    who recovered first. This class makes the policy explicit:

      * ``fifo`` — dispatch immediately in submission order; the
        executor's per-link FIFO queues serialize. This is bit-exactly
        the historical behavior (and the default).
      * ``criticality`` — hold a per-target-server drain queue with at
        most ONE in-flight load per server; the queue drains in
        (restore-before-upgrade, critical first, then request-rate)
        order, so a higher-criticality app failing MID-DRAIN preempts
        (jumps ahead of) every queued lower-criticality load, and no
        progressive UPGRADE transfer delays another app's first
        RESTORE transfer. Loads across different servers overlap
        freely; per-link I/O is still serialized by the executor's
        queues.

    Queued loads targeting a server that dies are dropped
    (`reset_server`); the superseding failure epoch re-plans them.
    """

    def __init__(self, executor: LoadExecutor, mode: str = "fifo",
                 alive_fn: Optional[Callable[[str], bool]] = None,
                 clock: Optional[Clock] = None):
        assert mode in SCHEDULERS, mode
        self.executor = executor
        self.mode = mode
        self.alive_fn = alive_fn or (lambda sid: True)
        self.clock = clock         # for drain-wait phase accounting
        self._seq = itertools.count()
        self._queued: Dict[str, List[_PendingLoad]] = {}
        self._inflight: Dict[str, _PendingLoad] = {}
        # autopilot-set per-app priority boosts (observed request rates):
        # empty by default, so the priority tuple's boost slot is 0.0
        # for every app and the historical ordering is untouched
        self.boosts: Dict[str, float] = {}
        # resilience-layer hook: ("start"|"end", t) fired when the
        # number of outstanding recovery loads crosses 0<->1, so the
        # traffic plane can admission-control during the drain. None
        # (the default) leaves every submission path bit-identical
        self.drain_observer: Optional[Callable[[str, float], None]] = None
        self._drain_active = 0

    def _now(self) -> float:
        return self.clock.now() if self.clock is not None else 0.0

    def _drain_begin(self):
        self._drain_active += 1
        if self._drain_active == 1:
            self.drain_observer("start", self._now())

    def _drain_end(self):
        self._drain_active = max(0, self._drain_active - 1)
        if self._drain_active == 0:
            self.drain_observer("end", self._now())

    def _tracked(self, on_ready: Callable[[float], None]
                 ) -> Callable[[float], None]:
        """Wrap a completion callback with drain accounting — only when
        an observer is installed (zero off-path change)."""
        if self.drain_observer is None:
            return on_ready
        self._drain_begin()

        def wrapped(t_ready: float):
            try:
                on_ready(t_ready)
            finally:
                self._drain_end()

        return wrapped

    def set_boosts(self, boosts: Dict[str, float]):
        """Reorder future drains by per-app boost (higher first); only
        the autopilot calls this. In-flight loads are not preempted."""
        self.boosts = dict(boosts)

    def priority(self, app: Application, stage: int = 0) -> tuple:
        return (stage, -self.boosts.get(app.id, 0.0), not app.critical,
                -app.request_rate, next(self._seq))

    def submit(self, app: Application, variant: Variant, server_id: str,
               on_ready: Callable[[float], None], *,
               stage: int = 0) -> _PendingLoad:
        """Enqueue one recovery load; returns its pending handle (the
        handle's `.ticket` holds the executor's LoadTicket once the
        load is dispatched). `stage` 0 = restore (an app comes back
        serving), 1 = progressive upgrade (quality, not availability) —
        upgrades never delay restores in criticality mode."""
        item = _PendingLoad(self.priority(app, stage), app, variant,
                            server_id, self._tracked(on_ready))
        if self.mode == "fifo":
            item.ticket = self.executor.load(app, variant, server_id,
                                             item.on_ready)
            return item
        if self.clock is not None:
            item.t_submit = self.clock.now()
        self._queued.setdefault(server_id, []).append(item)
        if server_id not in self._inflight:
            self._dispatch(server_id)
        return item

    def _dispatch(self, sid: str):
        q = self._queued.get(sid)
        if not q:
            self._queued.pop(sid, None)
            return
        if not self.alive_fn(sid):
            del self._queued[sid]          # superseded by a newer epoch
            return
        q.sort(key=lambda it: it.prio)     # stable: seq breaks ties
        item = q.pop(0)
        if not q:
            del self._queued[sid]
        self._inflight[sid] = item

        def _done(t_ready: float):
            mine = self._inflight.get(sid) is item
            if mine:
                del self._inflight[sid]
            try:
                item.on_ready(t_ready)
            finally:
                if mine:
                    self._dispatch(sid)

        item.ticket = self.executor.load(item.app, item.variant, sid,
                                         _done)
        if (item.ticket is not None and self.clock is not None
                and item.t_submit is not None):
            # time spent held in THIS drain queue is queueing too —
            # fold it into the ticket so phases still sum to MTTR
            item.ticket.queue_s += self.clock.now() - item.t_submit

    def reset_server(self, server_id: str):
        """Server crashed/rejoined: drop its queue and in-flight marker
        (stale completions are ignored via identity checks)."""
        dropped = self._queued.pop(server_id, None)
        self._inflight.pop(server_id, None)
        # queued-but-never-dispatched loads will never fire their
        # (tracked) on_ready — close their drain accounting here. The
        # in-flight load's completion event still fires and closes its
        # own (the executor always invokes on_ready).
        if dropped and self.drain_observer is not None:
            for _ in dropped:
                self._drain_end()

    def idle(self) -> bool:
        """No queued or in-flight recovery loads (fifo mode keeps no
        state here, so it is always 'idle' — the executor's own queues
        carry the work)."""
        return not self._queued and not self._inflight

    @property
    def n_pending(self) -> int:
        return (sum(len(q) for q in self._queued.values())
                + len(self._inflight))


class FailLiteController:
    def __init__(self, cluster: Cluster, clock: Clock,
                 executor: LoadExecutor, *,
                 policy: str = "faillite",
                 alpha: float = 0.1,
                 site_independence: bool = False,
                 use_ilp: bool = False,
                 planner: Optional[str] = None,
                 detector: Optional[FailureDetector] = None,
                 datastore: Optional[DataStore] = None,
                 registry: Optional[ModelRegistry] = None,
                 scheduler: str = "fifo",
                 autopilot: Optional[object] = None,
                 planner_dtype: str = "float64",
                 planner_backend: str = "numpy",
                 planner_coordinators: int = 0):
        assert policy in POLICIES, policy
        self.cluster = cluster
        self.clock = clock
        self.executor = executor
        # model-state plane: checkpoint residency + fetch-path selection
        # (None = no registry, i.e. the historical local-everything
        # assumption; the execution backends normally provide one)
        self.registry = registry
        # recovery-drain scheduler: "fifo" (historical dispatch order)
        # or "criticality" (priority drain queue with preemption)
        self.scheduler = RecoveryScheduler(
            executor, mode=scheduler,
            alive_fn=lambda sid: (sid in cluster.servers
                                  and cluster.servers[sid].alive),
            clock=clock)
        self.policy = policy
        self.alpha = alpha if policy == "faillite" else 0.0
        self.site_independence = site_independence
        self.use_ilp = use_ilp
        # planner selection by registry name (docs/PLANNER.md); the
        # legacy `use_ilp` flag maps onto the "ilp" planner.
        # backend/coordinator knobs only apply to the greedy family —
        # other policies (ilp, load-aware, ...) ignore them.
        self.planner_backend = planner_backend
        self.planner_coordinators = int(planner_coordinators)
        self.planner = self._resolve_planner(
            planner or ("ilp" if use_ilp else "greedy"))
        # the failover hot path (§3.3, MTTR-critical) always runs a
        # realtime planner; non-realtime ones (ilp) plan proactively only
        self.fast_planner = (self.planner if self.planner.realtime
                             else self._resolve_planner("greedy"))
        # persistent array-backed capacity view; Cluster notifies it of
        # per-server deltas, so planning never rebuilds a view per call
        self.state = PlannerState(cluster, dtype=planner_dtype)
        if registry is not None:
            self.state.attach_registry(registry)
        self.plan_wall_s = 0.0       # cumulative planner time (all calls)
        self._last_plan_wall = 0.0   # wall of the latest planning round
        self._replicating: Set[tuple] = set()   # (variant, target) in flight
        self.detector = detector or FailureDetector(clock)
        self.ds = datastore or DataStore()
        self.apps: Dict[str, Application] = {}
        self.primaries: Dict[str, str] = {}
        self.warm: Dict[str, Tuple[Variant, str, str]] = {}  # app->(v,srv,key)
        # incremental warm-gap tracking (docs/SCALE.md): candidate apps
        # currently lacking a warm backup, maintained at every warm
        # mutation so `replan_lost_backups` never scans all 100k apps.
        # `_reg_seq` records deploy order, because the historical full
        # scan iterated the apps dict in insertion order and baseline
        # placement (`_fullsize_assign`) is order-dependent.
        self._warm_missing: Set[str] = set()
        self._reg_seq: Dict[str, int] = {}
        self._reg_counter = itertools.count()
        # bumped on every warm-set mutation; observers (the simulator's
        # warm-bytes trend sample) cache their fold against it instead
        # of re-summing 100k warm entries per sweep
        self.warm_gen = 0
        # cluster mutation counter backing the futile-replan memo: a
        # reprotect plan over an unchanged cluster and unchanged app
        # list is deterministic, so a sweep that placed nothing is
        # skipped verbatim until something actually moves
        self.cluster_gen = 0
        cluster.subscribe(self._bump_cluster_gen)
        self._futile_replan = None
        self._futile_retry = None
        self.routing = RoutingTable()
        # `records` keeps the LATEST record per app (legacy view);
        # `epoch_records[k]` holds the records of failure epoch k, so
        # repeated `handle_failures` calls in one run stay distinguishable.
        self.records: Dict[str, RecoveryRecord] = {}
        self.epoch_records: List[Dict[str, RecoveryRecord]] = []
        self.cold_protected: Set[str] = set()   # warm evicted -> cold only
        # apps currently down: app_id -> (t_fail, epoch idx) awaiting the
        # re-protection loop to find capacity (e.g. after a rejoin)
        self._unrecovered: Dict[str, Tuple[float, int]] = {}
        # per-app recovery generation; bumping it invalidates callbacks of
        # loads scheduled before a newer failure/departure superseded them
        self._gen: Dict[str, int] = {}
        # adaptive protection (core/autopilot.py): None = the static
        # criticality rule, bit-exact historical behavior. When set, the
        # re-protection sweep consults it first and `_warm_candidates`
        # follows its protected set. `metrics_feed` is the backend's
        # window into the live traffic plane: a zero-arg callable
        # returning {app_id: AppSignal} at the current instant.
        self.autopilot = autopilot
        self.metrics_feed: Optional[Callable[[], Dict]] = None
        # shard plane (core/shardgroup.py): None = no tensor-parallel
        # groups, bit-exact historical behavior. When attached, grouped
        # apps are intercepted in `handle_failures` and walked through
        # the shard recovery ladder instead of the warm/cold split.
        self.shards = None

    def attach_shard_manager(self, manager) -> None:
        self.shards = manager

    @property
    def epoch(self) -> int:
        """Number of failure epochs handled so far."""
        return len(self.epoch_records)

    def _bump(self, app_id: str) -> int:
        self._gen[app_id] = self._gen.get(app_id, 0) + 1
        return self._gen[app_id]

    # -- warm-gap bookkeeping ----------------------------------------------
    def _is_warm_candidate(self, app: Application) -> bool:
        """Static warm-candidate rule per policy (the autopilot's
        adaptive set bypasses the incremental tracker entirely)."""
        if self.policy == "full-warm":
            return True
        if self.policy == "full-cold":
            return False
        return app.critical

    def _warm_set(self, app_id: str, variant: Variant, sid: str, key: str):
        """All warm-backup grants flow through here so `_warm_missing`
        stays exact."""
        self.warm[app_id] = (variant, sid, key)
        self.warm_gen += 1
        self._warm_missing.discard(app_id)

    def _warm_del(self, app_id: str):
        """All warm-backup losses flow through here: a still-present
        candidate app immediately becomes a replan target."""
        if self.warm.pop(app_id, None) is not None:
            self.warm_gen += 1
        app = self.apps.get(app_id)
        if app is not None and self._is_warm_candidate(app):
            self._warm_missing.add(app_id)

    # ------------------------------------------------------------------
    # Step 1: arrival + proactive failover
    # ------------------------------------------------------------------
    def deploy_primary(self, app: Application,
                       server_id: Optional[str] = None) -> str:
        """Worst-fit primary placement of the full model (paper §5.1)."""
        if server_id is None:
            server_id = self.state.worst_fit(app.full.demand_vec)
            if server_id is None:
                raise ValueError(f"no capacity for primary of {app.id}")
        self.cluster.place(app.id, app.full, server_id, "primary")
        # register only after placement succeeded: a rejected arrival
        # must not leak into controller state
        self.apps[app.id] = app
        self._reg_seq[app.id] = next(self._reg_counter)
        if self._is_warm_candidate(app):
            self._warm_missing.add(app.id)
        if self.registry is not None:
            # seed the app's checkpoint replicas (primary disk + spread)
            self.registry.ensure_app(app, server_id)
        self.primaries[app.id] = server_id
        self.routing.set(app.id, server_id, app.full.name)
        self.ds.put(f"primary/{app.id}", {"server": server_id,
                                          "variant": app.full.name})
        return server_id

    def _shard_protected(self, app_id: str) -> bool:
        """True while the app is protected by a live/degraded/resharding
        shard group — such apps get no warm monolith backups (their
        protection IS the shard ladder); a fallen-back group's app
        re-enters normal warm planning."""
        return self.shards is not None and self.shards.is_grouped(app_id)

    def _warm_candidates(self) -> List[Application]:
        if self.shards is not None:
            return [a for a in self._warm_candidates_base()
                    if not self.shards.is_grouped(a.id)]
        return self._warm_candidates_base()

    def _warm_candidates_base(self) -> List[Application]:
        if (self.autopilot is not None
                and getattr(self.autopilot, "protected", None) is not None
                and self.policy == "faillite"):
            # adaptive set, ranked by observed rate; before the first
            # decide() the static criticality rule below applies
            return [self.apps[aid] for aid in self.autopilot.last.protected
                    if aid in self.apps]
        if self.policy in ("faillite", "full-warm-k"):
            return [a for a in self.apps.values() if a.critical]
        if self.policy == "full-warm":
            crit = [a for a in self.apps.values() if a.critical]
            rest = [a for a in self.apps.values() if not a.critical]
            return crit + rest
        return []                  # full-cold

    def plan_warm_backups(self) -> Dict[str, Tuple[Variant, str]]:
        """Proactive step: the configured planner (ILP or a greedy
        policy) for FailLite; full-size placement for the baselines."""
        cands = self._warm_candidates()
        if not cands:
            return {}
        if self.policy == "faillite":
            assignment = self._plan(cands, alpha=self.alpha,
                                    proactive=True)
        else:
            assignment = self._fullsize_assign(cands)

        for app_id, (variant, sid) in assignment.items():
            key = self.cluster.place(app_id, variant, sid, "warm")
            self._warm_set(app_id, variant, sid, key)
            self.executor.prepare_warm(self.apps[app_id], variant, sid)
            self.ds.put(f"warm/{app_id}", {"server": sid,
                                           "variant": variant.name})
        # Re-derive the rows this proactive round just dirtied while we
        # are still in proactive time: sync() is idempotent and runs at
        # the start of every plan anyway, so paying it here keeps a big
        # warm-placement round's dirt out of the first failover round's
        # MTTR-critical plan wall.
        if assignment:
            self.state.sync()
        return assignment

    def _resolve_planner(self, name: str):
        """Instantiate a registered planner, forwarding the backend /
        coordinator knobs to the policies that take them."""
        kwargs = {}
        if name in ("greedy", "sharded"):
            kwargs["backend"] = self.planner_backend
        if name == "sharded" and self.planner_coordinators:
            kwargs["coordinators"] = self.planner_coordinators
        return get_planner(name, **kwargs)

    def planner_stats(self) -> dict:
        """Observability snapshot of the planner configuration and the
        per-instance counters the greedy-family policies maintain
        (backend routing, dense fallbacks) — surfaced in
        `RunResult.extras["planner"]`."""
        out = {"name": self.planner.name,
               "backend": self.planner_backend,
               "coordinators": self.planner_coordinators}
        skip = ("name", "backend", "coordinators")
        for planner in {id(self.planner): self.planner,
                        id(self.fast_planner): self.fast_planner}.values():
            for k, v in getattr(planner, "stats", {}).items():
                if k not in skip and isinstance(v, int):
                    out[k] = out.get(k, 0) + v
        return out

    def _plan(self, cands, *, alpha=0.0, proactive=False):
        """One planner round over `cands` against the persistent state.

        Proactive rounds (warm-backup planning) may use a non-realtime
        planner; the failover hot path always gets a realtime one."""
        planner = self.planner if proactive else self.fast_planner
        res = planner.plan(PlanRequest(
            apps=cands, cluster=self.cluster, state=self.state,
            primaries=self.primaries, alpha=alpha,
            site_independence=self.site_independence,
            now=self.clock.now()))
        self._last_plan_wall = getattr(res, "wall_s", 0.0)
        self.plan_wall_s += self._last_plan_wall
        return res.assignment

    def _fullsize_assign(self, cands):
        """Baselines: only the full-size variant, greedy worst-fit."""
        view = self.state.scratch()
        out = {}
        for app in cands:
            excl = {self.primaries.get(app.id)} - {None}
            if self.site_independence and self.primaries.get(app.id):
                p_site = self.cluster.servers[self.primaries[app.id]].site
                excl |= set(self.cluster.sites.get(p_site, ()))
            sid = view.worst_fit(app.full.demand_vec, excl)
            if sid is not None:
                view.take(sid, app.full.demand_vec)
                out[app.id] = (app.full, sid)
        return out

    # ------------------------------------------------------------------
    # Step 2: failure handling (progressive failover)
    # ------------------------------------------------------------------
    def handle_failures(self, failed_servers: List[str],
                        t_fail: float,
                        lost: Optional[List[Instance]] = None,
                        ) -> Dict[str, RecoveryRecord]:
        """Called when the detector declares servers failed.

        Re-entrant: may run any number of times per controller lifetime
        (cascades, rolling failures, flaky nodes). Each call opens a new
        failure *epoch*; its records land in `epoch_records[-1]`.
        Servers already dead are ignored, in-flight recovery loads onto a
        newly-failed server are invalidated and re-planned, and warm
        bookkeeping is reconciled against the surviving cluster state.

        `lost` lets the caller pass the instances that died when the
        crash actually happened (the simulator applies the physical
        failure at t_fail and detection fires ~65ms later — the server
        may even have rejoined inside that window); when omitted, the
        physical failure is applied now.
        """
        t_detect = self.clock.now()
        epoch = len(self.epoch_records)
        if lost is None:
            failed_set = {sid for sid in failed_servers
                          if self.cluster.servers[sid].alive}
            lost = []
            for sid in failed_set:
                lost.extend(self.cluster.fail_server(sid))
                self.detector.mark_failed(sid)
                self.executor.reset_server(sid)
        else:
            # crash already applied; only servers still down count for
            # the warm-backup reconciliation below
            failed_set = {sid for sid in failed_servers
                          if not self.cluster.servers[sid].alive}
        for sid in failed_set:
            # queued recovery loads onto a dead server are void; their
            # apps are re-planned by this epoch or the reprotect loop
            self.scheduler.reset_server(sid)

        records: Dict[str, RecoveryRecord] = {}

        # shard plane first: grouped apps (member slices carry role
        # "shard"; their reshard loads carry role "loading" too) are
        # walked through the shard recovery ladder and excluded from
        # the warm/cold split below. No-op when no manager is attached.
        grouped: Set[str] = set()
        if self.shards is not None:
            grouped = {aid for aid in self.apps
                       if self.shards.is_grouped(aid)}
            records.update(self.shards.handle_lost(failed_set, t_fail,
                                                   t_detect))

        # Apps hit by this epoch: lost their serving primary OR an
        # in-flight recovery load (role "loading" from a prior epoch).
        affected_ids: List[str] = []
        for inst in lost:
            if (inst.role in ("primary", "loading")
                    and inst.app_id in self.apps
                    and inst.app_id not in grouped
                    and inst.app_id not in affected_ids):
                affected_ids.append(inst.app_id)
        affected = [self.apps[a] for a in affected_ids]
        for app in affected:
            self._bump(app.id)           # invalidate stale load callbacks
            self.primaries.pop(app.id, None)
            self._unrecovered.pop(app.id, None)   # superseded by new epoch
        # warm backups that died with their server are gone; also drop any
        # entry whose instance vanished from the cluster out-of-band
        for app_id, (v, sid, key) in list(self.warm.items()):
            if (sid in failed_set
                    or key not in self.cluster.servers[sid].instances):
                self._warm_del(app_id)
                self.ds.delete(f"warm/{app_id}")

        # (a) warm switch for apps that still have a live warm backup
        cold_apps: List[Application] = []
        for app in affected:
            warm = self.warm.get(app.id)
            if warm is not None:
                v, sid, key = warm
                self.executor.activate(app, v, sid)
                self.cluster.servers[sid].instances[key].role = "primary"
                self.primaries[app.id] = sid
                self._warm_del(app.id)
                self.routing.set(app.id, sid, v.name)
                mttr = (t_detect - t_fail) + NOTIFY_OVERHEAD_S
                rec = RecoveryRecord(
                    app.id, True, mttr, v.name, v.accuracy, "warm")
                rec.phases = {"detect": t_detect - t_fail,
                              "route": NOTIFY_OVERHEAD_S}
                records[app.id] = rec
            else:
                cold_apps.append(app)

        # (b) progressive failover for the rest
        if cold_apps:
            records.update(self._progressive(cold_apps, t_fail, t_detect))
        for app_id, rec in records.items():
            rec.epoch = epoch
            rec.t_fail = t_fail
        self.epoch_records.append(records)
        self.records.update(records)
        return records

    def _commit(self, assignment) -> Dict[str, str]:
        """Reserve capacity for the selected variants NOW so later
        planning rounds see a consistent cluster state."""
        keys = {}
        for app_id, (v_sel, sid) in assignment.items():
            try:
                keys[app_id] = self.cluster.place(app_id, v_sel, sid,
                                                  "loading", ready=False)
            except ValueError:
                pass            # stays un-reserved -> reported unrecovered
        return keys

    def _progressive(self, apps: List[Application], t_fail: float,
                     t_detect: float) -> Dict[str, RecoveryRecord]:
        if self.policy == "faillite":
            assignment = self._plan(apps)
            keys = self._commit(assignment)
            missing = [a for a in apps if a.id not in keys]
            if missing:
                # Beyond-paper: warm-backup reclamation. Under widespread
                # (site-scale) failures the surviving warm replicas of
                # *unaffected* apps strand the capacity the affected apps
                # need; evict the lowest-value warm backups and retry.
                extra = self._reclaim_and_assign(missing)
                keys.update(self._commit(extra))
                assignment.update(extra)
        else:
            # baselines: K-critical first, then the rest, full-size only
            order = sorted(apps, key=lambda a: not a.critical)
            assignment = self._fullsize_assign(order)
            keys = self._commit(assignment)

        records = {}
        for app in apps:
            if app.id not in keys:
                records[app.id] = RecoveryRecord(app.id, False)
                # nothing committed: app stays down until the continuous
                # re-protection loop finds capacity (e.g. after a rejoin)
                self._unrecovered[app.id] = (t_fail,
                                             len(self.epoch_records))
                continue
            v_sel, sid = assignment[app.id]
            records[app.id] = self._progressive_load(
                app, v_sel, sid, t_fail, t_detect, key_sel=keys[app.id])
        return records

    def _reclaim_and_assign(self, missing: List[Application]):
        """Evict warm backups (lowest request-rate first) until the
        missing apps place; evicted apps keep cold protection."""
        evictable = sorted(
            self.warm.items(),
            key=lambda kv: self.apps[kv[0]].request_rate
            if kv[0] in self.apps else 0.0)
        i, batch = 0, 1
        while i < len(evictable):
            for app_id, (v, sid, key) in evictable[i:i + batch]:
                self.cluster.remove(key, sid)
                self._warm_del(app_id)
                self.ds.delete(f"warm/{app_id}")
                # demoted, not abandoned: the model artifact stays on
                # disk, so the app keeps cold (progressive) protection
                self.cold_protected.add(app_id)
                self.ds.put(f"cold/{app_id}", {"variant": v.name,
                                               "reason": "reclaimed"})
            i += batch
            batch *= 2          # exponential batching keeps this O(log n)
            assignment = self._plan(missing)
            if len(assignment) == len(missing):
                return assignment
        # one final, internally-consistent assignment (placements from
        # intermediate probes are never committed, so no double-booking)
        return self._plan(missing)

    def _progressive_load(self, app: Application, v_sel: Variant,
                          sid: str, t_fail: float, t_detect: float,
                          key_sel: Optional[str] = None) -> RecoveryRecord:
        rec = RecoveryRecord(app.id, False)
        progressive = (self.policy == "faillite"
                       and app.smallest.name != v_sel.name
                       and app.smallest.mem_bytes < v_sel.mem_bytes)
        first = app.smallest if progressive else v_sel

        if key_sel is None:
            # reserve the selected variant's demand (placement decision)
            try:
                key_sel = self.cluster.place(app.id, v_sel, sid, "loading",
                                             ready=False)
            except ValueError:
                # capacity raced away; report honestly
                self._unrecovered[app.id] = (t_fail,
                                             len(self.epoch_records))
                return rec

        # Loads scheduled now are void if a later epoch kills the target
        # server (gen bumped) or the app departs; callbacks check both.
        gen = self._gen.get(app.id, 0)
        plan_s = self._last_plan_wall

        def _stale() -> bool:
            return (self._gen.get(app.id, 0) != gen
                    or app.id not in self.apps
                    or not self.cluster.servers[sid].alive)

        def on_first_ready(t_ready: float):
            if _stale():
                return
            self.primaries[app.id] = sid
            self.routing.set(app.id, sid, first.name)
            rec.recovered = True
            rec.mttr = (t_detect - t_fail) + (t_ready - t_detect) \
                + NOTIFY_OVERHEAD_S
            rec.variant = first.name
            rec.accuracy = first.accuracy
            rec.mode = "cold-progressive" if progressive else "cold"
            rec.phases = {"detect": t_detect - t_fail, "plan": plan_s,
                          "route": NOTIFY_OVERHEAD_S}
            ticket = handle.ticket
            if ticket is not None:
                rec.source = ticket.source
                rec.phases.update(queue=ticket.queue_s,
                                  fetch=ticket.fetch_s,
                                  warmup=ticket.warmup_s)
            if not progressive:
                inst = self.cluster.servers[sid].instances.get(key_sel)
                if inst is not None:
                    inst.role = "primary"
                    inst.ready = True
            self.ds.put(f"primary/{app.id}", {"server": sid,
                                              "variant": first.name})

        def on_selected_ready(t_ready: float):
            if _stale():
                return
            inst = self.cluster.servers[sid].instances.get(key_sel)
            if inst is not None:
                inst.role = "primary"
                inst.ready = True
            self.routing.set(app.id, sid, v_sel.name)
            rec.variant = v_sel.name
            rec.accuracy = v_sel.accuracy
            rec.upgraded_to = v_sel.name

        handle = self.scheduler.submit(app, first, sid, on_first_ready)
        if progressive:
            self.scheduler.submit(app, v_sel, sid, on_selected_ready,
                                  stage=1)
        return rec

    # ------------------------------------------------------------------
    # Membership events (scenario engine)
    # ------------------------------------------------------------------
    def handle_rejoin(self, server_id: str):
        """A failed server rejoins EMPTY: reconcile detector/executor
        state and scrub stale references; the re-protection loop refills
        the returned capacity with warm backups / retried recoveries."""
        srv = self.cluster.servers[server_id]
        if srv.alive:
            return
        self.cluster.revive_server(server_id)
        self.detector.revive(server_id)
        self.executor.reset_server(server_id)
        self.scheduler.reset_server(server_id)
        # defensive scrub: nothing should still point at a node that was
        # down, but repeated epochs make invariants worth re-asserting
        for app_id in [a for a, s in self.primaries.items()
                       if s == server_id]:
            self._bump(app_id)
            del self.primaries[app_id]
        for app_id in [a for a, (_, s, _) in self.warm.items()
                       if s == server_id]:
            self._warm_del(app_id)
            self.ds.delete(f"warm/{app_id}")

    def handle_departure(self, app_id: str):
        """App leaves: release every replica and forget its bookkeeping."""
        self._bump(app_id)
        if self.shards is not None:
            self.shards.forget(app_id)
        app = self.apps.pop(app_id, None)
        if self.registry is not None and app is not None:
            # arch-mix siblings share variant names: keep checkpoints
            # any surviving app still depends on
            in_use = {v.name for a in self.apps.values()
                      for v in a.variants}
            self.registry.forget_app(app, in_use=in_use)
        self.cluster.remove_app(app_id)
        self.primaries.pop(app_id, None)
        if self.warm.pop(app_id, None) is not None:
            self.warm_gen += 1
        self._warm_missing.discard(app_id)
        self._reg_seq.pop(app_id, None)
        self._unrecovered.pop(app_id, None)
        self.cold_protected.discard(app_id)
        self.routing.drop(app_id)
        self.ds.delete(f"primary/{app_id}")
        self.ds.delete(f"warm/{app_id}")
        self.ds.delete(f"cold/{app_id}")

    # ------------------------------------------------------------------
    # Continuous re-protection (beyond-paper): a periodic loop, driven by
    # the simulator's event queue, that (1) retries progressive recovery
    # for apps still down from earlier epochs and (2) re-plans warm
    # backups lost to failures/evictions — so protection converges back
    # after every churn/failure/rejoin event.
    # ------------------------------------------------------------------
    def reprotect(self) -> Dict[str, int]:
        demoted = self._autopilot_step() if self.autopilot is not None \
            else 0
        retried = self._retry_unrecovered()
        replanned = self.replan_lost_backups()
        replicated = self._replicate_underprotected()
        return {"retried": retried, "replanned": len(replanned),
                "replicated": replicated, "demoted": demoted}

    def _autopilot_step(self) -> int:
        """Run one adaptive-protection sweep: consult the policy with a
        live view of the metrics plane, then apply its decisions —
        demotions are evicted here (promotions materialize through
        `replan_lost_backups`, which follows the protected set via
        `_warm_candidates`), the replication target is retuned on the
        registry, and the drain scheduler gets fresh priority boosts."""
        from repro.core.autopilot import AutopilotView

        signals = self.metrics_feed() if self.metrics_feed is not None \
            else {}
        view = AutopilotView(
            now=self.clock.now(),
            apps=dict(self.apps),
            warm_ids=set(self.warm),
            signals=signals,
            fail_times=[next(iter(ep.values())).t_fail
                        for ep in self.epoch_records if ep],
            base_replication=(self.registry.storage.replication
                              if self.registry is not None else 2),
            unrecovered=set(self._unrecovered))
        dec = self.autopilot.decide(view)

        n_demoted = 0
        for app_id in dec.demote:
            entry = self.warm.get(app_id)
            if entry is None:
                continue
            self._warm_del(app_id)
            v, sid, key = entry
            self.cluster.remove(key, sid)
            self.ds.delete(f"warm/{app_id}")
            # demoted, not abandoned: checkpoint bytes stay on disk, so
            # the app keeps cold (progressive) protection
            self.cold_protected.add(app_id)
            self.ds.put(f"cold/{app_id}", {"variant": v.name,
                                           "reason": "autopilot"})
            n_demoted += 1
        if (dec.replication is not None and self.registry is not None
                and not self.registry.storage.replicate_all
                and dec.replication != self.registry.storage.replication):
            self.registry.storage = self.registry.storage.with_(
                replication=dec.replication)
        self.scheduler.set_boosts(dec.boosts)
        return n_demoted

    def _replicate_underprotected(self, max_per_round: int = 2) -> int:
        """Idle-round proactive checkpoint re-replication: when the
        recovery drain queue is empty, copy the progressive-entry
        (smallest) variant of under-replicated apps onto fresh disks,
        critical/high-rate apps first — so the NEXT failure finds a
        nearby copy instead of paying the cloud uplink. A no-op under
        the default local-everything storage. "Idle" means no app is
        still awaiting recovery, the drain queue is empty, AND the
        executor reports no in-flight work (fifo mode keeps no
        scheduler state, so the executor's own view catches loads
        still streaming) — replication bytes must never delay recovery
        bytes on a shared link."""
        if (self.registry is None or self.registry.storage.replicate_all
                or self._unrecovered or not self.scheduler.idle()
                or not getattr(self.executor, "idle", lambda: True)()):
            return 0
        cands = sorted(self.apps.values(),
                       key=lambda a: (not a.critical, -a.request_rate,
                                      a.id))
        n = 0
        for app, v, _copies in self.registry.under_replicated(cands):
            if any(k[0] == v.name for k in self._replicating):
                continue                     # a copy is already in flight
            target = self.registry.replication_target(v.name)
            if target is None:
                continue
            key = (v.name, target)
            self._replicating.add(key)

            def _done(_t, key=key):
                self._replicating.discard(key)

            self.executor.replicate(app, v, target, _done)
            n += 1
            if n >= max_per_round:
                break
        return n

    def _bump_cluster_gen(self, _server_id: str) -> None:
        self.cluster_gen += 1

    def _retry_unrecovered(self) -> int:
        down = [(aid, tf, ep) for aid, (tf, ep) in self._unrecovered.items()
                if aid in self.apps]
        if not down:
            return 0
        # same apps against an unmoved cluster replays the exact plan
        # that already failed to place anything — skip it (bit-exact:
        # planning is deterministic in (apps, cluster) and a futile
        # plan mutates nothing)
        memo = (tuple(aid for aid, _, _ in down), self.cluster_gen)
        if memo == self._futile_retry:
            return 0
        apps = [self.apps[aid] for aid, _, _ in down]
        if self.policy == "faillite":
            assignment = self._plan(apps)
        else:
            assignment = self._fullsize_assign(apps)
        keys = self._commit(assignment)
        now = self.clock.now()
        n = 0
        for aid, t_fail, ep in down:
            if aid not in keys:
                continue
            del self._unrecovered[aid]
            self._bump(aid)
            v_sel, sid = assignment[aid]
            # MTTR keeps the ORIGINAL failure time: the outage lasted
            # from the first loss until this late recovery completes.
            rec = self._progressive_load(self.apps[aid], v_sel, sid,
                                         t_fail, now, key_sel=keys[aid])
            rec.epoch = ep
            rec.t_fail = t_fail
            if ep < len(self.epoch_records):
                self.epoch_records[ep][aid] = rec
            self.records[aid] = rec
            n += 1
        self._futile_retry = memo if not keys else None
        return n

    def _warm_gap_candidates(self) -> List[Application]:
        """Candidate apps lacking a warm backup, in the exact order the
        historical full scan over `_warm_candidates()` produced them.

        The incremental `_warm_missing` set makes this O(gap) instead of
        O(apps) per sweep — the difference between a sub-second and a
        minutes-long reprotect tick at 100k apps. The autopilot's
        adaptive protected set changes between sweeps outside the
        tracker's view, so it keeps the full scan."""
        if self.autopilot is not None:
            return [a for a in self._warm_candidates()
                    if a.id not in self.warm]
        if self.policy == "full-cold":
            return []
        apps = []
        for aid in list(self._warm_missing):
            app = self.apps.get(aid)
            if app is None:
                self._warm_missing.discard(aid)     # departed; lazily GC
            elif aid not in self.warm:
                apps.append(app)
        # historical order: the apps dict iterates in deploy order, and
        # full-warm scanned criticals first then the rest
        if self.policy == "full-warm":
            apps.sort(key=lambda a: (not a.critical, self._reg_seq[a.id]))
        else:
            apps.sort(key=lambda a: self._reg_seq[a.id])
        return apps

    def replan_lost_backups(self):
        """Apps whose warm backup died get a new one planned from the
        remaining capacity. Idempotent; safe to call every sweep."""
        missing = [a for a in self._warm_gap_candidates()
                   if self.primaries.get(a.id) in self.cluster.servers
                   and self.cluster.servers[self.primaries[a.id]].alive]
        if not missing:
            return {}
        # futile-replan memo: identical gap list + unmoved cluster =
        # the same deterministic plan that placed nothing last sweep
        memo = (tuple(a.id for a in missing), self.cluster_gen)
        if memo == self._futile_replan:
            return {}
        assignment = (self._plan(missing, alpha=self.alpha)
                      if self.policy == "faillite"
                      else self._fullsize_assign(missing))
        placed = {}
        for app_id, (variant, sid) in assignment.items():
            try:
                key = self.cluster.place(app_id, variant, sid, "warm")
            except ValueError:
                continue           # capacity raced away; retry next sweep
            self._warm_set(app_id, variant, sid, key)
            self.cold_protected.discard(app_id)
            self.executor.prepare_warm(self.apps[app_id], variant, sid)
            self.ds.put(f"warm/{app_id}", {"server": sid,
                                           "variant": variant.name})
            placed[app_id] = (variant, sid)
        self._futile_replan = memo if not placed else None
        # same rationale as plan_warm_backups: eager resync keeps the
        # repair round's dirt off the next failover plan wall
        if placed:
            self.state.sync()
        return placed

    @property
    def has_unrecovered(self) -> bool:
        """Apps still down, awaiting the re-protection loop."""
        return bool(self._unrecovered)

    # -- metrics -----------------------------------------------------------
    def flat_records(self) -> List[RecoveryRecord]:
        """Every epoch's records, flattened in epoch order."""
        return [r for ep in self.epoch_records for r in ep.values()]

    def overall_summary(self) -> Dict[str, float]:
        """Summary over ALL epoch records (not just the latest per app)."""
        flat = self.flat_records()
        return self.summarize({i: r for i, r in enumerate(flat)})

    def warm_coverage(self) -> float:
        """Fraction of critical apps (with a live primary) that hold a
        warm backup right now — the end-of-run protection view shared by
        both execution backends."""
        crit = [a for a in self.apps.values() if a.critical
                and self.primaries.get(a.id) in self.cluster.servers
                and self.cluster.servers[self.primaries[a.id]].alive]
        return (sum(1 for a in crit if a.id in self.warm
                    or self._shard_protected(a.id)) / len(crit)
                if crit else 1.0)

    def summarize(self, records=None) -> Dict[str, float]:
        recs = list((records or self.records).values())
        if not recs:
            return {"recovery_rate": 1.0, "mttr_avg": 0.0,
                    "accuracy_reduction": 0.0, "n": 0}
        recovered = [r for r in recs if r.recovered]
        rate = len(recovered) / len(recs)
        mttr = (sum(r.mttr for r in recovered) / len(recovered)
                if recovered else math.inf)
        acc_red = (sum(1.0 - r.accuracy for r in recovered)
                   / len(recovered) if recovered else 0.0)
        return {"recovery_rate": rate, "mttr_avg": mttr,
                "accuracy_reduction": acc_red, "n": len(recs)}

    def summarize_epochs(self) -> List[Dict[str, float]]:
        """One summary dict per failure epoch, in injection order."""
        return [self.summarize(recs) for recs in self.epoch_records]
