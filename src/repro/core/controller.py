"""FailLite controller: two-step proactive + progressive failover (§3).

Workflow (paper Fig. 4):
  (1) app arrival -> place primary, proactive warm-backup planning (ILP)
  (2) agents load models per policy
  (3) heartbeat failure detection -> progressive failover (Algorithm 1)
  (4) progressive loading: smallest variant first, hot-swap to selected
  (5) clients re-routed via routing-epoch push

The same controller frame runs the paper's three baselines
(Full-Size-Warm / -Cold / -Warm(K)) via `policy=`, and runs against
either the discrete-event simulator or the thread-based mini-testbed via
the LoadExecutor interface.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Set, Tuple

from repro.core.cluster import Cluster, Instance, RESOURCES
from repro.core.datastore import DataStore
from repro.core.heartbeat import Clock, FailureDetector
from repro.core.heuristic import faillite_heuristic, worst_fit, _FreeView
from repro.core.variants import Application, Variant

POLICIES = ("faillite", "full-warm", "full-cold", "full-warm-k")

NOTIFY_OVERHEAD_S = 0.010      # client push notification (paper §5.7)


class LoadExecutor:
    """Backend that actually loads/activates model instances."""

    def load(self, app: Application, variant: Variant, server_id: str,
             on_ready: Callable[[float], None]):
        """Asynchronously load; call on_ready(completion_time)."""
        raise NotImplementedError

    def unload(self, key: str, server_id: str):
        pass

    def activate(self, app: Application, variant: Variant, server_id: str):
        """Warm instance starts serving (instant)."""
        pass


@dataclass
class RecoveryRecord:
    app_id: str
    recovered: bool
    mttr: float = math.inf
    variant: Optional[str] = None
    accuracy: float = 0.0
    mode: str = "none"            # warm | cold | cold-progressive
    upgraded_to: Optional[str] = None


@dataclass
class RoutingTable:
    epoch: int = 0
    routes: Dict[str, Tuple[str, str]] = field(default_factory=dict)

    def set(self, app_id: str, server_id: str, variant_name: str):
        self.routes[app_id] = (server_id, variant_name)
        self.epoch += 1


class FailLiteController:
    def __init__(self, cluster: Cluster, clock: Clock,
                 executor: LoadExecutor, *,
                 policy: str = "faillite",
                 alpha: float = 0.1,
                 site_independence: bool = False,
                 use_ilp: bool = False,
                 detector: Optional[FailureDetector] = None,
                 datastore: Optional[DataStore] = None):
        assert policy in POLICIES, policy
        self.cluster = cluster
        self.clock = clock
        self.executor = executor
        self.policy = policy
        self.alpha = alpha if policy == "faillite" else 0.0
        self.site_independence = site_independence
        self.use_ilp = use_ilp
        self.detector = detector or FailureDetector(clock)
        self.ds = datastore or DataStore()
        self.apps: Dict[str, Application] = {}
        self.primaries: Dict[str, str] = {}
        self.warm: Dict[str, Tuple[Variant, str, str]] = {}  # app->(v,srv,key)
        self.routing = RoutingTable()
        self.records: Dict[str, RecoveryRecord] = {}

    # ------------------------------------------------------------------
    # Step 1: arrival + proactive failover
    # ------------------------------------------------------------------
    def deploy_primary(self, app: Application,
                       server_id: Optional[str] = None) -> str:
        """Worst-fit primary placement of the full model (paper §5.1)."""
        self.apps[app.id] = app
        if server_id is None:
            view = _FreeView(self.cluster.alive_servers())
            server_id = worst_fit(view, app.full.demand, set())
            if server_id is None:
                raise ValueError(f"no capacity for primary of {app.id}")
        self.cluster.place(app.id, app.full, server_id, "primary")
        self.primaries[app.id] = server_id
        self.routing.set(app.id, server_id, app.full.name)
        self.ds.put(f"primary/{app.id}", {"server": server_id,
                                          "variant": app.full.name})
        return server_id

    def _warm_candidates(self) -> List[Application]:
        if self.policy in ("faillite", "full-warm-k"):
            return [a for a in self.apps.values() if a.critical]
        if self.policy == "full-warm":
            crit = [a for a in self.apps.values() if a.critical]
            rest = [a for a in self.apps.values() if not a.critical]
            return crit + rest
        return []                  # full-cold

    def plan_warm_backups(self) -> Dict[str, Tuple[Variant, str]]:
        """Proactive step: ILP (or heuristic) for FailLite; greedy
        full-size placement for the baselines."""
        cands = self._warm_candidates()
        if not cands:
            return {}
        if self.policy == "faillite":
            if self.use_ilp:
                from repro.core.placement import solve_warm_placement
                res = solve_warm_placement(
                    cands, self.cluster, self.primaries, alpha=self.alpha,
                    site_independence=self.site_independence)
                assignment = res.assignment
            else:
                assignment = self._heuristic_assign(cands,
                                                    alpha=self.alpha)
        else:
            assignment = self._fullsize_assign(cands)

        for app_id, (variant, sid) in assignment.items():
            key = self.cluster.place(app_id, variant, sid, "warm")
            self.warm[app_id] = (variant, sid, key)
            self.ds.put(f"warm/{app_id}", {"server": sid,
                                           "variant": variant.name})
        return assignment

    def _heuristic_assign(self, cands, *, alpha=0.0, servers_view=None):
        excl = {a.id: {self.primaries.get(a.id)} for a in cands}
        site_excl = {}
        if self.site_independence:
            for a in cands:
                p = self.primaries.get(a.id)
                site_excl[a.id] = ({self.cluster.servers[p].site}
                                   if p else set())
        res = faillite_heuristic(cands, self.cluster, exclude=excl,
                                 site_exclude=site_excl, alpha=alpha)
        return res.assignment

    def _fullsize_assign(self, cands):
        """Baselines: only the full-size variant, greedy worst-fit."""
        view = _FreeView(self.cluster.alive_servers())
        out = {}
        for app in cands:
            excl = {self.primaries.get(app.id)}
            if self.site_independence and self.primaries.get(app.id):
                p_site = self.cluster.servers[self.primaries[app.id]].site
                excl |= set(self.cluster.sites.get(p_site, ()))
            sid = worst_fit(view, app.full.demand, excl)
            if sid is not None:
                view.take(sid, app.full.demand)
                out[app.id] = (app.full, sid)
        return out

    # ------------------------------------------------------------------
    # Step 2: failure handling (progressive failover)
    # ------------------------------------------------------------------
    def handle_failures(self, failed_servers: List[str],
                        t_fail: float) -> Dict[str, RecoveryRecord]:
        """Called when the detector declares servers failed."""
        t_detect = self.clock.now()
        failed_set = set(failed_servers)
        lost: List[Instance] = []
        for sid in failed_servers:
            lost.extend(self.cluster.fail_server(sid))

        affected: List[Application] = []
        for inst in lost:
            if inst.role == "primary" and inst.app_id in self.apps:
                affected.append(self.apps[inst.app_id])
        # warm backups that died with their server are gone
        for app_id, (v, sid, key) in list(self.warm.items()):
            if sid in failed_set:
                del self.warm[app_id]
                self.ds.delete(f"warm/{app_id}")

        records: Dict[str, RecoveryRecord] = {}

        # (a) warm switch for apps that still have a live warm backup
        cold_apps: List[Application] = []
        for app in affected:
            warm = self.warm.get(app.id)
            if warm is not None:
                v, sid, key = warm
                self.executor.activate(app, v, sid)
                self.cluster.servers[sid].instances[key].role = "primary"
                self.primaries[app.id] = sid
                del self.warm[app.id]
                self.routing.set(app.id, sid, v.name)
                mttr = (t_detect - t_fail) + NOTIFY_OVERHEAD_S
                records[app.id] = RecoveryRecord(
                    app.id, True, mttr, v.name, v.accuracy, "warm")
            else:
                cold_apps.append(app)

        # (b) progressive failover for the rest
        if cold_apps:
            records.update(self._progressive(cold_apps, t_fail, t_detect))
        self.records.update(records)
        return records

    def _commit(self, assignment) -> Dict[str, str]:
        """Reserve capacity for the selected variants NOW so later
        planning rounds see a consistent cluster state."""
        keys = {}
        for app_id, (v_sel, sid) in assignment.items():
            try:
                keys[app_id] = self.cluster.place(app_id, v_sel, sid,
                                                  "loading", ready=False)
            except ValueError:
                pass            # stays un-reserved -> reported unrecovered
        return keys

    def _progressive(self, apps: List[Application], t_fail: float,
                     t_detect: float) -> Dict[str, RecoveryRecord]:
        if self.policy == "faillite":
            assignment = self._heuristic_assign(apps, alpha=0.0)
            keys = self._commit(assignment)
            missing = [a for a in apps if a.id not in keys]
            if missing:
                # Beyond-paper: warm-backup reclamation. Under widespread
                # (site-scale) failures the surviving warm replicas of
                # *unaffected* apps strand the capacity the affected apps
                # need; evict the lowest-value warm backups and retry.
                extra = self._reclaim_and_assign(missing)
                keys.update(self._commit(extra))
                assignment.update(extra)
        else:
            # baselines: K-critical first, then the rest, full-size only
            order = sorted(apps, key=lambda a: not a.critical)
            assignment = self._fullsize_assign(order)
            keys = self._commit(assignment)

        records = {}
        for app in apps:
            if app.id not in keys:
                records[app.id] = RecoveryRecord(app.id, False)
                continue
            v_sel, sid = assignment[app.id]
            records[app.id] = self._progressive_load(
                app, v_sel, sid, t_fail, t_detect, key_sel=keys[app.id])
        return records

    def _reclaim_and_assign(self, missing: List[Application]):
        """Evict warm backups (lowest request-rate first) until the
        missing apps place; evicted apps keep cold protection."""
        evictable = sorted(
            self.warm.items(),
            key=lambda kv: self.apps[kv[0]].request_rate
            if kv[0] in self.apps else 0.0)
        i, batch = 0, 1
        while i < len(evictable):
            for app_id, (v, sid, key) in evictable[i:i + batch]:
                self.cluster.remove(key, sid)
                if app_id in self.warm:
                    del self.warm[app_id]
                self.ds.delete(f"warm/{app_id}")
            i += batch
            batch *= 2          # exponential batching keeps this O(log n)
            assignment = self._heuristic_assign(missing, alpha=0.0)
            if len(assignment) == len(missing):
                return assignment
        # one final, internally-consistent assignment (placements from
        # intermediate probes are never committed, so no double-booking)
        return self._heuristic_assign(missing, alpha=0.0)

    def _progressive_load(self, app: Application, v_sel: Variant,
                          sid: str, t_fail: float, t_detect: float,
                          key_sel: Optional[str] = None) -> RecoveryRecord:
        rec = RecoveryRecord(app.id, False)
        progressive = (self.policy == "faillite"
                       and app.smallest.name != v_sel.name
                       and app.smallest.mem_bytes < v_sel.mem_bytes)
        first = app.smallest if progressive else v_sel

        if key_sel is None:
            # reserve the selected variant's demand (placement decision)
            try:
                key_sel = self.cluster.place(app.id, v_sel, sid, "loading",
                                             ready=False)
            except ValueError:
                # capacity raced away; report honestly
                return rec

        def on_first_ready(t_ready: float):
            self.primaries[app.id] = sid
            self.routing.set(app.id, sid, first.name)
            rec.recovered = True
            rec.mttr = (t_detect - t_fail) + (t_ready - t_detect) \
                + NOTIFY_OVERHEAD_S
            rec.variant = first.name
            rec.accuracy = first.accuracy
            rec.mode = "cold-progressive" if progressive else "cold"
            if not progressive:
                inst = self.cluster.servers[sid].instances.get(key_sel)
                if inst is not None:
                    inst.role = "primary"
                    inst.ready = True
            self.ds.put(f"primary/{app.id}", {"server": sid,
                                              "variant": first.name})

        def on_selected_ready(t_ready: float):
            inst = self.cluster.servers[sid].instances.get(key_sel)
            if inst is not None:
                inst.role = "primary"
                inst.ready = True
            self.routing.set(app.id, sid, v_sel.name)
            rec.variant = v_sel.name
            rec.accuracy = v_sel.accuracy
            rec.upgraded_to = v_sel.name

        self.executor.load(app, first, sid, on_first_ready)
        if progressive:
            self.executor.load(app, v_sel, sid, on_selected_ready)
        return rec

    # ------------------------------------------------------------------
    # Re-protection (beyond-paper): apps whose warm backup died get a new
    # one planned from the remaining capacity.
    # ------------------------------------------------------------------
    def replan_lost_backups(self):
        missing = [a for a in self.apps.values()
                   if a.critical and a.id not in self.warm
                   and self.primaries.get(a.id) in self.cluster.servers
                   and self.cluster.servers[self.primaries[a.id]].alive]
        if not missing:
            return {}
        assignment = (self._heuristic_assign(missing, alpha=self.alpha)
                      if self.policy == "faillite"
                      else self._fullsize_assign(missing))
        for app_id, (variant, sid) in assignment.items():
            key = self.cluster.place(app_id, variant, sid, "warm")
            self.warm[app_id] = (variant, sid, key)
        return assignment

    # -- metrics -----------------------------------------------------------
    def summarize(self, records=None) -> Dict[str, float]:
        recs = list((records or self.records).values())
        if not recs:
            return {"recovery_rate": 1.0, "mttr_avg": 0.0,
                    "accuracy_reduction": 0.0, "n": 0}
        recovered = [r for r in recs if r.recovered]
        rate = len(recovered) / len(recs)
        mttr = (sum(r.mttr for r in recovered) / len(recovered)
                if recovered else math.inf)
        acc_red = (sum(1.0 - r.accuracy for r in recovered)
                   / len(recovered) if recovered else 0.0)
        return {"recovery_rate": rate, "mttr_avg": mttr,
                "accuracy_reduction": acc_red, "n": len(recs)}
