"""Heartbeat failure detection (paper §4: push-alive every T=20ms, two
consecutive misses => failure; controller sweep every 100ms).

A Clock abstraction lets the same detector run against the discrete-event
simulator (SimClock) and the real thread-based mini-testbed (WallClock).
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Set


class Clock:
    def now(self) -> float:
        raise NotImplementedError


class WallClock(Clock):
    def now(self) -> float:
        return time.monotonic()


class SimClock(Clock):
    def __init__(self):
        self.t = 0.0

    def now(self) -> float:
        return self.t

    def advance(self, dt: float):
        self.t += dt


@dataclass
class FailureDetector:
    """Declares a server failed after `miss_count` missed heartbeats."""
    clock: Clock
    interval: float = 0.020          # T (s)
    miss_count: int = 2
    last_seen: Dict[str, float] = field(default_factory=dict)
    failed: Set[str] = field(default_factory=set)
    _lock: threading.Lock = field(default_factory=threading.Lock)

    def beat(self, server_id: str):
        with self._lock:
            self.last_seen[server_id] = self.clock.now()
            self.failed.discard(server_id)

    def deregister(self, server_id: str):
        with self._lock:
            self.last_seen.pop(server_id, None)
            self.failed.discard(server_id)

    def mark_failed(self, server_id: str):
        """External confirmation (e.g. scenario injection) that a node is
        down; keeps sweep() from re-reporting it."""
        with self._lock:
            self.failed.add(server_id)

    def revive(self, server_id: str):
        """A node rejoined: treat its first heartbeat as just received so
        it is no longer considered failed."""
        with self._lock:
            self.last_seen[server_id] = self.clock.now()
            self.failed.discard(server_id)

    def sweep(self) -> List[str]:
        """Returns servers that newly crossed the failure threshold."""
        now = self.clock.now()
        newly = []
        with self._lock:
            for sid, seen in self.last_seen.items():
                if sid in self.failed:
                    continue
                if now - seen > self.miss_count * self.interval:
                    self.failed.add(sid)
                    newly.append(sid)
        return newly

    def detection_latency_bound(self) -> float:
        return self.miss_count * self.interval
