"""Cluster model: servers, sites (failure domains), instances, resources.

Maps the paper's edge testbed onto TPU serving cells (DESIGN.md §2): a
"server" is a serving cell with an HBM budget and compute budget; a
"site" is a correlated failure domain (pod / rack).  Resource vectors
follow the paper: r ∈ {mem, compute}.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional

RESOURCES = ("mem", "compute")


@dataclass
class Instance:
    """A deployed model variant on a server."""
    app_id: str
    variant: "object"            # core.variants.Variant
    server_id: str
    role: str                    # "primary" | "warm" | "cold" | "loading"
    ready: bool = True

    @property
    def demand(self) -> Dict[str, float]:
        return self.variant.demand


@dataclass
class Server:
    id: str
    site: str
    capacity: Dict[str, float]
    alive: bool = True
    instances: Dict[str, Instance] = field(default_factory=dict)

    def used(self, r: str) -> float:
        # cold instances live on disk/host, not in the accelerator budget
        return sum(inst.demand[r] for inst in self.instances.values()
                   if inst.role != "cold")

    def free(self, r: str) -> float:
        return self.capacity[r] - self.used(r)

    def fits(self, demand: Dict[str, float]) -> bool:
        return all(self.free(r) >= demand[r] - 1e-9 for r in RESOURCES)

    def headroom(self) -> float:
        """Normalized min free fraction across resources (worst-fit key)."""
        return min(self.free(r) / self.capacity[r] for r in RESOURCES)


class Cluster:
    """Servers grouped into sites; tracks placement + liveness.

    `storage` is the cluster's storage topology (per-server disk+NIC
    bandwidth, shared cloud uplink, checkpoint replication policy — a
    `core.modelstate.StorageConfig`); None means the default
    local-everything topology, under which model loading reduces to the
    historical flat ``bytes / LOAD_BW + warmup`` cost.
    """

    def __init__(self, servers: List[Server], storage=None):
        self.storage = storage
        self.servers: Dict[str, Server] = {s.id: s for s in servers}
        self.sites: Dict[str, List[str]] = {}
        for s in servers:
            self.sites.setdefault(s.site, []).append(s.id)
        self._counter = itertools.count()
        # change observers, fired with the touched server id on every
        # capacity-relevant mutation (place/remove/fail/revive) — the
        # planner's array state subscribes here for incremental sync
        self._observers: List = []

    # -- change notification -------------------------------------------------
    def subscribe(self, fn) -> None:
        """Register `fn(server_id)` to run after every mutation of that
        server's instances or liveness."""
        self._observers.append(fn)

    def unsubscribe(self, fn) -> None:
        if fn in self._observers:
            self._observers.remove(fn)

    def _notify(self, server_id: str) -> None:
        for fn in tuple(self._observers):
            fn(server_id)

    # -- queries ------------------------------------------------------------
    def alive_servers(self) -> List[Server]:
        return [s for s in self.servers.values() if s.alive]

    def server_of_site(self, site: str) -> List[Server]:
        return [self.servers[sid] for sid in self.sites.get(site, ())]

    def instances_of(self, app_id: str, role: Optional[str] = None):
        out = []
        for s in self.servers.values():
            for key, inst in s.instances.items():
                if inst.app_id == app_id and (role is None
                                              or inst.role == role):
                    out.append((key, inst))
        return out

    def total_free(self, alive_only=True) -> Dict[str, float]:
        servers = self.alive_servers() if alive_only else list(
            self.servers.values())
        return {r: sum(s.free(r) for s in servers) for r in RESOURCES}

    def total_capacity(self) -> Dict[str, float]:
        return {r: sum(s.capacity[r] for s in self.alive_servers())
                for r in RESOURCES}

    # -- placement ----------------------------------------------------------
    def place(self, app_id: str, variant, server_id: str, role: str,
              ready: bool = True) -> str:
        srv = self.servers[server_id]
        inst = Instance(app_id, variant, server_id, role, ready)
        if role != "cold" and not srv.fits(inst.demand):
            raise ValueError(
                f"{server_id} cannot fit {app_id}/{variant.name}: "
                f"free={ {r: round(srv.free(r),1) for r in RESOURCES} } "
                f"demand={inst.demand}")
        key = f"{app_id}@{variant.name}#{next(self._counter)}"
        srv.instances[key] = inst
        self._notify(server_id)
        return key

    def remove(self, key: str, server_id: str):
        if self.servers[server_id].instances.pop(key, None) is not None:
            self._notify(server_id)

    def remove_app(self, app_id: str) -> List[str]:
        """Drop every instance of an app (departure); returns the keys."""
        removed = []
        for srv in self.servers.values():
            keys = [k for k, inst in srv.instances.items()
                    if inst.app_id == app_id]
            for key in keys:
                del srv.instances[key]
                removed.append(key)
            if keys:
                self._notify(srv.id)
        return removed

    # -- failures -----------------------------------------------------------
    def fail_server(self, server_id: str) -> List[Instance]:
        """Idempotent: a second fail of a dead server loses nothing new."""
        srv = self.servers[server_id]
        if not srv.alive:
            return []
        srv.alive = False
        self._notify(server_id)
        return list(srv.instances.values())

    def fail_site(self, site: str) -> List[Instance]:
        lost = []
        for sid in self.sites.get(site, ()):
            lost.extend(self.fail_server(sid))
        return lost

    def revive_server(self, server_id: str) -> Server:
        """A rejoining node comes back alive and EMPTY (its accelerator
        state did not survive the crash); the control plane re-fills it."""
        srv = self.servers[server_id]
        srv.instances.clear()
        srv.alive = True
        self._notify(server_id)
        return srv

    # backwards-compatible alias
    def recover_server(self, server_id: str):
        self.revive_server(server_id)


def make_cluster(n_sites: int, servers_per_site: int,
                 mem: float = 16e9, compute: float = 1.0) -> Cluster:
    """Uniform cluster: paper testbed = 3 sites x 2; sim = 10 x 10."""
    servers = []
    for si in range(n_sites):
        for sj in range(servers_per_site):
            servers.append(Server(
                id=f"s{si}-{sj}", site=f"site{si}",
                capacity={"mem": mem, "compute": compute}))
    return Cluster(servers)
