"""Backend protocol + registry: one spec, two execution engines.

A `Backend` turns an `ExperimentSpec` into a `RunResult`. Two are
registered here:

  * ``sim`` — the discrete-event simulator (`core/simulation.py`):
    deterministic, seconds of wall clock for hundreds of apps, carries
    the bit-identical `fingerprint()` replay digest;
  * ``testbed`` — the thread-based mini-testbed (`serving/testbed.py`):
    real JAX engines on live worker threads, real heartbeats, real
    compile-bound model loads, real client-measured request outcomes —
    the same `ScenarioEvent` stream replayed on a wall clock.

Both resolve the scenario the same way (named library or a programmatic
`spec.scenario_builder(cluster, apps, rng)`) and both report through the
same `RunResult` schema, so `run_experiment(spec)` is the single entry
point of the repo and `spec.with_(backend=...)` is the only difference
between a simulated and a live run.
"""

from __future__ import annotations

import random
import time
from typing import Dict, Protocol, runtime_checkable

from repro.core.scenario import Scenario, build_scenario
from repro.experiment.result import RunResult
from repro.experiment.spec import ExperimentSpec


@runtime_checkable
class Backend(Protocol):
    """Execution engine: materialize + run one spec."""
    name: str

    def run(self, spec: ExperimentSpec) -> RunResult: ...


BACKENDS: Dict[str, Backend] = {}


def register_backend(backend: Backend) -> Backend:
    BACKENDS[backend.name] = backend
    return backend


def get_backend(name: str) -> Backend:
    try:
        return BACKENDS[name]
    except KeyError:
        raise KeyError(f"unknown backend {name!r}; "
                       f"have {sorted(BACKENDS)}") from None


def run_experiment(spec: ExperimentSpec) -> RunResult:
    """THE public entry point: run `spec` on its selected backend."""
    return get_backend(spec.backend).run(spec)


def resolve_scenario(spec: ExperimentSpec, cluster, apps) -> Scenario:
    """Named library or programmatic builder — same resolution on every
    backend, with the same (name, seed)-derived RNG."""
    if spec.scenario_builder is not None:
        rng = random.Random(f"{spec.scenario}:{spec.seed}")
        sc = spec.scenario_builder(cluster, list(apps), rng)
        sc.validate(cluster)
        return sc
    return build_scenario(spec.scenario, cluster, apps, seed=spec.seed)


def primary_kill_scenario(app_id=None, *, t_fail: float = 1.0,
                          horizon: float = 30.0):
    """Builder: crash the server hosting `app_id`'s primary (first app
    if None) — the paper's base experiment, victim chosen after
    placement so it is guaranteed to hit a serving replica."""
    from repro.core.scenario import ServerFail

    def build(cluster, apps, rng) -> Scenario:
        target = app_id if app_id is not None else apps[0].id
        victim = next(
            s.id for s in cluster.servers.values()
            for inst in s.instances.values()
            if inst.app_id == target and inst.role == "primary")
        return Scenario(
            name="primary-kill",
            events=[ServerFail(t=t_fail, server=victim)],
            horizon=horizon,
            description=f"crash the server hosting {target}'s primary")
    return build


# ---------------------------------------------------------------------------
# sim backend
# ---------------------------------------------------------------------------

class SimBackend:
    name = "sim"

    def run(self, spec: ExperimentSpec) -> RunResult:
        from repro.core.simulation import SimConfig, Simulation

        t0 = time.perf_counter()
        cfg_kw = dict(
            n_sites=spec.n_sites, servers_per_site=spec.servers_per_site,
            server_mem=spec.server_mem, headroom=spec.headroom,
            critical_frac=spec.critical_frac, alpha=spec.alpha,
            policy=spec.policy, site_independence=spec.site_independence,
            planner=spec.planner, seed=spec.seed,
            traffic_rate_scale=spec.traffic_rate_scale,
            traffic_chunk_s=spec.traffic_chunk_s,
            traffic_diurnal_amplitude=spec.traffic_diurnal_amplitude,
            traffic_diurnal_period=spec.traffic_diurnal_period,
            storage=spec.storage, scheduler=spec.scheduler,
            autopilot=spec.autopilot, resilience=spec.resilience,
            event_mode=spec.event_mode, planner_dtype=spec.planner_dtype,
            planner_backend=spec.planner_backend,
            planner_coordinators=spec.planner_coordinators,
            load_bw=spec.load_bw, warmup_s=spec.warmup_s,
            nic_bw=spec.nic_bw, cloud_bw=spec.cloud_bw,
            replication=spec.replication,
            tp_degree=spec.tp_degree, shard_policy=spec.shard_policy)
        apps = list(spec.apps) if spec.apps is not None else None
        if apps is None and spec.app_mix == "arch":
            from repro.experiment.workload import (ARCH_COMPUTE_CAP,
                                                   arch_mem_cap,
                                                   build_arch_apps)
            apps = build_arch_apps(spec.archs,
                                   apps_per_arch=spec.apps_per_arch,
                                   critical_frac=spec.critical_frac,
                                   seed=spec.seed)
            n_servers = spec.n_sites * spec.servers_per_site
            # mirror the testbed's capacity rule exactly (no other-tenant
            # blockers either: headroom already shaped the sizing)
            cfg_kw.update(
                server_mem=arch_mem_cap(apps, n_servers, spec.headroom),
                server_compute=ARCH_COMPUTE_CAP, headroom=1.0)

        sim = Simulation(SimConfig(**cfg_kw), apps=apps).setup()
        scenario = resolve_scenario(spec, sim.cluster, sim.apps)
        run_kw = {}
        if spec.settle_s is not None:
            run_kw["settle"] = spec.settle_s
        res = sim.run_scenario(scenario, **run_kw)
        return RunResult(
            backend=self.name, scenario=scenario.name, policy=spec.policy,
            seed=spec.seed, n_epochs=res.n_epochs, per_epoch=res.per_epoch,
            overall=res.overall, warm_coverage=res.warm_coverage,
            records=res.records, unplaced_arrivals=res.unplaced_arrivals,
            n_apps_final=res.n_apps_final, traffic=res.traffic,
            plan_wall_s=sim.controller.plan_wall_s,
            wall_s=time.perf_counter() - t0, sim_result=res,
            extras={"protection": sim.protection_summary(),
                    "planner": sim.controller.planner_stats(),
                    **({"shard": sim.shard_summary()}
                       if spec.tp_degree > 1 else {})})


# ---------------------------------------------------------------------------
# testbed backend
# ---------------------------------------------------------------------------

class TestbedBackend:
    name = "testbed"

    def run(self, spec: ExperimentSpec) -> RunResult:
        from repro.serving.testbed import MiniTestbed

        if spec.autopilot:
            raise ValueError(
                "autopilot needs the simulator's live metrics feed; "
                "run the spec with backend='sim'")
        t0 = time.perf_counter()
        tb = MiniTestbed(
            n_sites=spec.n_sites, servers_per_site=spec.servers_per_site,
            apps_per_arch=spec.apps_per_arch,
            critical_frac=spec.critical_frac, headroom=spec.headroom,
            policy=spec.policy, planner=spec.planner, alpha=spec.alpha,
            site_independence=spec.site_independence, seed=spec.seed,
            archs=spec.archs, storage=spec.storage,
            scheduler=spec.scheduler, load_bw=spec.load_bw,
            warmup_s=spec.warmup_s, nic_bw=spec.nic_bw,
            cloud_bw=spec.cloud_bw, replication=spec.replication,
            resilience=spec.resilience,
            tp_degree=spec.tp_degree, shard_policy=spec.shard_policy,
            apps=list(spec.apps) if spec.apps is not None else None)
        try:
            tb.deploy()
            scenario = resolve_scenario(spec, tb.cluster, tb.apps)
            out = tb.run_scenario(
                scenario, time_scale=spec.time_scale,
                settle_s=spec.settle_s, client_hz=spec.client_hz)
        finally:
            tb.shutdown()
        ctl = tb.controller
        return RunResult(
            backend=self.name, scenario=scenario.name, policy=spec.policy,
            seed=spec.seed, n_epochs=out["n_epochs"],
            per_epoch=out["per_epoch"], overall=out["overall"],
            warm_coverage=out["warm_coverage"], records=out["records"],
            unplaced_arrivals=out["unplaced_arrivals"],
            n_apps_final=len(ctl.apps), traffic=out["traffic"],
            plan_wall_s=ctl.plan_wall_s,
            wall_s=time.perf_counter() - t0,
            detect_latency_s=out["detect_latency_s"],
            extras={"client_stats": out["client_stats"],
                    "load_calibration": out.get("load_calibration", {}),
                    "planner": ctl.planner_stats(),
                    **({"shard": out.get("shard", {})}
                       if spec.tp_degree > 1 else {})})


register_backend(SimBackend())
register_backend(TestbedBackend())
