"""repro.experiment — the repo's single public experiment API.

    from repro.experiment import ExperimentSpec, run_experiment

    res = run_experiment(ExperimentSpec(scenario="cascade", seed=1))
    live = run_experiment(ExperimentSpec.smoke("testbed"))

One declarative `ExperimentSpec` runs on either registered `Backend`
("sim" = deterministic discrete-event simulator, "testbed" = live
worker threads with real JAX engines) and always returns the unified
`RunResult` schema. See docs/EXPERIMENTS.md.
"""

from repro.experiment.backends import (BACKENDS, Backend, SimBackend,
                                       TestbedBackend, get_backend,
                                       primary_kill_scenario,
                                       register_backend, resolve_scenario,
                                       run_experiment)
from repro.experiment.result import RunResult
from repro.experiment.spec import ExperimentSpec
from repro.experiment.workload import (TESTBED_ARCHS, arch_mem_cap,
                                       build_arch_apps, testbed_ladder)

__all__ = [
    "BACKENDS", "Backend", "ExperimentSpec", "RunResult", "SimBackend",
    "TESTBED_ARCHS", "TestbedBackend", "arch_mem_cap", "build_arch_apps",
    "get_backend", "primary_kill_scenario", "register_backend",
    "resolve_scenario", "run_experiment", "testbed_ladder",
]
