"""Shared arch-mix workload construction — one sizing rule, two backends.

The cross-backend guarantee ("same spec, same failover choices") only
holds if both engines hand the planner identical inputs. This module is
the single source of truth for the `app_mix="arch"` workload: the
variant ladders (reduced smoke configs of real architectures), the app
list (ids, rates, criticality drawn from one seeded stream), and the
capacity sizing rule (servers scaled so primaries fill ~50% of the
cluster at the requested headroom, as on the paper's testbed). The
testbed serves these apps with real JAX engines; the simulator places
the exact same objects on a cluster with the exact same capacities.

Imports of the model-config stack are kept inside functions so that
plain synthetic-mix simulation runs never pay the JAX import.
"""

from __future__ import annotations

import random
from typing import List, Optional, Sequence

from repro.core.variants import Application, Variant

# the real architectures the thread testbed can serve (reduced configs)
TESTBED_ARCHS = ["qwen2.5-3b", "qwen3-32b", "recurrentgemma-2b",
                 "rwkv6-3b", "qwen3-moe-30b-a3b"]

# uniform compute budget per serving cell (both backends)
ARCH_COMPUTE_CAP = 1e9


def testbed_ladder(arch: str) -> List[Variant]:
    """Variant ladder over an extra-reduced smoke config (CPU-budget:
    load time is dominated by XLA compiles, the testbed's stand-in for
    the paper's disk-bandwidth-dominated Triton loads)."""
    from repro import configs
    from repro.core.variants import build_ladder

    smoke = configs.get_smoke(arch)
    plen = len(smoke.block_pattern)
    n_layers = plen if not smoke.is_encoder_decoder else 2
    kw = dict(scan_layers=True, num_layers=n_layers)
    if smoke.is_encoder_decoder:
        kw.update(num_encoder_layers=1, num_decoder_layers=1)
    return build_ladder(smoke.replace(**kw), cell_mem=64e6)


def build_arch_apps(archs: Optional[Sequence[str]] = None, *,
                    apps_per_arch: int = 1, critical_frac: float = 0.5,
                    seed: int = 0) -> List[Application]:
    """The arch-mix application set; identical on every backend for the
    same (archs, apps_per_arch, critical_frac, seed)."""
    rng = random.Random(seed)
    apps: List[Application] = []
    i = 0
    for arch in (archs or TESTBED_ARCHS):
        for _ in range(apps_per_arch):
            ladder = testbed_ladder(arch)
            apps.append(Application(
                id=f"{arch}-app{i}", family=arch, variants=ladder,
                request_rate=rng.uniform(0.5, 2.0),
                critical=(rng.random() < critical_frac)))
            i += 1
    return apps


def arch_mem_cap(apps: Sequence[Application], n_servers: int,
                 headroom: float) -> float:
    """Per-server memory so primaries fill ~50% of usable capacity at
    the requested headroom (and the largest primary always fits)."""
    total_primary = sum(a.full.demand["mem"] for a in apps)
    max_primary = max(a.full.demand["mem"] for a in apps)
    return max(total_primary / (n_servers * (1.0 - headroom) * 0.5),
               1.5 * max_primary)
