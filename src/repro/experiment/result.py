"""RunResult — the unified outcome schema of both execution backends.

Whatever engine ran the spec, the caller gets the same shape back:

  * control plane: the controller's per-epoch `RecoveryRecord`s plus the
    per-epoch / overall summaries (`recovery_rate`, `mttr_avg`,
    `accuracy_reduction`, `n`) and end-of-run warm coverage;
  * request plane: one `core.metrics.TrafficSummary` — on the sim it is
    classified from the vectorized request streams, on the testbed it is
    aggregated by the SAME `core.metrics.aggregate` code from real
    request outcomes measured by live clients;
  * planner cost: cumulative planner wall time across every planning
    round of the run;
  * provenance: the spec that produced it and the run's wall-clock cost.

The sim path additionally keeps the raw `ScenarioResult` so the
deterministic `fingerprint()` (bit-identical replay digest, unchanged
from before this API existed) remains available; the testbed runs on a
wall clock and is inherently non-reproducible bit-for-bit, so
`fingerprint()` raises there.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import List, Optional

from repro.core.metrics import TrafficSummary


def _json_num(x):
    """JSON-safe float: inf/nan become the repo-wide -1.0 sentinel."""
    if isinstance(x, float) and not math.isfinite(x):
        return -1.0
    return x


@dataclass
class RunResult:
    backend: str
    scenario: str
    policy: str
    seed: int
    # control plane
    n_epochs: int
    per_epoch: List[dict]
    overall: dict
    warm_coverage: float
    records: List[object]              # flat per-epoch RecoveryRecords
    unplaced_arrivals: int = 0
    n_apps_final: int = 0
    # request plane
    traffic: Optional[TrafficSummary] = None
    # planner + run cost
    plan_wall_s: float = 0.0
    wall_s: float = 0.0
    # testbed-only: heartbeat-detection latency of the first injection
    detect_latency_s: float = math.nan
    # sim-only: the raw deterministic scenario outcome
    sim_result: Optional[object] = None
    # free-form backend extras (e.g. testbed per-app client stats)
    extras: dict = field(default_factory=dict)

    def fingerprint(self) -> tuple:
        """Deterministic replay digest (sim backend only)."""
        if self.sim_result is None:
            raise ValueError(
                f"fingerprint() needs a deterministic backend; "
                f"{self.backend!r} runs on a wall clock")
        return self.sim_result.fingerprint()

    def recovery_by_app(self) -> dict:
        """app_id -> (recovered, mode, final variant) over the run's
        LATEST record per app — the cross-backend parity view: backends
        may differ in wall-clock MTTR but not in failover choices."""
        out = {}
        for r in self.records:          # flat records are in epoch order
            out[r.app_id] = (r.recovered, r.mode,
                             r.upgraded_to or r.variant)
        return out

    def to_row(self) -> dict:
        """Flat CSV-friendly summary row (same keys on every backend)."""
        t = self.traffic
        return {
            "backend": self.backend,
            "scenario": self.scenario,
            "policy": self.policy,
            "seed": self.seed,
            "n_epochs": self.n_epochs,
            "n": self.overall.get("n", 0),
            "recovery_rate": self.overall.get("recovery_rate", 1.0),
            "ctl_mttr_ms": ms_sentinel(self.overall.get("mttr_avg", 0.0)),
            "acc_red_pct": 100.0
            * self.overall.get("accuracy_reduction", 0.0),
            "warm_coverage": self.warm_coverage,
            "unplaced": self.unplaced_arrivals,
            "n_offered": t.n_offered if t else 0,
            "availability": t.availability if t else 1.0,
            "client_mttr_ms": (ms_sentinel(t.client_mttr_avg)
                               if t else 0.0),
            "goodput": t.goodput if t else 1.0,
            "plan_wall_ms": self.plan_wall_s * 1e3,
            "wall_s": self.wall_s,
        }

    def to_json_dict(self) -> dict:
        """The full result as JSON-serializable plain data — what
        ``repro run --out result.json`` writes for CI trend tracking.
        Covers the flat summary row, per-epoch summaries, every
        recovery record (with MTTR phase breakdown), and the traffic
        summary; backend extras are included when they are plain data
        (e.g. the testbed's load calibration)."""
        t = self.traffic
        doc = {
            "row": self.to_row(),
            "per_epoch": [{k: _json_num(v) for k, v in e.items()}
                          for e in self.per_epoch],
            "overall": {k: _json_num(v) for k, v in self.overall.items()},
            "records": [record_to_dict(r) for r in self.records],
            "traffic": ({k: _json_num(v) for k, v in t.to_dict().items()}
                        if t is not None else None),
            "traffic_per_epoch": ([{k: _json_num(v) for k, v in e.items()}
                                   for e in t.per_epoch]
                                  if t is not None else []),
            "detect_latency_s": _json_num(self.detect_latency_s),
        }
        cal = self.extras.get("load_calibration")
        if cal:
            doc["load_calibration"] = {k: _json_num(v)
                                       for k, v in cal.items()}
        prot = self.extras.get("protection")
        if prot:
            # warm-replica headroom actually spent (sim backend): the
            # soak trend's equal-or-lower-headroom evidence
            doc["protection"] = {k: _json_num(v)
                                 for k, v in prot.items()}
        planner = self.extras.get("planner")
        if planner:
            # planner configuration + counters (backend routing, dense
            # fallbacks) — gates the backend-parity CI trend specs
            doc["planner"] = {k: (v if isinstance(v, str)
                                  else _json_num(v))
                              for k, v in planner.items()}
        shard = self.extras.get("shard")
        if shard:
            # shard plane report (tp_degree >= 2): group states, ladder
            # actions, per-action MTTRs, testbed reshard measurements
            doc["shard"] = {k: ({kk: _json_num(vv)
                                 for kk, vv in v.items()}
                                if isinstance(v, dict) else _json_num(v))
                            for k, v in shard.items()}
        return doc


def record_to_dict(r) -> dict:
    """One RecoveryRecord as JSON-safe plain data."""
    return {
        "app_id": r.app_id, "recovered": r.recovered,
        "mttr_ms": ms_sentinel(r.mttr), "variant": r.variant,
        "accuracy": r.accuracy, "mode": r.mode,
        "upgraded_to": r.upgraded_to, "epoch": r.epoch,
        "t_fail": r.t_fail, "source": getattr(r, "source", None),
        "phases": {k: _json_num(v)
                   for k, v in getattr(r, "phases", {}).items()},
    }


def ms_sentinel(seconds: float) -> float:
    """ms with the repo-wide -1.0 sentinel for inf (nothing recovered);
    the one converter behind every CSV column that prints MTTRs."""
    return seconds * 1e3 if math.isfinite(seconds) else -1.0
