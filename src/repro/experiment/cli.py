"""`python -m repro` / the `repro` console script.

    repro run [--backend {sim,testbed}] [--scenario NAME] [--policy P]
              [--seed N] [--smoke] [--json] [...cluster/traffic knobs]
    repro list

`run` builds an `ExperimentSpec` from the flags and executes it on the
selected backend; `--smoke` loads the reduced CI preset for that backend
(2x2 sim cluster / 2-server 2-app testbed) before applying explicit
overrides. `list` prints the available scenarios, backends, policies,
and planners.
"""

from __future__ import annotations

import argparse
import json
import math
import sys
from typing import List, Optional


def _build_parser() -> argparse.ArgumentParser:
    from repro.core.controller import POLICIES

    ap = argparse.ArgumentParser(
        prog="repro",
        description="FailLite reproduction — one experiment API, "
                    "two backends")
    sub = ap.add_subparsers(dest="cmd", required=True)

    from repro.experiment.backends import BACKENDS

    run = sub.add_parser("run", help="run one experiment spec")
    run.add_argument("--backend", default=None,
                     choices=sorted(BACKENDS),
                     help="execution engine (default: sim)")
    run.add_argument("--scenario", default=None,
                     help="named scenario (see `repro list`)")
    run.add_argument("--policy", default=None, choices=POLICIES)
    run.add_argument("--planner", default=None)
    run.add_argument("--seed", type=int, default=None)
    run.add_argument("--sites", type=int, default=None, dest="n_sites")
    run.add_argument("--servers-per-site", type=int, default=None)
    run.add_argument("--headroom", type=float, default=None)
    run.add_argument("--critical-frac", type=float, default=None)
    run.add_argument("--app-mix", default=None,
                     choices=["synthetic", "arch"])
    run.add_argument("--archs", default=None,
                     help="comma-separated arch list (arch mix)")
    run.add_argument("--apps-per-arch", type=int, default=None)
    run.add_argument("--traffic-rate-scale", type=float, default=None)
    run.add_argument("--diurnal-amplitude", type=float, default=None,
                     dest="traffic_diurnal_amplitude",
                     help="sinusoidal rate modulation depth (0 = plain "
                          "Poisson)")
    run.add_argument("--diurnal-period", type=float, default=None,
                     dest="traffic_diurnal_period")
    run.add_argument("--autopilot", action="store_true", default=None,
                     help="adaptive protection from the live metrics "
                          "plane (core/autopilot.py; sim only)")
    run.add_argument("--resilience", action="store_true", default=None,
                     help="request-plane resilience toolkit with default "
                          "knobs: hedging, breakers, bulkheads, "
                          "admission (core/resilience.py, both backends)")
    run.add_argument("--event-mode", default=None, dest="event_mode",
                     choices=["epoch", "per-event"],
                     help="sim event-loop drain: vectorized epoch folds "
                          "(bit-exact default) or the historical "
                          "per-event path (docs/SCALE.md)")
    run.add_argument("--planner-dtype", default=None, dest="planner_dtype",
                     choices=["float64", "float32"],
                     help="planner array dtype; float32 halves planner "
                          "memory for planet-scale runs (not bit-exact)")
    run.add_argument("--planner-backend", default=None,
                     dest="planner_backend", choices=["numpy", "jax"],
                     help="planner compute backend: numpy (default) or "
                          "jax compiled chunk kernels — bit-identical "
                          "plans (docs/PLANNER.md)")
    run.add_argument("--planner-coordinators", type=int, default=None,
                     dest="planner_coordinators", metavar="N",
                     help="sharded planner: plan with N concurrent "
                          "site-slice coordinators (numpy path)")
    run.add_argument("--client-hz", type=float, default=None)
    run.add_argument("--settle", type=float, default=None,
                     dest="settle_s")
    run.add_argument("--time-scale", type=float, default=None)
    run.add_argument("--storage", default=None,
                     help="storage preset: local | edge "
                          "(model-state plane, docs/ARCHITECTURE.md)")
    run.add_argument("--scheduler", default=None,
                     choices=["fifo", "criticality"],
                     help="recovery drain-queue order")
    run.add_argument("--tp-degree", type=int, default=None,
                     dest="tp_degree",
                     help="deploy every app as a tensor-parallel group "
                          "spanning this many servers (shard plane, "
                          "docs/SHARDING_FAILOVER.md); 1 = monoliths")
    run.add_argument("--shard-policy", default=None, dest="shard_policy",
                     choices=["auto", "degrade", "reshard", "monolith"],
                     help="shard recovery ladder on a member loss "
                          "(auto = critical->degrade, rest->reshard)")
    run.add_argument("--load-bw", type=float, default=None,
                     dest="load_bw",
                     help="disk->HBM bytes/s (Fig. 2b load model)")
    run.add_argument("--warmup-s", type=float, default=None,
                     dest="warmup_s")
    run.add_argument("--smoke", action="store_true",
                     help="reduced CI config for the chosen backend")
    run.add_argument("--json", action="store_true", dest="as_json",
                     help="emit the summary row as JSON")
    run.add_argument("--out", default=None, metavar="FILE",
                     help="dump the full RunResult as JSON to FILE "
                          "(CI trend tracking)")

    sub.add_parser("list", help="show scenarios/backends/policies/planners")
    return ap


def _spec_from_args(args) -> "ExperimentSpec":
    from repro.experiment.spec import ExperimentSpec

    backend = args.backend or "sim"
    spec = (ExperimentSpec.smoke(backend) if args.smoke
            else ExperimentSpec(backend=backend))
    overrides = {}
    for attr in ("backend", "scenario", "policy", "planner", "seed",
                 "n_sites", "servers_per_site", "headroom",
                 "critical_frac", "app_mix", "apps_per_arch",
                 "traffic_rate_scale", "traffic_diurnal_amplitude",
                 "traffic_diurnal_period", "autopilot", "client_hz",
                 "settle_s", "time_scale", "storage", "scheduler",
                 "load_bw", "warmup_s", "event_mode", "planner_dtype",
                 "planner_backend", "planner_coordinators",
                 "tp_degree", "shard_policy"):
        val = getattr(args, attr, None)
        if val is not None:
            overrides[attr] = val
    if args.archs is not None:
        overrides["archs"] = [a.strip() for a in args.archs.split(",")
                              if a.strip()]
        overrides.setdefault("app_mix", "arch")
    if getattr(args, "resilience", None):
        overrides["resilience"] = {"enabled": True}
    return spec.with_(**overrides)


def _print_result(res, as_json: bool):
    row = res.to_row()
    if as_json:
        print(json.dumps(row, indent=1))
        return
    print(f"\n[{res.backend}] scenario={res.scenario} "
          f"policy={res.policy} seed={res.seed}")
    o = res.overall
    mttr = (f"{o['mttr_avg']*1e3:.1f} ms"
            if math.isfinite(o.get("mttr_avg", 0.0)) else "inf")
    print(f"  control plane: {o['n']} affected over {res.n_epochs} "
          f"epoch(s), recovery {o['recovery_rate']:.1%}, "
          f"MTTR {mttr}, accuracy cost "
          f"{o['accuracy_reduction']:.2%}")
    if math.isfinite(res.detect_latency_s):
        print(f"  detection latency: {res.detect_latency_s*1e3:.0f} ms")
    t = res.traffic
    if t is not None:
        cli_mttr = (f"{t.client_mttr_avg*1e3:.1f} ms"
                    if math.isfinite(t.client_mttr_avg) else "inf")
        print(f"  request plane: {t.n_offered} offered, availability "
              f"{t.availability:.4%}, client MTTR {cli_mttr}, "
              f"goodput {t.goodput:.4f}, dropped {t.n_dropped}")
    print(f"  warm coverage {res.warm_coverage:.0%}, planner "
          f"{res.plan_wall_s*1e3:.1f} ms, run wall {res.wall_s:.1f} s")
    for r in sorted(res.records, key=lambda r: (r.epoch, r.app_id)):
        mt = f"{r.mttr*1e3:8.1f}" if math.isfinite(r.mttr) else "     inf"
        print(f"    e{r.epoch} {r.app_id:24s} "
              f"{'ok ' if r.recovered else 'DOWN'} {r.mode:17s} "
              f"{mt} ms -> {r.upgraded_to or r.variant}")


def _cmd_list():
    from repro.core.controller import POLICIES
    from repro.core.planner import available_planners
    from repro.core.scenario import SCENARIOS
    from repro.experiment.backends import BACKENDS

    print("backends: ", ", ".join(sorted(BACKENDS)))
    print("policies: ", ", ".join(POLICIES))
    print("planners: ", ", ".join(sorted(available_planners())))
    print("scenarios:")
    for name in sorted(SCENARIOS):
        print(f"  {name}")


def main(argv: Optional[List[str]] = None) -> int:
    args = _build_parser().parse_args(argv)
    if args.cmd == "list":
        _cmd_list()
        return 0
    from repro.experiment.backends import run_experiment

    spec = _spec_from_args(args)
    res = run_experiment(spec)
    _print_result(res, args.as_json)
    if args.out:
        from pathlib import Path

        doc = {"spec": spec.to_dict(), **res.to_json_dict()}
        Path(args.out).write_text(json.dumps(doc, indent=1) + "\n")
        print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
