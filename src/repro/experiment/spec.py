"""ExperimentSpec — the declarative entry point of the repo.

One spec describes a complete resilience experiment independently of the
engine that executes it: cluster shape, application mix, the failure
scenario to replay, the protection policy and planner, the traffic
configuration, and the seed. The `backend` field selects the execution
engine — `"sim"` (discrete-event simulator, core/simulation.py) or
`"testbed"` (live worker threads with real JAX engines on a wall clock,
serving/testbed.py) — and the SAME spec runs on either: both backends
replay the same `ScenarioEvent` stream and return the same `RunResult`
schema (see experiment/result.py).

App mixes:
  * ``synthetic`` — profile-only variant ladders sized by the paper's
    Small/Medium/Large family spread classes (simulator default; not
    servable on the testbed because the variants carry no ModelConfig);
  * ``arch`` — reduced smoke configs of real architectures
    (`serving.testbed.TESTBED_ARCHS`): servable on the testbed AND
    runnable in the simulator, which is what makes cross-backend parity
    experiments possible (same apps, same cluster sizing rule, same
    planner inputs on both engines).

Specs are plain data: `to_dict()`/`from_dict()` round-trip every
CLI-reachable field, so experiments can be stored/replayed as JSON. Two
escape hatches exist for programmatic use only (both excluded from the
dict form): `apps` pins an explicit Application list, and
`scenario_builder` supplies a custom Scenario factory where the named
library does not fit (e.g. "kill the server hosting app0's primary").
"""

from __future__ import annotations

from dataclasses import dataclass, field, fields, replace
from typing import Callable, List, Optional, Sequence

from repro.core.variants import LOAD_BW, WARMUP_S

APP_MIXES = ("synthetic", "arch")


@dataclass
class ExperimentSpec:
    # what to run
    scenario: str = "single-server"     # named scenario (core/scenario.py)
    backend: str = "sim"                # "sim" | "testbed"
    policy: str = "faillite"
    planner: Optional[str] = None       # registry name; None = policy default
    alpha: float = 0.1
    site_independence: bool = False
    seed: int = 0
    # cluster shape
    n_sites: int = 4
    servers_per_site: int = 5
    server_mem: float = 16e9            # synthetic mix only (arch mix sizes
                                        # capacity from the app set)
    headroom: float = 0.2
    critical_frac: float = 0.5
    # app mix
    app_mix: str = "synthetic"
    archs: Optional[List[str]] = None   # arch mix: None = TESTBED_ARCHS
    apps_per_arch: int = 1
    # traffic plane
    traffic_rate_scale: float = 20.0    # sim: requests/s per unit rate q_i
    traffic_chunk_s: float = 0.5
    traffic_diurnal_amplitude: float = 0.0   # sim: 0 = plain Poisson
    traffic_diurnal_period: float = 240.0
    client_hz: float = 10.0             # testbed: per-app client rate
    # model-state plane (core/modelstate.py): where checkpoint bytes
    # live and what moving them costs. "local" reduces bit-exactly to
    # the historical flat load model; "edge" is the paper-faithful
    # constrained topology (peer NICs + one shared cloud uplink).
    storage: str = "local"              # storage preset name
    scheduler: str = "fifo"             # recovery drain: fifo|criticality
    # adaptive protection (core/autopilot.py): sim-only closed loop from
    # observed traffic back into the warm set / replication / drain order
    autopilot: bool = False
    # request-plane resilience toolkit (core/resilience.py): a
    # ResilienceConfig as a plain dict ({"enabled": True} turns the
    # defaults on); None = historical request plane, bit-exact
    resilience: Optional[dict] = None
    # planet-scale engine knobs (docs/SCALE.md): event-loop drain
    # strategy ("epoch" = vectorized folds, bit-exact; "per-event" =
    # historical compat/baseline) and planner array dtype ("float32"
    # halves PlannerState memory; scale runs only, not bit-exact)
    event_mode: str = "epoch"
    planner_dtype: str = "float64"
    # planner compute backend: "numpy" (bit-exact default) or "jax"
    # (compiled chunk kernels, bit-identical — docs/PLANNER.md);
    # planner_coordinators >= 2 runs sharded numpy rounds with that
    # many concurrent site-slice coordinators
    planner_backend: str = "numpy"
    planner_coordinators: int = 0
    # shard plane (core/shardgroup.py): tp_degree >= 2 deploys every
    # app as a tensor-parallel group of that many servers; shard_policy
    # picks the recovery ladder rung on a member loss ("auto" =
    # critical -> degrade, rest -> reshard; or force "degrade" /
    # "reshard" / "monolith"). tp_degree=1 keeps the monolith path
    # bit-exact on both backends.
    tp_degree: int = 1
    shard_policy: str = "auto"
    load_bw: float = LOAD_BW            # bytes/s disk->HBM (Fig. 2b)
    warmup_s: float = WARMUP_S          # per-instance warmup seconds
    nic_bw: Optional[float] = None      # preset overrides (None = keep)
    cloud_bw: Optional[float] = None
    replication: Optional[int] = None
    # time control
    settle_s: Optional[float] = None    # post-horizon settle; None = default
    time_scale: float = 1.0             # testbed: event-time compression
    # programmatic escape hatches (not serialized)
    apps: Optional[Sequence] = field(default=None, repr=False)
    scenario_builder: Optional[Callable] = field(default=None, repr=False)

    _SKIP = ("apps", "scenario_builder")

    def __post_init__(self):
        if self.app_mix not in APP_MIXES:
            raise ValueError(f"unknown app_mix {self.app_mix!r}; "
                             f"have {APP_MIXES}")
        if self.backend == "testbed" and self.app_mix == "synthetic" \
                and self.apps is None:
            # synthetic ladders carry no ModelConfig -> nothing to serve
            self.app_mix = "arch"

    # -- serialization ------------------------------------------------------
    def to_dict(self) -> dict:
        return {f.name: getattr(self, f.name) for f in fields(self)
                if f.name not in self._SKIP}

    @classmethod
    def from_dict(cls, d: dict) -> "ExperimentSpec":
        known = {f.name for f in fields(cls)} - set(cls._SKIP)
        unknown = set(d) - known
        if unknown:
            raise ValueError(f"unknown ExperimentSpec fields: "
                             f"{sorted(unknown)}")
        return cls(**d)

    def with_(self, **kw) -> "ExperimentSpec":
        return replace(self, **kw)

    # -- presets ------------------------------------------------------------
    @classmethod
    def smoke(cls, backend: str = "sim") -> "ExperimentSpec":
        """CI smoke preset: smallest config that still exercises a full
        deploy -> crash -> detect -> failover -> recover cycle."""
        if backend == "testbed":
            return cls(backend="testbed", scenario="single-server",
                       app_mix="arch", archs=["qwen2.5-3b", "rwkv6-3b"],
                       apps_per_arch=1, n_sites=2, servers_per_site=1,
                       headroom=0.35, client_hz=20.0, time_scale=0.25,
                       settle_s=12.0, seed=3)
        return cls(backend=backend, scenario="single-server",
                   n_sites=2, servers_per_site=2, headroom=0.3,
                   traffic_rate_scale=5.0, settle_s=10.0, seed=0)
