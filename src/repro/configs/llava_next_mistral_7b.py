"""llava-next-mistral-7b — VLM: Mistral-7B backbone, anyres patch STUB.

[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified] 32L d_model=4096 32H
(GQA kv=8) d_ff=14336 vocab=32000, head_dim 128, rope 1e6 (Mistral
v0.2: no sliding window). ``input_specs()`` supplies 576 precomputed
patch embeddings prepended to the text tokens.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14_336,
    vocab_size=32_000,
    block_pattern=("global",),
    num_patch_tokens=576,
    rope_theta=1_000_000.0,
    tie_embeddings=False,
)

SMOKE = CONFIG.replace(
    num_layers=4, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=503, num_patch_tokens=8,
    param_dtype="float32", activation_dtype="float32", remat=False,
)
