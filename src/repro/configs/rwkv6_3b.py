"""rwkv6-3b — Finch: attention-free, data-dependent decay.

[arXiv:2404.05892; hf] 32L d_model=2560 (attn-free) d_ff=8960
vocab=65536, head_size 64 (40 heads).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    num_layers=32,
    d_model=2560,
    num_heads=0,
    num_kv_heads=0,
    head_dim=64,
    d_ff=8960,
    vocab_size=65_536,
    block_pattern=("rwkv",),
    rwkv_head_size=64,
    tie_embeddings=False,
)

SMOKE = CONFIG.replace(
    num_layers=4, d_model=64, d_ff=128, vocab_size=503, rwkv_head_size=16,
    param_dtype="float32", activation_dtype="float32", remat=False,
)
