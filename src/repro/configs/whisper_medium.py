"""whisper-medium — encoder-decoder; conv audio frontend is a STUB.

[arXiv:2212.04356; unverified] 24L encoder + 24L decoder, d_model=1024,
16H (kv=16) d_ff=4096 vocab=51865, head_dim 64, qkv_bias (whisper uses
biased projections). ``input_specs()`` supplies precomputed frame
embeddings (B, frames, d_model).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="encdec",
    num_layers=48,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=51_865,
    is_encoder_decoder=True,
    num_encoder_layers=24,
    num_decoder_layers=24,
    encoder_seq_len=1500,
    qkv_bias=True,
    tie_embeddings=True,
)

SMOKE = CONFIG.replace(
    num_layers=6, num_encoder_layers=3, num_decoder_layers=3,
    d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=503, encoder_seq_len=24,
    param_dtype="float32", activation_dtype="float32", remat=False,
)
