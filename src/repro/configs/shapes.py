"""Assigned input-shape cells and per-arch applicability.

Four LM shape cells per arch (40 total):
    train_4k     seq 4096   batch 256   -> train_step
    prefill_32k  seq 32768  batch 32    -> prefill_step
    decode_32k   KV 32768   batch 128   -> decode_step (one new token)
    long_500k    KV 524288  batch 1     -> decode_step, sub-quadratic only

Skips (recorded in DESIGN.md §Arch-applicability):
    long_500k only runs for archs with a sub-quadratic mechanism
    (recurrentgemma, gemma3 5:1 local:global, rwkv6).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


@dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str        # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES: Dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524_288, 1),
}

# archs allowed to run long_500k (sub-quadratic history mechanism)
LONG_OK = {"recurrentgemma-2b", "gemma3-27b", "rwkv6-3b"}


def cell_applicable(arch: str, shape: str) -> bool:
    if shape == "long_500k":
        return arch in LONG_OK
    return True


def applicable_cells(arch: str):
    return [s for s in SHAPES if cell_applicable(arch, s)]


def input_specs(cfg: ModelConfig, shape: ShapeCell) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of a shape cell.

    No device allocation — the dry-run lowers against these directly.
    """
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    sds = jax.ShapeDtypeStruct

    if shape.kind == "train":
        specs = {}
        if cfg.is_encoder_decoder:
            # encoder frames + teacher-forced decoder tokens
            specs["frame_embeds"] = sds((B, S, cfg.d_model), cfg.adtype)
            specs["tokens"] = sds((B, S), i32)
            specs["labels"] = sds((B, S), i32)
        elif cfg.num_patch_tokens:
            P = cfg.num_patch_tokens
            specs["patch_embeds"] = sds((B, P, cfg.d_model), cfg.adtype)
            specs["tokens"] = sds((B, S - P), i32)
            specs["labels"] = sds((B, S - P), i32)
        else:
            specs["tokens"] = sds((B, S), i32)
            specs["labels"] = sds((B, S), i32)
        return specs

    if shape.kind == "prefill":
        specs = {}
        if cfg.is_encoder_decoder:
            specs["frame_embeds"] = sds((B, S, cfg.d_model), cfg.adtype)
            specs["tokens"] = sds((B, S), i32)
        elif cfg.num_patch_tokens:
            P = cfg.num_patch_tokens
            specs["patch_embeds"] = sds((B, P, cfg.d_model), cfg.adtype)
            specs["tokens"] = sds((B, S - P), i32)
        else:
            specs["tokens"] = sds((B, S), i32)
        return specs

    # decode: one new token against a cache of size seq_len
    return {"tokens": sds((B,), i32)}
