"""recurrentgemma-2b — Griffin: RG-LRU + local attention, 1:2 ratio.

[arXiv:2402.19427; hf] 26L d_model=2560 10H (MQA kv=1) d_ff=7680
vocab=256000, local window 2048, lru_width 2560, head_dim 256.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256_000,
    block_pattern=("rglru", "rglru", "local"),
    window_size=2048,
    rnn_width=2560,
    rnn_blocks=8,
    conv1d_width=4,
    rope_theta=10_000.0,
    scale_embedding=True,
    tie_embeddings=True,
)

SMOKE = CONFIG.replace(
    num_layers=8,                      # 2 cycles + (rglru, rglru) tail
    d_model=64, num_heads=4, num_kv_heads=1, head_dim=16,
    d_ff=128, vocab_size=503, rnn_width=64, rnn_blocks=4,
    window_size=8,
    param_dtype="float32", activation_dtype="float32", remat=False,
)
