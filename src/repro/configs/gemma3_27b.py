"""gemma3-27b — dense GQA, 5:1 local:global interleave, 128k context.

[hf:google/gemma-3 family; unverified] 62L d_model=5376 32H (GQA kv=16)
d_ff=21504 vocab=262144, head_dim 128, qk_norm, window 1024,
rope 1e6 (global) / 10k (local), sqrt(d) embedding scale.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b",
    family="dense",
    num_layers=62,
    d_model=5376,
    num_heads=32,
    num_kv_heads=16,
    head_dim=128,
    d_ff=21_504,
    vocab_size=262_144,
    block_pattern=("local", "local", "local", "local", "local", "global"),
    window_size=1024,
    qk_norm=True,
    rope_theta=1_000_000.0,
    rope_theta_local=10_000.0,
    scale_embedding=True,
    tie_embeddings=True,
)

SMOKE = CONFIG.replace(
    num_layers=8,                      # 1 cycle + 2 local tail
    d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=503, window_size=8,
    param_dtype="float32", activation_dtype="float32", remat=False,
)
