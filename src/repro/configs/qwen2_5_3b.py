"""qwen2.5-3b — dense GQA with QKV bias.

[hf:Qwen/Qwen2.5-0.5B family; hf] 36L d_model=2048 16H (GQA kv=2)
d_ff=11008 vocab=151936, head_dim 128, qkv_bias, tied embeddings, rope 1e6.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-3b",
    family="dense",
    num_layers=36,
    d_model=2048,
    num_heads=16,
    num_kv_heads=2,
    head_dim=128,
    d_ff=11_008,
    vocab_size=151_936,
    block_pattern=("global",),
    qkv_bias=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
)

SMOKE = CONFIG.replace(
    num_layers=4, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=503,
    param_dtype="float32", activation_dtype="float32", remat=False,
)
