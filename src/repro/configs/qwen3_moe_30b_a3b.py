"""qwen3-moe-30b-a3b — MoE, 128 experts top-8, qk_norm GQA.

[hf:Qwen/Qwen3-30B-A3B; hf] 48L d_model=2048 32H (GQA kv=4) expert
d_ff=768 vocab=151936, head_dim 128, rope 1e6.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=768,
    vocab_size=151_936,
    block_pattern=("moe",),
    num_experts=128,
    top_k=8,
    moe_d_ff=768,
    capacity_factor=1.25,
    qk_norm=True,
    rope_theta=1_000_000.0,
    tie_embeddings=False,
)

SMOKE = CONFIG.replace(
    num_layers=4, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=32, vocab_size=503, num_experts=8, top_k=2, moe_d_ff=32,
    capacity_factor=4.0,
    param_dtype="float32", activation_dtype="float32", remat=False,
)
