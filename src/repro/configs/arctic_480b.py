"""arctic-480b — dense-MoE hybrid: 128 experts top-2 + dense residual MLP.

[hf:Snowflake/snowflake-arctic-base; hf] 35L d_model=7168 56H (GQA kv=8)
expert d_ff=4864 vocab=32000, head_dim 128, rope 10k. The dense residual
MLP runs in parallel with the MoE FFN on every layer (Arctic's
"dense-MoE hybrid" design); its hidden size is set to d_model.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    num_layers=35,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=4864,
    vocab_size=32_000,
    block_pattern=("moe_dense",),
    num_experts=128,
    top_k=2,
    moe_d_ff=4864,
    dense_residual_d_ff=7168,
    capacity_factor=1.25,
    rope_theta=10_000.0,
    tie_embeddings=False,
)

SMOKE = CONFIG.replace(
    num_layers=3, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=32, vocab_size=503, num_experts=8, top_k=2, moe_d_ff=32,
    dense_residual_d_ff=64, capacity_factor=4.0,
    param_dtype="float32", activation_dtype="float32", remat=False,
)
