"""qwen3-32b — dense GQA with qk_norm.

[hf:Qwen/Qwen3-8B family; hf] 64L d_model=5120 64H (GQA kv=8) d_ff=25600
vocab=151936, head_dim 128, qk_norm, untied embeddings, rope 1e6.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-32b",
    family="dense",
    num_layers=64,
    d_model=5120,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=25_600,
    vocab_size=151_936,
    block_pattern=("global",),
    qk_norm=True,
    rope_theta=1_000_000.0,
    tie_embeddings=False,
)

SMOKE = CONFIG.replace(
    num_layers=4, d_model=64, num_heads=8, num_kv_heads=2, head_dim=16,
    d_ff=160, vocab_size=503,
    param_dtype="float32", activation_dtype="float32", remat=False,
)
