"""qwen1.5-4b — dense MHA (kv == q heads) with QKV bias.

[hf:Qwen/Qwen1.5-0.5B family; hf] 40L d_model=2560 20H (kv=20) d_ff=6912
vocab=151936, head_dim 128, qkv_bias, rope 5e6.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-4b",
    family="dense",
    num_layers=40,
    d_model=2560,
    num_heads=20,
    num_kv_heads=20,
    head_dim=128,
    d_ff=6912,
    vocab_size=151_936,
    block_pattern=("global",),
    qkv_bias=True,
    rope_theta=5_000_000.0,
    tie_embeddings=False,
)

SMOKE = CONFIG.replace(
    num_layers=4, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=503,
    param_dtype="float32", activation_dtype="float32", remat=False,
)
