"""Architecture config registry: ``--arch <id>`` resolution."""

from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.config import ModelConfig

_MODULES = {
    "recurrentgemma-2b": "recurrentgemma_2b",
    "qwen3-32b": "qwen3_32b",
    "qwen1.5-4b": "qwen1_5_4b",
    "qwen2.5-3b": "qwen2_5_3b",
    "gemma3-27b": "gemma3_27b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "arctic-480b": "arctic_480b",
    "whisper-medium": "whisper_medium",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "rwkv6-3b": "rwkv6_3b",
}

ARCHS: List[str] = list(_MODULES)


def _module(arch: str):
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCHS}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch]}")


def get_config(arch: str) -> ModelConfig:
    return _module(arch).CONFIG


def get_smoke(arch: str) -> ModelConfig:
    return _module(arch).SMOKE


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCHS}
