"""RWKV-6 "Finch" time-mix / channel-mix (arXiv:2404.05892).

Core recurrence per head (state S in R^{hs x hs}, data-dependent decay w_t):

    y_t = r_t @ (S_{t-1} + diag(u) k_t v_t^T)
    S_t = diag(w_t) S_{t-1} + k_t v_t^T

Two implementations:
  * `wkv_scan`    — step-by-step lax.scan (numerical oracle, decode path)
  * `wkv_chunked` — chunk-parallel form: all cross-step exponents are kept
    <= 0 (decays accumulate from chunk start), so the masked matmul variant
    is stable in fp32. This is the MXU-friendly formulation the Pallas
    kernel (kernels/rwkv6_scan) tiles into VMEM.

The hallmark Finch feature — per-channel *data-dependent* decay via a small
bottleneck MLP — is kept; the ddlerp token-shift is simplified to learned
static lerp (it is a parameter-mixing detail orthogonal to the recurrence).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.parallel.sharding import logical_constraint
from repro.models.layers import _he, init_layernorm, layernorm


DECAY_BOTTLENECK = 64


def init_time_mix(key, cfg, dtype=None):
    dtype = dtype or cfg.pdtype
    d = cfg.d_model
    hs = cfg.rwkv_head_size
    nh = d // hs
    ks = jax.random.split(key, 9)
    s = 1 / math.sqrt(d)
    return {
        "mix": _he(ks[0], (5, d), 0.2, jnp.float32),   # r,k,v,g,w lerp coeffs
        "w_r": _he(ks[1], (d, d), s, dtype),
        "w_k": _he(ks[2], (d, d), s, dtype),
        "w_v": _he(ks[3], (d, d), s, dtype),
        "w_g": _he(ks[4], (d, d), s, dtype),
        "w_o": _he(ks[5], (d, d), s, dtype),
        "decay_w1": _he(ks[6], (d, DECAY_BOTTLENECK), s, jnp.float32),
        "decay_w2": _he(ks[7], (DECAY_BOTTLENECK, d), 1 / math.sqrt(DECAY_BOTTLENECK), jnp.float32),
        # base decay: init so w in (0.3, 0.99) across channels
        "decay_base": jnp.linspace(-6.0, 0.5, d, dtype=jnp.float32),
        "bonus_u": _he(ks[8], (nh, hs), 0.5, jnp.float32),
        "ln_x": init_layernorm(d, jnp.float32),
    }


def init_channel_mix(key, cfg, dtype=None):
    dtype = dtype or cfg.pdtype
    d, ff = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 4)
    return {
        "mix": _he(ks[0], (2, d), 0.2, jnp.float32),   # k, r lerp coeffs
        "w_in": _he(ks[1], (d, ff), 1 / math.sqrt(d), dtype),
        "w_out": _he(ks[2], (ff, d), 1 / math.sqrt(ff), dtype),
        "w_r": _he(ks[3], (d, d), 1 / math.sqrt(d), dtype),
    }


def _token_shift(x, last=None):
    """x_{t-1} with optional carried last token. x: (B,S,d)."""
    if last is None:
        last = jnp.zeros_like(x[:, :1])
    else:
        last = last[:, None, :].astype(x.dtype)
    return jnp.concatenate([last, x[:, :-1]], axis=1)


def _lerp(x, x_prev, mu):
    mu = jax.nn.sigmoid(mu).astype(x.dtype)
    return x + (x_prev - x) * mu


def _rkvgw(params, cfg, x, shifted):
    """Project mixed inputs to r,k,v,g and log-decay lw (<= 0)."""
    d = cfg.d_model
    hs = cfg.rwkv_head_size
    nh = d // hs
    B, S, _ = x.shape
    mr, mk, mv, mg, mw = params["mix"]
    xr = _lerp(x, shifted, mr)
    xk = _lerp(x, shifted, mk)
    xv = _lerp(x, shifted, mv)
    xg = _lerp(x, shifted, mg)
    xw = _lerp(x, shifted, mw)
    r = jnp.einsum("bsd,de->bse", xr, params["w_r"]).reshape(B, S, nh, hs)
    k = jnp.einsum("bsd,de->bse", xk, params["w_k"]).reshape(B, S, nh, hs)
    v = jnp.einsum("bsd,de->bse", xv, params["w_v"]).reshape(B, S, nh, hs)
    g = jnp.einsum("bsd,de->bse", xg, params["w_g"])
    # data-dependent decay (Finch): lw = -exp(base + tanh(x W1) W2) <= 0
    dd = jnp.tanh(xw.astype(jnp.float32) @ params["decay_w1"]) @ params["decay_w2"]
    lw = -jnp.exp(params["decay_base"] + dd)            # (B,S,d), <= 0
    lw = lw.reshape(B, S, nh, hs)
    return r, k, v, g, lw


def wkv_scan(r, k, v, lw, u, state=None):
    """Sequential oracle. r,k,v,lw: (B,S,nh,hs) — returns (y, S_out).

    state: (B,nh,hs,hs) fp32 or None.
    """
    B, S, nh, hs = r.shape
    rf = r.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    w = jnp.exp(lw.astype(jnp.float32))
    if state is None:
        state = jnp.zeros((B, nh, hs, hs), jnp.float32)

    def step(s, inp):
        rt, kt, vt, wt = inp
        a = kt[..., :, None] * vt[..., None, :]           # (B,nh,hs,hs)
        y = jnp.einsum("bnk,bnkv->bnv", rt, s + u[..., :, None] * a)
        s = wt[..., :, None] * s + a
        return s, y

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (rf, kf, vf, w))
    s_out, ys = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(ys, 0, 1), s_out


def wkv_chunked(r, k, v, lw, u, state=None, chunk=32):
    """Chunk-parallel WKV with non-positive cross-step exponents."""
    B, S, nh, hs = r.shape
    pad = (-S) % chunk
    if pad:
        r = jnp.pad(r, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        lw = jnp.pad(lw, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Sp = S + pad
    nchunk = Sp // chunk
    C = chunk

    def to_chunks(t):
        return jnp.moveaxis(
            t.reshape(B, nchunk, C, nh, hs), 1, 0).astype(jnp.float32)

    rc, kc, vc, lwc = map(to_chunks, (r, k, v, lw))
    if state is None:
        state = jnp.zeros((B, nh, hs, hs), jnp.float32)

    causal = jnp.tril(jnp.ones((C, C), bool), k=-1)        # strict lower

    def chunk_step(s, inp):
        rt, kt, vt, lwt = inp                               # (B,C,nh,hs)
        cum = jnp.cumsum(lwt, axis=1)                       # inclusive
        cum_prev = cum - lwt                                # exclusive
        cum_last = cum[:, -1:]                              # (B,1,nh,hs)
        # inter-chunk: y_t += (r_t * exp(cum_prev)) @ S_in
        r_dec = rt * jnp.exp(cum_prev)
        y = jnp.einsum("bcnk,bnkv->bcnv", r_dec, s)
        # intra-chunk (s < t): A[t,s] = sum_k r_t[k] k_s[k] e^{cum_prev_t - cum_s}
        # exponent <= 0 whenever s <= t-1; mask kills the rest.
        expo = cum_prev[:, :, None] - cum[:, None, :]       # (B,C,C,nh,hs)
        a = jnp.einsum("bcnk,bsnk,bcsnk->bcsn", rt, kt,
                       jnp.exp(jnp.minimum(expo, 0.0)))
        a = a * causal[None, :, :, None]
        y = y + jnp.einsum("bcsn,bsnv->bcnv", a, vt)
        # diagonal (bonus) term
        y = y + jnp.einsum("bcnk,bcnk,bcnv->bcnv", rt, u * kt, vt)
        # state update: S_out = e^{cum_last} S_in + sum_s (k_s e^{cum_last-cum_s}) v_s
        k_dec = kt * jnp.exp(cum_last - cum)
        s = jnp.exp(cum_last[:, 0, :, :, None]) * s + \
            jnp.einsum("bsnk,bsnv->bnkv", k_dec, vt)
        return s, y

    from repro.models import layers as _L
    unroll = min(_L.WKV_UNROLL, nchunk) if _L.EXACT_COST_MODE else 1
    s_out, ys = jax.lax.scan(chunk_step, state, (rc, kc, vc, lwc),
                             unroll=unroll)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, Sp, nh, hs)[:, :S]
    return y, s_out


def time_mix(params, cfg, x, state=None, use_chunked=True):
    """Full RWKV-6 time-mix layer.

    x: (B,S,d). state: None or {"last": (B,d), "wkv": (B,nh,hs,hs) fp32}.
    """
    B, S, d = x.shape
    shifted = _token_shift(x, None if state is None else state["last"])
    r, k, v, g, lw = _rkvgw(params, cfg, x, shifted)
    u = params["bonus_u"]
    wkv_state = None if state is None else state["wkv"]
    if use_chunked and S > 1:
        y, s_out = wkv_chunked(r, k, v, lw, u, wkv_state)
    else:
        y, s_out = wkv_scan(r, k, v, lw, u, wkv_state)
    y = y.reshape(B, S, d)
    y = layernorm(params["ln_x"], y, eps=1e-5)
    y = y.astype(x.dtype) * jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("bsd,de->bse", y, params["w_o"])
    out = logical_constraint(out, P(("pod", "data"), None, None))
    new_state = {"last": x[:, -1].astype(jnp.float32), "wkv": s_out}
    return out, new_state


def channel_mix(params, cfg, x, state=None):
    """RWKV channel-mix (squared-ReLU FFN with receptance gate).

    state: None or {"last": (B,d)}.
    """
    shifted = _token_shift(x, None if state is None else state["last"])
    mk, mr = params["mix"]
    xk = _lerp(x, shifted, mk)
    xr = _lerp(x, shifted, mr)
    rgate = jax.nn.sigmoid(
        jnp.einsum("bsd,de->bse", xr, params["w_r"]).astype(jnp.float32))
    h = jnp.einsum("bsd,df->bsf", xk, params["w_in"])
    h = jnp.square(jax.nn.relu(h.astype(jnp.float32))).astype(x.dtype)
    h = logical_constraint(h, P(("pod", "data"), None, "model"))
    out = jnp.einsum("bsf,fd->bsd", h, params["w_out"])
    out = rgate.astype(x.dtype) * out
    return out, {"last": x[:, -1].astype(jnp.float32)}


def init_rwkv_state(cfg, batch):
    d = cfg.d_model
    hs = cfg.rwkv_head_size
    nh = d // hs
    return {
        "tm": {"last": jnp.zeros((batch, d), jnp.float32),
               "wkv": jnp.zeros((batch, nh, hs, hs), jnp.float32)},
        "cm": {"last": jnp.zeros((batch, d), jnp.float32)},
    }
