"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)
    a_t = exp(-c * softplus(Lambda) * r_t),  r_t, i_t block-diag sigmoid gates

Train/prefill uses `jax.lax.associative_scan` (log-depth, elementwise
combine) — the TPU-native stand-in for the GPU Blelloch-shuffle scan; decode is a
single fused elementwise update.  The Pallas kernel (kernels/rglru_scan)
implements the blocked sequential-grid variant; this module is its oracle.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.parallel.sharding import logical_constraint
from repro.models.layers import _he

RGLRU_C = 8.0


def init_rglru(key, cfg, dtype=None):
    dtype = dtype or cfg.pdtype
    d = cfg.d_model
    w = cfg.rnn_width or d
    nb = cfg.rnn_blocks
    assert w % nb == 0, (w, nb)
    ks = jax.random.split(key, 7)
    # Lambda init so that a in [0.9, 0.999] at r=1 (Griffin appendix).
    lam_min, lam_max = 0.9, 0.999
    u = jax.random.uniform(ks[5], (w,), jnp.float32)
    a_init = lam_min + u * (lam_max - lam_min)
    # a = exp(-c*softplus(L)) => softplus(L) = -log(a)/c
    sp = -jnp.log(a_init) / RGLRU_C
    log_lambda = jnp.log(jnp.expm1(sp))
    return {
        "w_x": _he(ks[0], (d, w), 1 / math.sqrt(d), dtype),
        "w_gate_rec": _he(ks[1], (d, w), 1 / math.sqrt(d), dtype),
        "conv_w": _he(ks[2], (cfg.conv1d_width, w), 1 / math.sqrt(cfg.conv1d_width), dtype),
        "conv_b": jnp.zeros((w,), dtype),
        "gate_a": _he(ks[3], (nb, w // nb, w // nb), 1 / math.sqrt(w // nb), dtype),
        "gate_x": _he(ks[4], (nb, w // nb, w // nb), 1 / math.sqrt(w // nb), dtype),
        "log_lambda": log_lambda,
        "w_out_rec": _he(ks[6], (w, d), 1 / math.sqrt(w), dtype),
    }


def _block_gate(weight, x, nb):
    """Block-diagonal linear: x (B,S,w) -> (B,S,w)."""
    B, S, w = x.shape
    xb = x.reshape(B, S, nb, w // nb)
    return jnp.einsum("bsnw,nwv->bsnv", xb, weight).reshape(B, S, w)


def _gates(params, cfg, xb):
    nb = cfg.rnn_blocks
    r = jax.nn.sigmoid(_block_gate(params["gate_a"], xb, nb).astype(jnp.float32))
    i = jax.nn.sigmoid(_block_gate(params["gate_x"], xb, nb).astype(jnp.float32))
    log_a = -RGLRU_C * jax.nn.softplus(params["log_lambda"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    # sqrt(1 - a^2) computed in log space for stability
    mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    b = mult * i * xb.astype(jnp.float32)
    return a, b


def rglru_scan(params, cfg, xb, h0=None):
    """Associative scan over the sequence. xb: (B, S, w) post-conv input.

    Returns (h (B,S,w) fp32, h_last (B,w) fp32).
    """
    a, b = _gates(params, cfg, xb)
    if h0 is not None:
        # fold the carried state into the first step
        b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h, h[:, -1]


def rglru_step(params, cfg, x_t, h_prev):
    """Single decode step. x_t: (B, w); h_prev: (B, w) fp32."""
    a, b = _gates(params, cfg, x_t[:, None, :])
    return a[:, 0] * h_prev + b[:, 0]


def causal_conv1d(params, x, tail=None):
    """Depthwise causal conv. x: (B,S,w); tail: (B,width-1,w) history or None.

    Returns (y (B,S,w), new_tail (B,width-1,w)).
    """
    w = params["conv_w"]                   # (width, w)
    width = w.shape[0]
    B, S, _ = x.shape
    if tail is None:
        tail = jnp.zeros((B, width - 1, x.shape[-1]), x.dtype)
    xp = jnp.concatenate([tail, x], axis=1)
    y = sum(xp[:, i:i + S] * w[i] for i in range(width))
    new_tail = xp[:, S:S + width - 1] if width > 1 else tail
    return y + params["conv_b"], new_tail


def recurrent_block(params, cfg, x, state=None):
    """Full Griffin recurrent block.

    x: (B, S, d). state: None or {"h": (B,w) fp32, "conv": (B,width-1,w)}.
    Returns (out (B,S,d), new_state).
    """
    xb = jnp.einsum("bsd,dw->bsw", x, params["w_x"])
    gate = jnp.einsum("bsd,dw->bsw", x, params["w_gate_rec"])
    xb = logical_constraint(xb, P(("pod", "data"), None, "model"))
    xb, new_tail = causal_conv1d(params, xb,
                                 None if state is None else state["conv"])
    h0 = None if state is None else state["h"]
    h, h_last = rglru_scan(params, cfg, xb, h0)
    y = h.astype(x.dtype) * jax.nn.gelu(gate.astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("bsw,wd->bsd", y, params["w_out_rec"])
    return out, {"h": h_last, "conv": new_tail}


def recurrent_block_step(params, cfg, x_t, state):
    """Decode step. x_t: (B, d). state: {"h", "conv"}."""
    out, new_state = recurrent_block(params, cfg, x_t[:, None, :], state)
    return out[:, 0], new_state


def init_rglru_state(cfg, batch, dtype):
    w = cfg.rnn_width or cfg.d_model
    return {
        "h": jnp.zeros((batch, w), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv1d_width - 1, w), dtype),
    }
