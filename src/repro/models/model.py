"""Model assembly: layer stacks, scan-over-cycles, caches, fwd/prefill/decode.

The layer stack is grouped into *cycles* of ``cfg.block_pattern``; cycles are
jnp-stacked and iterated with ``lax.scan`` (small HLO, fast multi-pod
compiles), any remainder layers run unrolled as the tail.  One code path
serves all ten assigned architectures; encoder-decoder (whisper) lives in
``encdec.py`` and is dispatched from the public API here.
"""

from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import layers as L
from repro.models import moe as M
from repro.models import rglru as R
from repro.models import rwkv6 as W
from repro.models.config import ModelConfig
from repro.parallel.sharding import logical_constraint

ACT_SPEC = P(("pod", "data"), None, None)
HEAD_SPEC = P(("pod", "data"), None, "model", None)
# Megatron-style sequence parallelism: the residual stream (and therefore
# the scan/remat activation stash) lives sequence-sharded over "model";
# GSPMD turns the TP all-reduces into all-gather + reduce-scatter pairs at
# the attention/FFN boundaries. 16x smaller stash; same collective bytes.
RESID_SPEC = P(("pod", "data"), "model", None)


# ---------------------------------------------------------------------------
# Per-layer init
# ---------------------------------------------------------------------------

def init_layer(key, cfg: ModelConfig, kind: str) -> Dict[str, Any]:
    ks = jax.random.split(key, 4)
    dt = cfg.pdtype
    d = cfg.d_model
    if kind in ("global", "local"):
        return {
            "norm1": L.init_rmsnorm(d, dt),
            "attn": L.init_attention(ks[0], cfg),
            "norm2": L.init_rmsnorm(d, dt),
            "ffn": L.init_swiglu(ks[1], d, cfg.d_ff, dt),
        }
    if kind in ("moe", "moe_dense"):
        return {
            "norm1": L.init_rmsnorm(d, dt),
            "attn": L.init_attention(ks[0], cfg),
            "norm2": L.init_rmsnorm(d, dt),
            "moe": M.init_moe(ks[1], cfg),
        }
    if kind == "rglru":
        return {
            "norm1": L.init_rmsnorm(d, dt),
            "rec": R.init_rglru(ks[0], cfg),
            "norm2": L.init_rmsnorm(d, dt),
            "ffn": L.init_swiglu(ks[1], d, cfg.d_ff, dt),
        }
    if kind == "rwkv":
        return {
            "norm1": L.init_layernorm(d, dt),
            "tm": W.init_time_mix(ks[0], cfg),
            "norm2": L.init_layernorm(d, dt),
            "cm": W.init_channel_mix(ks[1], cfg),
        }
    raise ValueError(kind)


def _tree_stack(trees):
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *trees)


def init_params(key, cfg: ModelConfig) -> Dict[str, Any]:
    if cfg.is_encoder_decoder:
        from repro.models import encdec
        return encdec.init_params(key, cfg)
    kinds = cfg.layer_kinds()
    pat = cfg.block_pattern
    plen = len(pat)
    n_cycles = cfg.num_layers // plen

    keys = jax.random.split(key, cfg.num_layers + 2)
    layer_params = [init_layer(keys[i], cfg, kinds[i])
                    for i in range(cfg.num_layers)]

    cycles = []
    if cfg.scan_layers and n_cycles > 0:
        for pos in range(plen):
            cycles.append(_tree_stack(
                [layer_params[c * plen + pos] for c in range(n_cycles)]))
        tail = layer_params[n_cycles * plen:]
    else:
        cycles = []
        tail = layer_params
        n_cycles, n_tail = 0, cfg.num_layers

    p = {
        "embed": L.init_embedding(keys[-1], cfg.vocab_size, cfg.d_model, cfg.pdtype),
        "final_norm": (L.init_layernorm(cfg.d_model, cfg.pdtype)
                       if "rwkv" in pat else L.init_rmsnorm(cfg.d_model, cfg.pdtype)),
        "cycles": cycles,
        "tail": tail,
    }
    if not cfg.tie_embeddings:
        p["unembed"] = L.init_embedding(keys[-2], cfg.vocab_size, cfg.d_model, cfg.pdtype)
    return p


# ---------------------------------------------------------------------------
# Per-layer apply: full-sequence (train / prefill) and single-step (decode)
# ---------------------------------------------------------------------------

def _attn_common(params, cfg, kind, x, positions, theta_override=None):
    if theta_override is not None:
        theta = theta_override
    else:
        theta = (cfg.rope_theta_local
                 if (kind == "local" and cfg.rope_theta_local)
                 else cfg.rope_theta)
    q, k, v = L._qkv(params["attn"], cfg, x, positions, theta=theta)
    # NOTE (§Perf log, refuted): for head counts that don't divide the TP
    # axis (qwen1.5: 20 on 16) we tried sequence-parallel attention
    # (q/scores seq-sharded, K/V gathered). With MHA the per-layer K/V
    # gathers are as large as Q and the collective term got 2.6-7x WORSE
    # (24.5s -> 63.6s train; 20.5s -> 157s prefill); head-parallel with
    # replicated remainder is the better baseline. The real remedy is
    # padding heads to the axis size (documented in EXPERIMENTS.md).
    q = logical_constraint(q, HEAD_SPEC)
    return q, k, v


def layer_forward(params, cfg, kind, x, positions, cache=None,
                  window_override=None, theta_override=None):
    """Full-sequence layer apply.

    Returns (x, aux_loss, new_cache). cache=None means train (no caching).
    window_override/theta_override: traced per-layer values for the
    uniform attention scan (gemma3-style interleaves).
    """
    aux = jnp.zeros((), jnp.float32)
    new_cache = None
    if window_override is not None:
        window = window_override
    else:
        window = cfg.window_size if kind == "local" else 0

    if kind == "rwkv":
        st = cache or {}
        xn = logical_constraint(
            L.layernorm(params["norm1"], x, cfg.norm_eps), ACT_SPEC)
        h, tm_state = W.time_mix(params["tm"], cfg, xn, st.get("tm"))
        x = x + logical_constraint(h, RESID_SPEC)
        xn = logical_constraint(
            L.layernorm(params["norm2"], x, cfg.norm_eps), ACT_SPEC)
        h, cm_state = W.channel_mix(params["cm"], cfg, xn, st.get("cm"))
        x = x + logical_constraint(h, RESID_SPEC)
        if cache is not None:
            new_cache = {"tm": tm_state, "cm": cm_state}
        return x, aux, new_cache

    if kind == "rglru":
        xn = logical_constraint(
            L.rmsnorm(params["norm1"], x, cfg.norm_eps), ACT_SPEC)
        h, rec_state = R.recurrent_block(params["rec"], cfg, xn,
                                         cache if cache else None)
        x = x + logical_constraint(h, RESID_SPEC)
        xn = logical_constraint(
            L.rmsnorm(params["norm2"], x, cfg.norm_eps), ACT_SPEC)
        x = x + logical_constraint(L.swiglu(params["ffn"], xn), RESID_SPEC)
        if cache is not None:
            new_cache = rec_state
        return x, aux, new_cache

    # attention-bearing kinds
    xn = L.rmsnorm(params["norm1"], x, cfg.norm_eps)
    xn = logical_constraint(xn, ACT_SPEC)            # SP all-gather
    q, k, v = _attn_common(params, cfg, kind, xn, positions,
                           theta_override)
    if (isinstance(window, int) and window > 0 and q.shape[1] > window):
        # static sliding window: banded attention touches only the
        # (window + q_block) KV band per q block instead of masking the
        # full sequence (21x fewer score FLOPs at 32k prefill)
        o = L.banded_local_attention_jnp(q, k, v, window=window)
    else:
        o = L.flash_attention_jnp(q, k, v, causal=True, window=window,
                                  kv_block=min(1024, max(128, q.shape[1])))
    o = jnp.einsum("bshk,hkd->bsd", o, params["attn"]["wo"])
    x = x + logical_constraint(o, RESID_SPEC)        # SP reduce-scatter

    xn = L.rmsnorm(params["norm2"], x, cfg.norm_eps)
    xn = logical_constraint(xn, ACT_SPEC)
    if kind in ("moe", "moe_dense"):
        h, aux = M.moe_ffn(params["moe"], cfg, xn)
    else:
        h = L.swiglu(params["ffn"], xn)
    x = x + logical_constraint(h, RESID_SPEC)

    if cache is not None:
        new_cache = _write_kv_prefill(cache, cfg, kind, k, v, positions)
    return x, aux, new_cache


def _kv_cache_len(cfg, kind, max_len):
    return min(cfg.window_size, max_len) if kind == "local" else max_len


def _write_kv_prefill(cache, cfg, kind, k, v, positions):
    """Write prefill K/V into the (ring-)buffer cache."""
    S = k.shape[1]
    W_ = cache["k"].shape[1]
    if kind == "local" and S > W_:
        # keep only the last window tokens; absolute slot = t % W
        tail_idx = jnp.arange(S - W_, S)
        slots = tail_idx % W_
        knew = cache["k"].at[:, slots].set(k[:, S - W_:])
        vnew = cache["v"].at[:, slots].set(v[:, S - W_:])
    else:
        knew = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, 0, axis=1)
        vnew = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, 0, axis=1)
    return {"k": knew, "v": vnew}


def layer_decode(params, cfg, kind, x, pos, cache):
    """Single-token layer apply. x: (B,1,d); pos: (B,) absolute position."""
    if kind == "rwkv":
        h, tm_state = W.time_mix(params["tm"], cfg,
                                 L.layernorm(params["norm1"], x, cfg.norm_eps),
                                 cache["tm"], use_chunked=False)
        x = x + h
        h, cm_state = W.channel_mix(params["cm"], cfg,
                                    L.layernorm(params["norm2"], x, cfg.norm_eps),
                                    cache["cm"])
        x = x + h
        return x, {"tm": tm_state, "cm": cm_state}

    if kind == "rglru":
        h, rec_state = R.recurrent_block(
            params["rec"], cfg, L.rmsnorm(params["norm1"], x, cfg.norm_eps),
            cache)
        x = x + h
        x = x + L.swiglu(params["ffn"],
                         L.rmsnorm(params["norm2"], x, cfg.norm_eps))
        return x, rec_state

    xn = L.rmsnorm(params["norm1"], x, cfg.norm_eps)
    q, k, v = _attn_common(params, cfg, kind, xn, pos[:, None])
    W_ = cache["k"].shape[1]
    slot = (pos % W_) if kind == "local" else pos
    # one-hot masked write instead of a scatter: GSPMD handles the
    # elementwise select shard-locally on the (batch, seq)-sharded cache,
    # where a scatter forced a full-cache regather (measured: dominant
    # collective term of the decode cells).
    onehot = (jax.lax.broadcasted_iota(jnp.int32, (x.shape[0], W_), 1)
              == slot[:, None])[..., None, None]
    knew = jnp.where(onehot, k[:, 0][:, None], cache["k"])
    vnew = jnp.where(onehot, v[:, 0][:, None], cache["v"])
    filled = jnp.minimum(pos + 1, W_)
    o = L.decode_attention_jnp(q, knew, vnew, filled)
    o = jnp.einsum("bshk,hkd->bsd", o, params["attn"]["wo"])
    x = x + o

    xn = L.rmsnorm(params["norm2"], x, cfg.norm_eps)
    if kind in ("moe", "moe_dense"):
        h, _ = M.moe_ffn(params["moe"], cfg, xn)
    else:
        h = L.swiglu(params["ffn"], xn)
    x = x + h
    return x, {"k": knew, "v": vnew}


# ---------------------------------------------------------------------------
# Cache allocation
# ---------------------------------------------------------------------------

def init_layer_cache(cfg, kind, batch, max_len):
    dt = cfg.adtype
    if kind == "rwkv":
        return W.init_rwkv_state(cfg, batch)
    if kind == "rglru":
        return R.init_rglru_state(cfg, batch, dt)
    S = _kv_cache_len(cfg, kind, max_len)
    kv = cfg.num_kv_heads
    hd = cfg.head_dim
    shape = (batch, S, kv, hd)
    return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Dict[str, Any]:
    if cfg.is_encoder_decoder:
        from repro.models import encdec
        return encdec.init_cache(cfg, batch, max_len)
    kinds = cfg.layer_kinds()
    pat = cfg.block_pattern
    plen = len(pat)
    n_cycles = (cfg.num_layers // plen) if cfg.scan_layers else 0
    cycles = []
    for pos in range(plen):
        if n_cycles:
            per = [init_layer_cache(cfg, pat[pos], batch, max_len)
                   for _ in range(n_cycles)]
            cycles.append(_tree_stack(per))
    tail_kinds = kinds[n_cycles * plen:]
    tail = [init_layer_cache(cfg, k, batch, max_len) for k in tail_kinds]
    return {"pos": jnp.zeros((batch,), jnp.int32), "cycles": cycles,
            "tail": tail}


# ---------------------------------------------------------------------------
# Whole-model forward / prefill / decode
# ---------------------------------------------------------------------------

def _embed_inputs(params, cfg, tokens, prefix_embeds=None):
    x = L.embed(params["embed"], tokens).astype(cfg.adtype)
    if cfg.scale_embedding:
        x = x * math.sqrt(cfg.d_model)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(cfg.adtype), x], axis=1)
    return logical_constraint(x, RESID_SPEC)


def _unembed(params, cfg, x):
    x = (L.layernorm if "rwkv" in cfg.block_pattern else L.rmsnorm)(
        params["final_norm"], x, cfg.norm_eps)
    table = params["unembed" if "unembed" in params else "embed"]
    return L.unembed(table, x, cfg.logit_softcap)


def unembed_table(params):
    return params["unembed" if "unembed" in params else "embed"]


def _uniform_attention(cfg) -> bool:
    """True when every layer is plain attention (local/global) — the
    stack can then scan per-LAYER with traced (window, theta) inputs."""
    return (len(cfg.block_pattern) > 1 and
            all(k in ("local", "global") for k in cfg.block_pattern))


def _merge_attention_stack(params, cfg):
    """Interleave per-position cycle stacks (+tail) into one (L, ...)
    stack, with per-layer window/theta arrays.

    gemma3's 5-local:1-global cycle otherwise forces the remat scan body
    to hold SIX layers' backward intermediates at once (measured
    48 GiB/device on train_4k); a per-layer scan caps the peak at one.
    """
    kinds = cfg.layer_kinds()

    def interleave(*stacks):
        # stacks: plen arrays of (n_cycles, ...) -> (n_cycles*plen, ...)
        st = jnp.stack(stacks, axis=1)
        return st.reshape((-1,) + st.shape[2:])

    merged = jax.tree_util.tree_map(interleave, *params["cycles"])
    if params["tail"]:
        tail = _tree_stack(params["tail"])
        merged = jax.tree_util.tree_map(
            lambda a, b: jnp.concatenate([a, b], axis=0), merged, tail)
    windows = jnp.asarray(
        [cfg.window_size if k == "local" else 0 for k in kinds],
        jnp.int32)
    thetas = jnp.asarray(
        [(cfg.rope_theta_local if (k == "local" and cfg.rope_theta_local)
          else cfg.rope_theta) for k in kinds], jnp.float32)
    return merged, windows, thetas


def _stack_body(cfg, mode):
    """Build the scan body over cycles for `forward` or `prefill`."""
    pat = cfg.block_pattern

    def body(carry, xs):
        x, aux, positions = carry
        if mode == "forward":
            cycle_params = xs
            for i, kind in enumerate(pat):
                x, a, _ = layer_forward(cycle_params[i], cfg, kind, x,
                                        positions)
                aux = aux + a
            return (x, aux, positions), None
        cycle_params, cycle_cache = xs
        new_caches = []
        for i, kind in enumerate(pat):
            x, a, c = layer_forward(cycle_params[i], cfg, kind, x, positions,
                                    cache=cycle_cache[i])
            aux = aux + a
            new_caches.append(c)
        return (x, aux, positions), tuple(new_caches)
    return body


def forward(params, cfg: ModelConfig, tokens, prefix_embeds=None):
    """Training/eval forward. Returns (logits, aux_loss)."""
    x, aux = forward_features(params, cfg, tokens, prefix_embeds)
    table = params["unembed" if "unembed" in params else "embed"]
    return L.unembed(table, x, cfg.logit_softcap), aux


def forward_features(params, cfg: ModelConfig, tokens, prefix_embeds=None):
    """Forward up to (and incl.) the final norm; no unembed matmul.

    The training loss pairs this with a chunked cross-entropy so the
    (B, S, vocab) logits tensor is never materialized in full.
    """
    if cfg.is_encoder_decoder:
        from repro.models import encdec
        return encdec.forward_features(params, cfg, tokens, prefix_embeds)
    x = _embed_inputs(params, cfg, tokens, prefix_embeds)
    S = x.shape[1]
    positions = jnp.arange(S)[None, :]
    aux = jnp.zeros((), jnp.float32)

    # banded local attention (static window) needs the cycle path; the
    # uniform merged scan only pays off when windows don't bind anyway
    banded_applicable = ("local" in cfg.block_pattern
                         and cfg.window_size < x.shape[1])
    if params["cycles"] and _uniform_attention(cfg) and not banded_applicable:
        # per-layer scan with traced (window, theta): one layer's backward
        # intermediates at a time instead of a whole pattern cycle's
        merged, windows, thetas = _merge_attention_stack(params, cfg)

        def ubody(carry, xs):
            x, aux, positions = carry
            p_l, w, th = xs
            x, a, _ = layer_forward(p_l, cfg, "global", x, positions,
                                    window_override=w, theta_override=th)
            return (x, aux + a, positions), None

        if cfg.remat:
            ubody = jax.checkpoint(
                ubody, policy=jax.checkpoint_policies.nothing_saveable)
        (x, aux, _), _ = jax.lax.scan(ubody, (x, aux, positions),
                                      (merged, windows, thetas))
    else:
        body = _stack_body(cfg, "forward")
        if cfg.remat:
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable)
        if params["cycles"]:
            (x, aux, _), _ = jax.lax.scan(body, (x, aux, positions),
                                          tuple(params["cycles"]))
        kinds = cfg.layer_kinds()
        tail_kinds = kinds[len(kinds) - len(params["tail"]):]
        for p_l, kind in zip(params["tail"], tail_kinds):
            x, a, _ = layer_forward(p_l, cfg, kind, x, positions)
            aux = aux + a
    norm = L.layernorm if "rwkv" in cfg.block_pattern else L.rmsnorm
    return norm(params["final_norm"], x, cfg.norm_eps), aux


def prefill(params, cfg: ModelConfig, tokens, cache, prefix_embeds=None):
    """Process a prompt, fill the cache. Returns (last-token logits, cache)."""
    if cfg.is_encoder_decoder:
        from repro.models import encdec
        return encdec.prefill(params, cfg, tokens, cache, prefix_embeds)
    x = _embed_inputs(params, cfg, tokens, prefix_embeds)
    B, S, _ = x.shape
    positions = cache["pos"][:, None] + jnp.arange(S)[None, :]
    aux = jnp.zeros((), jnp.float32)

    new_cycles = []
    if params["cycles"]:
        body = _stack_body(cfg, "prefill")
        (x, aux, _), ys = jax.lax.scan(
            body, (x, aux, positions),
            (tuple(params["cycles"]), tuple(cache["cycles"])))
        new_cycles = list(ys)
    kinds = cfg.layer_kinds()
    tail_kinds = kinds[len(kinds) - len(params["tail"]):]
    new_tail = []
    for p_l, c_l, kind in zip(params["tail"], cache["tail"], tail_kinds):
        x, a, c = layer_forward(p_l, cfg, kind, x, positions, cache=c_l)
        new_tail.append(c)
    logits = _unembed(params, cfg, x[:, -1:])
    new_cache = {"pos": cache["pos"] + S, "cycles": new_cycles,
                 "tail": new_tail}
    return logits[:, 0], new_cache


def decode_step(params, cfg: ModelConfig, tokens, cache):
    """One decode step. tokens: (B,) int32. Returns (logits (B,V), cache)."""
    if cfg.is_encoder_decoder:
        from repro.models import encdec
        return encdec.decode_step(params, cfg, tokens, cache)
    pos = cache["pos"]
    x = _embed_inputs(params, cfg, tokens[:, None])
    pat = cfg.block_pattern

    def body(x, xs):
        cycle_params, cycle_cache = xs
        new_caches = []
        for i, kind in enumerate(pat):
            x, c = layer_decode(cycle_params[i], cfg, kind, x, pos,
                                cycle_cache[i])
            new_caches.append(c)
        return x, tuple(new_caches)

    new_cycles = []
    if params["cycles"]:
        x, ys = jax.lax.scan(body, x, (tuple(params["cycles"]),
                                       tuple(cache["cycles"])))
        new_cycles = list(ys)
    kinds = cfg.layer_kinds()
    tail_kinds = kinds[len(kinds) - len(params["tail"]):]
    new_tail = []
    for p_l, c_l, kind in zip(params["tail"], cache["tail"], tail_kinds):
        x, c = layer_decode(p_l, cfg, kind, x, pos, c_l)
        new_tail.append(c)
    logits = _unembed(params, cfg, x)
    new_cache = {"pos": pos + 1, "cycles": new_cycles, "tail": new_tail}
    return logits[:, 0], new_cache


def param_shapes(cfg: ModelConfig):
    """Shape/dtype tree without allocation (for the dry-run)."""
    return jax.eval_shape(partial(init_params, cfg=cfg), jax.random.PRNGKey(0))


# -- per-slot cache views (serving engine continuous batching) -------------

_STACKED_KEYS = ("cycles", "self", "cross")   # leading dim = layer stack


def cache_take_slot(cache: Dict[str, Any], slot: int) -> Dict[str, Any]:
    """Length-1 batch view of one slot of a decode cache."""
    out = {}
    for k, v in cache.items():
        ax = 1 if k in _STACKED_KEYS else 0
        out[k] = jax.tree_util.tree_map(
            lambda t: jax.lax.slice_in_dim(t, slot, slot + 1, axis=ax), v)
    return out


def cache_put_slot(cache: Dict[str, Any], slot: int,
                   sub: Dict[str, Any]) -> Dict[str, Any]:
    """Write a length-1 batch view back into slot `slot`."""
    out = {}
    for k, v in cache.items():
        ax = 1 if k in _STACKED_KEYS else 0
        out[k] = jax.tree_util.tree_map(
            lambda full, part: jax.lax.dynamic_update_slice_in_dim(
                full, part.astype(full.dtype), slot, axis=ax), v, sub[k])
    return out
