"""Encoder-decoder backbone (whisper-medium).

The conv audio frontend is a STUB per the harness: ``input_specs()`` feeds
precomputed frame embeddings (B, T_frames, d_model).  Encoder layers are
bidirectional attention + GELU MLP; decoder layers add cross-attention to
the encoder output.  Cross K/V are computed once at prefill and cached.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.parallel.sharding import logical_constraint
from repro.models.model import ACT_SPEC, RESID_SPEC, _tree_stack


def _maybe_scan(cfg, body, carry, xs, length):
    """lax.scan, or an unrolled loop when cfg.scan_layers is False (the
    dry-run cost probes unroll so HLO op counts are exact)."""
    if cfg.scan_layers:
        return jax.lax.scan(body, carry, xs)
    ys = []
    for i in range(length):
        x_i = jax.tree_util.tree_map(lambda t: t[i], xs)
        carry, y = body(carry, x_i)
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree_util.tree_map(lambda *a: jnp.stack(a), *ys)
    else:
        ys = None
    return carry, ys


def _init_enc_layer(key, cfg):
    ks = jax.random.split(key, 2)
    d = cfg.d_model
    return {
        "norm1": L.init_layernorm(d, cfg.pdtype),
        "attn": L.init_attention(ks[0], cfg),
        "norm2": L.init_layernorm(d, cfg.pdtype),
        "mlp": L.init_gelu_mlp(ks[1], d, cfg.d_ff, cfg.pdtype),
    }


def _init_dec_layer(key, cfg):
    ks = jax.random.split(key, 3)
    d = cfg.d_model
    return {
        "norm1": L.init_layernorm(d, cfg.pdtype),
        "self_attn": L.init_attention(ks[0], cfg),
        "norm2": L.init_layernorm(d, cfg.pdtype),
        "cross_attn": L.init_attention(ks[1], cfg),
        "norm3": L.init_layernorm(d, cfg.pdtype),
        "mlp": L.init_gelu_mlp(ks[2], d, cfg.d_ff, cfg.pdtype),
    }


def init_params(key, cfg):
    n_enc = cfg.num_encoder_layers
    n_dec = cfg.num_decoder_layers
    keys = jax.random.split(key, n_enc + n_dec + 2)
    enc = _tree_stack([_init_enc_layer(keys[i], cfg) for i in range(n_enc)])
    dec = _tree_stack([_init_dec_layer(keys[n_enc + i], cfg)
                       for i in range(n_dec)])
    return {
        "embed": L.init_embedding(keys[-1], cfg.vocab_size, cfg.d_model,
                                  cfg.pdtype),
        "enc_layers": enc,
        "enc_final_norm": L.init_layernorm(cfg.d_model, cfg.pdtype),
        "dec_layers": dec,
        "final_norm": L.init_layernorm(cfg.d_model, cfg.pdtype),
    }


def _enc_layer_fwd(p, cfg, x, positions):
    xn = logical_constraint(L.layernorm(p["norm1"], x, cfg.norm_eps),
                            ACT_SPEC)
    q, k, v = L._qkv(p["attn"], cfg, xn, positions)
    o = L.flash_attention_jnp(q, k, v, causal=False,
                              kv_block=min(1024, max(128, x.shape[1])))
    o = jnp.einsum("bshk,hkd->bsd", o, p["attn"]["wo"])
    x = x + logical_constraint(o, RESID_SPEC)
    xn = logical_constraint(L.layernorm(p["norm2"], x, cfg.norm_eps),
                            ACT_SPEC)
    x = x + logical_constraint(L.gelu_mlp(p["mlp"], xn), RESID_SPEC)
    return x


def encode(params, cfg, frame_embeds):
    """frame_embeds: (B, T, d_model) from the stub frontend."""
    x = frame_embeds.astype(cfg.adtype)
    x = logical_constraint(x, ACT_SPEC)
    T = x.shape[1]
    positions = jnp.arange(T)[None, :]

    def body(x, p_l):
        return _enc_layer_fwd(p_l, cfg, x, positions), None

    if cfg.remat:
        bodyfn = jax.checkpoint(body,
                                policy=jax.checkpoint_policies.nothing_saveable)
    else:
        bodyfn = body
    x, _ = _maybe_scan(cfg, bodyfn, x, params["enc_layers"],
                       cfg.num_encoder_layers)
    return L.layernorm(params["enc_final_norm"], x, cfg.norm_eps)


def _cross_kv(p, cfg, enc_out):
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p["cross_attn"]["wk"])
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p["cross_attn"]["wv"])
    if cfg.qkv_bias:
        k, v = k + p["cross_attn"]["bk"], v + p["cross_attn"]["bv"]
    return k, v


def _dec_layer_fwd(p, cfg, x, positions, enc_out=None, cross_kv=None,
                   cache=None, decode_pos=None):
    """Decoder layer; full-seq if decode_pos is None else single-step."""
    # --- causal self attention ---
    xn = logical_constraint(L.layernorm(p["norm1"], x, cfg.norm_eps),
                            ACT_SPEC)
    q, k, v = L._qkv(p["self_attn"], cfg, xn, positions)
    new_cache = None
    if decode_pos is not None:
        W_ = cache["k"].shape[1]
        onehot = (jax.lax.broadcasted_iota(jnp.int32, (x.shape[0], W_), 1)
                  == decode_pos[:, None])[..., None, None]
        knew = jnp.where(onehot, k[:, 0][:, None], cache["k"])
        vnew = jnp.where(onehot, v[:, 0][:, None], cache["v"])
        o = L.decode_attention_jnp(q, knew, vnew, decode_pos + 1)
        new_cache = {"k": knew, "v": vnew}
    elif cache is not None:
        knew = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, 0, axis=1)
        vnew = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, 0, axis=1)
        o = L.flash_attention_jnp(q, k, v, causal=True,
                                  kv_block=min(1024, max(128, x.shape[1])))
        new_cache = {"k": knew, "v": vnew}
    else:
        o = L.flash_attention_jnp(q, k, v, causal=True,
                                  kv_block=min(1024, max(128, x.shape[1])))
    o = jnp.einsum("bshk,hkd->bsd", o, p["self_attn"]["wo"])
    x = x + logical_constraint(o, RESID_SPEC)

    # --- cross attention (no RoPE) ---
    xn = logical_constraint(L.layernorm(p["norm2"], x, cfg.norm_eps),
                            ACT_SPEC)
    qx = jnp.einsum("bsd,dhk->bshk", xn, p["cross_attn"]["wq"])
    if cfg.qkv_bias:
        qx = qx + p["cross_attn"]["bq"]
    if cross_kv is not None:
        kx, vx = cross_kv
    else:
        kx, vx = _cross_kv(p, cfg, enc_out)
    o = L.flash_attention_jnp(qx, kx, vx, causal=False,
                              kv_block=min(1024, max(128, kx.shape[1])))
    o = jnp.einsum("bshk,hkd->bsd", o, p["cross_attn"]["wo"])
    x = x + logical_constraint(o, RESID_SPEC)

    xn = logical_constraint(L.layernorm(p["norm3"], x, cfg.norm_eps),
                            ACT_SPEC)
    x = x + logical_constraint(L.gelu_mlp(p["mlp"], xn), RESID_SPEC)
    return x, new_cache


def forward(params, cfg, tokens, frame_embeds):
    """Teacher-forced training forward. Returns (logits, aux)."""
    x, aux = forward_features(params, cfg, tokens, frame_embeds)
    return L.unembed(params["embed"], x), aux


def forward_features(params, cfg, tokens, frame_embeds):
    """Forward to the final decoder norm; no unembed matmul."""
    enc_out = encode(params, cfg, frame_embeds)
    x = L.embed(params["embed"], tokens).astype(cfg.adtype)
    x = logical_constraint(x, RESID_SPEC)
    S = x.shape[1]
    positions = jnp.arange(S)[None, :]

    def body(x, p_l):
        y, _ = _dec_layer_fwd(p_l, cfg, x, positions, enc_out=enc_out)
        return y, None

    if cfg.remat:
        bodyfn = jax.checkpoint(body,
                                policy=jax.checkpoint_policies.nothing_saveable)
    else:
        bodyfn = body
    x, _ = _maybe_scan(cfg, bodyfn, x, params["dec_layers"],
                       cfg.num_decoder_layers)
    x = L.layernorm(params["final_norm"], x, cfg.norm_eps)
    return x, jnp.zeros((), jnp.float32)


def init_cache(cfg, batch, max_len):
    kv, hd = cfg.num_kv_heads, cfg.head_dim
    n_dec = cfg.num_decoder_layers
    dt = cfg.adtype
    T = cfg.encoder_seq_len
    self_kv = {
        "k": jnp.zeros((n_dec, batch, max_len, kv, hd), dt),
        "v": jnp.zeros((n_dec, batch, max_len, kv, hd), dt),
    }
    cross_kv = {
        "k": jnp.zeros((n_dec, batch, T, kv, hd), dt),
        "v": jnp.zeros((n_dec, batch, T, kv, hd), dt),
    }
    return {"pos": jnp.zeros((batch,), jnp.int32), "self": self_kv,
            "cross": cross_kv}


def prefill(params, cfg, tokens, cache, frame_embeds):
    """Encode audio, compute cross-KV, prefill decoder self-KV."""
    enc_out = encode(params, cfg, frame_embeds)
    x = L.embed(params["embed"], tokens).astype(cfg.adtype)
    x = logical_constraint(x, ACT_SPEC)
    B, S, _ = x.shape
    positions = cache["pos"][:, None] + jnp.arange(S)[None, :]

    def body(x, xs):
        p_l, sc = xs
        kx, vx = _cross_kv(p_l, cfg, enc_out)
        y, new_sc = _dec_layer_fwd(p_l, cfg, x, positions,
                                   cross_kv=(kx, vx), cache=sc)
        return y, (new_sc, {"k": kx, "v": vx})

    x, (new_self, new_cross) = _maybe_scan(
        cfg, body, x, (params["dec_layers"],
                       {"k": cache["self"]["k"], "v": cache["self"]["v"]}),
        cfg.num_decoder_layers)
    x = L.layernorm(params["final_norm"], x, cfg.norm_eps)
    logits = L.unembed(params["embed"], x[:, -1:])
    new_cache = {"pos": cache["pos"] + S, "self": new_self,
                 "cross": new_cross}
    return logits[:, 0], new_cache


def decode_step(params, cfg, tokens, cache):
    pos = cache["pos"]
    x = L.embed(params["embed"], tokens[:, None]).astype(cfg.adtype)

    def body(x, xs):
        p_l, sc, cc = xs
        y, new_sc = _dec_layer_fwd(p_l, cfg, x, pos[:, None],
                                   cross_kv=(cc["k"], cc["v"]),
                                   cache=sc, decode_pos=pos)
        return y, new_sc

    x, new_self = _maybe_scan(
        cfg, body, x, (params["dec_layers"], cache["self"], cache["cross"]),
        cfg.num_decoder_layers)
    x = L.layernorm(params["final_norm"], x, cfg.norm_eps)
    logits = L.unembed(params["embed"], x)
    new_cache = {"pos": pos + 1, "self": new_self, "cross": cache["cross"]}
    return logits[:, 0], new_cache
