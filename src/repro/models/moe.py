"""Mixture-of-Experts FFN: top-k routing with capacity-bucketed dispatch.

Dispatch strategy (TPU-adapted, pure JAX): sort token-slots by expert id,
scatter into a dense (E, C, d) buffer (out-of-capacity slots dropped), run
all experts as one batched einsum (MXU-friendly), scatter-add back with
gate weights.  Experts are sharded over the "model" axis (EP); XLA inserts
the token all-to-all at the sharding boundary.

Used by qwen3-moe (128e top-8) and arctic (128e top-2 + dense residual).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.parallel.sharding import logical_constraint
from repro.models.layers import _he, init_swiglu, swiglu


def init_moe(key, cfg, dtype=None):
    dtype = dtype or cfg.pdtype
    E, d, ff = cfg.num_experts, cfg.d_model, cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    p = {
        "router": _he(ks[0], (d, E), 1 / math.sqrt(d), jnp.float32),
        "we_gate": _he(ks[1], (E, d, ff), 1 / math.sqrt(d), dtype),
        "we_up": _he(ks[2], (E, d, ff), 1 / math.sqrt(d), dtype),
        "we_down": _he(ks[3], (E, ff, d), 1 / math.sqrt(ff), dtype),
    }
    if cfg.dense_residual_d_ff:
        p["dense"] = init_swiglu(ks[4], d, cfg.dense_residual_d_ff, dtype)
    return p


def _capacity(tokens: int, cfg) -> int:
    c = int(math.ceil(tokens * cfg.top_k / cfg.num_experts
                      * cfg.capacity_factor))
    return max(8, -(-c // 8) * 8)  # round up to 8 for lane alignment


def moe_ffn(params, cfg, x):
    """x: (B, S, d) -> (out (B, S, d), aux_loss scalar fp32)."""
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.top_k
    T = B * S
    C = _capacity(T, cfg)
    xt = x.reshape(T, d)

    logits = (xt.astype(jnp.float32) @ params["router"])      # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, k)                       # (T, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # Switch-style load-balancing aux loss.
    me = probs.mean(0)                                        # (E,)
    ce = jnp.zeros((E,), jnp.float32).at[idx.reshape(-1)].add(
        1.0 / (T * k))
    aux = E * jnp.sum(me * ce)

    # --- dispatch: sort token-slots by expert --------------------------------
    # Scatter/gather carry only SCALAR token ids into the (E, C) slot
    # grid; the (E, C, d) buffer is then a row-gather. Scattering the
    # full (T*k, d) updates made XLA materialize (T*k, d)-shaped index
    # tensors (measured 16 GiB x dozens on the MoE train cells).
    flat_e = idx.reshape(-1)                                  # (T*k,)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    counts = jnp.zeros((E,), jnp.int32).at[flat_e].add(1)
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(T * k) - starts[sorted_e]                # slot in expert
    tok = order // k                                          # source token

    slot_tok = jnp.full((E, C), T, jnp.int32)                 # T = invalid
    slot_tok = slot_tok.at[sorted_e, pos].set(tok, mode="drop")
    buf = jnp.take(xt, slot_tok.reshape(-1), axis=0,
                   fill_value=0, mode="fill").reshape(E, C, d)
    buf = logical_constraint(buf, P("model", None, None))

    # --- expert computation (batched SwiGLU) --------------------------------
    g = jnp.einsum("ecd,edf->ecf", buf, params["we_gate"])
    u = jnp.einsum("ecd,edf->ecf", buf, params["we_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    h = logical_constraint(h, P("model", None, None))
    eo = jnp.einsum("ecf,efd->ecd", h, params["we_down"])

    # --- combine: slot grid of (expert, slot) per token-slot, row gather ----
    slot_of = jnp.full((T * k,), E * C, jnp.int32)            # invalid
    slot_of = slot_of.at[order].set(
        jnp.where(pos < C, sorted_e * C + pos, E * C))
    slot_out = jnp.take(eo.reshape(E * C, d), slot_of, axis=0,
                        fill_value=0, mode="fill")            # (T*k, d)
    w = gate.reshape(-1).astype(x.dtype)[:, None]
    y = jnp.sum((slot_out * w).reshape(T, k, d), axis=1)
    y = y.reshape(B, S, d)
    y = logical_constraint(y, P(("pod", "data"), None, None))

    if "dense" in params:
        y = y + swiglu(params["dense"], x)
    return y, aux


def moe_ffn_dense_ref(params, cfg, x):
    """O(T*E) oracle: every expert on every token, exact top-k combine.

    Used by tests to validate the dispatch path (no capacity drops when
    capacity_factor is large enough).
    """
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.top_k
    xt = x.reshape(-1, d)
    logits = xt.astype(jnp.float32) @ params["router"]
    probs = jax.nn.softmax(logits, -1)
    gate, idx = jax.lax.top_k(probs, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    g = jnp.einsum("td,edf->etf", xt, params["we_gate"])
    u = jnp.einsum("td,edf->etf", xt, params["we_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    eo = jnp.einsum("etf,efd->etd", h, params["we_down"])     # (E, T, d)

    onehot = jax.nn.one_hot(idx, E, dtype=jnp.float32)        # (T, k, E)
    w = (onehot * gate[..., None]).sum(1)                     # (T, E)
    y = jnp.einsum("te,etd->td", w.astype(x.dtype), eo).reshape(B, S, d)
    if "dense" in params:
        y = y + swiglu(params["dense"], x)
    return y
