"""Model configuration for the repro model zoo.

One frozen dataclass covers all 10 assigned architecture families:
dense GQA transformers (qwen*, gemma3, llava backbone), MoE (qwen3-moe,
arctic), hybrid recurrent (recurrentgemma), attention-free (rwkv6) and
encoder-decoder (whisper).  Family-specific behaviour is selected by
``block_pattern`` / ``family`` rather than subclassing, so configs stay
declarative and serializable.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Tuple

import jax.numpy as jnp

# Layer kinds usable in ``block_pattern`` (cycled over the layer stack):
#   "global"     full (causal) attention + FFN
#   "local"      sliding-window causal attention + FFN
#   "rglru"      RG-LRU recurrent block + FFN            (RecurrentGemma)
#   "rwkv"       RWKV-6 time-mix + channel-mix           (Finch)
#   "moe"        attention + top-k MoE FFN
#   "moe_dense"  attention + dense-FFN residual + MoE    (Arctic)
LAYER_KINDS = ("global", "local", "rglru", "rwkv", "moe", "moe_dense")


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | hybrid | ssm | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int                   # query heads (0 for attention-free)
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128

    # --- layer stack -------------------------------------------------------
    block_pattern: Tuple[str, ...] = ("global",)
    window_size: int = 4096          # sliding window for "local" layers

    # --- attention flavour -------------------------------------------------
    qk_norm: bool = False            # qwen3 / gemma3
    qkv_bias: bool = False           # qwen1.5 / qwen2.5
    rope_theta: float = 10_000.0
    rope_theta_local: float = 0.0    # gemma3: different theta on local layers
    logit_softcap: float = 0.0       # gemma-style final-logit softcap (0=off)
    scale_embedding: bool = False    # gemma-style sqrt(d) embedding scale

    # --- MoE ---------------------------------------------------------------
    num_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0                # per-expert hidden dim
    dense_residual_d_ff: int = 0     # Arctic: dense FFN residual next to MoE
    capacity_factor: float = 1.25

    # --- recurrent (rglru / rwkv) ------------------------------------------
    rnn_width: int = 0               # RG-LRU recurrent width (lru_width)
    rnn_blocks: int = 8              # block-diagonal gate blocks (Griffin)
    conv1d_width: int = 4            # temporal conv in recurrent block
    rwkv_head_size: int = 64

    # --- encoder-decoder ----------------------------------------------------
    is_encoder_decoder: bool = False
    num_encoder_layers: int = 0
    num_decoder_layers: int = 0
    encoder_seq_len: int = 1500      # whisper frame count after conv stub

    # --- multimodal stub -----------------------------------------------------
    num_patch_tokens: int = 0        # llava: image-patch prefix length

    # --- numerics / implementation -----------------------------------------
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    param_dtype: str = "bfloat16"
    activation_dtype: str = "bfloat16"
    remat: bool = True
    scan_layers: bool = True
    use_pallas: bool = False         # kernels (interpret-mode on CPU tests)

    # --- variant ladder metadata (FailLite heterogeneous replication) ------
    width_mult: float = 1.0          # applied scaling vs. the full model
    depth_mult: float = 1.0
    quant_bits: int = 16             # 16 = bf16, 8 = weight-only int8

    def __post_init__(self):
        for k in self.block_pattern:
            if k not in LAYER_KINDS:
                raise ValueError(f"unknown layer kind {k!r}")
        if self.family == "moe" and self.num_experts <= 0:
            raise ValueError("moe family requires num_experts > 0")

    # -- derived ------------------------------------------------------------
    @property
    def attention_free(self) -> bool:
        return all(k in ("rwkv",) for k in self.block_pattern)

    @property
    def sub_quadratic(self) -> bool:
        """True if no layer kind attends to unbounded full history."""
        return all(k in ("local", "rglru", "rwkv") for k in self.block_pattern)

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def adtype(self):
        return jnp.dtype(self.activation_dtype)

    def layer_kinds(self) -> Tuple[str, ...]:
        """Concrete per-layer kind list, cycling block_pattern."""
        n = self.num_layers
        pat = self.block_pattern
        return tuple(pat[i % len(pat)] for i in range(n))

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # -- sizing (used by the FailLite variant ladder & roofline napkin math) -
    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + norms)."""
        d, ff, v = self.d_model, self.d_ff, self.vocab_size
        h, kv, hd = self.num_heads, self.num_kv_heads, self.head_dim
        embed = v * d
        unembed = 0 if self.tie_embeddings else v * d
        total = embed + unembed + d  # final norm

        def attn_params() -> int:
            p = d * h * hd + 2 * d * kv * hd + h * hd * d
            if self.qkv_bias:
                p += h * hd + 2 * kv * hd
            if self.qk_norm:
                p += 2 * hd
            return p

        def ffn_params(width: int) -> int:
            return 3 * d * width  # SwiGLU: gate, up, down

        kinds = self.layer_kinds()
        if self.is_encoder_decoder:
            # encoder: self-attn + ffn; decoder: self + cross + ffn (GELU mlp)
            enc = self.num_encoder_layers * (attn_params() + 2 * d * ff + 2 * d)
            dec = self.num_decoder_layers * (2 * attn_params() + 2 * d * ff + 3 * d)
            return total + enc + dec

        for kind in kinds:
            total += 2 * d  # pre norms
            if kind in ("global", "local"):
                total += attn_params() + ffn_params(ff)
            elif kind == "rglru":
                w = self.rnn_width or d
                # x/gate in-projections, temporal conv, block-diagonal
                # recurrence/input gates (W_a, W_x), Λ, out-proj, shared FFN.
                nb = self.rnn_blocks
                total += 2 * d * w + self.conv1d_width * w
                total += 2 * nb * (w // nb) ** 2 + w
                total += w * d
                total += ffn_params(ff)
            elif kind == "rwkv":
                hs = self.rwkv_head_size
                nh = d // hs
                # time-mix: r,k,v,g,o projections + decay MLPs; channel-mix
                total += 5 * d * d + 2 * d * 64 + 64 * d + nh * hs
                total += 2 * d * ff
            elif kind in ("moe", "moe_dense"):
                total += attn_params()
                total += self.num_experts * 3 * d * self.moe_d_ff  # experts
                total += d * self.num_experts                       # router
                if kind == "moe_dense" or self.dense_residual_d_ff:
                    total += 3 * d * (self.dense_residual_d_ff or ff)
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top-k experts count)."""
        if self.num_experts == 0:
            return self.param_count()
        full = self.param_count()
        kinds = self.layer_kinds()
        n_moe = sum(1 for k in kinds if k in ("moe", "moe_dense"))
        all_exp = n_moe * self.num_experts * 3 * self.d_model * self.moe_d_ff
        act_exp = n_moe * self.top_k * 3 * self.d_model * self.moe_d_ff
        return full - all_exp + act_exp

    def param_bytes(self) -> int:
        bits = self.quant_bits
        return self.param_count() * bits // 8

    def kv_bytes_per_token(self) -> int:
        """KV-cache bytes per token per sequence (window-capped for local)."""
        if self.attention_free:
            return 0
        per_layer = 2 * self.num_kv_heads * self.head_dim * 2  # bf16 K+V
        kinds = self.layer_kinds()
        n_attn = sum(1 for k in kinds if k not in ("rwkv", "rglru"))
        if self.is_encoder_decoder:
            n_attn = self.num_decoder_layers
        return n_attn * per_layer
