"""LM head utilities: cross-entropy loss (fp32, z-loss) for training.

The training loss is *chunked over the sequence*: logits for one sequence
chunk at a time are computed, reduced to (nll, z-loss) partials, and
discarded; `jax.checkpoint` around the chunk body makes the backward pass
recompute them.  The full (B, S, vocab) logits tensor — 318 GB for the
qwen train_4k cell — is never materialized, which is what lets the
train cells fit v5e HBM (measured: 28 GiB -> ~9 GiB per device).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import model as MDL
from repro.models import layers as _layers
from repro.parallel.sharding import logical_constraint

CE_CHUNK = 512


def _chunk_nll(table, xc, labels, mask, softcap, z_loss):
    """One chunk: xc (B,C,d) -> (sum nll, sum mask). Never keeps logits."""
    logits = jnp.einsum("bsd,vd->bsv", xc, table)
    if softcap > 0:
        logits = jnp.tanh(logits / softcap) * softcap
    logits = logical_constraint(logits, P(("pod", "data"), None, "model"))
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
    ll = jnp.sum(jnp.where(vocab_iota == labels[..., None], logits, 0.0),
                 axis=-1)
    nll = lse - ll
    if z_loss:
        nll = nll + z_loss * jnp.square(lse)
    m = mask.astype(jnp.float32)
    return jnp.sum(nll * m), jnp.sum(m)


def chunked_cross_entropy(table, x, labels, mask=None, softcap=0.0,
                          z_loss=1e-4, chunk=CE_CHUNK):
    """x: (B,S,d) final hidden; table: (V,d). Returns mean masked nll."""
    B, S, d = x.shape
    if mask is None:
        mask = jnp.ones((B, S), jnp.float32)
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    n = (S + pad) // chunk

    xc = jnp.moveaxis(x.reshape(B, n, chunk, d), 1, 0)
    lc = jnp.moveaxis(labels.reshape(B, n, chunk), 1, 0)
    mc = jnp.moveaxis(mask.reshape(B, n, chunk), 1, 0)

    def body(carry, inp):
        tot, cnt = carry
        xcb, lcb, mcb = inp
        s, c = _chunk_nll(table, xcb, lcb, mcb, softcap, z_loss)
        return (tot + s, cnt + c), None

    body = jax.checkpoint(body,
                          policy=jax.checkpoint_policies.nothing_saveable)
    (tot, cnt), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (xc, lc, mc), unroll=n if _layers.EXACT_COST_MODE else 1)
    return tot / jnp.maximum(cnt, 1.0)


def cross_entropy(logits, labels, mask=None, z_loss=1e-4):
    """Direct CE on materialized logits (eval / small paths)."""
    lse = jax.scipy.special.logsumexp(logits.astype(jnp.float32), axis=-1)
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                          len(logits.shape) - 1)
    onehot = (vocab_iota == labels[..., None])
    ll = jnp.sum(jnp.where(onehot, logits.astype(jnp.float32), 0.0), axis=-1)
    nll = lse - ll
    if z_loss:
        nll = nll + z_loss * jnp.square(lse)
    if mask is None:
        return nll.mean()
    mask = mask.astype(jnp.float32)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def lm_loss(params, cfg, batch, aux_weight=0.01):
    """batch: {"tokens", "labels", optional "mask", "frame_embeds",
    "patch_embeds"}. Returns (loss, metrics)."""
    prefix = batch.get("patch_embeds")
    if cfg.is_encoder_decoder:
        feats, aux = MDL.forward_features(params, cfg, batch["tokens"],
                                          batch["frame_embeds"])
    else:
        feats, aux = MDL.forward_features(params, cfg, batch["tokens"],
                                          prefix)
    labels = batch["labels"]
    mask = batch.get("mask")
    if prefix is not None:
        # image-prefix positions carry no labels; score text tail only
        Pfx = prefix.shape[1]
        feats = feats[:, Pfx:]
    table = MDL.unembed_table(params)["table"]
    ce = chunked_cross_entropy(table, feats, labels, mask,
                               softcap=cfg.logit_softcap)
    loss = ce + aux_weight * aux
    return loss, {"ce": ce, "aux": aux}
