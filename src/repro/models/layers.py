"""Core transformer layers, pure JAX (no flax).

Params are plain nested dicts of jnp arrays.  Every layer comes as an
``init_*`` returning a param tree and an ``apply`` function.

Attention is implemented *flash-style in jnp*: a `lax.scan` over KV blocks
with an online-softmax carry.  This keeps the traced memory footprint
O(q_block x kv_block) instead of O(S^2), which is what lets the 32k-prefill
and 500k-decode dry-run cells compile and fit; it also doubles as the
numerical oracle for the Pallas flash_attention kernel.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.parallel.sharding import logical_constraint

NEG_INF = -1e30

# Dry-run "exact cost" mode: XLA's cost_analysis counts a lax.scan body
# once regardless of trip count, so the dry-run unrolls intra-layer scans
# (flash KV blocks, CE chunks) to make HLO FLOP/byte counts exact.
# The WKV chunk scan is too long to unroll (1024 bodies at 32k prefill);
# instead the dry-run probes it at WKV_UNROLL ∈ {1, 2} and recovers the
# exact per-chunk cost from the difference (see dryrun.lower_cell).
EXACT_COST_MODE = False
WKV_UNROLL = 1


def set_exact_cost_mode(on: bool, wkv_unroll: int = 1):
    global EXACT_COST_MODE, WKV_UNROLL
    EXACT_COST_MODE = bool(on)
    WKV_UNROLL = int(wkv_unroll)


def _he(key, shape, scale, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# --------------------------------------------------------------------------
# Norms
# --------------------------------------------------------------------------

def init_rmsnorm(d, dtype):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params, x, eps=1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * params["scale"].astype(jnp.float32)).astype(dt)


def init_layernorm(d, dtype):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(params, x, eps=1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    out = x * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return out.astype(dt)


# --------------------------------------------------------------------------
# Rotary position embedding
# --------------------------------------------------------------------------

def rope(x, positions, theta=10_000.0):
    """x: (..., S, H, hd); positions broadcastable to (..., S); theta may
    be a traced scalar (uniform layer scan)."""
    hd = x.shape[-1]
    log_theta = (math.log(theta) if isinstance(theta, (int, float))
                 else jnp.log(theta))
    freqs = jnp.exp(-jnp.arange(0, hd, 2, dtype=jnp.float32) / hd * log_theta)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    angles = angles[..., None, :]                              # (..., S, 1, hd/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# Attention (GQA, causal / sliding-window / bidirectional / cross)
# --------------------------------------------------------------------------

def init_attention(key, cfg, dtype=None):
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    dtype = dtype or cfg.pdtype
    ks = jax.random.split(key, 4)
    s_in = 1.0 / math.sqrt(d)
    s_out = 1.0 / math.sqrt(h * hd)
    p = {
        "wq": _he(ks[0], (d, h, hd), s_in, dtype),
        "wk": _he(ks[1], (d, kv, hd), s_in, dtype),
        "wv": _he(ks[2], (d, kv, hd), s_in, dtype),
        "wo": _he(ks[3], (h, hd, d), s_out, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h, hd), dtype)
        p["bk"] = jnp.zeros((kv, hd), dtype)
        p["bv"] = jnp.zeros((kv, hd), dtype)
    if cfg.qk_norm:
        p["q_norm"] = init_rmsnorm(hd, dtype)
        p["k_norm"] = init_rmsnorm(hd, dtype)
    return p


def _qkv(params, cfg, x, positions, rope_on=True, theta=None):
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    if cfg.qkv_bias:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    if cfg.qk_norm:
        q = rmsnorm(params["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(params["k_norm"], k, cfg.norm_eps)
    if rope_on:
        theta = cfg.rope_theta if theta is None else theta
        q = rope(q, positions, theta)
        k = rope(k, positions, theta)
    return q, k, v


def flash_attention_jnp(q, k, v, *, causal=True, window=0, q_offset=0,
                        kv_block=1024, kv_len_mask=None):
    """Online-softmax attention, scanned over KV blocks.

    q: (B, Sq, H, hd); k, v: (B, Skv, KVH, hd) with H % KVH == 0.
    window > 0 => sliding-window causal attention (each q attends to the
    last `window` kv positions, inclusive of itself).
    q_offset: absolute position of q[0] relative to kv[0] (decode: Skv - Sq).
    kv_len_mask: optional (B, Skv) bool validity mask (ragged batches).
    Returns (B, Sq, H, hd).
    """
    B, Sq, H, hd = q.shape
    _, Skv, KVH, _ = k.shape
    G = H // KVH
    scale = 1.0 / math.sqrt(hd)

    # Flat-head layout: KV broadcast to H query heads. Keeping the head
    # axis whole lets the "model" sharding propagate cleanly through the
    # score/grad ops (a (KVH, G) split is inexpressible in a PartitionSpec
    # and forces GSPMD into full-tensor regathers in the backward pass).
    def expand(t):
        if G == 1:
            return t
        Bt, St = t.shape[0], t.shape[1]
        t = jnp.broadcast_to(t[:, :, :, None, :], (Bt, St, KVH, G, hd))
        return t.reshape(Bt, St, H, hd)

    # operands stay in the model dtype; dots accumulate in fp32 via
    # preferred_element_type (avoids materializing fp32 copies of K/V)
    qf = q * jnp.asarray(scale, q.dtype)
    nblk = -(-Skv // kv_block)
    pad = nblk * kv_block - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        if kv_len_mask is None:
            kv_len_mask = jnp.arange(Skv + pad) < Skv
            kv_len_mask = jnp.broadcast_to(kv_len_mask, (B, Skv + pad))
        else:
            kv_len_mask = jnp.pad(kv_len_mask, ((0, 0), (0, pad)))
    kb = k.reshape(B, nblk, kv_block, KVH, hd)
    vb = v.reshape(B, nblk, kv_block, KVH, hd)
    mb = (None if kv_len_mask is None
          else kv_len_mask.reshape(B, nblk, kv_block))

    q_pos = q_offset + jnp.arange(Sq)
    S_SPEC = P(("pod", "data"), None, "model", None)

    def body(carry, blk):
        m_prev, l_prev, acc = carry
        kblk, vblk, vmask, start = blk
        kblk = expand(kblk)
        vblk = expand(vblk)
        kv_pos = start + jnp.arange(kv_block)
        # (B, Sq, H, kv_block), fp32 accumulation over bf16 operands
        s = jnp.einsum("bqhk,bshk->bqhs", qf, kblk,
                       preferred_element_type=jnp.float32)
        s = logical_constraint(s, S_SPEC)
        mask = jnp.ones((Sq, kv_block), bool)
        if causal:
            mask &= q_pos[:, None] >= kv_pos[None, :]
        if isinstance(window, (int, float)):
            if window > 0:
                mask &= kv_pos[None, :] > q_pos[:, None] - window
        else:
            # traced per-layer window (uniform layer scan); <= 0 = global
            eff = jnp.where(window > 0, window, Skv + 1)
            mask &= kv_pos[None, :] > q_pos[:, None] - eff
        s = jnp.where(mask[None, :, None, :], s, NEG_INF)
        if vmask is not None:
            s = jnp.where(vmask[:, None, None, :], s, NEG_INF)
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bqhs,bshk->bqhk", p.astype(vblk.dtype), vblk,
                        preferred_element_type=jnp.float32)
        acc = acc * corr[..., None] + pv
        acc = logical_constraint(acc, S_SPEC)
        return (m_new, l_new, acc), None

    # without this, the backward pass stacks per-trip score tensors
    # (B,Sq,H,block) across the whole KV scan — checkpointing the body
    # keeps only the (m,l,acc) carries and recomputes scores in bwd
    body = jax.checkpoint(body,
                          policy=jax.checkpoint_policies.nothing_saveable)

    m0 = jnp.full((B, Sq, H), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Sq, H), jnp.float32)
    a0 = jnp.zeros((B, Sq, H, hd), jnp.float32)
    starts = jnp.arange(nblk) * kv_block
    kb = jnp.moveaxis(kb, 1, 0)
    vb = jnp.moveaxis(vb, 1, 0)
    xs = (kb, vb,
          None if mb is None else jnp.moveaxis(mb, 1, 0),
          starts)
    unroll = nblk if EXACT_COST_MODE else 1
    if mb is None:
        (m, l, acc), _ = jax.lax.scan(
            lambda c, b: body(c, (b[0], b[1], None, b[2])), (m0, l0, a0),
            (kb, vb, starts), unroll=unroll)
    else:
        (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), xs, unroll=unroll)
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.astype(q.dtype)


def banded_local_attention_jnp(q, k, v, *, window, q_block=512):
    """Sliding-window self-attention over a (window + q_block) KV band.

    The generic flash path visits every KV block and masks — for
    gemma3's window-1024 local layers at 32k prefill that is 21x more
    score FLOPs/bytes than the band actually needs.  Here each q-block
    slices only its [i*bq - window, i*bq + bq) KV band (left-padded so
    slices stay in range).  Causal, full-sequence (Sq == Skv) only.
    """
    B, S, H, hd = q.shape
    _, _, KVH, _ = k.shape
    G = H // KVH
    scale = 1.0 / math.sqrt(hd)
    q_block = min(q_block, S)
    pad_s = (-S) % q_block
    if pad_s:
        q = jnp.pad(q, ((0, 0), (0, pad_s), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad_s), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_s), (0, 0), (0, 0)))
    Sp = S + pad_s
    nq = Sp // q_block
    band = window + q_block
    kp = jnp.pad(k, ((0, 0), (window, 0), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (window, 0), (0, 0), (0, 0)))

    def expand(t):
        if G == 1:
            return t
        Bt, St = t.shape[0], t.shape[1]
        t = jnp.broadcast_to(t[:, :, :, None, :], (Bt, St, KVH, G, hd))
        return t.reshape(Bt, St, H, hd)

    qs = (q * jnp.asarray(scale, q.dtype))

    def body(_, i):
        qb = jax.lax.dynamic_slice_in_dim(qs, i * q_block, q_block, 1)
        kb = expand(jax.lax.dynamic_slice_in_dim(kp, i * q_block, band, 1))
        vb = expand(jax.lax.dynamic_slice_in_dim(vp, i * q_block, band, 1))
        q_pos = i * q_block + jnp.arange(q_block)
        kv_pos = i * q_block - window + jnp.arange(band)
        s = jnp.einsum("bqhk,bshk->bqhs", qb, kb,
                       preferred_element_type=jnp.float32)
        s = logical_constraint(s, P(("pod", "data"), None, "model", None))
        mask = ((q_pos[:, None] >= kv_pos[None, :])
                & (kv_pos[None, :] > q_pos[:, None] - window)
                & (kv_pos[None, :] >= 0) & (q_pos[:, None] < S))
        s = jnp.where(mask[None, :, None, :], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        ob = jnp.einsum("bqhs,bshk->bqhk", p.astype(vb.dtype), vb,
                        preferred_element_type=jnp.float32)
        return None, ob.astype(q.dtype)

    body = jax.checkpoint(body,
                          policy=jax.checkpoint_policies.nothing_saveable)
    _, out = jax.lax.scan(body, None, jnp.arange(nq),
                          unroll=nq if EXACT_COST_MODE else 1)
    out = jnp.moveaxis(out, 0, 1).reshape(B, Sp, H, hd)[:, :S]
    return out


def decode_attention_jnp(q, k_cache, v_cache, cache_len, *, window=0):
    """Single-token attention against a (possibly longer, padded) KV cache.

    q: (B, 1, H, hd); k_cache/v_cache: (B, Smax, KVH, hd);
    cache_len: (B,) int32 number of valid cache entries INCLUDING this step.

    NOTE (measured, §Perf log): routing this through the chunked flash
    path regressed decode memory/collectives (scan stash + per-block mask
    machinery with q_len=1); the direct whole-cache dot is better here.
    The fp32 operand copies XLA:CPU materializes for bf16 dots are a
    host-backend artifact — the TPU MXU consumes bf16 natively.
    """
    B, _, H, hd = q.shape
    _, Smax, KVH, _ = k_cache.shape
    G = H // KVH
    scale = 1.0 / math.sqrt(hd)
    # Grouped-KV einsum against the UNEXPANDED cache: broadcasting KV to
    # H heads forced GSPMD to all-gather the sequence-sharded cache
    # (measured: 268MB x 2 x L per decode step — the entire collective
    # term of the decode cells). Q is one token, so regrouping it is
    # free; scores (B, KVH, G, S) keep S on the "model" axis.
    qg = (q * jnp.asarray(scale, q.dtype))[:, 0].reshape(B, KVH, G, hd)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache,
                   preferred_element_type=jnp.float32)
    pos = jnp.arange(Smax)
    valid = pos[None, :] < cache_len[:, None]
    if window > 0:
        valid &= pos[None, :] > cache_len[:, None] - 1 - window
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, H, hd).astype(q.dtype)


def init_cross_attention(key, cfg, dtype=None):
    """Cross-attention (whisper decoder): kv from encoder states."""
    return init_attention(key, cfg, dtype)


# --------------------------------------------------------------------------
# FFN
# --------------------------------------------------------------------------

def init_swiglu(key, d, ff, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": _he(k1, (d, ff), 1 / math.sqrt(d), dtype),
        "w_up": _he(k2, (d, ff), 1 / math.sqrt(d), dtype),
        "w_down": _he(k3, (ff, d), 1 / math.sqrt(ff), dtype),
    }


def swiglu(params, x):
    g = jnp.einsum("bsd,df->bsf", x, params["w_gate"])
    u = jnp.einsum("bsd,df->bsf", x, params["w_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    h = logical_constraint(h, P(("pod", "data"), None, "model"))
    return jnp.einsum("bsf,fd->bsd", h, params["w_down"])


def init_gelu_mlp(key, d, ff, dtype):
    k1, k2 = jax.random.split(key, 2)
    return {
        "w_in": _he(k1, (d, ff), 1 / math.sqrt(d), dtype),
        "b_in": jnp.zeros((ff,), dtype),
        "w_out": _he(k2, (ff, d), 1 / math.sqrt(ff), dtype),
        "b_out": jnp.zeros((d,), dtype),
    }


def gelu_mlp(params, x):
    h = jnp.einsum("bsd,df->bsf", x, params["w_in"]) + params["b_in"]
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    h = logical_constraint(h, P(("pod", "data"), None, "model"))
    return jnp.einsum("bsf,fd->bsd", h, params["w_out"]) + params["b_out"]


# --------------------------------------------------------------------------
# Embedding / LM head
# --------------------------------------------------------------------------

def init_embedding(key, vocab, d, dtype):
    # 1/sqrt(d) keeps tied-unembed logits O(1); gemma-style configs restore
    # O(1) activations via scale_embedding (x * sqrt(d)) after lookup.
    return {"table": _he(key, (vocab, d), 1.0 / math.sqrt(d), dtype)}


def embed(params, tokens):
    return jnp.take(params["table"], tokens, axis=0)


def unembed(params, x, softcap=0.0):
    logits = jnp.einsum("bsd,vd->bsv", x, params["table"])
    if softcap > 0:
        logits = jnp.tanh(logits / softcap) * softcap
    return logical_constraint(logits, P(("pod", "data"), None, "model"))
