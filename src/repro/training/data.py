"""Synthetic sharded token pipeline.

Deterministic, seekable token stream (restartable from a step index — the
checkpoint/restore path needs bit-identical batches after restart), with
host-sharded loading: each data-parallel host materializes only its own
batch shard, as a real multi-pod input pipeline would.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np

from repro.models.config import ModelConfig


@dataclass
class DataConfig:
    vocab_size: int
    global_batch: int
    seq_len: int
    seed: int = 1234


class SyntheticTokenStream:
    """Zipf-ish token stream; batch(step) is a pure function of (seed, step)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        # zipf-like unigram distribution (heavy head, long tail)
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        p = 1.0 / ranks
        self.p = (p / p.sum()).astype(np.float64)

    def batch(self, step: int, model_cfg: Optional[ModelConfig] = None
              ) -> Dict[str, np.ndarray]:
        c = self.cfg
        rng = np.random.default_rng((c.seed, step))
        toks = rng.choice(c.vocab_size, size=(c.global_batch, c.seq_len + 1),
                          p=self.p).astype(np.int32)
        out = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if model_cfg is not None and model_cfg.is_encoder_decoder:
            out["frame_embeds"] = rng.standard_normal(
                (c.global_batch, model_cfg.encoder_seq_len,
                 model_cfg.d_model)).astype(np.float32)
        if model_cfg is not None and model_cfg.num_patch_tokens:
            out["patch_embeds"] = rng.standard_normal(
                (c.global_batch, model_cfg.num_patch_tokens,
                 model_cfg.d_model)).astype(np.float32)
        return out

    def host_shard(self, step: int, host_index: int, host_count: int,
                   model_cfg: Optional[ModelConfig] = None):
        """Per-host slice of the global batch (sharded ingestion)."""
        full = self.batch(step, model_cfg)
        per = self.cfg.global_batch // host_count
        lo = host_index * per
        return {k: v[lo:lo + per] for k, v in full.items()}

    def iterate(self, start_step: int = 0,
                model_cfg: Optional[ModelConfig] = None
                ) -> Iterator[Dict[str, np.ndarray]]:
        step = start_step
        while True:
            yield self.batch(step, model_cfg)
            step += 1
