"""Sharded numpy checkpointing with elastic restore.

Fault tolerance for the training path (DESIGN.md §9): every N steps each
leaf of (params, opt_state) is written as a .npy under a step directory
with an atomic manifest commit; restore rebuilds the pytree and re-shards
onto whatever mesh the restart has — including a *smaller* mesh after a
pod loss (elastic restart), the training-side analogue of FailLite's
progressive failover.
"""

from __future__ import annotations

import json
import os
import shutil
import tempfile
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree, prefix=""):
    if isinstance(tree, dict):
        for k in sorted(tree):
            yield from _flatten(tree[k], f"{prefix}/{k}" if prefix else k)
    elif isinstance(tree, (list, tuple)) and not hasattr(tree, "_fields"):
        for i, v in enumerate(tree):
            yield from _flatten(v, f"{prefix}/{i}")
    elif hasattr(tree, "_fields"):          # NamedTuple (opt state)
        for f in tree._fields:
            yield from _flatten(getattr(tree, f),
                                f"{prefix}/{f}" if prefix else f)
    else:
        yield prefix, tree


def save_checkpoint(ckpt_dir: Path, step: int, params, opt_state=None,
                    extra: Optional[Dict[str, Any]] = None) -> Path:
    """Atomic checkpoint: write to tmp dir, fsync manifest, rename."""
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    final = ckpt_dir / f"step_{step:08d}"
    tmp = Path(tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_"))
    manifest = {"step": step, "leaves": [], "extra": extra or {}}
    trees = {"params": params}
    if opt_state is not None:
        trees["opt"] = opt_state
    for root, tree in trees.items():
        for path, leaf in _flatten(tree, root):
            arr = np.asarray(jax.device_get(leaf))
            fname = path.replace("/", "__") + ".npy"
            np.save(tmp / fname, arr)
            manifest["leaves"].append({"path": path, "file": fname,
                                       "dtype": str(arr.dtype),
                                       "shape": list(arr.shape)})
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(ckpt_dir: Path) -> Optional[int]:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = [int(p.name.split("_")[1]) for p in ckpt_dir.glob("step_*")
             if (p / "manifest.json").exists()]
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: Path, step: int, params_tmpl,
                       opt_tmpl=None, *, shardings=None, opt_shardings=None):
    """Restore into the templates' structure; re-shard via `shardings`
    (works across mesh sizes — elastic restart)."""
    d = Path(ckpt_dir) / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())
    flat = {e["path"]: e["file"] for e in manifest["leaves"]}

    def rebuild(tmpl, root, shs):
        sh_leaves = dict(_flatten(shs, root)) if shs is not None else {}

        def walk(t, prefix):
            if isinstance(t, dict):
                return {k: walk(v, f"{prefix}/{k}" if prefix else k)
                        for k, v in t.items()}
            if isinstance(t, (list, tuple)) and not hasattr(t, "_fields"):
                return type(t)(walk(v, f"{prefix}/{i}")
                               for i, v in enumerate(t))
            if hasattr(t, "_fields"):
                return type(t)(**{f: walk(getattr(t, f), f"{prefix}/{f}")
                                  for f in t._fields})
            arr = np.load(d / flat[prefix])
            arr = jnp.asarray(arr, dtype=t.dtype)
            sh = sh_leaves.get(prefix)
            if sh is not None:
                arr = jax.device_put(arr, sh)
            return arr
        return walk(tmpl, root)

    params = rebuild(params_tmpl, "params", shardings)
    opt = (rebuild(opt_tmpl, "opt", opt_shardings)
           if opt_tmpl is not None else None)
    return manifest["step"], params, opt, manifest.get("extra", {})
