"""AdamW with fp32 master weights and global-norm clipping (pure JAX).

Optimizer state is sharded like the params (m/v/master inherit each
param's PartitionSpec), giving ZeRO-3-style fully sharded optimizer
memory over the (data, model) mesh axes.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any
    master: Any          # fp32 copy, or None-like empty dict if params fp32


@dataclass(frozen=True)
class AdamW:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    max_grad_norm: float = 1.0
    warmup_steps: int = 100
    schedule: str = "cosine"          # "cosine" | "constant"
    total_steps: int = 10_000

    def _lr(self, step):
        step = step.astype(jnp.float32)
        warm = jnp.minimum(1.0, (step + 1) / max(1, self.warmup_steps))
        if self.schedule == "cosine":
            t = jnp.clip((step - self.warmup_steps)
                         / max(1, self.total_steps - self.warmup_steps), 0, 1)
            decay = 0.5 * (1 + jnp.cos(jnp.pi * t))
        else:
            decay = 1.0
        return self.lr * warm * decay

    def init(self, params) -> AdamWState:
        zeros = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        needs_master = any(
            p.dtype != jnp.float32 for p in jax.tree_util.tree_leaves(params))
        master = (jax.tree_util.tree_map(
            lambda p: p.astype(jnp.float32), params) if needs_master else {})
        return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                          v=jax.tree_util.tree_map(jnp.copy, zeros),
                          master=master)

    def update(self, grads, state: AdamWState, params):
        gleaves = jax.tree_util.tree_leaves(grads)
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in gleaves))
        scale = jnp.minimum(1.0, self.max_grad_norm / (gnorm + 1e-9))
        lr = self._lr(state.step)
        t = state.step.astype(jnp.float32) + 1.0
        bc1 = 1.0 - self.b1 ** t
        bc2 = 1.0 - self.b2 ** t
        base = state.master if state.master else params

        def upd(g, m, v, p):
            g = g.astype(jnp.float32) * scale
            m = self.b1 * m + (1 - self.b1) * g
            v = self.b2 * v + (1 - self.b2) * jnp.square(g)
            mh = m / bc1
            vh = v / bc2
            step = mh / (jnp.sqrt(vh) + self.eps)
            pf = p.astype(jnp.float32)
            pf = pf - lr * (step + self.weight_decay * pf)
            return m, v, pf

        out = jax.tree_util.tree_map(upd, grads, state.m, state.v, base)
        m = jax.tree_util.tree_map(lambda o: o[0], out,
                                   is_leaf=lambda x: isinstance(x, tuple))
        v = jax.tree_util.tree_map(lambda o: o[1], out,
                                   is_leaf=lambda x: isinstance(x, tuple))
        new_master = jax.tree_util.tree_map(
            lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
        new_params = jax.tree_util.tree_map(
            lambda pf, p: pf.astype(p.dtype), new_master, params)
        new_state = AdamWState(step=state.step + 1, m=m, v=v,
                               master=new_master if state.master else {})
        return new_params, new_state, gnorm

    def state_shapes(self, param_shapes):
        """eval_shape twin of init (dry-run)."""
        return jax.eval_shape(self.init, param_shapes)
