"""Sharding rules: DP / FSDP / TP / EP / SP over the production mesh.

Mesh axes (launch/mesh.py):
    single-pod : (data=16, model=16)
    multi-pod  : (pod=2, data=16, model=16)

Conventions
-----------
* batch           -> ("pod", "data")          (pure DP over pods)
* d_model of params -> "data"                  (FSDP / ZeRO-3 style)
* heads / d_ff / experts / vocab -> "model"    (TP / EP)
* decode KV sequence -> "model"                (flash-decoding split-KV)
* long-context KV sequence -> ("data","model") when batch == 1 (SP)

All helpers degrade gracefully: axes missing from the ambient mesh are
dropped from specs, as are axes that do not divide the dimension (so the
same model code runs on the 1-device CPU smoke tests and the 512-device
dry-run unchanged).
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _mesh_nonempty(m) -> bool:
    empty = getattr(m, "empty", None)
    if empty is not None:
        return not empty
    return bool(getattr(m, "axis_names", ()))


def _abstract_mesh_getters():
    get = getattr(jax.sharding, "get_abstract_mesh", None)
    if get is not None:
        yield get                  # public export (newer jax)
    try:
        from jax._src import mesh as _mesh_lib
        yield _mesh_lib.get_abstract_mesh
    except Exception:              # pragma: no cover - very old jax
        return


def _mesh_from_abstract() -> Optional[Mesh]:
    """Ambient mesh via the current abstract-mesh API (set by
    `use_mesh`/`set_mesh`): `jax.sharding.get_abstract_mesh` where it
    exists, else the same accessor from `jax._src.mesh` on jax
    versions that predate the public export."""
    for get in _abstract_mesh_getters():
        try:
            am = get()
        except Exception:
            continue
        if am is not None and _mesh_nonempty(am):
            return am
    return None


def _mesh_from_pxla() -> Optional[Mesh]:
    """Legacy `with Mesh(...):` scope via the deprecated
    `pxla.thread_resources` — kept as a fallback for callers still on
    the context-manager idiom."""
    import warnings
    try:
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            from jax.interpreters import pxla
            mesh = pxla.thread_resources.env.physical_mesh
    except Exception:
        return None
    if mesh is not None and not mesh.empty:
        return mesh
    return None


def current_mesh() -> Optional[Mesh]:
    """Ambient mesh from `use_mesh`/`set_mesh` or a `with mesh:` scope,
    or None. The non-deprecated abstract-mesh discovery runs first; the
    pxla thread-resources probe is only a legacy fallback."""
    return _mesh_from_abstract() or _mesh_from_pxla()


def _axis_size(mesh, name) -> int:
    return dict(zip(mesh.axis_names, mesh.axis_sizes if hasattr(mesh, "axis_sizes") else mesh.devices.shape))[name]


def filter_spec(spec: P, mesh: Mesh, shape=None) -> P:
    """Drop mesh axes that are absent or do not divide the dimension."""
    names = set(mesh.axis_names)
    sizes = dict(zip(mesh.axis_names,
                     getattr(mesh, "axis_sizes", None) or mesh.devices.shape))
    out = []
    for i, entry in enumerate(spec):
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        axes = tuple(a for a in axes if a in names)
        if shape is not None and axes:
            total = int(np.prod([sizes[a] for a in axes]))
            if shape[i] % total != 0:
                # try progressively shorter prefixes of the axis tuple
                while axes:
                    total = int(np.prod([sizes[a] for a in axes]))
                    if shape[i] % total == 0:
                        break
                    axes = axes[:-1]
        if not axes:
            out.append(None)
        elif len(axes) == 1:
            out.append(axes[0])
        else:
            out.append(tuple(axes))
    return P(*out)


def logical_constraint(x, spec: P):
    """with_sharding_constraint that is a no-op without a mesh."""
    mesh = current_mesh()
    if mesh is None:
        return x
    fs = filter_spec(spec, mesh, x.shape)
    try:
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, fs))
    except (ValueError, TypeError):
        # abstract mesh path (inside jit under `use_mesh`)
        return jax.lax.with_sharding_constraint(x, fs)


# ---------------------------------------------------------------------------
# Parameter partition specs
# ---------------------------------------------------------------------------

# Leaf-name -> spec template, by *trailing* path component. Templates are
# written for the full (pod, data, model) mesh; filter_spec() adapts them.
_PARAM_RULES = {
    # embedding / head
    "table":   P("model", "data"),
    # attention
    "wq":      P("data", "model", None),
    "wk":      P("data", "model", None),
    "wv":      P("data", "model", None),
    "wo":      P("model", None, "data"),
    "bq":      P("model", None),
    "bk":      P("model", None),
    "bv":      P("model", None),
    # mlp
    "w_gate":  P("data", "model"),
    "w_up":    P("data", "model"),
    "w_down":  P("model", "data"),
    "w_in":    P("data", "model"),
    "w_out":   P("model", "data"),
    "b_in":    P("model"),
    "b_out":   P(None),
    # MoE (leading expert dim)
    "we_gate": P("model", "data", None),
    "we_up":   P("model", "data", None),
    "we_down": P("model", None, "data"),
    "router":  P("data", None),
    # RG-LRU recurrent block
    "w_x":     P("data", "model"),
    "w_gate_rec": P("data", "model"),
    "conv_w":  P(None, "model"),
    "conv_b":  P("model"),
    "gate_a":  P("model", None, None),   # (blocks, w/b, w/b)
    "gate_x":  P("model", None, None),
    "log_lambda": P("model"),
    "w_out_rec": P("model", "data"),
    # RWKV-6
    "w_r":     P("data", "model"),
    "w_k":     P("data", "model"),
    "w_v":     P("data", "model"),
    "w_g":     P("data", "model"),
    "w_o":     P("model", "data"),
    "decay_w1": P("data", None),
    "decay_w2": P(None, "model"),
    "bonus_u": P("model", None),
    "mix":     P(None),
    # norms
    "scale":   P(None),
    "bias":    P(None),
}


def spec_for_param(path: str, shape) -> P:
    """Partition spec for one parameter, by path suffix.

    Stacked (scanned) block params carry a leading layer/cycle dim which
    is never sharded; we right-align the rule spec against the shape.
    """
    leaf = path.split("/")[-1]
    rule = _PARAM_RULES.get(leaf)
    if rule is None:
        return P(*([None] * len(shape)))
    rule_dims = len(rule)
    extra = len(shape) - rule_dims
    if extra < 0:
        return P(*([None] * len(shape)))
    return P(*([None] * extra + list(rule)))


def _tree_paths(tree, prefix=""):
    if isinstance(tree, dict):
        for k, v in tree.items():
            yield from _tree_paths(v, f"{prefix}/{k}" if prefix else str(k))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            yield from _tree_paths(v, f"{prefix}/{i}" if prefix else str(i))
    else:
        yield prefix, tree


def param_specs(shapes_tree) -> Any:
    """Map a tree of ShapeDtypeStructs/arrays to a tree of PartitionSpecs."""
    def walk(tree, prefix=""):
        if isinstance(tree, dict):
            return {k: walk(v, f"{prefix}/{k}" if prefix else str(k))
                    for k, v in tree.items()}
        if isinstance(tree, (list, tuple)):
            t = [walk(v, f"{prefix}/{i}" if prefix else str(i))
                 for i, v in enumerate(tree)]
            return type(tree)(t)
        return spec_for_param(prefix, tree.shape)
    return walk(shapes_tree)


def _drop_axes(spec: P, axes: set) -> P:
    out = []
    for e in spec:
        if e is None:
            out.append(None)
        elif isinstance(e, tuple):
            kept = tuple(a for a in e if a not in axes)
            out.append(kept if len(kept) > 1 else (kept[0] if kept
                                                   else None))
        else:
            out.append(None if e in axes else e)
    return P(*out)


def param_shardings(shapes_tree, mesh: Mesh, *, serving: bool = False):
    """NamedShardings for a param tree, with divisibility-aware filtering.

    serving=True = weight-stationary layout: the FSDP ("data"/"pod")
    axes are dropped so weights are only TP-sharded — no per-step weight
    all-gathers at decode (used when params/TP-shard fit the cell HBM).
    """
    def walk(tree, prefix=""):
        if isinstance(tree, dict):
            return {k: walk(v, f"{prefix}/{k}" if prefix else str(k))
                    for k, v in tree.items()}
        if isinstance(tree, (list, tuple)):
            t = [walk(v, f"{prefix}/{i}" if prefix else str(i))
                 for i, v in enumerate(tree)]
            return type(tree)(t)
        spec = spec_for_param(prefix, tree.shape)
        if serving:
            spec = _drop_axes(spec, {"data", "pod"})
        return NamedSharding(mesh, filter_spec(spec, mesh, tree.shape))
    return walk(shapes_tree)


# Common activation/data specs --------------------------------------------

BATCH = P(("pod", "data"))


def batch_spec(ndim: int, *, seq_axis: Optional[int] = None,
               shard_seq: bool = False) -> P:
    entries: list = [("pod", "data")] + [None] * (ndim - 1)
    if shard_seq and seq_axis is not None:
        entries[seq_axis] = "model"
    return P(*entries)


def data_shardings(tree, mesh: Mesh, spec: P):
    def walk(leaf):
        return NamedSharding(mesh, filter_spec(spec, mesh, leaf.shape))
    return jax.tree_util.tree_map(walk, tree)


def batch_shardings(batch_shapes, mesh: Mesh):
    """Shardings for input batches: leading batch dim over (pod, data)."""
    def walk(leaf):
        spec = P(*([("pod", "data")] + [None] * (len(leaf.shape) - 1)))
        return NamedSharding(mesh, filter_spec(spec, mesh, leaf.shape))
    return jax.tree_util.tree_map(walk, batch_shapes)


def _mesh_sizes(mesh):
    return dict(zip(mesh.axis_names,
                    getattr(mesh, "axis_sizes", None) or mesh.devices.shape))


def decode_cache_shardings(cache_shapes, mesh: Mesh):
    """Shardings for decode caches (KV buffers + recurrent states).

    KV (.../B, S, KVH, hd): batch over (pod, data), sequence over "model"
    (flash-decoding split-KV).  When the batch does not divide the data
    axes (long_500k, B=1) the sequence dim takes (pod, data, model) —
    sequence parallelism over the full mesh.
    """
    sizes = _mesh_sizes(mesh)
    dp = sizes.get("pod", 1) * sizes.get("data", 1)

    def walk(tree, name=""):
        if isinstance(tree, dict):
            return {k: walk(v, k) for k, v in tree.items()}
        if isinstance(tree, (list, tuple)):
            return type(tree)([walk(v, name) for v in tree])
        shape = tree.shape
        nd = len(shape)
        if name in ("k", "v") and nd >= 4:
            B, S = shape[-4], shape[-3]
            lead = [None] * (nd - 4)
            if B % dp == 0 and dp > 1:
                spec = P(*lead, ("pod", "data"), "model", None, None)
            else:
                spec = P(*lead, None, ("pod", "data", "model"), None, None)
        elif name == "wkv" and nd >= 4:
            lead = [None] * (nd - 4)
            spec = P(*lead, ("pod", "data"), "model", None, None)
        elif name in ("h", "last") and nd >= 2:
            lead = [None] * (nd - 2)
            spec = P(*lead, ("pod", "data"), "model")
        elif name == "conv" and nd >= 3:
            lead = [None] * (nd - 3)
            spec = P(*lead, ("pod", "data"), None, "model")
        elif name == "pos":
            spec = P(*([None] * (nd - 1)), ("pod", "data"))
        else:
            spec = P(*([None] * nd))
        return NamedSharding(mesh, filter_spec(spec, mesh, shape))
    return walk(cache_shapes)


def replicated(tree, mesh: Mesh):
    def walk(leaf):
        return NamedSharding(mesh, P(*([None] * len(leaf.shape))))
    return jax.tree_util.tree_map(walk, tree)
