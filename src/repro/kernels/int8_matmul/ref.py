"""Pure-jnp oracle for the int8 weight-only matmul."""

from __future__ import annotations

import jax.numpy as jnp


def int8_matmul_ref(x, w_q, scale, out_dtype=None):
    """x: (M,K); w_q: (K,N) int8; scale: (N,)."""
    out_dtype = out_dtype or x.dtype
    acc = x.astype(jnp.float32) @ w_q.astype(jnp.float32)
    return (acc * scale.astype(jnp.float32)).astype(out_dtype)
