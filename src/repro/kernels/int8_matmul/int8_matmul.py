"""Pallas TPU weight-only int8 matmul (dequant fused into the epilogue).

FailLite's heterogeneous replication stores failover replicas as int8
variants (half the HBM of bf16) — this kernel is what makes serving them
cheap: weights stream HBM->VMEM as int8 (halving the memory-bound decode
cost) and are dequantized with per-output-channel scales inside the MXU
matmul epilogue, never materializing a bf16 copy of the weight matrix.

x (M, K) bf16/f32 @ w_q (K, N) int8 * scale (N,) f32 -> (M, N).
Grid (M/bm, N/bn, K/bk); fp32 accumulator in VMEM scratch across the
sequential K dimension.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _int8_mm_kernel(x_ref, w_ref, s_ref, o_ref, acc_scr, *, nk):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    x = x_ref[...].astype(jnp.float32)        # (bm, bk)
    w = w_ref[...].astype(jnp.float32)        # (bk, bn) — int8 upcast in VREG
    acc_scr[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ik == nk - 1)
    def _finalize():
        scale = s_ref[...].astype(jnp.float32)        # (1, bn)
        o_ref[...] = (acc_scr[...] * scale).astype(o_ref.dtype)


def int8_matmul_pallas(x, w_q, scale, *, block_m=128, block_n=128,
                       block_k=512, out_dtype=None, interpret=False):
    """x: (M,K); w_q: (K,N) int8; scale: (N,) -> (M,N)."""
    M, K = x.shape
    _, N = w_q.shape
    out_dtype = out_dtype or x.dtype
    block_m = min(block_m, M)
    block_n = min(block_n, N)
    block_k = min(block_k, K)
    pm, pn, pk = (-M) % block_m, (-N) % block_n, (-K) % block_k
    if pm or pk:
        x = jnp.pad(x, ((0, pm), (0, pk)))
    if pk or pn:
        w_q = jnp.pad(w_q, ((0, pk), (0, pn)))
    if pn:
        scale = jnp.pad(scale, (0, pn))
    nm, nn, nk = (M + pm) // block_m, (N + pn) // block_n, (K + pk) // block_k

    kernel = functools.partial(_int8_mm_kernel, nk=nk)
    out = pl.pallas_call(
        kernel,
        grid=(nm, nn, nk),
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda im, in_, ik: (im, ik)),
            pl.BlockSpec((block_k, block_n), lambda im, in_, ik: (ik, in_)),
            pl.BlockSpec((1, block_n), lambda im, in_, ik: (0, in_)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n),
                               lambda im, in_, ik: (im, in_)),
        out_shape=jax.ShapeDtypeStruct((M + pm, N + pn), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
    )(x, w_q, scale.reshape(1, -1))
    return out[:M, :N]


def quantize_int8(w, axis=0):
    """Symmetric per-channel int8 quantization along `axis` (contraction).

    Returns (w_q int8 (K,N), scale f32 (N,)) such that w ~= w_q * scale.
    """
    amax = jnp.max(jnp.abs(w.astype(jnp.float32)), axis=axis, keepdims=True)
    scale = (amax / 127.0).clip(1e-8)
    w_q = jnp.clip(jnp.round(w.astype(jnp.float32) / scale), -127, 127)
    return w_q.astype(jnp.int8), scale.reshape(-1)
