"""jit'd public wrapper for the int8 weight-only matmul."""

from __future__ import annotations

from functools import partial

import jax

from repro.kernels.int8_matmul.int8_matmul import (int8_matmul_pallas,
                                                   quantize_int8)


@partial(jax.jit, static_argnames=("block_m", "block_n", "block_k",
                                   "interpret"))
def int8_matmul(x, w_q, scale, *, block_m=128, block_n=128, block_k=512,
                interpret=None):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return int8_matmul_pallas(x, w_q, scale, block_m=block_m,
                              block_n=block_n, block_k=block_k,
                              interpret=interpret)


__all__ = ["int8_matmul", "quantize_int8"]
