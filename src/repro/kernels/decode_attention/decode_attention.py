"""Pallas TPU split-KV decode attention (flash-decoding, arXiv:2311.01282).

One query token per sequence attends to a long KV cache.  The GPU
flash-decoding kernel splits KV across SMs and reduces partials in a
second kernel; on TPU the KV-chunk axis is the sequential last grid
dimension and the partial (m, l, acc) reduction lives in VMEM scratch —
one kernel, no inter-core reduction.  Grid: (B, H, n_kv_chunks).

Layouts: q (B, H, hd); k/v caches (B, KVH, Smax, hd); lens (B,) valid
entries.  Ring-buffer (sliding-window) caches pass window=0 and a
pre-clamped `lens` since the buffer holds exactly the window.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _dec_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                *, scale, block_k, nk, window):
    b = pl.program_id(0)
    ik = pl.program_id(2)
    n_valid = len_ref[b]

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    needed = (ik * block_k) < n_valid
    if window > 0:
        needed &= (ik * block_k + block_k) > (n_valid - window)

    @pl.when(needed)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale          # (1, hd)
        k = k_ref[0, 0].astype(jnp.float32)               # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        k_pos = ik * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (1, block_k), 1)
        mask = k_pos < n_valid
        if window > 0:
            mask &= k_pos >= n_valid - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_new = l_scr[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * corr + pv
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(ik == nk - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, ...] = (acc_scr[...] / l).astype(o_ref.dtype)


def decode_attention_bhd(q, k_cache, v_cache, lens, *, window=0,
                         block_k=256, interpret=False):
    """q: (B,H,hd); caches (B,KVH,Smax,hd); lens (B,). Returns (B,H,hd)."""
    B, H, hd = q.shape
    _, KVH, Smax, _ = k_cache.shape
    G = H // KVH
    scale = 1.0 / math.sqrt(hd)
    block_k = min(block_k, max(8, Smax))
    pad = (-Smax) % block_k
    if pad:
        k_cache = jnp.pad(k_cache, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, 0), (0, pad), (0, 0)))
    nk = (Smax + pad) // block_k

    kernel = functools.partial(_dec_kernel, scale=scale, block_k=block_k,
                               nk=nk, window=window)
    out = pl.pallas_call(
        kernel,
        grid=(B, H, nk),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),         # lens
            pl.BlockSpec((1, 1, hd), lambda b, h, ik: (b, h, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, ik: (b, h // G, ik, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, ik: (b, h // G, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, hd), lambda b, h, ik: (b, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, hd), jnp.float32),
        ],
        interpret=interpret,
    )(lens.astype(jnp.int32), q.reshape(B, H, 1, hd)[:, :, 0], k_cache,
      v_cache)
    return out
