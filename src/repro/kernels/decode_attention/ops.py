"""jit'd public wrapper for split-KV decode attention."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.decode_attention import \
    decode_attention_bhd


@partial(jax.jit, static_argnames=("window", "block_k", "interpret"))
def decode_attention(q, k_cache, v_cache, lens, *, window=0, block_k=256,
                     interpret=None):
    """q: (B,1,H,hd); caches (B,Smax,KVH,hd); lens (B,) -> (B,1,H,hd)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    qt = q[:, 0]                                  # (B,H,hd)
    kt = jnp.swapaxes(k_cache, 1, 2)              # (B,KVH,Smax,hd)
    vt = jnp.swapaxes(v_cache, 1, 2)
    o = decode_attention_bhd(qt, kt, vt, lens, window=window,
                             block_k=block_k, interpret=interpret)
    return o[:, None]
