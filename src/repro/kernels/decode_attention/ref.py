"""Pure-jnp oracle for split-KV decode attention."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def decode_attention_ref(q, k_cache, v_cache, lens, *, window=0):
    """q: (B,H,hd); caches (B,KVH,Smax,hd); lens (B,). Returns (B,H,hd)."""
    B, H, hd = q.shape
    _, KVH, Smax, _ = k_cache.shape
    G = H // KVH
    kx = jnp.repeat(k_cache, G, axis=1)
    vx = jnp.repeat(v_cache, G, axis=1)
    s = jnp.einsum("bhk,bhsk->bhs", q.astype(jnp.float32),
                   kx.astype(jnp.float32)) / math.sqrt(hd)
    pos = jnp.arange(Smax)
    valid = pos[None, :] < lens[:, None]
    if window > 0:
        valid &= pos[None, :] >= lens[:, None] - window
    s = jnp.where(valid[:, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhs,bhsk->bhk", p, vx.astype(jnp.float32))
    return o.astype(q.dtype)
