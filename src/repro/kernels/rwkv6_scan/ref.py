"""Pure-jnp oracle for the WKV-6 kernel (sequential scan)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv6_ref(r, k, v, lw, u):
    """r,k,v,lw: (B,NH,S,hs); u: (NH,hs). Zero init state.

    Returns (y (B,NH,S,hs), S_out (B,NH,hs,hs)).
    """
    B, NH, S, hs = r.shape
    w = jnp.exp(lw.astype(jnp.float32))
    state = jnp.zeros((B, NH, hs, hs), jnp.float32)

    def step(s, inp):
        rt, kt, vt, wt = inp              # (B,NH,hs)
        a = kt[..., :, None] * vt[..., None, :]
        y = jnp.einsum("bnk,bnkv->bnv", rt, s + u[..., :, None] * a)
        s = wt[..., :, None] * s + a
        return s, y

    xs = tuple(jnp.moveaxis(t.astype(jnp.float32), 2, 0)
               for t in (r, k, v, w))
    s_out, ys = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(ys, 0, 2).reshape(B, NH, S, hs), s_out
