"""Pallas TPU chunked RWKV-6 WKV recurrence (Finch, arXiv:2404.05892).

Per head: S_t = diag(w_t) S_{t-1} + k_t^T v_t;  y_t = r_t (S_{t-1} + u k_t^T v_t).

The CUDA kernel in the paper runs one thread per channel, sequential over
time.  The TPU adaptation uses the chunk-parallel form (as in GLA,
arXiv:2312.06635): grid = (B, NH, n_chunks) with chunks sequential; the
(hs x hs) state lives in VMEM scratch; intra-chunk work is two MXU
matmuls plus a (C x C) decay-masked score matmul, with all cross-step
decay exponents kept <= 0 for fp32 stability.

Inputs per head: r,k,v (B,NH,S,hs) fp32; lw (B,NH,S,hs) log-decay <= 0;
u (NH,hs) bonus.  Returns (y (B,NH,S,hs), S_out (B,NH,hs,hs)).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, y_ref, sout_ref,
                s_scr, *, chunk, nc):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    r = r_ref[0, 0]                       # (C, hs) fp32
    k = k_ref[0, 0]
    v = v_ref[0, 0]
    lw = lw_ref[0, 0]
    u = u_ref[0]                          # (1, hs)
    s = s_scr[...]                        # (hs, hs)

    cum = jnp.cumsum(lw, axis=0)          # inclusive
    cum_prev = cum - lw                   # exclusive
    cum_last = cum[-1:]                   # (1, hs)

    # inter-chunk: y += (r * e^{cum_prev}) @ S_in
    r_dec = r * jnp.exp(cum_prev)
    y = jax.lax.dot_general(r_dec, s, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    # intra-chunk strict-lower part: A[t,s] = sum_k r_t k_s e^{cum_prev_t - cum_s}
    k_div = k * jnp.exp(-cum)             # NOTE: may be large; masked below
    a = jax.lax.dot_general(r_dec, k_div, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    ti = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    si = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    a = jnp.where(ti > si, a, 0.0)
    y = y + jax.lax.dot_general(a, v, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    # diagonal bonus: y_t += (r_t . u*k_t) v_t
    diag = jnp.sum(r * (u * k), axis=-1, keepdims=True)
    y = y + diag * v
    y_ref[0, 0, ...] = y.astype(y_ref.dtype)

    # state update: S_out = e^{cum_last} ⊙ S_in + sum_s (k_s e^{cum_last-cum_s})^T v_s
    k_dec = k * jnp.exp(cum_last - cum)
    s_new = jnp.exp(cum_last).reshape(-1, 1) * s + jax.lax.dot_general(
        k_dec, v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    s_scr[...] = s_new

    @pl.when(ic == nc - 1)
    def _finalize():
        sout_ref[0, 0, ...] = s_new


def wkv6_pallas(r, k, v, lw, u, *, chunk=32, interpret=False):
    """r,k,v,lw: (B,NH,S,hs) fp32; u: (NH,hs). Zero initial state.

    The intra-chunk two-factor decomposition (r e^{cum_prev}) @ (k e^{-cum})
    requires |cum| within a chunk to stay in fp32 range; chunk<=64 with
    lw >= -20 is safe (e^{1280} overflow is masked out but Inf*0 = NaN is
    not, so lw is clamped here).
    """
    B, NH, S, hs = r.shape
    lw = jnp.maximum(lw, -40.0 / chunk)   # stability clamp (see docstring)
    pad = (-S) % chunk
    if pad:
        r = jnp.pad(r, ((0, 0), (0, 0), (0, pad), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        lw = jnp.pad(lw, ((0, 0), (0, 0), (0, pad), (0, 0)))
    nc = (S + pad) // chunk

    kernel = functools.partial(_wkv_kernel, chunk=chunk, nc=nc)
    y, s_out = pl.pallas_call(
        kernel,
        grid=(B, NH, nc),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, hs), lambda b, h, ic: (b, h, ic, 0)),
            pl.BlockSpec((1, 1, chunk, hs), lambda b, h, ic: (b, h, ic, 0)),
            pl.BlockSpec((1, 1, chunk, hs), lambda b, h, ic: (b, h, ic, 0)),
            pl.BlockSpec((1, 1, chunk, hs), lambda b, h, ic: (b, h, ic, 0)),
            pl.BlockSpec((1, hs), lambda b, h, ic: (h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, hs), lambda b, h, ic: (b, h, ic, 0)),
            pl.BlockSpec((1, 1, hs, hs), lambda b, h, ic: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, NH, S + pad, hs), jnp.float32),
            jax.ShapeDtypeStruct((B, NH, hs, hs), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hs, hs), jnp.float32)],
        interpret=interpret,
    )(r, k, v, lw, u)
    return y[:, :, :S], s_out
