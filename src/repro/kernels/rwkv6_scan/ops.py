"""jit'd public wrapper for the WKV-6 chunked kernel."""

from __future__ import annotations

from functools import partial

import jax

from repro.kernels.rwkv6_scan.rwkv6_scan import wkv6_pallas


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6(r, k, v, lw, u, *, chunk=32, interpret=None):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return wkv6_pallas(r, k, v, lw, u, chunk=chunk, interpret=interpret)
