"""jit'd public wrapper for the flash attention kernel.

Accepts the model's (B, S, H, hd) layout, handles the transpose to the
kernel's (B, H, S, hd) layout, and falls back to interpret mode off-TPU.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention_bhsd


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_k",
                                   "interpret"))
def flash_attention(q, k, v, *, causal=True, window=0, block_q=128,
                    block_k=128, interpret=None):
    """q: (B,S,H,hd); k,v: (B,S,KVH,hd) -> (B,S,H,hd)."""
    if interpret is None:
        interpret = not _on_tpu()
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    ot = flash_attention_bhsd(qt, kt, vt, causal=causal, window=window,
                              block_q=block_q, block_k=block_k,
                              interpret=interpret)
    return jnp.swapaxes(ot, 1, 2)
