"""Pallas TPU flash attention (causal / sliding-window, GQA).

TPU adaptation of the FlashAttention algorithm (arXiv:2205.14135): the
GPU formulation parallelizes KV-block reduction across warps with shared
memory; on TPU the KV axis is the *last, sequential* grid dimension so the
online-softmax state (m, l, acc) lives in VMEM scratch across grid steps,
and the MXU sees (block_q x head_dim) @ (head_dim x block_k) matmuls.

Layouts: q (B, H, Sq, hd); k/v (B, KVH, Skv, hd); out (B, H, Sq, hd).
GQA is handled in the BlockSpec index_map (kv head = q head // group).
Fully-masked KV blocks (causal upper triangle, outside the sliding
window) are skipped with pl.when — that is the causal 2x FLOP saving the
jnp reference path does not get.  Validated on CPU via interpret mode.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
               scale, causal, window, block_q, block_k, nk, seq_q, seq_kv):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # whole-block skip (causal upper triangle / outside window / padding)
    needed = (ik * block_k) < seq_kv
    if causal:
        needed &= (ik * block_k) <= (iq * block_q + block_q - 1)
    if window > 0:
        needed &= (ik * block_k + block_k - 1) > (iq * block_q - window)

    @pl.when(needed)
    def _compute():
        q_pos = iq * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0)
        k_pos = ik * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        q = q_ref[0, 0].astype(jnp.float32) * scale       # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)               # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        mask = (q_pos < seq_q) & (k_pos < seq_kv)
        if causal:
            mask &= q_pos >= k_pos
        if window > 0:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                               # (bq, 1)
        l_prev = l_scr[...]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(p, axis=-1, keepdims=True)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * corr + pv
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(ik == nk - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0, ...] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_attention_bhsd(q, k, v, *, causal=True, window=0,
                         block_q=128, block_k=128, interpret=False):
    """q: (B,H,Sq,hd); k,v: (B,KVH,Skv,hd). Returns (B,H,Sq,hd)."""
    B, H, Sq, hd = q.shape
    _, KVH, Skv, _ = k.shape
    G = H // KVH
    scale = 1.0 / math.sqrt(hd)

    block_q = min(block_q, max(8, Sq))
    block_k = min(block_k, max(8, Skv))
    pad_q = (-Sq) % block_q
    pad_k = (-Skv) % block_k
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    nq = (Sq + pad_q) // block_q
    nk = (Skv + pad_k) // block_k

    kernel = functools.partial(
        _fa_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, nk=nk, seq_q=Sq, seq_kv=Skv)

    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd),
                         lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, iq, ik: (b, h // G, ik, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, iq, ik: (b, h // G, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd),
                               lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq + pad_q, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :Sq]
