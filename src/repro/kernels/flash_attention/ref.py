"""Pure-jnp oracle for the flash attention kernel."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal=True, window=0):
    """q: (B,H,Sq,hd); k,v: (B,KVH,Skv,hd). Naive materialized softmax."""
    B, H, Sq, hd = q.shape
    _, KVH, Skv, _ = k.shape
    G = H // KVH
    kx = jnp.repeat(k, G, axis=1)
    vx = jnp.repeat(v, G, axis=1)
    s = jnp.einsum("bhqk,bhsk->bhqs", q.astype(jnp.float32),
                   kx.astype(jnp.float32)) / math.sqrt(hd)
    qp = jnp.arange(Sq)[:, None]
    kp = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= qp >= kp
    if window > 0:
        mask &= kp > qp - window
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqs,bhsk->bhqk", p, vx.astype(jnp.float32))
    return o.astype(q.dtype)
