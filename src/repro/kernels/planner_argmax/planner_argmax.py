"""Pallas TPU tiled masked argmax — the planner's worst-fit reduction.

FailLite's Algorithm 1 answers every placement attempt with one masked
argmax over the per-server headroom column: "the feasible alive server
of maximal normalized headroom, FIRST row on ties" (state.py:183 /
vectorized.py:196 — the first-maximum rule is what makes the vectorized,
sharded, and jax planner backends bit-identical). This kernel is that
reduction as a tiled one-pass scan: values stream HBM->VMEM one
(1, block) tile at a time, each tile reduces to (tile max, first index
achieving it), and a scalar carry in SMEM combines tiles in ascending
order — a later tile only wins on a STRICT improvement, so the global
winner is the first maximum, exactly `np.argmax(np.where(mask, v, -inf))`.

Returns (idx int32, val) with idx = -1 / val = -inf when the mask is
empty — callers branch on feasibility the same way the numpy path
branches on `feas.any()`.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _masked_argmax_kernel(v_ref, m_ref, idx_ref, val_ref, *, block, n):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        idx_ref[0, 0] = jnp.int32(-1)
        val_ref[0, 0] = jnp.array(-jnp.inf, val_ref.dtype)

    v = v_ref[...]                                     # (1, block)
    m = m_ref[...]
    vv = jnp.where(m, v, -jnp.inf)
    tile_max = vv.max()
    # first in-tile column achieving the max (iota ascending, min wins)
    col = jax.lax.broadcasted_iota(jnp.int32, vv.shape, 1)
    tile_idx = jnp.where(vv == tile_max, col, n).min() + i * block

    # ascending-tile combine: strict improvement only, so ties keep the
    # earlier (smaller-index) tile — the first-maximum rule
    best = val_ref[0, 0]
    take = tile_max > best
    val_ref[0, 0] = jnp.where(take, tile_max, best)
    idx_ref[0, 0] = jnp.where(take, tile_idx.astype(jnp.int32),
                              idx_ref[0, 0])


def masked_argmax_pallas(values, mask, *, block: int = 512,
                         interpret: bool = False):
    """(S,) values + (S,) bool mask -> (idx int32, val): the first
    maximum among masked-in entries; (-1, -inf) when none."""
    n = values.shape[0]
    block = max(128, min(block, max(128, n)))
    pad = (-n) % block
    if pad:
        values = jnp.pad(values, (0, pad), constant_values=0)
        mask = jnp.pad(mask, (0, pad), constant_values=False)
    nt = (n + pad) // block
    v2 = values.reshape(1, n + pad)
    m2 = mask.reshape(1, n + pad)

    kernel = functools.partial(_masked_argmax_kernel, block=block, n=n)
    idx, val = pl.pallas_call(
        kernel,
        grid=(nt,),
        in_specs=[
            pl.BlockSpec((1, block), lambda i: (0, i)),
            pl.BlockSpec((1, block), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, 1), jnp.int32),
            jax.ShapeDtypeStruct((1, 1), values.dtype),
        ],
        interpret=interpret,
    )(v2, m2)
    return idx[0, 0], val[0, 0]
