"""Dispatching wrapper for the planner's masked-argmax reduction.

Two implementations, one contract (first maximum among masked-in rows,
(-1, -inf) on an empty mask — see ref.py):

  * ``pallas`` — the tiled TPU kernel (planner_argmax.py): used when
    the default JAX backend is a TPU, or forced via ``impl="pallas"``
    (interpret-mode on CPU — the parity tests run it this way);
  * ``jnp``    — the jittable jnp equivalent: the CPU fast path the
    jax planner backend inlines into its fused placement scan.

Both are exact — comparisons and argmax only, no accumulation — so the
choice never changes a placement, only where the reduction runs.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.planner_argmax.planner_argmax import masked_argmax_pallas


def masked_argmax_jnp(values, mask):
    """Jittable jnp implementation of the ref contract.

    Formulated as a max-reduce plus a first-index min-reduce over iota
    rather than one variadic argmax reduce: XLA:CPU vectorizes plain
    min/max reductions but emits scalar code for index-carrying
    reductions, which made `argmax` the dominant cost of the planner's
    placement scan (~40us vs ~10us per step at S=10000). The min over
    iota of positions attaining the max IS numpy's first-occurrence
    argmax, so the tie rule is unchanged; the `mask &` term keeps the
    empty-mask case on the ref contract. Values must be finite (-inf is
    reserved as the mask sentinel) — true of every planner call site,
    where values are normalized headroom."""
    n = values.shape[0]
    masked = jnp.where(mask, values, -jnp.inf)
    mx = masked.max()
    iota = jax.lax.iota(jnp.int32, n)
    i = jnp.where(mask & (masked == mx), iota, jnp.int32(n)).min()
    found = i < n
    return (jnp.where(found, i, -1).astype(jnp.int32),
            jnp.where(found, mx, -jnp.inf))


def masked_argmax(values, mask, *, impl: str | None = None,
                  block: int = 512, interpret: bool | None = None):
    """(S,) values + (S,) bool mask -> (idx int32, val).

    ``impl=None`` auto-selects: the Pallas kernel on TPU, the jnp path
    everywhere else (the kernel still runs anywhere via
    ``impl="pallas"`` + interpret mode)."""
    if impl is None:
        impl = "pallas" if jax.default_backend() == "tpu" else "jnp"
    if impl == "jnp":
        return masked_argmax_jnp(values, mask)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return masked_argmax_pallas(values, mask, block=block,
                                interpret=interpret)


__all__ = ["masked_argmax", "masked_argmax_jnp"]
