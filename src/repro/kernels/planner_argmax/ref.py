"""Numpy reference for the masked-argmax reduction (tie rule oracle).

This is literally the planner's selection rule (state.py:183 /
vectorized.py:196): `np.argmax` over the masked column returns the
FIRST maximum in ascending row order. The Pallas kernel and the jnp
fallback are both asserted bit-identical to this, including ties and
the empty-mask case."""

from __future__ import annotations

import numpy as np


def masked_argmax_ref(values, mask):
    """(S,) values + (S,) bool mask -> (idx, val); (-1, -inf) when the
    mask admits nothing. Values must be finite: -inf is reserved as
    the mask sentinel (the planner only ever reduces normalized
    headroom, which is finite)."""
    values = np.asarray(values)
    mask = np.asarray(mask, dtype=bool)
    if not mask.any():
        return -1, float("-inf")
    masked = np.where(mask, values, -np.inf)
    i = int(np.argmax(masked))
    return i, masked[i]
