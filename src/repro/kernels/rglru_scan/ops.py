"""jit'd public wrapper for the RG-LRU scan kernel."""

from __future__ import annotations

from functools import partial

import jax

from repro.kernels.rglru_scan.rglru_scan import rglru_scan_pallas


@partial(jax.jit, static_argnames=("block_s", "block_w", "interpret"))
def rglru_scan(a, b, h0, *, block_s=128, block_w=256, interpret=None):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return rglru_scan_pallas(a, b, h0, block_s=block_s, block_w=block_w,
                             interpret=interpret)
