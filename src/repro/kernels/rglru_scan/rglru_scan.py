"""Pallas TPU blocked RG-LRU scan (Griffin, arXiv:2402.19427).

The recurrence h_t = a_t*h_{t-1} + b_t is elementwise over the width dim,
so the GPU implementation uses a warp-level Blelloch scan.  The TPU
adaptation: grid = (B blocks, W blocks, S blocks) with the sequence axis
last (sequential); each grid step loads a (block_s, block_w) tile of
(a, b) into VMEM, runs the short sequential scan over block_s with the
8x128-lane VPU vectorizing the width dim, and carries h across grid
steps in VMEM scratch.  Wall-clock depth is S/block_s instead of S.

Inputs are the precomputed gate products: a = exp(log_a), b (both fp32,
shape (B, S, W)); initial state h0 (B, W).  Returns (h (B,S,W), h_last).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rglru_kernel(a_ref, b_ref, h0_ref, h_ref, hs_scr, *, block_s, ns):
    isq = pl.program_id(2)

    @pl.when(isq == 0)
    def _init():
        hs_scr[...] = h0_ref[...].astype(jnp.float32)

    a = a_ref[0]                  # (block_s, block_w) fp32
    b = b_ref[0]
    h = hs_scr[...]               # (1, block_w)

    def step(t, carry):
        h = carry
        at = jax.lax.dynamic_slice_in_dim(a, t, 1, axis=0)
        bt = jax.lax.dynamic_slice_in_dim(b, t, 1, axis=0)
        h = at * h + bt
        h_ref[0, pl.ds(t, 1), :] = h
        return h

    h = jax.lax.fori_loop(0, block_s, step, h)
    hs_scr[...] = h


def rglru_scan_pallas(a, b, h0, *, block_s=128, block_w=256,
                      interpret=False):
    """a, b: (B, S, W) fp32; h0: (B, W) fp32 -> (h (B,S,W), h_last (B,W))."""
    B, S, W = a.shape
    block_s = min(block_s, S)
    block_w = min(block_w, W)
    pad_s = (-S) % block_s
    if pad_s:
        a = jnp.pad(a, ((0, 0), (0, pad_s), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad_s), (0, 0)))
    ns = (S + pad_s) // block_s
    nw = W // block_w
    assert W % block_w == 0, (W, block_w)

    kernel = functools.partial(_rglru_kernel, block_s=block_s, ns=ns)
    h = pl.pallas_call(
        kernel,
        grid=(B, nw, ns),
        in_specs=[
            pl.BlockSpec((1, block_s, block_w),
                         lambda bb, iw, isq: (bb, isq, iw)),
            pl.BlockSpec((1, block_s, block_w),
                         lambda bb, iw, isq: (bb, isq, iw)),
            pl.BlockSpec((1, block_w), lambda bb, iw, isq: (bb, iw)),
        ],
        out_specs=pl.BlockSpec((1, block_s, block_w),
                               lambda bb, iw, isq: (bb, isq, iw)),
        out_shape=jax.ShapeDtypeStruct((B, S + pad_s, W), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1, block_w), jnp.float32)],
        interpret=interpret,
    )(a, b, h0)
    h = h[:, :S]
    return h, h[:, -1]
