"""Pure-jnp oracle for the RG-LRU scan kernel."""

from __future__ import annotations

import jax


def rglru_scan_ref(a, b, h0):
    """a, b: (B,S,W); h0: (B,W). h_t = a_t*h_{t-1} + b_t."""
    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    b0 = b.at[:, 0].add(a[:, 0] * h0)
    _, h = jax.lax.associative_scan(combine, (a, b0), axis=1)
    return h, h[:, -1]
