"""Production mesh construction.

Kept as FUNCTIONS (not module constants) so importing this module never
touches jax device state.  The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; smoke tests and benches see the real single CPU device.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """TPU v5e: one pod = 16x16 = 256 chips; two pods = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh (tests use tiny ones, e.g. (2, 2))."""
    return jax.make_mesh(tuple(shape), tuple(axes))


def make_local_mesh():
    """Single-device mesh for CPU smoke paths."""
    return jax.make_mesh((1, 1), ("data", "model"))
