"""End-to-end serving driver: FailLite-managed cluster on this host.

Spins up worker cells hosting real JAX engines for the selected
architectures, serves batched client traffic, injects a crash, and
reports the two-step failover — controller MTTR next to client-observed
downtime.  This is the serving twin of `launch/train.py`.

Usage:
  PYTHONPATH=src python -m repro.launch.serve \
      [--archs qwen2.5-3b,rwkv6-3b] [--policy faillite] [--observe 30]
"""

from __future__ import annotations

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--archs", default="qwen2.5-3b,rwkv6-3b,"
                                       "recurrentgemma-2b")
    ap.add_argument("--policy", default="faillite",
                    choices=["faillite", "full-warm", "full-cold",
                             "full-warm-k"])
    ap.add_argument("--sites", type=int, default=3)
    ap.add_argument("--servers-per-site", type=int, default=2)
    ap.add_argument("--headroom", type=float, default=0.3)
    ap.add_argument("--observe", type=float, default=30.0)
    ap.add_argument("--client-hz", type=float, default=10.0)
    ap.add_argument("--seed", type=int, default=1)
    args = ap.parse_args()

    from repro.serving.testbed import MiniTestbed
    archs = [a.strip() for a in args.archs.split(",") if a.strip()]
    print(f"deploying {len(archs)} applications under policy="
          f"{args.policy} on {args.sites}x{args.servers_per_site} cells "
          f"(real JAX engines — ~1 min of compiles)...")
    tb = MiniTestbed(apps_per_arch=1, archs=archs, seed=args.seed,
                     headroom=args.headroom, policy=args.policy,
                     n_sites=args.sites,
                     servers_per_site=args.servers_per_site)
    tb.deploy()
    for app in tb.apps:
        route = tb.router.lookup(app.id)
        warm = tb.controller.warm.get(app.id)
        print(f"  {app.id:28s} primary={route[0]} "
              f"warm={'%s@%s' % (warm[0].name, warm[1]) if warm else '-'}"
              f"{' [critical]' if app.critical else ''}")

    res = tb.run_failure_experiment(observe_s=args.observe,
                                    client_hz=args.client_hz)
    print(f"\ncrashed {res['victim']}; detected in "
          f"{res['detect_latency_s']*1e3:.0f} ms")
    s = res["summary"]
    print(f"recovery {s['recovery_rate']:.0%}  MTTR {s['mttr_avg']*1e3:.0f} ms  "
          f"accuracy cost {s['accuracy_reduction']:.2%}")
    for app_id, rec in res["records"].items():
        print(f"  {app_id:28s} {rec.mode:17s} "
              f"{'%.0f ms' % (rec.mttr*1e3) if rec.recovered else 'LOST':>9s}"
              f" -> {rec.variant}")
    print("client view:")
    for app_id, st in res["client_stats"].items():
        down = f"{st.downtime*1e3:.0f} ms" if st.downtime else "none"
        print(f"  {app_id:28s} ok={st.ok:4d} failed={st.failed:4d} "
              f"downtime={down}")
    tb.shutdown()


if __name__ == "__main__":
    main()
