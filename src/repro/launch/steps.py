"""Step builders: train_step / prefill_step / decode_step per config.

These are the functions the dry-run lowers and the drivers jit.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models import model as MDL
from repro.models.config import ModelConfig
from repro.models.lm import lm_loss
from repro.training.optimizer import AdamW


def make_train_step(cfg: ModelConfig, opt: AdamW, microbatches: int = 1):
    """microbatches > 1 = gradient accumulation: the global batch is
    split and processed sequentially, dividing every activation temp
    (stash, attention carries, CE chunks) by the microbatch count at the
    cost of re-running the collectives per microbatch."""
    grad_fn = jax.value_and_grad(lm_loss, has_aux=True)

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            (loss, metrics), grads = grad_fn(params, cfg, batch)
        else:
            mb = jax.tree_util.tree_map(
                lambda t: t.reshape((microbatches,
                                     t.shape[0] // microbatches)
                                    + t.shape[1:]), batch)
            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def acc(carry, b):
                gsum, lsum, auxsum = carry
                (l, m), g = grad_fn(params, cfg, b)
                gsum = jax.tree_util.tree_map(
                    lambda a, x: a + x.astype(jnp.float32), gsum, g)
                return (gsum, lsum + l, auxsum + m["aux"]), None

            (gsum, lsum, auxsum), _ = jax.lax.scan(
                acc, (g0, jnp.zeros((), jnp.float32),
                      jnp.zeros((), jnp.float32)), mb)
            grads = jax.tree_util.tree_map(
                lambda g: g / microbatches, gsum)
            loss = lsum / microbatches
            metrics = {"ce": loss, "aux": auxsum / microbatches}
        params, opt_state, gnorm = opt.update(grads, opt_state, params)
        metrics = dict(metrics, loss=loss, grad_norm=gnorm)
        return params, opt_state, metrics
    return train_step


def make_prefill_step(cfg: ModelConfig, max_len: int):
    def prefill_step(params, cache, batch):
        if cfg.is_encoder_decoder:
            return MDL.prefill(params, cfg, batch["tokens"], cache,
                               batch["frame_embeds"])
        return MDL.prefill(params, cfg, batch["tokens"], cache,
                           batch.get("patch_embeds"))
    return prefill_step


def make_decode_step(cfg: ModelConfig):
    def decode_step(params, cache, batch):
        return MDL.decode_step(params, cfg, batch["tokens"], cache)
    return decode_step


def make_forward(cfg: ModelConfig):
    def fwd(params, batch):
        if cfg.is_encoder_decoder:
            return MDL.forward(params, cfg, batch["tokens"],
                               batch["frame_embeds"])[0]
        return MDL.forward(params, cfg, batch["tokens"],
                           batch.get("patch_embeds"))[0]
    return fwd
