"""HLO post-processing: collective-byte accounting + roofline terms.

The dry-run's compiled artifact gives FLOPs and HBM bytes via
``cost_analysis()``; collective bytes are NOT included there, so we parse
the (optimized) HLO text and sum the output-shape bytes of every
communication op.  Roofline terms follow the harness formulas for
TPU v5e: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, asdict
from typing import Dict

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter",
                  "all-to-all", "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Sum bytes over every `dtype[dims]` group in a shape string."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        nbytes = _DTYPE_BYTES.get(dtype)
        if nbytes is None:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * nbytes
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-op-kind byte totals from optimized HLO text.

    Counts the *output* shape of each collective instruction (for
    all-reduce this equals the payload; for all-gather it is the gathered
    size — a consistent, slightly conservative convention).
    """
    out: Dict[str, int] = {k: 0 for k in COLLECTIVE_OPS}
    out["count"] = 0
    for line in hlo_text.splitlines():
        s = line.strip()
        # `%name = <shape> <opcode>(...)`
        m = re.match(r"%?[\w.\-]+\s*=\s*(\(?[^=]*?\)?)\s+([\w\-]+)\(", s)
        if not m:
            continue
        shape_str, opcode = m.groups()
        base = opcode
        for k in COLLECTIVE_OPS:
            if base == k or base.startswith(k + "-start") or base == k + "-done":
                if base.endswith("-done"):
                    break  # counted at -start
                out[k] += _shape_bytes(shape_str)
                out["count"] += 1
                break
    out["total"] = sum(out[k] for k in COLLECTIVE_OPS)
    return out


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    coll_bytes: float
    model_flops: float
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    useful_flop_frac: float
    per_device_temp_bytes: float = 0.0
    per_device_arg_bytes: float = 0.0

    def to_dict(self):
        return asdict(self)


def roofline_terms(*, arch: str, shape: str, mesh_name: str, chips: int,
                   hlo_flops: float, hlo_bytes: float, coll_bytes: float,
                   model_flops: float, temp_bytes: float = 0.0,
                   arg_bytes: float = 0.0) -> Roofline:
    compute_s = hlo_flops / (chips * PEAK_FLOPS)
    memory_s = hlo_bytes / (chips * HBM_BW)
    collective_s = coll_bytes / (chips * ICI_BW)
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    return Roofline(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        hlo_flops=hlo_flops, hlo_bytes=hlo_bytes, coll_bytes=coll_bytes,
        model_flops=model_flops, compute_s=compute_s, memory_s=memory_s,
        collective_s=collective_s, dominant=dominant,
        useful_flop_frac=(model_flops / hlo_flops) if hlo_flops else 0.0,
        per_device_temp_bytes=temp_bytes, per_device_arg_bytes=arg_bytes)


def model_flops_for(cfg, shape) -> float:
    """6·N·D (dense) / 6·N_active·D (MoE); decode: D = batch tokens."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens          # forward only
    return 2.0 * n * shape.global_batch  # decode: one token per sequence
