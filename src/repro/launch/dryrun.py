import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this builds the full-size config, creates ShapeDtypeStruct
stand-ins for params / optimizer state / caches / batch (no allocation),
lowers the appropriate step under the production mesh with explicit
in/out shardings, compiles it, and records:

  * memory_analysis()  — proves the cell fits per-device HBM
  * cost_analysis()    — HLO FLOPs / bytes for the roofline
  * collective bytes   — parsed from the optimized HLO text

Results land in experiments/dryrun/<mesh>/<arch>__<shape>.json; the
roofline benchmark and EXPERIMENTS.md tables are generated from them.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-32b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--mesh-scale N]
"""

import argparse
import json
import time
import traceback
from functools import partial
from pathlib import Path

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.configs.shapes import SHAPES, cell_applicable, input_specs
from repro.launch import hlo_analysis as H
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import make_train_step, make_prefill_step, \
    make_decode_step
from repro.models import model as MDL
from repro.parallel import sharding as SH
from repro.training.optimizer import AdamW

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def _mesh_name(mesh):
    return "x".join(str(s) for s in mesh.devices.shape)


def _named(mesh, spec_tree, shape_tree):
    def walk(spec, leaf):
        return NamedSharding(mesh, SH.filter_spec(spec, mesh, leaf.shape))
    return jax.tree_util.tree_map(walk, spec_tree, shape_tree)


def _scaled_cfg(cfg, k_cycles: int):
    """Config with k cycles (+ original tail) for 2-point cost extrapolation."""
    if cfg.is_encoder_decoder:
        return cfg.replace(num_layers=2 * k_cycles,
                           num_encoder_layers=k_cycles,
                           num_decoder_layers=k_cycles,
                           scan_layers=False)
    plen = len(cfg.block_pattern)
    tail = cfg.num_layers % plen
    return cfg.replace(num_layers=k_cycles * plen + tail,
                       scan_layers=False)


def _extrapolation_factor(cfg) -> float:
    """Number of scan trips N such that cost(L) = c1 + (N-1)*(c2-c1)."""
    if cfg.is_encoder_decoder:
        return cfg.num_encoder_layers  # enc and dec scale together
    plen = len(cfg.block_pattern)
    return cfg.num_layers // plen


SERVING_WEIGHT_BUDGET = 6e9      # bytes/device for weight-stationary


def _lower_one(cfg, shape, mesh, opt, microbatches: int = 1,
               serving_layout=None):
    """Lower + compile a single config at one shape. Returns artifacts."""
    param_shapes = MDL.param_shapes(cfg)
    # decode: weight-stationary layout when the TP-sharded weights fit
    # the cell (kills per-token FSDP weight gathers)
    if serving_layout is None:
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        tp = sizes.get("model", 1)
        serving_layout = (shape.kind == "decode"
                          and cfg.param_bytes() / tp
                          < SERVING_WEIGHT_BUDGET)
    param_sh = SH.param_shardings(param_shapes, mesh,
                                  serving=serving_layout)
    batch_shapes = input_specs(cfg, shape)
    batch_sh = SH.batch_shardings(batch_shapes, mesh)

    if shape.kind == "train":
        opt_shapes = opt.state_shapes(param_shapes)
        opt_sh = jax.tree_util.tree_map(
            lambda s: (NamedSharding(mesh, P()) if s.ndim == 0 else None),
            opt_shapes)
        # m/v/master mirror the param tree shardings
        opt_sh = opt_sh._replace(
            m=SH.param_shardings(opt_shapes.m, mesh),
            v=SH.param_shardings(opt_shapes.v, mesh),
            master=SH.param_shardings(opt_shapes.master, mesh))
        step = make_train_step(cfg, opt, microbatches=microbatches)
        out_shapes = jax.eval_shape(step, param_shapes, opt_shapes,
                                    batch_shapes)
        metric_sh = SH.replicated(out_shapes[2], mesh)
        jitted = jax.jit(step,
                         in_shardings=(param_sh, opt_sh, batch_sh),
                         out_shardings=(param_sh, opt_sh, metric_sh))
        args = (param_shapes, opt_shapes, batch_shapes)
    else:
        max_len = shape.seq_len
        cache_shapes = jax.eval_shape(
            partial(MDL.init_cache, cfg, shape.global_batch, max_len))
        cache_sh = SH.decode_cache_shardings(cache_shapes, mesh)
        if shape.kind == "prefill":
            step = make_prefill_step(cfg, max_len)
        else:
            step = make_decode_step(cfg)
        out_shapes = jax.eval_shape(step, param_shapes, cache_shapes,
                                    batch_shapes)
        logits_sh = NamedSharding(
            mesh, SH.filter_spec(P(("pod", "data"), "model"), mesh,
                                 out_shapes[0].shape))
        jitted = jax.jit(step,
                         in_shardings=(param_sh, cache_sh, batch_sh),
                         out_shardings=(logits_sh, cache_sh))
        args = (param_shapes, cache_shapes, batch_shapes)

    with mesh:
        lowered = jitted.lower(*args)
        compiled = lowered.compile()
    return lowered, compiled


def _costs_of(compiled):
    cost = compiled.cost_analysis()
    coll = H.collective_bytes(compiled.as_text())
    return (float(cost.get("flops", 0.0)),
            float(cost.get("bytes accessed", 0.0)),
            coll)


def lower_cell(arch: str, shape_name: str, mesh, *, opt=None,
               cfg_override=None, exact_costs: bool = True,
               microbatches: int = 1, serving_layout=None):
    """Lower + compile one cell. Returns (record dict, compiled).

    Cost accounting: XLA's cost_analysis is per-device and counts a scan
    body once, so (i) intra-layer scans are unrolled (EXACT_COST_MODE),
    (ii) layer-stack scan costs are recovered by compiling 1-cycle and
    2-cycle configs and extrapolating linearly, (iii) totals are scaled
    by chip count to report globals.  memory_analysis comes from the
    full-size compile (which is also the shardability proof).
    """
    from repro.models import layers as LAYERS
    cfg = cfg_override or configs.get_config(arch)
    shape = SHAPES[shape_name]
    opt = opt or AdamW()
    chips = mesh.devices.size
    t0 = time.time()

    lowered, compiled = _lower_one(cfg, shape, mesh, opt,
                                   microbatches=microbatches,
                                   serving_layout=serving_layout)
    t_full = time.time() - t0
    mem = compiled.memory_analysis()

    has_wkv = ("rwkv" in cfg.block_pattern
               and shape.kind in ("train", "prefill"))
    if exact_costs:
        try:
            LAYERS.set_exact_cost_mode(True, wkv_unroll=1)
            _, c1 = _lower_one(_scaled_cfg(cfg, 1), shape, mesh, opt)
            _, c2 = _lower_one(_scaled_cfg(cfg, 2), shape, mesh, opt)
            if has_wkv:
                LAYERS.set_exact_cost_mode(True, wkv_unroll=2)
                _, c1b = _lower_one(_scaled_cfg(cfg, 1), shape, mesh, opt)
        finally:
            LAYERS.set_exact_cost_mode(False)
        f1, b1, coll1 = _costs_of(c1)
        f2, b2, coll2 = _costs_of(c2)
        n = _extrapolation_factor(cfg)
        flops = (f1 + (n - 1) * (f2 - f1)) * chips
        hbytes = (b1 + (n - 1) * (b2 - b1)) * chips
        coll = {k: int((coll1[k] + (n - 1) * (coll2[k] - coll1[k])) * chips)
                for k in coll1}
        if has_wkv:
            # chunk-scan correction: cost_analysis counts the WKV chunk
            # body once; the (unroll=2) - (unroll=1) delta is one chunk's
            # exact cost, multiplied out over all chunks and layers.
            nchunk = -(-shape.seq_len // 32)
            f1b, b1b, _ = _costs_of(c1b)
            # fusion differences can make the byte delta slightly
            # negative; clamp (flops are robust — validated against a
            # fully-unrolled compile within 5%).
            flops += n * (nchunk - 1) * max(0.0, f1b - f1) * chips
            hbytes += n * (nchunk - 1) * max(0.0, b1b - b1) * chips
    else:
        f1, b1, coll1 = _costs_of(compiled)
        flops, hbytes = f1 * chips, b1 * chips
        coll = {k: v * chips for k, v in coll1.items()}
    t_cost = time.time() - t0 - t_full

    roof = H.roofline_terms(
        arch=arch, shape=shape_name, mesh_name=_mesh_name(mesh),
        chips=chips, hlo_flops=flops, hlo_bytes=hbytes,
        coll_bytes=float(coll["total"]),
        model_flops=H.model_flops_for(cfg, shape),
        temp_bytes=float(mem.temp_size_in_bytes),
        arg_bytes=float(mem.argument_size_in_bytes))

    record = {
        "arch": arch, "shape": shape_name, "mesh": _mesh_name(mesh),
        "chips": chips, "microbatches": microbatches,
        "lower_s": round(t_full, 2), "compile_s": round(t_cost, 2),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "code_bytes": mem.generated_code_size_in_bytes,
            "per_device_total": (mem.argument_size_in_bytes
                                 + mem.temp_size_in_bytes
                                 + mem.generated_code_size_in_bytes),
        },
        "cost": {"global_flops": flops, "global_bytes": hbytes},
        "collectives": coll,
        "roofline": roof.to_dict(),
    }
    return record, compiled


HBM_BUDGET = 16 * 2**30          # v5e per-chip


def run_cell(arch, shape_name, mesh, save=True, verbose=True, tag="",
             exact_costs=True, skip_existing=False):
    if skip_existing:
        d = OUT_DIR / (_mesh_name(mesh) + tag)
        f = d / f"{arch}__{shape_name}.json".replace("/", "_")
        if f.exists() and "error" not in json.loads(f.read_text()):
            if verbose:
                print(f"[{_mesh_name(mesh)}] {arch:24s} {shape_name:12s} "
                      f"CACHED", flush=True)
            return json.loads(f.read_text()), True
    try:
        record, compiled = lower_cell(arch, shape_name, mesh,
                                      exact_costs=exact_costs)
        # train cells over HBM budget escalate to gradient accumulation;
        # the (exact) cost terms from the first record are kept — only
        # the memory analysis comes from the escalated compile.
        if (SHAPES[shape_name].kind == "train"
                and record["memory"]["per_device_total"] > HBM_BUDGET):
            rec1 = record
            mem1 = record["memory"]["per_device_total"]
            for mb in (2, 4):
                record, compiled = lower_cell(arch, shape_name, mesh,
                                              microbatches=mb,
                                              exact_costs=False)
                if record["memory"]["per_device_total"] <= HBM_BUDGET:
                    break
            record["cost"] = rec1["cost"]
            record["collectives"] = rec1["collectives"]
            record["roofline"] = dict(
                rec1["roofline"],
                per_device_temp_bytes=record["memory"]["temp_bytes"])
            record["memory_mb1_bytes"] = mem1
        # decode cells where weight-stationary overshoots the HBM budget
        # fall back to the FSDP weight layout (keep whichever fits /
        # is smaller)
        if (SHAPES[shape_name].kind == "decode"
                and record["memory"]["per_device_total"] > HBM_BUDGET):
            rec_fsdp, _ = lower_cell(arch, shape_name, mesh,
                                     exact_costs=exact_costs,
                                     serving_layout=False)
            if (rec_fsdp["memory"]["per_device_total"]
                    < record["memory"]["per_device_total"]):
                rec_fsdp["weight_stationary"] = False
                record = rec_fsdp
        ok = True
    except Exception as e:  # noqa: BLE001 — record the failure, keep going
        record = {"arch": arch, "shape": shape_name,
                  "mesh": _mesh_name(mesh), "error": str(e),
                  "traceback": traceback.format_exc()}
        ok = False
    if verbose:
        if ok:
            m = record["memory"]
            r = record["roofline"]
            print(f"[{record['mesh']}] {arch:24s} {shape_name:12s} "
                  f"OK  mem/dev={m['per_device_total']/2**30:.2f}GiB "
                  f"compute={r['compute_s']*1e3:.2f}ms "
                  f"memory={r['memory_s']*1e3:.2f}ms "
                  f"coll={r['collective_s']*1e3:.2f}ms "
                  f"dom={r['dominant']} "
                  f"useful={r['useful_flop_frac']:.2f} "
                  f"(lower {record['lower_s']}s compile {record['compile_s']}s)",
                  flush=True)
        else:
            print(f"[{record['mesh']}] {arch:24s} {shape_name:12s} FAILED: "
                  f"{record['error'][:200]}", flush=True)
    if save:
        d = OUT_DIR / (record["mesh"] + tag)
        d.mkdir(parents=True, exist_ok=True)
        fname = f"{arch}__{shape_name}.json".replace("/", "_")
        (d / fname).write_text(json.dumps(record, indent=2))
    return record, ok


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--no-save", action="store_true")
    ap.add_argument("--fast-costs", action="store_true",
                    help="skip the exact-cost probes (multi-pod sweep: "
                         "the roofline table is single-pod only)")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    meshes = []
    if args.both_meshes:
        meshes = [make_production_mesh(), make_production_mesh(multi_pod=True)]
    else:
        meshes = [make_production_mesh(multi_pod=args.multi_pod)]

    archs = configs.ARCHS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]

    n_ok = n_fail = n_skip = 0
    for mesh in meshes:
        for arch in archs:
            for shape_name in shapes:
                if not cell_applicable(arch, shape_name):
                    print(f"[{_mesh_name(mesh)}] {arch:24s} {shape_name:12s} "
                          f"SKIP (full-attention arch; see DESIGN.md)",
                          flush=True)
                    n_skip += 1
                    continue
                _, ok = run_cell(arch, shape_name, mesh,
                                 save=not args.no_save,
                                 exact_costs=not args.fast_costs,
                                 skip_existing=args.skip_existing)
                n_ok += ok
                n_fail += (not ok)
    print(f"\ndry-run summary: {n_ok} ok, {n_fail} failed, {n_skip} skipped")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
