"""End-to-end training driver.

Trains an LM (default: a ~100M-param qwen2.5-family config) for a few
hundred steps on the local device(s), with the production fault-tolerance
path wired in: periodic sharded checkpoints, automatic restart from the
latest checkpoint (bit-identical data order via the seekable pipeline),
and a per-step straggler deadline that logs and skips pathological steps.

Usage:
  PYTHONPATH=src python -m repro.launch.train --steps 300 --arch qwen2.5-3b \
      --scale 100m [--resume] [--simulate-failure-at 150]
"""

from __future__ import annotations

import argparse
import time
from pathlib import Path

import jax

from repro import configs
from repro.launch.steps import make_train_step
from repro.models import model as MDL
from repro.training import checkpoint as CKPT
from repro.training.data import DataConfig, SyntheticTokenStream
from repro.training.optimizer import AdamW


def scale_config(cfg, scale: str):
    """Shrink an assigned arch config to a target param budget."""
    presets = {
        "100m": dict(num_layers=8, d_model=512, num_heads=8,
                     num_kv_heads=2, head_dim=64, d_ff=2048,
                     vocab_size=32_000),
        "20m": dict(num_layers=4, d_model=256, num_heads=4,
                    num_kv_heads=2, head_dim=64, d_ff=1024,
                    vocab_size=16_000),
        "toy": dict(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                    head_dim=16, d_ff=128, vocab_size=503),
    }
    kw = dict(presets[scale])
    kw.update(param_dtype="float32", activation_dtype="float32",
              remat=False, tie_embeddings=True)
    if cfg.num_experts:
        kw.update(num_experts=8, top_k=2, moe_d_ff=kw["d_ff"] // 4)
    if cfg.rnn_width:
        kw.update(rnn_width=kw["d_model"], rnn_blocks=4)
    if cfg.is_encoder_decoder:
        kw.update(num_encoder_layers=kw["num_layers"] // 2,
                  num_decoder_layers=kw["num_layers"] // 2,
                  encoder_seq_len=64)
    return cfg.replace(**kw)


def train(arch: str = "qwen2.5-3b", scale: str = "100m", steps: int = 300,
          batch: int = 8, seq: int = 256, ckpt_every: int = 50,
          ckpt_dir: str = "checkpoints", resume: bool = False,
          straggler_deadline_s: float = 300.0,
          simulate_failure_at: int = -1, log_every: int = 10,
          seed: int = 0):
    cfg = scale_config(configs.get_config(arch), scale)
    print(f"arch={arch} scale={scale}: {cfg.param_count()/1e6:.1f}M params")

    opt = AdamW(lr=3e-4, warmup_steps=max(10, steps // 20),
                total_steps=steps)
    step_fn = jax.jit(make_train_step(cfg, opt))
    data = SyntheticTokenStream(DataConfig(cfg.vocab_size, batch, seq,
                                           seed=seed))
    ckpt_path = Path(ckpt_dir) / f"{arch}-{scale}"

    start = 0
    params = opt_state = None
    if resume:
        last = CKPT.latest_step(ckpt_path)
        if last is not None:
            tmpl_p = MDL.init_params(jax.random.PRNGKey(seed), cfg)
            tmpl_o = opt.init(tmpl_p)
            start, params, opt_state, _ = CKPT.restore_checkpoint(
                ckpt_path, last, tmpl_p, tmpl_o)
            print(f"resumed from step {start}")
    if params is None:
        params = MDL.init_params(jax.random.PRNGKey(seed), cfg)
        opt_state = opt.init(params)

    losses = []
    t_start = time.time()
    for step in range(start, steps):
        if step == simulate_failure_at:
            print(f"[fault-injection] simulated crash at step {step}; "
                  f"restart with --resume to continue from "
                  f"step {CKPT.latest_step(ckpt_path)}")
            return {"crashed_at": step, "losses": losses}
        t0 = time.time()
        b = data.batch(step, cfg)
        params, opt_state, metrics = step_fn(params, opt_state, b)
        loss = float(metrics["loss"])
        dt = time.time() - t0
        if dt > straggler_deadline_s:
            print(f"[straggler] step {step} took {dt:.1f}s "
                  f"(deadline {straggler_deadline_s}s)")
        losses.append(loss)
        if step % log_every == 0:
            print(f"step {step:5d} loss {loss:8.4f} "
                  f"gnorm {float(metrics['grad_norm']):7.3f} "
                  f"({dt*1e3:.0f} ms/step)", flush=True)
        if ckpt_every and (step + 1) % ckpt_every == 0:
            CKPT.save_checkpoint(ckpt_path, step + 1, params, opt_state)

    wall = time.time() - t_start
    print(f"done: {steps - start} steps in {wall:.1f}s; "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    return {"losses": losses, "wall_s": wall,
            "final_loss": losses[-1] if losses else None}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--scale", default="100m",
                    choices=["100m", "20m", "toy"])
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--ckpt-dir", default="checkpoints")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--simulate-failure-at", type=int, default=-1)
    ap.add_argument("--seed", type=int, default=0)
    a = ap.parse_args()
    train(a.arch, a.scale, a.steps, a.batch, a.seq, a.ckpt_every,
          a.ckpt_dir, a.resume, simulate_failure_at=a.simulate_failure_at,
          seed=a.seed)


if __name__ == "__main__":
    main()
