"""Testbed shard plane: REAL tensor-parallel groups on worker threads.

`core/shardgroup.py` gives the control plane (group lifecycle, the
degrade/reshard/monolith ladder, recovery records); this module is the
mini-testbed's data plane for it. Nothing here is modeled:

* at deploy, the app's full param tree is built once and **partitioned
  along the `parallel/sharding.py` "model" axes** (heads / d_ff /
  vocab — the production TP rules) into `tp_degree` rank slices, each
  hosted in a different `WorkerServer`'s memory (`host_shard`; a
  `kill()` loses the slice, the cold store does not have it);
* the serving engine is assembled by gathering the slices off the
  member workers (`jnp.concatenate` per model axis — the all-gather)
  and compiled on the rank-0 lead;
* a shard-host kill breaks the group: the ladder's real costs are paid
  on the wall clock — degraded-TP continuation rebuilds an engine from
  the surviving slices with the lost partition zero-filled (KevlarFlow:
  fewer effective heads/channels, measurably degraded output), and a
  reshard re-materializes the lost slice from the deterministic
  checkpoint seed, pays the slice-byte fetch through the model-state
  plane, then re-gathers and recompiles;
* every measured wall time is folded back into the sim's reshard cost
  model through `ShardGroupManager.calibrate_repartition`, and the raw
  measurements ride out through ``extras["shard"]["measured"]``.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import numpy as np

from repro.core.shardgroup import ShardGroup, ShardGroupManager, slice_name
from repro.core.variants import Application, Variant
from repro.models import model as MDL
from repro.parallel.sharding import param_specs
from repro.serving.engine import InferenceEngine

# ---------------------------------------------------------------------------
# param-tree partitioning along the production TP ("model") axes
# ---------------------------------------------------------------------------


def _walk2(a, b, fn):
    """Parallel structural walk: `b` mirrors `a`'s dict/list nesting
    (PartitionSpecs are tuples but sit at `a`'s leaf positions, so
    dispatch on `a` only)."""
    if isinstance(a, dict):
        return {k: _walk2(a[k], b[k], fn) for k in a}
    if isinstance(a, (list, tuple)):
        return type(a)([_walk2(x, y, fn) for x, y in zip(a, b)])
    return fn(a, b)


def _model_axis(spec, shape, k: int) -> Optional[int]:
    """The axis this leaf is TP-split on, or None (replicated)."""
    for i, entry in enumerate(spec):
        axes = entry if isinstance(entry, tuple) else (entry,)
        if "model" in axes and i < len(shape) and shape[i] >= k:
            return i
    return None


def split_axes(params, k: int):
    """Tree of split-axis indices (None = replicated), derived from the
    same `param_specs` rules the production mesh uses."""
    specs = param_specs(params)
    return _walk2(params, specs,
                  lambda leaf, spec: _model_axis(spec, leaf.shape, k))


def rank_slice(params, axes, k: int, rank: int):
    """Rank `rank`'s slice of the full tree (host numpy — this is what
    one worker's memory holds)."""
    def cut(leaf, ax):
        a = np.asarray(leaf)
        if ax is None:
            return a
        return np.array_split(a, k, axis=ax)[rank]
    return _walk2(params, axes, cut)


class _LeafMeta:
    """Shape+dtype of one slice leaf (a non-tuple leaf type, so the
    structural walkers don't recurse into it)."""
    __slots__ = ("shape", "dtype")

    def __init__(self, shape, dtype):
        self.shape = tuple(shape)
        self.dtype = str(dtype)


def slice_meta(slice_tree, axes):
    """Shape/dtype tree of one rank slice — enough to zero-fill a lost
    partition for degraded-TP continuation."""
    return _walk2(slice_tree, axes,
                  lambda leaf, _ax: _LeafMeta(leaf.shape, leaf.dtype))


def zero_slice(meta):
    return _walk2(meta, meta,
                  lambda m, _: np.zeros(m.shape, m.dtype))


def gather(rank_trees: List, axes):
    """All-gather: concatenate the k rank slices back into one param
    tree (replicated leaves come from the first rank)."""
    t0 = rank_trees[0]

    def walk(node0, ax_node, picks):
        if isinstance(node0, dict):
            return {key: walk(node0[key], ax_node[key],
                              [p[key] for p in picks]) for key in node0}
        if isinstance(node0, (list, tuple)):
            return type(node0)(
                [walk(v, ax_node[i], [p[i] for p in picks])
                 for i, v in enumerate(node0)])
        if ax_node is None:
            return node0
        return np.concatenate(picks, axis=ax_node)
    return walk(t0, axes, rank_trees)


def checkpoint_params(variant: Variant):
    """The deterministic 'checkpoint': identical to what
    `WorkerServer.load` builds for this variant, so a re-materialized
    slice is bit-identical to the lost one."""
    cfg = variant.config
    assert cfg is not None, "sharded testbed variants need real configs"
    return MDL.init_params(
        jax.random.PRNGKey(hash(variant.name) % (2**31)), cfg)


@dataclass
class _GroupLayout:
    """Per-group partition metadata kept OFF the workers (the slices
    themselves live on the workers and die with them)."""
    axes: object
    rank_meta: Dict[int, object] = field(default_factory=dict)


# ---------------------------------------------------------------------------
# the testbed manager: control plane + real data plane
# ---------------------------------------------------------------------------


class TestbedShardManager(ShardGroupManager):
    """`ShardGroupManager` whose repartition/degrade phases are real
    JAX work on the testbed's worker threads, wall-clock measured."""

    def __init__(self, testbed, *, tp_degree: int, policy: str = "auto"):
        super().__init__(testbed.controller, tp_degree=tp_degree,
                         policy=policy, defer=None)
        self.tb = testbed
        self._layout: Dict[str, _GroupLayout] = {}
        # routes consumed while their engine is still building:
        # app_id -> (server_id, variant_name), pushed on install
        self._deferred: Dict[str, tuple] = {}
        self._fail_ctx: Dict[str, float] = {}      # app_id -> t_fail
        self._meas_lock = threading.Lock()
        self.measured: Dict[str, List[float]] = {
            "deploy_build_s": [],       # initial gather+compile per group
            "slice_fetch_s": [],        # reshard slice re-materialization
            "repartition_s": [],        # reshard re-gather + recompile
            "reshard_mttr_s": [],       # kill -> resharded engine serving
            "degrade_rebuild_s": [],    # zero-filled degraded recompile
            "degrade_mttr_s": [],       # kill -> degraded engine serving
        }

    def _note(self, key: str, value: float):
        with self._meas_lock:
            self.measured[key].append(value)

    # -- data-plane deploy --------------------------------------------------
    def is_slice(self, name: str) -> bool:
        return "::shard" in name

    def deploy_real(self, app: Application):
        """Partition the app's full params across the group members and
        bring up the gathered engine on the lead. Call after the
        controller-side `deploy_group`."""
        g = self.groups[app.id]
        k = g.tp_degree
        t0 = time.monotonic()
        params = checkpoint_params(g.base)
        axes = split_axes(params, k)
        layout = _GroupLayout(axes=axes)
        slices = {}
        for rank, m in sorted(g.members.items()):
            sl = rank_slice(params, axes, k, rank)
            layout.rank_meta[rank] = slice_meta(sl, axes)
            self.tb.workers[m.server_id].host_shard(
                slice_name(g.base, rank, k), sl)
            slices[rank] = sl
        del params                      # the engine comes from the slices
        self._layout[app.id] = layout
        gathered = gather([slices[r] for r in sorted(slices)], axes)
        self._install(g, g.lead.server_id, g.base.name, gathered)
        self._note("deploy_build_s", time.monotonic() - t0)
        self._push_if_current(app.id)

    def _install(self, g: ShardGroup, server_id: str, name: str,
                 params) -> None:
        w = self.tb.workers[server_id]
        eng = InferenceEngine(g.base.config, params,
                              batch_slots=w.batch_slots,
                              max_len=w.max_len)
        eng.warmup()
        w.install(name, eng)

    # -- route interception -------------------------------------------------
    def on_route(self, app_id: str, server_id: str,
                 variant_name: str) -> bool:
        """RoutingTable-observer hook: push the route to the serving
        router only once the target engine is actually resident.
        Returns True when the push is deferred to an install."""
        g = self.groups.get(app_id)
        if g is None or g.state == "fallen-back":
            return False
        w = self.tb.workers.get(server_id)
        if w is None:
            return False
        if not w.has(variant_name) and "::tp" in variant_name:
            # degraded route: the lead's gathered engine (if it
            # survived) keeps answering under the degraded name until
            # the honest zero-filled rebuild swaps in underneath
            w.alias(variant_name, g.base.name)
        if w.has(variant_name):
            return False
        self._deferred[app_id] = (server_id, variant_name)
        return True

    def _push_if_current(self, app_id: str):
        """Flush a deferred route if it still matches the controller's
        current routing decision."""
        pending = self._deferred.pop(app_id, None)
        if pending is None:
            return
        with self.tb._ctl_lock:
            current = self.controller.routing.routes.get(app_id)
        if current is None or tuple(current) != tuple(pending):
            return
        self.tb._push_route(app_id, pending[0], pending[1])

    # -- ladder overrides: real work ----------------------------------------
    def handle_lost(self, failed_set, t_fail, t_detect):
        for gid, g in self.groups.items():
            if g.state == "fallen-back":
                continue
            if any(m.server_id in failed_set
                   for m in g.members.values()) or (
                    g.pending is not None
                    and g.pending.server_id in failed_set):
                self._fail_ctx[gid] = t_fail
        return super().handle_lost(failed_set, t_fail, t_detect)

    def _teardown_engines(self, g: ShardGroup):
        """A member died and the ladder is NOT continuing seamlessly:
        the TP collective is broken, so the gathered engine must stop
        answering until it is rebuilt."""
        for m in g.members.values():
            w = self.tb.workers.get(m.server_id)
            if w is None or not w.alive:
                continue
            w.unload(g.base.name)
            for name in list(w.engines):
                if name.startswith(g.base.name + "::"):
                    w.unload(name)

    def _degrade(self, g, app, t_fail, t_detect):
        rec = super()._degrade(g, app, t_fail, t_detect)
        lead = g.lead

        def rebuild():
            t0 = time.monotonic()
            try:
                parts = self._collect_slices(g, zero_missing=True)
                if parts is None:
                    return
                gathered = gather(parts, self._layout[app.id].axes)
                self._install(g, lead.server_id, rec.variant, gathered)
            except RuntimeError:
                return                       # lead died mid-rebuild
            self._note("degrade_rebuild_s", time.monotonic() - t0)
            t_kill = self._fail_ctx.get(app.id, t_fail)
            self._note("degrade_mttr_s", time.monotonic() - t_kill)
            self._push_if_current(app.id)

        self.tb.executor._spawn(rebuild)
        return rec

    def _collect_slices(self, g: ShardGroup,
                        zero_missing: bool = False) -> Optional[list]:
        """The k rank slices off the member workers (pending member
        included); missing ranks come back zero-filled when allowed."""
        layout = self._layout.get(g.app_id)
        if layout is None:
            return None
        holders = dict(g.members)
        if g.pending is not None:
            holders[g.pending.rank] = g.pending
        parts = []
        for rank in range(g.tp_degree):
            m = holders.get(rank)
            sl = None
            if m is not None:
                w = self.tb.workers.get(m.server_id)
                if w is not None:
                    sl = w.shard(slice_name(g.base, rank, g.tp_degree))
            if sl is None:
                meta = layout.rank_meta.get(rank)
                if not zero_missing or meta is None:
                    return None
                sl = zero_slice(meta)
            parts.append(sl)
        return parts

    def materialize_slice(self, app: Application, sv: Variant,
                          server_id: str) -> float:
        """Executor hook for a reshard's slice load: re-materialize the
        lost rank from the deterministic checkpoint seed and host it on
        the replacement worker. Returns wall seconds (the 'warmup' leg
        of the load ticket; the byte transfer was already slept at
        slice-byte cost by the executor's fetch plan)."""
        g = self.groups[app.id]
        rank = int(sv.name.rsplit("::shard", 1)[1].split("of")[0])
        t0 = time.monotonic()
        params = checkpoint_params(g.base)
        axes = self._layout[app.id].axes
        sl = rank_slice(params, axes, g.tp_degree, rank)
        self._layout[app.id].rank_meta[rank] = slice_meta(sl, axes)
        self.tb.workers[server_id].host_shard(sv.name, sl)
        wall = time.monotonic() - t0
        self._note("slice_fetch_s", wall)
        return wall

    def _reshard(self, g, app, rank, failed_set, t_fail, t_detect):
        self._teardown_engines(g)
        return super()._reshard(g, app, rank, failed_set, t_fail,
                                t_detect)

    def _after_repartition(self, g, sv, repart_s, finish):
        """The real repartition: re-gather all k slices (the pending
        member now hosts the re-materialized one), recompile on the
        post-commit lead, then commit the controller-side state. The
        measured wall time calibrates the sim's modeled cost."""
        def work():
            t0 = time.monotonic()
            holders = dict(g.members)
            if g.pending is not None:
                holders[g.pending.rank] = g.pending
            lead_sid = holders[min(holders)].server_id
            try:
                parts = self._collect_slices(g)
                if parts is None:
                    return         # a holder died; next epoch falls back
                gathered = gather(parts, self._layout[g.app_id].axes)
                self._install(g, lead_sid, g.base.name, gathered)
            except RuntimeError:
                return
            measured = time.monotonic() - t0
            with self.tb._ctl_lock:
                finish()
            self.calibrate_repartition(measured, sv.mem_bytes)
            self._note("repartition_s", measured)
            t_kill = self._fail_ctx.get(g.app_id, t0)
            self._note("reshard_mttr_s", time.monotonic() - t_kill)
            self._push_if_current(g.app_id)

        del repart_s
        self.tb.executor._spawn(work)

    def _fallback(self, g, app, t_fail, t_detect):
        self._teardown_engines(g)
        self._deferred.pop(app.id, None)
        return super()._fallback(g, app, t_fail, t_detect)

    # -- reporting ----------------------------------------------------------
    def summary(self) -> dict:
        out = super().summary()
        with self._meas_lock:
            out["measured"] = {
                k: {"n": len(v),
                    "avg_s": sum(v) / len(v) if v else -1.0,
                    "max_s": max(v) if v else -1.0}
                for k, v in self.measured.items()}
        return out
