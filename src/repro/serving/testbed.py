"""Thread-based mini-testbed: the paper's edge testbed, on one CPU.

Real components everywhere the paper's testbed had them:
  * WorkerServer threads host real JAX engines and send real heartbeats
  * failure injection kills the worker (heartbeats stop mid-flight)
  * the FailureDetector declares failure after 2 missed beats
  * the controller runs the two-step failover; cold loads really build
    params + compile (their wall-clock duration is the measured
    load time, Fig. 2b analogue)
  * clients measure end-to-end downtime around the failure

This is the live execution engine behind the `testbed` backend of
`repro.experiment`: `run_scenario()` replays the SAME `ScenarioEvent`
stream the simulator replays — `ServerFail`/`SiteFail`/`ServerRejoin`/
`AppArrival`/`AppDeparture`/`LoadSpike` — against worker threads on a
wall clock. Controller route changes reach the serving `Router` and the
request-level telemetry through the first-class `RoutingTable`
observer/drop_observer hooks (no monkey-patching), and the real request
outcomes measured by the client threads are folded through the same
`core.metrics.aggregate` code the simulator's traffic plane uses, so
client-observed MTTR/availability/goodput mean the same thing on both
backends.

Model ladders use the reduced smoke configs so everything runs on CPU;
capacities come from the shared arch-mix sizing rule
(`repro.experiment.workload`), which is what lets the simulator run the
exact same workload on the exact same cluster shape for cross-backend
parity experiments.
"""

from __future__ import annotations

import math
import random
import threading
import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.cluster import Cluster, Server
from repro.core.controller import (FailLiteController, LoadExecutor,
                                   RecoveryRecord)
from repro.core.heartbeat import FailureDetector, WallClock
from repro.core.metrics import AppLog, DowntimeWindow, TrafficSummary, aggregate
from repro.core.modelstate import (LOCAL, LinkScale, LoadTicket,
                                   ModelRegistry, storage_preset)
from repro.core.resilience import (Bulkhead, CircuitBreaker, RetryBudget,
                                   hedged_call)
from repro.core.resilience import active as resilience_active
from repro.core.scenario import (AppArrival, AppDeparture, LinkDegrade,
                                 LoadSpike, Scenario, ServerFail,
                                 ServerRejoin, ShardFail, SiteFail)
from repro.core.variants import Application
from repro.experiment.workload import (ARCH_COMPUTE_CAP, TESTBED_ARCHS,
                                       arch_mem_cap, build_arch_apps,
                                       testbed_ladder)
from repro.serving.router import Router
from repro.serving.server import WorkerServer
from repro.serving.shard import TestbedShardManager
from repro.serving.workload import make_request

DETECT_POLL_S = 0.02          # sweeper poll (controller sweep, §5.1)
REPROTECT_EVERY_S = 1.0       # continuous re-protection loop period


class TestbedExecutor(LoadExecutor):
    """Executes controller load orders on real worker threads.

    Loads are serialized per server (one PCIe/disk channel per cell, as
    on the paper's testbed) and ordered: the progressive small-first load
    completes before the selected-variant load starts. Controller
    callbacks run under the testbed's controller lock, AFTER the server
    channel is released (lock-ordering: never hold a server channel
    while waiting for the controller).
    """

    def __init__(self, workers: Dict[str, WorkerServer], router: Router,
                 ctl_lock: threading.RLock,
                 registry: Optional[ModelRegistry] = None):
        self.workers = workers
        self.router = router
        self.ctl_lock = ctl_lock
        # model-state plane: fetch-path selection + load-cost
        # calibration. Every REAL load's wall time is observed into the
        # registry's LoadCostModel (the Fig. 2b feedback loop), and
        # non-local fetch paths pay an emulated transfer sleep priced by
        # the same model the simulator uses.
        self.registry = registry
        # testbed shard plane (serving/shard.py): slice loads are
        # re-materialized partitions, not whole-model compiles
        self.shard_plane = None
        self._scales = LinkScale()                 # LinkDegrade windows
        self._locks: Dict[str, threading.Lock] = {
            sid: threading.Lock() for sid in workers}
        self._threads: List[threading.Thread] = []
        self._outstanding = 0
        self._n_lock = threading.Lock()

    def _spawn(self, fn) -> None:
        with self._n_lock:
            self._outstanding += 1

        def run():
            try:
                fn()
            finally:
                with self._n_lock:
                    self._outstanding -= 1

        t = threading.Thread(target=run, daemon=True)
        self._threads.append(t)
        t.start()

    def idle(self) -> bool:
        with self._n_lock:
            return self._outstanding == 0

    def degrade_link(self, link: str, factor: float, duration: float):
        """LinkDegrade analogue: scale the emulated fetch sleeps that
        touch `link` for `duration` wall seconds."""
        t = threading.Timer(duration, self._scales.degrade(link, factor))
        t.daemon = True
        t.start()

    def _fetch_sleep(self, variant, server_id) -> tuple:
        """(sleep_s, source): the emulated byte-transfer cost of a
        non-local fetch path — zero for a local disk hit (the real
        compile IS the local load cost on this testbed)."""
        if self.registry is None:
            return 0.0, LOCAL
        plan = self.registry.fetch_plan(variant.name, server_id)
        if plan.source == LOCAL or not math.isfinite(plan.bw):
            return 0.0, plan.source
        scale = self._scales.min_over(plan.links)
        return variant.mem_bytes / (plan.bw * scale), plan.source

    def load(self, app, variant, server_id, on_ready) -> LoadTicket:
        ticket = LoadTicket()

        def work():
            t0 = time.monotonic()       # before the lock: queue_s must
            try:                        # include the channel wait
                with self._locks[server_id]:
                    sleep_s, source = self._fetch_sleep(variant,
                                                        server_id)
                    if sleep_s > 0:
                        time.sleep(sleep_s)
                    if (self.shard_plane is not None
                            and self.shard_plane.is_slice(variant.name)):
                        wall = self.shard_plane.materialize_slice(
                            app, variant, server_id)
                    else:
                        wall = self.workers[server_id].load(app, variant)
                    ticket.source = source
                    ticket.fetch_s = sleep_s
                    ticket.warmup_s = wall
                    ticket.queue_s = (time.monotonic() - t0
                                      - sleep_s - wall)
                    ticket.done = True
                    if self.registry is not None:
                        # Fig. 2b feedback: the measured wall time
                        # calibrates the shared load-cost model
                        self.registry.calibration.observe(
                            variant, source, sleep_s + wall)
                        self.registry.stage(variant.name, server_id)
            except RuntimeError:
                return                    # server died mid-load
            except Exception:             # noqa: BLE001
                import traceback
                traceback.print_exc()
                return
            with self.ctl_lock:
                on_ready(time.monotonic())
        self._spawn(work)
        return ticket

    def activate(self, app, variant, server_id):
        w = self.workers[server_id]
        if not w.has(variant.name):        # warm = pre-loaded at plan time
            w.load(app, variant)

    def prepare_warm(self, app, variant, server_id):
        """Warm backup planned: load it in the background so a later
        `activate` finds the engine resident."""
        def work():
            try:
                with self._locks[server_id]:
                    if not self.workers[server_id].has(variant.name):
                        self.workers[server_id].load(app, variant)
                if self.registry is not None:
                    self.registry.stage(variant.name, server_id)
            except RuntimeError:
                pass
            except Exception:             # noqa: BLE001
                import traceback
                traceback.print_exc()
        self._spawn(work)

    def replicate(self, app, variant, server_id, on_done=None):
        """Background checkpoint copy: pay the emulated transfer, then
        stage the bytes on the worker's cold store + the registry."""
        def work():
            sleep_s, _source = self._fetch_sleep(variant, server_id)
            if sleep_s > 0:
                time.sleep(sleep_s)
            w = self.workers.get(server_id)
            if w is not None:
                w.stage_cold(app, variant)
            if self.registry is not None:
                self.registry.stage(variant.name, server_id)
            if on_done is not None:
                with self.ctl_lock:
                    on_done(time.monotonic())
        self._spawn(work)

    def join(self, timeout: float = 15.0):
        deadline = time.monotonic() + timeout
        for t in self._threads:
            t.join(timeout=max(0.0, deadline - time.monotonic()))


@dataclass
class ClientStats:
    """Per-app client-side counters (compat view; the authoritative
    request-level metrics are the shared `TrafficSummary`)."""
    app_id: str
    ok: int = 0
    failed: int = 0
    last_ok: Optional[float] = None
    downtime: Optional[float] = None


class TestbedTelemetry:
    """Real request outcomes + route-transition windows, folded through
    the SAME `core.metrics.aggregate` code as the simulator's traffic
    plane — the testbed's half of the shared request-level metrics."""

    def __init__(self):
        self._lock = threading.Lock()
        # app_id -> list of (t, ok, accuracy, request-or-None)
        self._attempts: Dict[str, list] = {}
        self._full_acc: Dict[str, float] = {}
        self._slo: Dict[str, float] = {}
        self.windows: List[DowntimeWindow] = []
        self._open: Dict[str, DowntimeWindow] = {}

    # -- control-plane hooks (RoutingTable observers) -----------------------
    def app_seen(self, app: Application):
        with self._lock:
            if app.id not in self._attempts:
                self._attempts[app.id] = []
                self._full_acc[app.id] = app.full.accuracy
                self._slo[app.id] = app.latency_slo

    def route_up(self, app_id: str, t: float):
        """A route push reached the clients: close any open blackout."""
        with self._lock:
            w = self._open.pop(app_id, None)
            if w is not None:
                w.t_end = t
                self.windows.append(w)

    def mark_down(self, app_id: str, t: float, epoch: int):
        """The app's serving replica just died (crash instant)."""
        with self._lock:
            if app_id in self._open or app_id not in self._attempts:
                return
            self._open[app_id] = DowntimeWindow(app_id=app_id, epoch=epoch,
                                                t_start=t)

    def mark_gone(self, app_id: str):
        """App departed: an open blackout is censored (never recovered)."""
        with self._lock:
            w = self._open.pop(app_id, None)
            if w is not None:
                self.windows.append(w)

    # -- data plane (client threads) ----------------------------------------
    def record(self, app_id: str, t: float, ok: bool, accuracy: float,
               req=None, outcome: Optional[str] = None):
        """`outcome` tags the resilience layer's classes: "hedged"
        (served via the warm backup), "fast_failed" (open breaker
        answered instantly), "shed" (admission/bulkhead reject);
        None = the plain served/failed path."""
        with self._lock:
            self._attempts[app_id].append((t, ok, accuracy, req, outcome))

    # -- aggregation --------------------------------------------------------
    def summarize(self, t_end: float) -> TrafficSummary:
        with self._lock:
            attempts = {a: list(v) for a, v in self._attempts.items()}
            windows = ([DowntimeWindow(w.app_id, w.epoch, w.t_start,
                                       w.t_end)
                        for w in self.windows]
                       + [DowntimeWindow(w.app_id, w.epoch, w.t_start)
                          for w in self._open.values()])
        logs: List[AppLog] = []
        for app_id in sorted(attempts):
            rows = attempts[app_id]
            n = len(rows)
            arrivals = np.array([r[0] for r in rows], np.float64)
            served = np.array([r[1] for r in rows], bool)
            accuracy = np.array([r[2] if r[1] else math.nan
                                 for r in rows], np.float64)
            latency = np.array(
                [(r[3].done_at - r[3].submitted_at)
                 if (r[1] and r[3] is not None
                     and r[3].done_at is not None) else math.nan
                 for r in rows], np.float64)
            # resilience outcome tags (all-False without the toolkit)
            hedged = np.array([r[4] == "hedged" for r in rows], bool)
            fast_failed = np.array([r[4] == "fast_failed"
                                    for r in rows], bool)
            shed = np.array([r[4] == "shed" for r in rows], bool)
            # dropped = failed while inside a client-visible blackout;
            # fast-failed and shed requests are their own terminal
            # classes, not drops
            dropped = np.zeros(n, bool)
            for w in windows:
                if w.app_id != app_id:
                    continue
                hi = w.t_end if w.recovered else math.inf
                dropped |= (~served & (arrivals >= w.t_start)
                            & (arrivals < hi))
            dropped &= ~(fast_failed | shed)
            full_acc = self._full_acc[app_id]
            slo = self._slo[app_id]
            with np.errstate(invalid="ignore"):
                degraded = served & (accuracy < full_acc - 1e-12)
                slo_violated = served & (latency > slo)
            logs.append(AppLog(
                app_id, arrivals, served, dropped,
                offered=np.ones(n, bool), degraded=degraded,
                slo_violated=slo_violated, accuracy=accuracy,
                latency=latency, hedged=hedged,
                fast_failed=fast_failed, shed=shed,
                retried=np.zeros(n, bool)))
        return aggregate(logs, windows, t_end)

    def client_stats(self, windows: Optional[List[DowntimeWindow]] = None,
                     ) -> Dict[str, ClientStats]:
        """Per-app counters. Pass `TrafficSummary.windows` (back-filled
        by `aggregate` with each window's first served request) so
        `downtime` is the client-observed gap; the raw internal windows
        only know the route-outage interval."""
        if windows is None:
            windows = self.windows
        with self._lock:
            out = {}
            for app_id, rows in self._attempts.items():
                st = ClientStats(app_id)
                for t, ok, _acc, _req, _outcome in rows:
                    if ok:
                        st.ok += 1
                        st.last_ok = t
                    else:
                        st.failed += 1
                downs = [w.client_downtime
                         for w in windows if w.app_id == app_id
                         and w.recovered
                         and math.isfinite(w.client_downtime)]
                st.downtime = max(downs) if downs else None
                out[app_id] = st
            return out


class MiniTestbed:
    def __init__(self, *, n_sites: int = 3, servers_per_site: int = 2,
                 apps_per_arch: int = 1, critical_frac: float = 0.5,
                 headroom: float = 0.35, policy: str = "faillite",
                 planner: Optional[str] = None, alpha: float = 0.1,
                 site_independence: bool = False, seed: int = 0,
                 archs: Optional[List[str]] = None,
                 storage: str = "local", scheduler: str = "fifo",
                 load_bw: Optional[float] = None,
                 warmup_s: Optional[float] = None,
                 nic_bw: Optional[float] = None,
                 cloud_bw: Optional[float] = None,
                 replication: Optional[int] = None,
                 resilience=None,
                 tp_degree: int = 1, shard_policy: str = "auto",
                 apps: Optional[Sequence[Application]] = None):
        self.rng = random.Random(seed)
        # request-plane resilience toolkit (None = historical client
        # path): per-app breakers/budgets, per-server bulkheads, live
        # hedging to the router's backup table
        self.resilience = resilience_active(resilience)
        self._breakers: Dict[str, CircuitBreaker] = {}
        self._budgets: Dict[str, RetryBudget] = {}
        self._bulkheads: Dict[str, Bulkhead] = {}
        self._lat_samples: Dict[str, List[float]] = {}
        self._admit_credit: Dict[str, float] = {}
        self._res_lock = threading.Lock()
        self.clock = WallClock()
        self.detector = FailureDetector(self.clock, interval=0.020)
        self.router = Router()
        self.telemetry = TestbedTelemetry()
        self._ctl_lock = threading.RLock()
        self._archs = list(archs or TESTBED_ARCHS)

        # --- applications: the shared arch-mix workload ------------------
        if apps is not None:
            self.apps: List[Application] = list(apps)
            for app in self.apps:
                if app.full.config is None:
                    raise ValueError(
                        f"testbed apps need real ModelConfigs; "
                        f"{app.id} has a profile-only ladder")
        else:
            self.apps = build_arch_apps(
                self._archs, apps_per_arch=apps_per_arch,
                critical_frac=critical_frac, seed=seed)

        # --- capacity: the shared sizing rule ----------------------------
        n_servers = n_sites * servers_per_site
        mem_cap = arch_mem_cap(self.apps, n_servers, headroom)
        servers = [Server(id=f"s{si}-{sj}", site=f"site{si}",
                          capacity={"mem": mem_cap,
                                    "compute": ARCH_COMPUTE_CAP})
                   for si in range(n_sites)
                   for sj in range(servers_per_site)]
        # model-state plane: same storage presets + ModelRegistry as
        # the simulator; real measured loads calibrate its cost model
        self.cluster = Cluster(servers, storage=storage_preset(
            storage, disk_bw=load_bw, warmup_s=warmup_s, nic_bw=nic_bw,
            cloud_bw=cloud_bw, replication=replication))
        self.registry = ModelRegistry(self.cluster, self.cluster.storage)

        # --- worker threads ----------------------------------------------
        self.workers: Dict[str, WorkerServer] = {
            s.id: WorkerServer(s.id, self.detector).start()
            for s in servers}
        self.executor = TestbedExecutor(self.workers, self.router,
                                        self._ctl_lock,
                                        registry=self.registry)
        self.controller = FailLiteController(
            self.cluster, self.clock, self.executor, policy=policy,
            alpha=alpha, site_independence=site_independence,
            planner=planner, detector=self.detector,
            registry=self.registry, scheduler=scheduler)
        # controller routing -> serving router + telemetry, through the
        # first-class RoutingTable observer hooks
        self.controller.routing.observer = self._on_route_set
        self.controller.routing.drop_observer = self._on_route_drop

        # --- run-time state ----------------------------------------------
        self._stop = threading.Event()
        self._departed: set = set()
        self._spike_factor: Dict[str, float] = {}
        self._kill_times: Dict[str, float] = {}
        self._injection_seq = 0
        self._detect_latency: Optional[float] = None
        self._client_threads: List[threading.Thread] = []
        self._aux_threads: List[threading.Thread] = []
        self._timers: List[threading.Timer] = []
        self._arrival_i = 0

        # --- shard plane (tp_degree >= 2): REAL tensor-parallel groups
        # across the worker threads (serving/shard.py). tp_degree=1
        # keeps every historical path untouched.
        self.shards: Optional[TestbedShardManager] = None
        if tp_degree > 1:
            self.shards = TestbedShardManager(
                self, tp_degree=tp_degree, policy=shard_policy)
            self.executor.shard_plane = self.shards

    # -- routing observers (replace the old monkey-patch) -------------------
    def _on_route_set(self, app_id: str, server_id: str,
                      variant_name: str):
        if (self.shards is not None
                and self.shards.on_route(app_id, server_id,
                                         variant_name)):
            return      # pushed by the shard plane once the engine is up
        self._push_route(app_id, server_id, variant_name)

    def _push_route(self, app_id: str, server_id: str,
                    variant_name: str):
        self.router.set_route(app_id, server_id, variant_name)
        self.telemetry.route_up(app_id, time.monotonic())

    def _accuracy_of(self, app: Application, variant_name: str) -> float:
        """Served accuracy for a routed variant name; falls back to the
        shard plane's synthesized (degraded-TP) variants."""
        try:
            return app.variant_by_name(variant_name).accuracy
        except KeyError:
            if self.shards is not None:
                v = self.shards.lookup_variant(variant_name)
                if v is not None:
                    return v.accuracy
            raise

    def _on_route_drop(self, app_id: str):
        self.router.drop_route(app_id)
        self.telemetry.mark_gone(app_id)

    # -- resilience layer ----------------------------------------------------
    def _sync_backups(self):
        """Mirror the controller's warm set into the router's backup
        table (the hedge / fail-fast target). No-op without the
        toolkit."""
        if self.resilience is None:
            return
        with self._ctl_lock:
            table = {aid: (sid, v.name)
                     for aid, (v, sid, _key)
                     in self.controller.warm.items()}
        self.router.sync_backups(table)

    def _res_state(self, app_id: str):
        r = self.resilience
        with self._res_lock:
            breaker = self._breakers.get(app_id)
            if breaker is None:
                breaker = self._breakers[app_id] = CircuitBreaker(r)
                self._budgets[app_id] = RetryBudget(r)
                self._lat_samples[app_id] = []
                self._admit_credit[app_id] = 0.0
            return breaker, self._budgets[app_id]

    def _bulkhead(self, server_id: str) -> Bulkhead:
        with self._res_lock:
            bh = self._bulkheads.get(server_id)
            if bh is None:
                bh = self._bulkheads[server_id] = Bulkhead(
                    self.resilience.bulkhead_slots)
            return bh

    def _hedge_delay(self, app_id: str) -> float:
        """p99-based hedge delay from this app's recent live latencies."""
        r = self.resilience
        with self._res_lock:
            lats = sorted(self._lat_samples.get(app_id, ()))
        if not lats:
            return r.hedge_min_delay_s
        p99 = lats[min(len(lats) - 1, int(0.99 * len(lats)))]
        return max(r.hedge_min_delay_s, r.hedge_delay_factor * p99)

    def _submit_arm(self, app: Application, route, req, *,
                    bulkhead: bool, flags: dict, key: str):
        """Build one hedged_call arm: submit `req` on `route`. Returns
        (accuracy, req) on success, None on any failure; outcome flags
        are reported through `flags` (thread-safe enough: one writer
        per key)."""
        def arm(cancel: threading.Event):
            if cancel.is_set() or route is None:
                return None
            sid, vname = route
            w = self.workers.get(sid)
            if not (w and w.alive and w.has(vname)):
                flags[key] = False
                return None
            bh = self._bulkhead(sid) if bulkhead else None
            if bh is not None and not bh.try_acquire():
                flags[key + "_shed"] = True
                flags[key] = False
                return None
            try:
                t0 = time.monotonic()
                ok = w.submit(vname, req)
                flags[key] = bool(ok)
                if not ok:
                    return None
                with self._res_lock:
                    samples = self._lat_samples.setdefault(app.id, [])
                    samples.append(time.monotonic() - t0)
                    del samples[:-64]          # keep a rolling window
                return (self._accuracy_of(app, vname), req)
            finally:
                if bh is not None:
                    bh.release()
        return arm

    def _attempt_resilient(self, app: Application, rng: random.Random,
                           seq: int):
        """One client request through the toolkit. Returns
        (ok, accuracy, req, outcome)."""
        r = self.resilience
        breaker, budget = self._res_state(app.id)
        # admission control: while recovery loads are draining, thin
        # offered load to the admit_util fraction (deterministic
        # credit counter, same rule as the simulator's shaping)
        if not self.executor.idle():
            with self._res_lock:
                credit = self._admit_credit[app.id] + r.admit_util
                if credit < 1.0:
                    self._admit_credit[app.id] = credit
                    return False, math.nan, None, "shed"
                self._admit_credit[app.id] = credit - 1.0
        budget.on_request()
        primary = self.router.lookup(app.id)
        backup = self.router.lookup_backup(app.id)
        vocab = app.variants[0].config.vocab_size
        flags: dict = {}

        if not breaker.allow():
            # open breaker: fail fast to the degraded (backup) variant
            # instead of queueing on the dead primary — a redirect, so
            # no retry-budget spend
            if backup is not None:
                req_b = make_request(rng, f"{app.id}-b{seq}", vocab)
                out = self._submit_arm(app, backup, req_b, bulkhead=True,
                                       flags=flags, key="backup")(
                                           threading.Event())
                if out is not None:
                    return True, out[0], out[1], "hedged"
            return False, math.nan, None, "fast_failed"

        req_p = make_request(rng, f"{app.id}-r{seq}", vocab)
        primary_arm = self._submit_arm(app, primary, req_p,
                                       bulkhead=True, flags=flags,
                                       key="primary")
        backup_arm = None
        if backup is not None:
            req_b = make_request(rng, f"{app.id}-h{seq}", vocab)
            inner = self._submit_arm(app, backup, req_b, bulkhead=True,
                                     flags=flags, key="backup")

            def _gated_backup(cancel):
                # a hedge is a re-issue: it spends retry budget
                if not budget.try_spend():
                    return None
                return inner(cancel)
            backup_arm = _gated_backup

        value, winner = hedged_call(primary_arm, backup_arm,
                                    self._hedge_delay(app.id))
        if "primary" in flags:             # primary arm actually ran
            breaker.record(flags["primary"])
        if winner == "primary":
            return True, value[0], value[1], None
        if winner == "backup":
            return True, value[0], value[1], "hedged"
        if flags.get("primary_shed") or flags.get("backup_shed"):
            return False, math.nan, None, "shed"
        return False, math.nan, None, None

    # -- deployment ---------------------------------------------------------
    def deploy(self):
        for app in self.apps:
            self.telemetry.app_seen(app)
            if self.shards is not None:
                # TP-k group: slice the real param tree across k
                # workers, gather + compile the serving engine on the
                # lead (serving/shard.py)
                with self._ctl_lock:
                    self.shards.deploy_group(app)
                self.shards.deploy_real(app)
            else:
                with self._ctl_lock:
                    sid = self.controller.deploy_primary(app)
                self.workers[sid].load(app, app.full)
            for w in self.workers.values():      # cold replicas everywhere
                for v in app.variants:
                    w.stage_cold(app, v)
        with self._ctl_lock:
            warm = self.controller.plan_warm_backups()
        # prepare_warm loads run in the background; wait for residency so
        # the experiment starts from the paper's protected steady state
        deadline = time.monotonic() + 120.0
        for app_id, (variant, sid) in warm.items():
            while (not self.workers[sid].has(variant.name)
                   and time.monotonic() < deadline):
                time.sleep(0.05)
        self._sync_backups()
        return self

    # -- clients ------------------------------------------------------------
    def _client_loop(self, app: Application, hz: float):
        st_ok = 0
        seq = 0
        rng = random.Random(hash(app.id) & 0xffff)
        while not self._stop.is_set() and app.id not in self._departed:
            ok = False
            acc = math.nan
            req = None
            outcome = None
            seq += 1
            try:
                if self.resilience is not None:
                    ok, acc, req, outcome = self._attempt_resilient(
                        app, rng, seq)
                    if ok:
                        st_ok += 1
                else:
                    route = self.router.lookup(app.id)
                    if route:
                        sid, vname = route
                        w = self.workers.get(sid)
                        if w and w.alive and w.has(vname):
                            req = make_request(
                                rng, f"{app.id}-r{st_ok}",
                                app.variants[0].config.vocab_size)
                            ok = w.submit(vname, req)
                            if ok:
                                acc = self._accuracy_of(app, vname)
                                st_ok += 1
            except Exception:                      # noqa: BLE001
                ok = False
            self.telemetry.record(app.id, time.monotonic(), ok, acc,
                                  req if ok else None, outcome=outcome)
            time.sleep(1.0 / (hz * self._spike_factor.get(app.id, 1.0)))

    def _start_client(self, app: Application, hz: float):
        t = threading.Thread(target=self._client_loop, args=(app, hz),
                             daemon=True)
        self._client_threads.append(t)
        t.start()

    # -- background control loops -------------------------------------------
    def _sweeper_loop(self):
        while not self._stop.is_set():
            time.sleep(DETECT_POLL_S)
            newly = self.detector.sweep()
            # scheduling-noise suppression: multi-second XLA compiles
            # hold the GIL and can starve a HEALTHY worker's heartbeat
            # thread past the miss threshold. A real deployment has no
            # such cross-server coupling, so spurious detections (the
            # worker was never killed) are re-armed instead of declared.
            for sid in [s for s in newly if self.workers[s].alive]:
                self.detector.revive(sid)
                newly.remove(sid)
            if not newly:
                continue
            now = time.monotonic()
            t_fail = min(self._kill_times.get(sid, now) for sid in newly)
            if self._detect_latency is None:
                self._detect_latency = now - t_fail
            with self._ctl_lock:
                self.controller.handle_failures(newly, t_fail)
            self._sync_backups()

    def _reprotect_loop(self, every: float):
        while not self._stop.wait(every):
            with self._ctl_lock:
                self.controller.reprotect()
            self._sync_backups()

    # -- scenario event handlers ---------------------------------------------
    def _fail_servers(self, sids: List[str]):
        t_kill = time.monotonic()
        epoch = self._injection_seq
        self._injection_seq += 1
        with self._ctl_lock:
            routes = dict(self.controller.routing.routes)
        for sid in sids:
            self._kill_times[sid] = t_kill
            self.workers[sid].kill()
        # clients see the blackout from the crash instant, well before
        # detection — same window semantics as the simulator
        marked = set()
        for app_id, (sid, _v) in routes.items():
            if sid in sids:
                self.telemetry.mark_down(app_id, t_kill, epoch)
                marked.add(app_id)
        if self.shards is not None:
            # shard groups darken when ANY member dies unless the loss
            # degrades seamlessly on a surviving lead — same rule the
            # simulator applies at the crash instant
            with self._ctl_lock:
                dark = self.shards.darkened_by(set(sids))
            for app_id in sorted(dark - marked):
                self.telemetry.mark_down(app_id, t_kill, epoch)

    def _rejoin(self, sid: str):
        with self._ctl_lock:
            if self.cluster.servers[sid].alive:
                # rejoin raced ahead of detection: apply the failure
                # first so bookkeeping stays consistent
                self.controller.handle_failures(
                    [sid], self._kill_times.get(sid, time.monotonic()))
            self.workers[sid].revive()
            self.controller.handle_rejoin(sid)
        for app in self.apps:                    # disk content survived
            for v in app.variants:
                self.workers[sid].stage_cold(app, v)

    def _adapt_arrival(self, app: Application) -> Application:
        """Scenario arrivals carry synthetic (profile-only) ladders; the
        testbed serves real models, so map the arrival onto a reduced
        arch ladder, preserving id / rate / criticality / SLO."""
        if app.full.config is not None:
            return app
        arch = self._archs[self._arrival_i % len(self._archs)]
        self._arrival_i += 1
        return Application(id=app.id, family=arch,
                           variants=testbed_ladder(arch),
                           request_rate=app.request_rate,
                           latency_slo=app.latency_slo,
                           critical=app.critical)

    def _on_arrival(self, app: Application, stats: dict, hz: float):
        app = self._adapt_arrival(app)
        self.telemetry.app_seen(app)
        if self.shards is not None:
            with self._ctl_lock:
                try:
                    self.shards.deploy_group(app)
                except ValueError:
                    stats["unplaced_arrivals"] += 1
                    return
            self.apps.append(app)
            for w in self.workers.values():
                for v in app.variants:
                    w.stage_cold(app, v)
            # slices + gathered engine build in the background; clients
            # fail until the group's lead engine comes up

            def build():
                try:
                    self.shards.deploy_real(app)
                except RuntimeError:
                    pass                  # a member died mid-deploy
            self.executor._spawn(build)
            self._start_client(app, hz)
            return
        with self._ctl_lock:
            try:
                sid = self.controller.deploy_primary(app)
            except ValueError:
                stats["unplaced_arrivals"] += 1
                return
        self.apps.append(app)
        for w in self.workers.values():
            for v in app.variants:
                w.stage_cold(app, v)
        # the primary engine loads in the background: clients fail until
        # the (real) cold deploy completes — that is what arriving
        # mid-outage costs
        self.executor.load(app, app.full, sid, lambda t: None)
        self._start_client(app, hz)

    def _on_departure(self, app_id: str):
        self._departed.add(app_id)
        with self._ctl_lock:
            self.controller.handle_departure(app_id)
        self.apps = [a for a in self.apps if a.id != app_id]

    def _on_spike(self, ev: LoadSpike, time_scale: float):
        # multiplicative with save/restore, mirroring the simulator's
        # handling so overlapping spikes compose identically
        ids = (set(ev.app_ids) if ev.app_ids is not None
               else {a.id for a in self.apps})
        saved = {aid: self._spike_factor.get(aid, 1.0) for aid in ids}
        for aid in ids:
            self._spike_factor[aid] = saved[aid] * ev.factor

        def restore():
            for aid, f in saved.items():
                self._spike_factor[aid] = f
        timer = threading.Timer(ev.duration * time_scale, restore)
        timer.daemon = True
        self._timers.append(timer)
        timer.start()

    # -- scenario replay ------------------------------------------------------
    def run_scenario(self, scenario: Scenario, *,
                     time_scale: float = 1.0,
                     settle_s: Optional[float] = None,
                     client_hz: float = 10.0,
                     reprotect_every: float = REPROTECT_EVERY_S) -> dict:
        """Replay `scenario` on the wall clock (event times scaled by
        `time_scale`); run until horizon + settle, exiting early once
        every recovery and in-flight load has completed."""
        scenario.validate(self.cluster)
        settle = settle_s if settle_s is not None else 15.0
        stats = {"unplaced_arrivals": 0}

        for app in self.apps:
            self._start_client(app, client_hz)
        for target, args in ((self._sweeper_loop, ()),
                             (self._reprotect_loop, (reprotect_every,))):
            t = threading.Thread(target=target, args=args, daemon=True)
            self._aux_threads.append(t)
            t.start()

        t0 = time.monotonic()
        for ev in scenario.sorted_events():
            delay = t0 + ev.t * time_scale - time.monotonic()
            if delay > 0:
                if self._stop.wait(delay):
                    break
            if isinstance(ev, ServerFail):
                self._fail_servers([ev.server])
            elif isinstance(ev, ShardFail):
                self._fail_servers([ev.server])
            elif isinstance(ev, SiteFail):
                self._fail_servers(list(self.cluster.sites[ev.site]))
            elif isinstance(ev, ServerRejoin):
                self._rejoin(ev.server)
            elif isinstance(ev, AppArrival):
                self._on_arrival(ev.app, stats, client_hz)
            elif isinstance(ev, AppDeparture):
                self._on_departure(ev.app_id)
            elif isinstance(ev, LoadSpike):
                self._on_spike(ev, time_scale)
            elif isinstance(ev, LinkDegrade):
                self.executor.degrade_link(ev.link, ev.factor,
                                           ev.duration * time_scale)
            else:
                raise TypeError(f"unhandled scenario event: {ev}")

        # observe until recovery converges (or the deadline passes)
        deadline = t0 + scenario.horizon * time_scale + settle
        grace = max(1.0, 3.0 / client_hz)
        while time.monotonic() < deadline:
            with self._ctl_lock:
                recs = list(self.controller.records.values())
                down = self.controller.has_unrecovered
            if recs and not down and self.executor.idle() \
                    and all(r.recovered for r in recs):
                time.sleep(grace)       # let clients observe the routes
                break
            time.sleep(0.1)
        t_end = time.monotonic()

        self._stop.set()
        for t in self._client_threads:
            t.join(timeout=2.0)

        ctl = self.controller
        with self._ctl_lock:
            flat = ctl.flat_records()
            overall = ctl.overall_summary()
            per_epoch = ctl.summarize_epochs()
            cov = ctl.warm_coverage()
        traffic = self.telemetry.summarize(t_end)
        out_shard = ({"shard": self.shards.summary()}
                     if self.shards is not None else {})
        return {
            **out_shard,
            "n_epochs": len(ctl.epoch_records),
            "per_epoch": per_epoch,
            "overall": overall,
            "warm_coverage": cov,
            "unplaced_arrivals": stats["unplaced_arrivals"],
            "records": flat,
            "traffic": traffic,
            # Fig. 2b feedback: effective load bandwidth per fetch
            # source, calibrated from the REAL loads this run executed
            # (feed into a sim spec to price loads identically there)
            "load_calibration": self.registry.calibration.to_dict(),
            "detect_latency_s": (self._detect_latency
                                 if self._detect_latency is not None
                                 else math.nan),
            # the summary's windows carry the back-filled
            # t_first_served, so per-app downtime is the true
            # client-observed gap, not just the route outage
            "client_stats": self.telemetry.client_stats(traffic.windows),
        }

    # -- compat: the paper's base experiment ----------------------------------
    def run_failure_experiment(self, victim: Optional[str] = None, *,
                               settle_s: float = 0.3,
                               observe_s: float = 6.0,
                               client_hz: float = 20.0) -> dict:
        """Kill one (primary-hosting) server; measure recovery via the
        detector + live clients. Thin wrapper over `run_scenario`."""
        victim = victim or next(
            sid for sid, srv in self.cluster.servers.items()
            if any(i.role == "primary"
                   for i in srv.instances.values()))
        scenario = Scenario(
            name="primary-kill",
            events=[ServerFail(t=settle_s, server=victim)],
            horizon=settle_s,
            description=f"kill {victim}, observe recovery")
        out = self.run_scenario(scenario, settle_s=observe_s,
                                client_hz=client_hz)
        records: Dict[str, RecoveryRecord] = (
            dict(self.controller.epoch_records[0])
            if self.controller.epoch_records else {})
        return {
            "victim": victim,
            "detect_latency_s": out["detect_latency_s"],
            "records": records,
            "summary": self.controller.summarize(records),
            "client_stats": out["client_stats"],
            "traffic": out["traffic"],
        }

    def shutdown(self):
        """Stop every thread this testbed started and JOIN it, so no
        JAX work survives into interpreter teardown (the old abort-at-
        exit came from daemon threads compiling during shutdown)."""
        self._stop.set()
        for timer in self._timers:
            timer.cancel()
        for t in self._client_threads + self._aux_threads:
            t.join(timeout=2.0)
        self.executor.join(timeout=20.0)
        for w in self.workers.values():
            w.kill()
        for w in self.workers.values():
            w.join(timeout=2.0)
