"""Thread-based mini-testbed: the paper's edge testbed, on one CPU.

Real components everywhere the paper's testbed had them:
  * WorkerServer threads host real JAX engines and send real heartbeats
  * failure injection kills the worker (heartbeats stop mid-flight)
  * the FailureDetector declares failure after 2 missed beats
  * the controller runs the two-step failover; cold loads really build
    params + compile (their wall-clock duration is the measured
    load time, Fig. 2b analogue)
  * clients measure end-to-end downtime around the failure

Model ladders use the reduced smoke configs so everything runs on CPU;
capacities are scaled so contention matches the paper's ~50% utilization
+ configurable headroom.
"""

from __future__ import annotations

import random
import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro import configs
from repro.core.cluster import Cluster, Server
from repro.core.controller import (FailLiteController, LoadExecutor,
                                   RecoveryRecord)
from repro.core.heartbeat import FailureDetector, WallClock
from repro.core.variants import Application, Variant, build_ladder
from repro.serving.engine import Request
from repro.serving.router import Router
from repro.serving.server import WorkerServer
from repro.serving.workload import make_request

TESTBED_ARCHS = ["qwen2.5-3b", "qwen3-32b", "recurrentgemma-2b",
                 "rwkv6-3b", "qwen3-moe-30b-a3b"]


def testbed_ladder(arch: str) -> List[Variant]:
    """Variant ladder over an extra-reduced smoke config (CPU-budget:
    load time is dominated by XLA compiles, the testbed's stand-in for
    the paper's disk-bandwidth-dominated Triton loads)."""
    smoke = configs.get_smoke(arch)
    plen = len(smoke.block_pattern)
    n_layers = plen if not smoke.is_encoder_decoder else 2
    kw = dict(scan_layers=True, num_layers=n_layers)
    if smoke.is_encoder_decoder:
        kw.update(num_encoder_layers=1, num_decoder_layers=1)
    return build_ladder(smoke.replace(**kw), cell_mem=64e6)


class TestbedExecutor(LoadExecutor):
    """Executes controller load orders on real worker threads.

    Loads are serialized per server (one PCIe/disk channel per cell, as
    on the paper's testbed) and ordered: the progressive small-first load
    completes before the selected-variant load starts.
    """

    def __init__(self, workers: Dict[str, WorkerServer], router: Router):
        self.workers = workers
        self.router = router
        self._locks: Dict[str, threading.Lock] = {
            sid: threading.Lock() for sid in workers}

    def load(self, app, variant, server_id, on_ready):
        def work():
            try:
                with self._locks[server_id]:
                    self.workers[server_id].load(app, variant)
                on_ready(time.monotonic())
            except RuntimeError:
                pass                      # server died mid-load
            except Exception:             # noqa: BLE001
                import traceback
                traceback.print_exc()
        threading.Thread(target=work, daemon=True).start()

    def activate(self, app, variant, server_id):
        w = self.workers[server_id]
        if not w.has(variant.name):        # warm = pre-loaded at plan time
            w.load(app, variant)


@dataclass
class ClientStats:
    app_id: str
    ok: int = 0
    failed: int = 0
    last_ok: Optional[float] = None
    first_ok_after_gap: Optional[float] = None
    downtime: Optional[float] = None


class MiniTestbed:
    def __init__(self, *, n_sites: int = 3, servers_per_site: int = 2,
                 apps_per_arch: int = 1, critical_frac: float = 0.5,
                 headroom: float = 0.35, policy: str = "faillite",
                 seed: int = 0, archs: Optional[List[str]] = None):
        self.rng = random.Random(seed)
        self.clock = WallClock()
        self.detector = FailureDetector(self.clock, interval=0.020)
        self.router = Router()

        # --- applications from reduced configs -------------------------
        self.apps: List[Application] = []
        i = 0
        for arch in (archs or TESTBED_ARCHS):
            for _ in range(apps_per_arch):
                ladder = testbed_ladder(arch)
                self.apps.append(Application(
                    id=f"{arch}-app{i}", family=arch, variants=ladder,
                    request_rate=self.rng.uniform(0.5, 2.0),
                    critical=(self.rng.random() < critical_frac)))
                i += 1

        # --- capacity scaled to primaries + headroom ---------------------
        total_primary = sum(a.full.demand["mem"] for a in self.apps)
        max_primary = max(a.full.demand["mem"] for a in self.apps)
        n_servers = n_sites * servers_per_site
        mem_cap = max(total_primary / (n_servers * (1.0 - headroom) * 0.5),
                      1.5 * max_primary)
        servers = [Server(id=f"s{si}-{sj}", site=f"site{si}",
                          capacity={"mem": mem_cap, "compute": 1e9})
                   for si in range(n_sites)
                   for sj in range(servers_per_site)]
        self.cluster = Cluster(servers)

        # --- worker threads ----------------------------------------------
        self.workers: Dict[str, WorkerServer] = {
            s.id: WorkerServer(s.id, self.detector).start()
            for s in servers}
        self.executor = TestbedExecutor(self.workers, self.router)
        self.controller = FailLiteController(
            self.cluster, self.clock, self.executor, policy=policy,
            detector=self.detector)
        # controller routing -> real router pushes
        orig_set = self.controller.routing.set

        def set_and_push(app_id, server_id, variant_name):
            orig_set(app_id, server_id, variant_name)
            self.router.set_route(app_id, server_id, variant_name)
        self.controller.routing.set = set_and_push

    # -- deployment ---------------------------------------------------------
    def deploy(self):
        for app in self.apps:
            sid = self.controller.deploy_primary(app)
            self.workers[sid].load(app, app.full)
            self.router.set_route(app.id, sid, app.full.name)
            for w in self.workers.values():      # cold replicas everywhere
                for v in app.variants:
                    w.stage_cold(app, v)
        warm = self.controller.plan_warm_backups()
        for app_id, (variant, sid) in warm.items():
            app = next(a for a in self.apps if a.id == app_id)
            self.workers[sid].load(app, variant)
        return self

    # -- failure experiment ---------------------------------------------------
    def run_failure_experiment(self, victim: Optional[str] = None, *,
                               settle_s: float = 0.3,
                               observe_s: float = 6.0,
                               client_hz: float = 20.0):
        """Kill one server; measure recovery via detector + clients."""
        victim = victim or next(
            sid for sid, w in self.workers.items()
            if any(i.role == "primary"
                   for i in self.cluster.servers[sid].instances.values()))

        stats = {a.id: ClientStats(a.id) for a in self.apps}
        stop = threading.Event()

        def client_loop(app: Application):
            st = stats[app.id]
            period = 1.0 / client_hz
            rng = random.Random(hash(app.id) & 0xffff)
            while not stop.is_set():
                ok = False
                try:
                    route = self.router.lookup(app.id)
                    if route:
                        sid, vname = route
                        w = self.workers.get(sid)
                        if w and w.alive and w.has(vname):
                            req = make_request(
                                rng, f"{app.id}-r{st.ok}",
                                app.variants[0].config.vocab_size)
                            ok = w.submit(vname, req)
                except Exception:                      # noqa: BLE001
                    import traceback
                    traceback.print_exc()
                now = time.monotonic()
                if ok:
                    if (st.last_ok is not None and st.downtime is None
                            and now - st.last_ok > 4 * period):
                        st.downtime = now - st.last_ok
                    st.ok += 1
                    st.last_ok = now
                else:
                    st.failed += 1
                time.sleep(period)

        threads = [threading.Thread(target=client_loop, args=(a,),
                                    daemon=True) for a in self.apps]
        for t in threads:
            t.start()
        time.sleep(settle_s)

        # --- inject crash ------------------------------------------------
        t_fail = time.monotonic()
        self.workers[victim].kill()

        # --- detection loop (controller sweep every 100ms) ----------------
        detected: List[str] = []
        t_deadline = t_fail + observe_s
        while time.monotonic() < t_deadline and not detected:
            time.sleep(0.01)
            detected = self.detector.sweep()
        t_detect = time.monotonic()
        records: Dict[str, RecoveryRecord] = {}
        if detected:
            records = self.controller.handle_failures(detected, t_fail)
        # wait for progressive loads (engine compiles are real work)
        deadline = time.monotonic() + observe_s
        while time.monotonic() < deadline:
            if all(r.recovered for r in records.values()) and records:
                time.sleep(0.5)     # let clients observe the new route
                break
            time.sleep(0.05)
        stop.set()
        for t in threads:
            t.join(timeout=1.0)

        return {
            "victim": victim,
            "detect_latency_s": t_detect - t_fail,
            "records": records,
            "summary": self.controller.summarize(records),
            "client_stats": stats,
        }

    def shutdown(self):
        for w in self.workers.values():
            w.kill()
