"""Request workload generators for the serving testbed/benchmarks.

Arrival generation is shared with the simulator's request-level traffic
plane (`repro.core.traffic`): the same batched order-statistics sampler
produces both the testbed's wall-clock schedules and the simulator's
bulk per-chunk streams, so testbed and simulation runs draw from the
same arrival-process family (Poisson, optionally diurnally modulated).
"""

from __future__ import annotations

import random
import time
from typing import List

import numpy as np

from repro.core.traffic import diurnal_arrival_times, poisson_arrival_times
from repro.serving.engine import Request


def make_request(rng: random.Random, rid: str, vocab: int,
                 prompt_len=(8, 8), new_tokens=(2, 6)) -> Request:
    """Fixed prompt length by default: the engine's prefill is jitted per
    shape, so clients use one bucket to avoid recompiles on the hot path."""
    S = rng.randint(*prompt_len)
    return Request(
        id=rid,
        prompt=np.asarray([rng.randrange(vocab) for _ in range(S)],
                          np.int32),
        max_new_tokens=rng.randint(*new_tokens),
        submitted_at=time.monotonic())


def _np_rng(rng: random.Random) -> np.random.Generator:
    """Derive a numpy generator from the caller's seeded random.Random
    so existing call sites keep their (seed-driven) determinism."""
    return np.random.default_rng(rng.getrandbits(64))


def poisson_arrivals(rng: random.Random, rate_hz: float,
                     duration_s: float) -> List[float]:
    """Arrival offsets (s) of a Poisson process over [0, duration).

    Delegates to the vectorized shared layer (one batched draw instead
    of N sequential exponentials).
    """
    return poisson_arrival_times(_np_rng(rng), rate_hz,
                                 0.0, duration_s).tolist()


def diurnal_arrivals(rng: random.Random, base_rate_hz: float,
                     duration_s: float, *, period_s: float = 240.0,
                     amplitude: float = 0.5) -> List[float]:
    """Arrival offsets of a diurnally-modulated Poisson process."""
    return diurnal_arrival_times(_np_rng(rng), base_rate_hz,
                                 0.0, duration_s, period=period_s,
                                 amplitude=amplitude).tolist()
