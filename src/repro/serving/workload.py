"""Request workload generators for the serving testbed/benchmarks."""

from __future__ import annotations

import itertools
import random
import time
from typing import Iterator, List

import numpy as np

from repro.serving.engine import Request


def make_request(rng: random.Random, rid: str, vocab: int,
                 prompt_len=(8, 8), new_tokens=(2, 6)) -> Request:
    """Fixed prompt length by default: the engine's prefill is jitted per
    shape, so clients use one bucket to avoid recompiles on the hot path."""
    S = rng.randint(*prompt_len)
    return Request(
        id=rid,
        prompt=np.asarray([rng.randrange(vocab) for _ in range(S)],
                          np.int32),
        max_new_tokens=rng.randint(*new_tokens),
        submitted_at=time.monotonic())


def poisson_arrivals(rng: random.Random, rate_hz: float,
                     duration_s: float) -> List[float]:
    """Arrival offsets (s) of a Poisson process over [0, duration)."""
    t, out = 0.0, []
    while True:
        t += rng.expovariate(rate_hz)
        if t >= duration_s:
            return out
        out.append(t)
