"""Worker agent: one serving cell — hosts engines, heartbeats, fails.

Real work happens here in the mini-testbed: `load()` actually builds JAX
params and compiles the engine (that wall-clock time IS the measured
cold-load cost, the analogue of the paper's Fig. 2b Triton loads), and
`submit()` runs real batched inference on the CPU device.
"""

from __future__ import annotations

import queue
import threading
import time
from typing import Dict

import jax

from repro.core.heartbeat import FailureDetector
from repro.core.variants import Application, Variant
from repro.models import model as MDL
from repro.serving.engine import InferenceEngine, Request


class WorkerServer:
    """Thread-backed serving cell with heartbeat + engine hosting."""

    def __init__(self, server_id: str, detector: FailureDetector, *,
                 heartbeat_s: float = 0.020, batch_slots: int = 2,
                 max_len: int = 96):
        self.id = server_id
        self.detector = detector
        self.heartbeat_s = heartbeat_s
        self.batch_slots = batch_slots
        self.max_len = max_len
        self.engines: Dict[str, InferenceEngine] = {}     # variant -> engine
        self.cold_store: Dict[str, Variant] = {}          # on "disk"
        self.shard_store: Dict[str, object] = {}          # TP slices (HBM)
        self._alive = threading.Event()
        self._alive.set()
        self._threads = []
        self._lock = threading.Lock()
        self._work = queue.Queue()

    # -- lifecycle -----------------------------------------------------------
    def start(self):
        hb = threading.Thread(target=self._heartbeat_loop, daemon=True)
        wk = threading.Thread(target=self._serve_loop, daemon=True)
        hb.start()
        wk.start()
        self._threads = [hb, wk]
        return self

    def kill(self):
        """Crash-failure injection: heartbeats stop, engines vanish."""
        self._alive.clear()
        with self._lock:
            self.engines.clear()
            self.shard_store.clear()

    def revive(self):
        """Rejoin after a crash: the node returns EMPTY (engines were
        lost) but its cold store (disk) survived; heartbeats resume."""
        if self._alive.is_set():
            return self
        self._alive.set()
        return self.start()

    def join(self, timeout: float = 2.0):
        """Wait for the worker's threads to exit (after kill()); keeps
        JAX work out of interpreter teardown."""
        for t in self._threads:
            t.join(timeout=timeout)

    @property
    def alive(self) -> bool:
        return self._alive.is_set()

    def _heartbeat_loop(self):
        while self._alive.is_set():
            self.detector.beat(self.id)
            time.sleep(self.heartbeat_s)

    def _serve_loop(self):
        while True:
            try:
                fn = self._work.get(timeout=0.05)
            except queue.Empty:
                if not self._alive.is_set():
                    return
                continue
            if not self._alive.is_set():
                return
            fn()

    # -- model management (Triton Load/Unload analogue) -----------------------
    def stage_cold(self, app: Application, variant: Variant):
        """Cold replica: weights on disk/host only."""
        self.cold_store[variant.name] = variant

    def load(self, app: Application, variant: Variant,
             warm: bool = True) -> float:
        """Build params + compile; returns wall-clock load seconds."""
        if not self.alive:
            raise RuntimeError(f"{self.id} is down")
        t0 = time.monotonic()
        cfg = variant.config
        assert cfg is not None, "testbed variants need real configs"
        params = MDL.init_params(jax.random.PRNGKey(hash(variant.name)
                                                    % (2**31)), cfg)
        eng = InferenceEngine(cfg, params, batch_slots=self.batch_slots,
                              max_len=self.max_len)
        eng.warmup()
        with self._lock:
            if not self.alive:
                raise RuntimeError(f"{self.id} died during load")
            self.engines[variant.name] = eng
        return time.monotonic() - t0

    def install(self, variant_name: str, engine: InferenceEngine):
        """Adopt a pre-built engine (tensor-parallel deployments gather
        their shard slices off-worker and install the result here)."""
        if not self.alive:
            raise RuntimeError(f"{self.id} is down")
        with self._lock:
            if not self.alive:
                raise RuntimeError(f"{self.id} died during install")
            self.engines[variant_name] = engine

    def alias(self, dst: str, src: str) -> bool:
        """Serve `src`'s resident engine under the name `dst` too
        (degraded-TP routes keep answering on the gathered engine until
        the honest rebuild swaps in). False if `src` is not resident."""
        with self._lock:
            eng = self.engines.get(src)
            if eng is None or not self.alive:
                return False
            self.engines[dst] = eng
            return True

    def host_shard(self, name: str, slice_tree) -> None:
        """Hold one TP weight slice in this cell's memory. Lost on
        kill() (unlike the cold store, which models disk)."""
        if not self.alive:
            raise RuntimeError(f"{self.id} is down")
        with self._lock:
            if not self.alive:
                raise RuntimeError(f"{self.id} died hosting a shard")
            self.shard_store[name] = slice_tree

    def shard(self, name: str):
        """The hosted slice, or None if this cell is dead/empty."""
        if not self.alive:
            return None
        with self._lock:
            return self.shard_store.get(name)

    def unload(self, variant_name: str):
        with self._lock:
            self.engines.pop(variant_name, None)

    def has(self, variant_name: str) -> bool:
        with self._lock:
            return variant_name in self.engines

    # -- serving ---------------------------------------------------------------
    def submit(self, variant_name: str, req: Request) -> bool:
        with self._lock:
            eng = self.engines.get(variant_name)
        if eng is None or not self.alive:
            return False
        if not eng.try_admit(req):
            return False
        self._work.put(lambda: self._drain(eng))
        return True

    def _drain(self, eng: InferenceEngine):
        while eng.active_count() and self._alive.is_set():
            eng.step()
