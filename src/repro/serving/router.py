"""Request router: epoch-versioned routing table + client notification.

The controller bumps the routing epoch on every failover (the paper's
websocket push, §4); clients observe the new (server, variant) on their
next request — plus explicit notify callbacks for push semantics.

Concurrency contract (relied on by the mini-testbed and asserted by
tests/test_router.py):

  * epochs are strictly monotonic: every successful `set_route` returns
    a unique epoch, and concurrent calls never reuse or skip one;
  * subscribers see every route change **exactly once and in epoch
    order** — notification happens while the (reentrant) lock is held,
    so two concurrent `set_route` calls cannot interleave their
    callbacks or deliver out of order;
  * `snapshot()` returns an (epoch, routes) pair that is internally
    consistent: the routes are exactly the table contents at that epoch.

Subscribers must not block: they run inside the router's critical
section. The lock is reentrant, so a subscriber may read the router
(`lookup`, `epoch`, `snapshot`) but should not call `set_route`.
"""

from __future__ import annotations

import threading
from typing import Callable, Dict, List, Optional, Tuple


class Router:
    def __init__(self):
        self._routes: Dict[str, Tuple[str, str]] = {}
        self._backups: Dict[str, Tuple[str, str]] = {}
        self._epoch = 0
        self._lock = threading.RLock()
        self._subscribers: List[Callable[[str, str, str], None]] = []
        self._versioned: List[Callable[[int, str, str, str], None]] = []

    def set_route(self, app_id: str, server_id: str,
                  variant: str) -> int:
        """Install a route, bump the epoch, push to subscribers.

        Returns the epoch assigned to this change (strictly monotonic
        across threads).
        """
        with self._lock:
            self._routes[app_id] = (server_id, variant)
            self._epoch += 1
            epoch = self._epoch
            for fn in list(self._subscribers):
                fn(app_id, server_id, variant)       # push notification
            for fn in list(self._versioned):
                fn(epoch, app_id, server_id, variant)
        return epoch

    def drop_route(self, app_id: str) -> Optional[int]:
        """Remove a route (app departure); returns the epoch of the
        change, or None if the app had no route.

        Drops are pushed like sets — subscribers receive server=None,
        variant=None — so the exactly-once/no-gaps epoch contract holds
        across every route change, not just installs.
        """
        with self._lock:
            if self._routes.pop(app_id, None) is None:
                return None
            self._epoch += 1
            epoch = self._epoch
            for fn in list(self._subscribers):
                fn(app_id, None, None)
            for fn in list(self._versioned):
                fn(epoch, app_id, None, None)
        return epoch

    def lookup(self, app_id: str) -> Optional[Tuple[str, str]]:
        with self._lock:
            return self._routes.get(app_id)

    # -- backup routes (resilience layer) -----------------------------------
    # Hedged requests and breaker fail-fast need the app's warm-backup
    # (server, variant) next to the primary route. Backups do not bump
    # the epoch: they are advisory (the hedge target), not the serving
    # route — the epoch contract above stays exactly as documented.
    def set_backup(self, app_id: str, server_id: str, variant: str):
        with self._lock:
            self._backups[app_id] = (server_id, variant)

    def drop_backup(self, app_id: str):
        with self._lock:
            self._backups.pop(app_id, None)

    def lookup_backup(self, app_id: str) -> Optional[Tuple[str, str]]:
        with self._lock:
            return self._backups.get(app_id)

    def sync_backups(self, table: Dict[str, Tuple[str, str]]):
        """Replace the whole backup table (controller warm-set sync)."""
        with self._lock:
            self._backups = dict(table)

    def snapshot(self) -> Tuple[int, Dict[str, Tuple[str, str]]]:
        """Consistent (epoch, routes-copy) pair."""
        with self._lock:
            return self._epoch, dict(self._routes)

    @property
    def epoch(self) -> int:
        with self._lock:
            return self._epoch

    def subscribe(self, fn: Callable[[str, str, str], None]):
        with self._lock:
            self._subscribers.append(fn)

    def subscribe_versioned(self, fn: Callable[[int, str, str, str],
                                               None]):
        """Like subscribe, but the callback also receives the epoch the
        change was assigned — lets clients detect missed pushes."""
        with self._lock:
            self._versioned.append(fn)
