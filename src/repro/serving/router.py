"""Request router: epoch-versioned routing table + client notification.

The controller bumps the routing epoch on every failover (the paper's
websocket push, §4); clients observe the new (server, variant) on their
next request — plus an explicit notify callback for push semantics.
"""

from __future__ import annotations

import threading
from typing import Callable, Dict, List, Optional, Tuple


class Router:
    def __init__(self):
        self._routes: Dict[str, Tuple[str, str]] = {}
        self._epoch = 0
        self._lock = threading.Lock()
        self._subscribers: List[Callable[[str, str, str], None]] = []

    def set_route(self, app_id: str, server_id: str, variant: str):
        with self._lock:
            self._routes[app_id] = (server_id, variant)
            self._epoch += 1
            subs = list(self._subscribers)
        for fn in subs:
            fn(app_id, server_id, variant)       # push notification

    def lookup(self, app_id: str) -> Optional[Tuple[str, str]]:
        with self._lock:
            return self._routes.get(app_id)

    @property
    def epoch(self) -> int:
        with self._lock:
            return self._epoch

    def subscribe(self, fn: Callable[[str, str, str], None]):
        with self._lock:
            self._subscribers.append(fn)
