"""Batched inference engine: continuous batching over a slotted KV cache.

One engine = one loaded model variant on one serving cell.  Requests are
admitted into free batch slots; each step() runs one decode step for all
active slots (prefill on admission).  Greedy sampling; per-slot position
bookkeeping lives in the model cache ("pos").
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as MDL
from repro.models.config import ModelConfig


@dataclass
class Request:
    id: str
    prompt: np.ndarray              # (S,) int32
    max_new_tokens: int = 8
    submitted_at: float = 0.0
    first_token_at: Optional[float] = None
    done_at: Optional[float] = None
    tokens: List[int] = field(default_factory=list)
    error: Optional[str] = None

    @property
    def latency(self) -> Optional[float]:
        return None if self.done_at is None else \
            self.done_at - self.submitted_at


class InferenceEngine:
    """Slot-based continuous batching for one model instance."""

    def __init__(self, cfg: ModelConfig, params, *, batch_slots: int = 4,
                 max_len: int = 256):
        self.cfg = cfg
        self.params = params
        self.batch_slots = batch_slots
        self.max_len = max_len
        self.cache = MDL.init_cache(cfg, batch_slots, max_len)
        self.slots: List[Optional[Request]] = [None] * batch_slots
        self.remaining: np.ndarray = np.zeros(batch_slots, np.int32)
        self._lock = threading.Lock()

        self._decode = jax.jit(
            lambda p, c, t: MDL.decode_step(p, cfg, t, c))
        self._prefill_one = jax.jit(
            lambda p, c, t: MDL.prefill(p, cfg, t, c))

    def warmup(self, prompt_bucket: int = 8):
        """Compile decode + bucketed prefill (counts toward load time,
        the paper's Fig. 2b load+warmup analogue)."""
        tok = jnp.zeros((self.batch_slots,), jnp.int32)
        logits, _ = self._decode(self.params, self.cache, tok)
        logits.block_until_ready()
        if not self.cfg.is_encoder_decoder:
            sub = MDL.cache_take_slot(self.cache, 0)
            sub["pos"] = jnp.zeros((1,), jnp.int32)
            pl_, _ = self._prefill_one(
                self.params, sub, jnp.zeros((1, prompt_bucket), jnp.int32))
            pl_.block_until_ready()

    # -- admission -----------------------------------------------------------
    def try_admit(self, req: Request) -> bool:
        with self._lock:
            try:
                slot = self.slots.index(None)
            except ValueError:
                return False
            self.slots[slot] = req
            self.remaining[slot] = req.max_new_tokens
        # single-sequence prefill into the slot (pos bookkeeping per slot)
        prompt = jnp.asarray(req.prompt, jnp.int32)[None, :]
        sub = MDL.cache_take_slot(self.cache, slot)
        sub["pos"] = jnp.zeros((1,), jnp.int32)
        logits, sub = self._prefill_one(self.params, sub, prompt)
        with self._lock:
            self.cache = MDL.cache_put_slot(self.cache, slot, sub)
            first = int(jnp.argmax(logits[0]))
            req.tokens.append(first)
            req.first_token_at = time.monotonic()
        return True

    # -- decode ---------------------------------------------------------------
    def step(self) -> List[Request]:
        """One decode step for all active slots; returns finished reqs."""
        with self._lock:
            active = [i for i, r in enumerate(self.slots) if r is not None]
            if not active:
                return []
            last = [r.tokens[-1] if r is not None and r.tokens else 0
                    for r in self.slots]
        tok = jnp.asarray(last, jnp.int32)
        logits, self.cache = self._decode(self.params, self.cache, tok)
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        finished = []
        with self._lock:
            for i in active:
                req = self.slots[i]
                req.tokens.append(int(nxt[i]))
                self.remaining[i] -= 1
                if self.remaining[i] <= 0:
                    req.done_at = time.monotonic()
                    finished.append(req)
                    self.slots[i] = None
        return finished

    def active_count(self) -> int:
        with self._lock:
            return sum(r is not None for r in self.slots)
