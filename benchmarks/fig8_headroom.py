"""Fig. 8 — impact of resource constraints (headroom 10-50%), DES at
100 servers, all four policies."""

from __future__ import annotations


def run(quick: bool = True):
    from repro.core.simulation import SimConfig, Simulation

    headrooms = [0.1, 0.3, 0.5] if quick else [0.1, 0.2, 0.3, 0.4, 0.5]
    policies = ["faillite", "full-warm", "full-cold", "full-warm-k"]
    scale = dict(n_sites=4, servers_per_site=5) if quick else \
        dict(n_sites=10, servers_per_site=10)
    seeds = (0,) if quick else (0, 1, 2)
    print("# fig8: policy,headroom,recovery_rate,mttr_ms,acc_red_pct")
    rows = []
    for policy in policies:
        for h in headrooms:
            acc = {"r": 0.0, "m": 0.0, "a": 0.0}
            n = 0
            for seed in seeds:
                # controller metrics only: skip the traffic plane
                cfg = SimConfig(headroom=h, policy=policy, seed=seed,
                                traffic_rate_scale=0.0, **scale)
                sim = Simulation(cfg).setup()
                victim = sim.rng.choice(sim.cluster.alive_servers()).id
                res = sim.inject_failure(servers=[victim])
                if res.n_affected == 0:
                    continue
                acc["r"] += res.recovery_rate
                acc["m"] += (res.mttr_avg if res.recovery_rate else 0.0)
                acc["a"] += res.accuracy_reduction
                n += 1
            if n == 0:
                continue
            rows.append((policy, h, acc["r"] / n, acc["m"] / n * 1e3,
                         acc["a"] / n * 100))
            print(f"fig8,{policy},{h:.1f},{acc['r']/n:.3f},"
                  f"{acc['m']/n*1e3:.0f},{acc['a']/n*100:.2f}")
    return rows


if __name__ == "__main__":
    run(quick=False)
