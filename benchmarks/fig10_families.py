"""Fig. 10 — impact of model-family class (Small/Medium/Large demand
spread between largest and smallest variant)."""

from __future__ import annotations


def run(quick: bool = True):
    from repro.core.simulation import (SimConfig, Simulation,
                                       synthetic_apps)
    import random

    classes = ["small", "large"] if quick else ["small", "medium", "large"]
    policies = ["faillite", "full-cold", "full-warm-k"]
    scale = dict(n_sites=4, servers_per_site=5) if quick else \
        dict(n_sites=10, servers_per_site=10)
    print("# fig10: class,policy,n_apps,recovery_rate,mttr_ms,acc_red_pct")
    rows = []
    for cls in classes:
        for policy in policies:
            # controller metrics only: skip the traffic plane
            cfg = SimConfig(policy=policy, seed=0, headroom=0.2,
                            traffic_rate_scale=0.0, **scale)
            rng = random.Random(cfg.seed)
            apps = synthetic_apps(cfg, rng, family_class=cls)
            sim = Simulation(cfg, apps=apps).setup()
            victim = sim.rng.choice(sim.cluster.alive_servers()).id
            res = sim.inject_failure(servers=[victim])
            rows.append((cls, policy, len(apps), res.recovery_rate,
                         res.mttr_avg * 1e3,
                         res.accuracy_reduction * 100))
            print(f"fig10,{cls},{policy},{len(apps)},"
                  f"{res.recovery_rate:.3f},{res.mttr_avg*1e3:.0f},"
                  f"{res.accuracy_reduction*100:.2f}")
    return rows


if __name__ == "__main__":
    run(quick=False)
