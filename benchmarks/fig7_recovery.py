"""Fig. 7 + §5.7 overheads — real mini-testbed: recovery rate and MTTR
across FailLite and the three full-size baselines, real failure
injection, real (compile-bound) model loads, client-observed downtime.

Reports controller MTTR (`ctl_mttr_ms`) next to the client-observed
downtime measured from the request stream (`client_mttr_ms`) — the
wall-clock analogue of the request-level metrics the simulator's
traffic plane produces (see core/metrics.py and benchmarks/scenarios.py
for the simulated counterpart).
"""

from __future__ import annotations


def run(quick: bool = True):
    from repro.serving.testbed import MiniTestbed

    archs = (["qwen2.5-3b", "rwkv6-3b"] if quick else
             ["qwen2.5-3b", "rwkv6-3b", "recurrentgemma-2b",
              "qwen3-moe-30b-a3b"])
    policies = (["faillite", "full-warm-k"] if quick
                else ["faillite", "full-warm", "full-cold", "full-warm-k"])
    print("# fig7: policy,n,recovery_rate,ctl_mttr_ms,acc_red_pct,"
          "detect_ms,client_mttr_ms")
    rows = []
    for policy in policies:
        tb = MiniTestbed(apps_per_arch=1, archs=archs, seed=2,
                         headroom=0.3, policy=policy)
        tb.deploy()
        res = tb.run_failure_experiment(observe_s=30.0, client_hz=10.0)
        s = res["summary"]
        downs = [st.downtime for st in res["client_stats"].values()
                 if st.downtime]
        down_ms = (sum(downs) / len(downs) * 1e3) if downs else float("nan")
        rows.append((policy, s["n"], s["recovery_rate"],
                     s["mttr_avg"] * 1e3,
                     s["accuracy_reduction"] * 100,
                     res["detect_latency_s"] * 1e3, down_ms))
        print(f"fig7,{policy},{s['n']},{s['recovery_rate']:.2f},"
              f"{s['mttr_avg']*1e3:.0f},{s['accuracy_reduction']*100:.2f},"
              f"{res['detect_latency_s']*1e3:.0f},{down_ms:.0f}")
        tb.shutdown()
    return rows


if __name__ == "__main__":
    run(quick=False)
