"""Fig. 7 + §5.7 overheads — recovery rate and MTTR across FailLite and
the three full-size baselines under real failure injection on the
mini-testbed (real compile-bound model loads, client-observed downtime).

A thin client of `repro.experiment`: one spec per policy, default
backend "testbed" (the figure's native engine); `--backend sim` replays
the IDENTICAL specs — same arch workload, same capacity sizing rule,
same scenario — on the discrete-event simulator, which is the
cross-backend parity check in benchmark form.

Reports controller MTTR (`ctl_mttr_ms`) next to the client-observed
downtime measured from the request stream (`client_mttr_ms`), both
computed by the shared `core/metrics.py` aggregation.
"""

from __future__ import annotations


def run(quick: bool = True, backend: str = "testbed"):
    import math

    from repro.experiment import (ExperimentSpec, primary_kill_scenario,
                                  run_experiment)

    archs = (["qwen2.5-3b", "rwkv6-3b"] if quick else
             ["qwen2.5-3b", "rwkv6-3b", "recurrentgemma-2b",
              "qwen3-moe-30b-a3b"])
    policies = (["faillite", "full-warm-k"] if quick
                else ["faillite", "full-warm", "full-cold", "full-warm-k"])
    print("# fig7: backend,policy,n,recovery_rate,ctl_mttr_ms,"
          "acc_red_pct,detect_ms,client_mttr_ms")
    rows = []
    for policy in policies:
        spec = ExperimentSpec(
            backend=backend, policy=policy, app_mix="arch", archs=archs,
            apps_per_arch=1, seed=2, n_sites=3, servers_per_site=2,
            headroom=0.3, client_hz=10.0, time_scale=0.25,
            settle_s=(None if backend == "sim" else 25.0),
            scenario="primary-kill",
            scenario_builder=primary_kill_scenario())
        res = run_experiment(spec)
        s = res.overall
        t = res.traffic
        down_ms = (t.client_mttr_avg * 1e3
                   if t and math.isfinite(t.client_mttr_avg)
                   else float("nan"))
        detect_ms = (res.detect_latency_s * 1e3
                     if math.isfinite(res.detect_latency_s) else 0.0)
        rows.append((policy, s["n"], s["recovery_rate"],
                     s["mttr_avg"] * 1e3,
                     s["accuracy_reduction"] * 100, detect_ms, down_ms))
        print(f"fig7,{backend},{policy},{s['n']},"
              f"{s['recovery_rate']:.2f},{s['mttr_avg']*1e3:.0f},"
              f"{s['accuracy_reduction']*100:.2f},{detect_ms:.0f},"
              f"{down_ms:.0f}")
    return rows


if __name__ == "__main__":
    run(quick=False)
