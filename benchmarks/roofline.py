"""Roofline table — reads the dry-run records (experiments/dryrun/) and
prints the per-(arch x shape x mesh) three-term roofline with dominant
bottleneck, MODEL_FLOPS/HLO_FLOPS ratio, and per-device memory."""

from __future__ import annotations

import json
from pathlib import Path

DRYRUN_DIR = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


def load_records(mesh: str = None):
    recs = []
    if not DRYRUN_DIR.exists():
        return recs
    for d in sorted(DRYRUN_DIR.iterdir()):
        if not d.is_dir():
            continue
        if mesh and d.name != mesh:
            continue
        for f in sorted(d.glob("*.json")):
            rec = json.loads(f.read_text())
            if "roofline" in rec:
                recs.append(rec)
    return recs


def run(quick: bool = True):
    recs = load_records()
    if not recs:
        print("# roofline: no dry-run records — run "
              "`python -m repro.launch.dryrun --all` first")
        return []
    print("# roofline: mesh,arch,shape,compute_ms,memory_ms,coll_ms,"
          "dominant,useful_frac,mem_per_dev_gib,fits_16g")
    rows = []
    for rec in recs:
        r = rec["roofline"]
        m = rec["memory"]["per_device_total"] / 2**30
        fits = m <= 16.0
        rows.append(r)
        print(f"roofline,{rec['mesh']},{rec['arch']},{rec['shape']},"
              f"{r['compute_s']*1e3:.2f},{r['memory_s']*1e3:.2f},"
              f"{r['collective_s']*1e3:.2f},{r['dominant']},"
              f"{r['useful_flop_frac']:.3f},{m:.2f},{int(fits)}")
    # aggregate: dominant-term histogram
    from collections import Counter
    doms = Counter(r["dominant"] for r in rows)
    print(f"roofline,summary,dominant_hist,{dict(doms)}")
    return rows


def markdown_tables(mesh: str = "16x16") -> str:
    """Markdown roofline tables (EXPERIMENTS.md §Roofline source)."""
    recs = [r for r in load_records(mesh)]
    by_shape = {}
    for r in recs:
        by_shape.setdefault(r["shape"], []).append(r)
    out = []
    for shape in ("train_4k", "prefill_32k", "decode_32k", "long_500k"):
        if shape not in by_shape:
            continue
        out.append(f"\n### {shape} ({mesh}, per step)\n")
        out.append("| arch | compute | memory | collective | dominant "
                   "| useful | mem/dev | mb |")
        out.append("|---|---|---|---|---|---|---|---|")
        for rec in sorted(by_shape[shape], key=lambda x: x["arch"]):
            r = rec["roofline"]
            m = rec["memory"]["per_device_total"] / 2**30
            unit = 1e3  # ms
            out.append(
                f"| {rec['arch']} | {r['compute_s']*unit:.2f} ms "
                f"| {r['memory_s']*unit:.2f} ms "
                f"| {r['collective_s']*unit:.2f} ms "
                f"| {r['dominant']} | {r['useful_flop_frac']:.2f} "
                f"| {m:.1f} GiB | {rec.get('microbatches', 1)} |")
    return "\n".join(out)


if __name__ == "__main__":
    import sys
    if len(sys.argv) > 1 and sys.argv[1] == "--markdown":
        print(markdown_tables())
    else:
        run(quick=False)
