"""Fig. 11 — edge-site-wide failures: fail 1..7 of 10 sites with the
site-independence constraint enabled (§3.4)."""

from __future__ import annotations


def run(quick: bool = True):
    from repro.core.simulation import SimConfig, Simulation

    fails = [1, 5] if quick else [1, 2, 3, 4, 5, 6, 7]
    policies = ["faillite", "full-cold"] if quick else \
        ["faillite", "full-warm", "full-cold", "full-warm-k"]
    print("# fig11: policy,failed_sites,recovery_rate,mttr_ms,acc_red_pct")
    rows = []
    for policy in policies:
        for nf in fails:
            # controller metrics only: skip the traffic plane
            cfg = SimConfig(n_sites=10, servers_per_site=10 if not quick
                            else 3, policy=policy, seed=0, headroom=0.2,
                            site_independence=True,
                            traffic_rate_scale=0.0)
            sim = Simulation(cfg).setup()
            sites = list(sim.cluster.sites)[:nf]
            res = sim.inject_failure(sites=sites)
            rows.append((policy, nf, res.recovery_rate,
                         res.mttr_avg * 1e3,
                         res.accuracy_reduction * 100))
            print(f"fig11,{policy},{nf},{res.recovery_rate:.3f},"
                  f"{res.mttr_avg*1e3:.0f},"
                  f"{res.accuracy_reduction*100:.2f}")
    return rows


if __name__ == "__main__":
    run(quick=False)
