"""Benchmark harness — one module per paper figure/table + roofline.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only fig8,...]

Emits `name,...` CSV lines per benchmark (quick mode by default; --full
reproduces the paper-scale sweeps).
"""

from __future__ import annotations

import argparse
import inspect
import sys
import time
import traceback

MODULES = [
    "fig2_tradeoff",
    "fig5_failover",
    "fig8_headroom",
    "fig9_criticality",
    "fig10_families",
    "fig11_sites",
    "fig12_scalability",
    "fig_mttr_breakdown",
    "ilp_vs_heuristic",
    "scenarios",
    "kernels_bench",
    "roofline",
    "fig7_recovery",      # last: slowest (real testbed)
]

# JAX-compile / wall-clock heavy modules excluded from CI --smoke runs
HEAVY = {"kernels_bench", "roofline", "fig7_recovery"}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale sweeps (slow)")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of modules")
    ap.add_argument("--skip-testbed", action="store_true",
                    help="skip the wall-clock mini-testbed benchmark")
    ap.add_argument("--backend", default=None,
                    choices=["sim", "testbed"],
                    help="execution backend for the experiment-API "
                         "figures (fig5/fig7/scenarios); each keeps its "
                         "native default otherwise")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: quick mode over every figure script, "
                         "skipping compile-heavy kernel/testbed benches; "
                         "catches benchmark bit-rot without asserting "
                         "numbers")
    args = ap.parse_args()

    mods = MODULES
    if args.smoke:
        args.full = False
        mods = [m for m in mods if m not in HEAVY]
    if args.only:
        want = set(args.only.split(","))
        mods = [m for m in MODULES if m in want]
    if args.skip_testbed:
        mods = [m for m in mods if m != "fig7_recovery"]

    failures = 0
    for name in mods:
        print(f"\n=== {name} {'(full)' if args.full else '(quick)'} ===",
              flush=True)
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["run"])
            kw = {}
            if (args.backend is not None
                    and "backend" in inspect.signature(mod.run).parameters):
                kw["backend"] = args.backend
            mod.run(quick=not args.full, **kw)
            print(f"=== {name} done in {time.time()-t0:.1f}s ===",
                  flush=True)
        except Exception:
            failures += 1
            traceback.print_exc()
            print(f"=== {name} FAILED ===", flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
