"""Fig. 12 — planner scalability: wall time of Algorithm 1 vs number of
applications / servers / variants (paper fixes 500 servers, 1000 apps,
4 variants and sweeps each), now per registered policy.

The sweep runs every realtime planner from the registry (vectorized
`greedy`, the `legacy-greedy` loop oracle, `load-aware`, site-sharded
`sharded`) on identical instances. The fleet-scale stage is NOT an
ad-hoc sweep: it replays the exact (servers x apps) cells from
tools/bench_scale.py through that harness's own `run_cell`, so the
numbers behind the paper figure and the numbers the CI trend gate
checks (BENCH_scale*.json via tools/check_trend.py) come from one
code path and can never disagree."""

from __future__ import annotations

import importlib.util
import random
import sys
import time
from pathlib import Path

POLICIES = ("greedy", "legacy-greedy", "load-aware", "sharded")


def _load_bench_scale():
    """tools/ is not a package; load the scale harness by path."""
    root = Path(__file__).resolve().parents[1]
    spec = importlib.util.spec_from_file_location(
        "bench_scale", root / "tools" / "bench_scale.py")
    mod = importlib.util.module_from_spec(spec)
    sys.modules["bench_scale"] = mod
    spec.loader.exec_module(mod)
    return mod


def _instance(n_apps, n_servers, n_variants):
    from repro.core.cluster import make_cluster
    from repro.core.variants import Application, synthetic_family

    rng = random.Random(0)
    cluster = make_cluster(max(1, n_servers // 10), 10, mem=64e9)
    apps = []
    for i in range(n_apps):
        lad = synthetic_family(f"f{i}", rng.uniform(1e9, 4e9),
                               n_variants=n_variants)
        apps.append(Application(id=f"a{i}", family=f"f{i}",
                                variants=lad,
                                request_rate=rng.uniform(0.5, 2)))
    return apps, cluster


def _bench(policy, n_apps, n_servers, n_variants):
    from repro.core.planner import PlanRequest, get_planner

    apps, cluster = _instance(n_apps, n_servers, n_variants)
    t0 = time.perf_counter()
    res = get_planner(policy).plan(PlanRequest(apps=apps, cluster=cluster))
    dt = time.perf_counter() - t0
    return dt, len(res.assignment)


def run(quick: bool = True):
    apps_sweep = [100, 1000] if quick else [100, 500, 1000, 2000, 3000]
    srv_sweep = [50, 100] if quick else [100, 250, 500, 750, 1000]
    var_sweep = [4] if quick else [2, 4, 6, 8]

    print("# fig12: sweep,value,policy,wall_s,placed")
    rows = []
    for n in apps_sweep:
        for pol in POLICIES:
            dt, placed = _bench(pol, n, 100, 4)
            rows.append(("apps", n, pol, dt, placed))
            print(f"fig12,apps,{n},{pol},{dt:.4f},{placed}")
    for n in srv_sweep:
        for pol in POLICIES:
            dt, placed = _bench(pol, 1000, n, 4)
            rows.append(("servers", n, pol, dt, placed))
            print(f"fig12,servers,{n},{pol},{dt:.4f},{placed}")
    for n in var_sweep:
        for pol in POLICIES:
            dt, placed = _bench(pol, 1000, 100, n)
            rows.append(("variants", n, pol, dt, placed))
            print(f"fig12,variants,{n},{pol},{dt:.4f},{placed}")

    # fleet-scale stage: the SAME cells and measurement function the
    # committed BENCH_scale*.json trend (and its CI gate) are built
    # from — figure and gate share one code path by construction
    bs = _load_bench_scale()
    cells = bs.SMOKE_CELLS if quick else bs.FULL_CELLS
    print("# fig12-scale: n_servers,n_apps,events_per_sec,"
          "plan_wall_peak_s,recovery_rate")
    for cell in cells:
        r = bs.run_cell(cell, "epoch")
        rows.append(("scale", cell["n_servers"], cell["n_apps"],
                     r["events_per_sec"], r["plan_wall_peak_s"],
                     r["recovery_rate"]))
        print(f"fig12-scale,{cell['n_servers']},{cell['n_apps']},"
              f"{r['events_per_sec']:.0f},{r['plan_wall_peak_s']:.4f},"
              f"{r['recovery_rate']:.3f}")
    return rows


if __name__ == "__main__":
    run(quick=False)
