"""Fig. 12 — planner scalability: wall time of Algorithm 1 vs number of
applications / servers / variants (paper fixes 500 servers, 1000 apps,
4 variants and sweeps each), now per registered policy.

The sweep runs every realtime planner from the registry (vectorized
`greedy`, the `legacy-greedy` loop oracle, `load-aware`) on identical
instances, and a second stage reports end-to-end recovery: MTTR and
cumulative planner wall time for a single-server failure at fleet
scale (>= 1000 apps / 100 servers in quick mode, beyond in --full)."""

from __future__ import annotations

import random
import time

POLICIES = ("greedy", "legacy-greedy", "load-aware")


def _instance(n_apps, n_servers, n_variants):
    from repro.core.cluster import make_cluster
    from repro.core.variants import Application, synthetic_family

    rng = random.Random(0)
    cluster = make_cluster(max(1, n_servers // 10), 10, mem=64e9)
    apps = []
    for i in range(n_apps):
        lad = synthetic_family(f"f{i}", rng.uniform(1e9, 4e9),
                               n_variants=n_variants)
        apps.append(Application(id=f"a{i}", family=f"f{i}",
                                variants=lad,
                                request_rate=rng.uniform(0.5, 2)))
    return apps, cluster


def _bench(policy, n_apps, n_servers, n_variants):
    from repro.core.planner import PlanRequest, get_planner

    apps, cluster = _instance(n_apps, n_servers, n_variants)
    t0 = time.perf_counter()
    res = get_planner(policy).plan(PlanRequest(apps=apps, cluster=cluster))
    dt = time.perf_counter() - t0
    return dt, len(res.assignment)


def _mttr_point(n_servers, server_mem, planner, seed=0):
    """End-to-end: one server failure at fleet scale; returns
    (#apps, planner wall time inside the controller, controller MTTR)."""
    from repro.core.simulation import SimConfig, Simulation

    cfg = SimConfig(n_sites=max(1, n_servers // 10), servers_per_site=10,
                    server_mem=server_mem, planner=planner, seed=seed,
                    traffic_rate_scale=0.0)
    sim = Simulation(cfg).setup()
    victim = max(sim.cluster.alive_servers(),
                 key=lambda s: sum(1 for i in s.instances.values()
                                   if i.role == "primary"))
    res = sim.inject_failure(servers=[victim.id], run_for=30.0)
    return (len(sim.controller.apps), sim.controller.plan_wall_s,
            res.mttr_avg)


def run(quick: bool = True):
    apps_sweep = [100, 1000] if quick else [100, 500, 1000, 2000, 3000]
    srv_sweep = [50, 100] if quick else [100, 250, 500, 750, 1000]
    var_sweep = [4] if quick else [2, 4, 6, 8]

    print("# fig12: sweep,value,policy,wall_s,placed")
    rows = []
    for n in apps_sweep:
        for pol in POLICIES:
            dt, placed = _bench(pol, n, 100, 4)
            rows.append(("apps", n, pol, dt, placed))
            print(f"fig12,apps,{n},{pol},{dt:.4f},{placed}")
    for n in srv_sweep:
        for pol in POLICIES:
            dt, placed = _bench(pol, 1000, n, 4)
            rows.append(("servers", n, pol, dt, placed))
            print(f"fig12,servers,{n},{pol},{dt:.4f},{placed}")
    for n in var_sweep:
        for pol in POLICIES:
            dt, placed = _bench(pol, 1000, 100, n)
            rows.append(("variants", n, pol, dt, placed))
            print(f"fig12,variants,{n},{pol},{dt:.4f},{placed}")

    # planner wall time alongside MTTR, end-to-end at fleet scale:
    # 100 servers sized so ~1000 primaries place (~2.3 GB avg full model)
    print("# fig12-mttr: n_servers,policy,n_apps,planner_wall_s,mttr_s")
    mttr_points = [(100, 48e9)] if quick else [(100, 48e9), (200, 48e9)]
    for n_servers, mem in mttr_points:
        for pol in ("greedy", "load-aware"):
            n_apps, wall, mttr = _mttr_point(n_servers, mem, pol)
            rows.append(("mttr", n_servers, pol, wall, n_apps, mttr))
            print(f"fig12-mttr,{n_servers},{pol},{n_apps},"
                  f"{wall:.4f},{mttr:.4f}")
    return rows


if __name__ == "__main__":
    run(quick=False)
