"""Fig. 12 — heuristic scalability: wall time of Algorithm 1 vs number of
applications / servers / variants (paper fixes 500 servers, 1000 apps,
4 variants and sweeps each)."""

from __future__ import annotations

import random
import time


def run(quick: bool = True):
    from repro.core.cluster import make_cluster
    from repro.core.heuristic import faillite_heuristic
    from repro.core.variants import Application, synthetic_family

    def bench(n_apps, n_servers, n_variants):
        rng = random.Random(0)
        cluster = make_cluster(max(1, n_servers // 10), 10, mem=64e9)
        apps = []
        for i in range(n_apps):
            lad = synthetic_family(f"f{i}", rng.uniform(1e9, 4e9),
                                   n_variants=n_variants)
            apps.append(Application(id=f"a{i}", family=f"f{i}",
                                    variants=lad,
                                    request_rate=rng.uniform(0.5, 2)))
        t0 = time.perf_counter()
        res = faillite_heuristic(apps, cluster)
        dt = time.perf_counter() - t0
        return dt, len(res.assignment)

    apps_sweep = [100, 1000] if quick else [100, 500, 1000, 2000, 3000]
    srv_sweep = [100, 500] if quick else [100, 250, 500, 750, 1000]
    var_sweep = [2, 4] if quick else [2, 4, 6, 8]

    print("# fig12: sweep,value,wall_s,placed")
    rows = []
    for n in apps_sweep:
        dt, placed = bench(n, 500, 4)
        rows.append(("apps", n, dt, placed))
        print(f"fig12,apps,{n},{dt:.3f},{placed}")
    for n in srv_sweep:
        dt, placed = bench(1000, n, 4)
        rows.append(("servers", n, dt, placed))
        print(f"fig12,servers,{n},{dt:.3f},{placed}")
    for n in var_sweep:
        dt, placed = bench(1000, 500, n)
        rows.append(("variants", n, dt, placed))
        print(f"fig12,variants,{n},{dt:.3f},{placed}")
    return rows


if __name__ == "__main__":
    run(quick=False)
