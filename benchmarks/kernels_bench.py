"""Kernel microbenchmarks: Pallas (interpret) vs jnp reference wall time
and numerical agreement on CPU.  On-TPU timing is not available in this
container; the roofline deltas for the kernels are argued structurally in
EXPERIMENTS.md §Perf (blockwise HBM traffic vs materialized scores)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp


def _time(fn, *args, reps=3):
    fn(*args)                      # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.tree_util.tree_map(lambda x: x.block_until_ready(), out)
    return (time.perf_counter() - t0) / reps


def run(quick: bool = True):
    print("# kernels: name,case,ref_us,kernel_interpret_us,max_err")
    ks = jax.random.split(jax.random.PRNGKey(0), 6)
    rows = []

    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.flash_attention.ref import attention_ref
    B, S, H, KVH, hd = (1, 128, 4, 2, 64) if quick else (2, 512, 8, 2, 64)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, KVH, hd))
    v = jax.random.normal(ks[2], (B, S, KVH, hd))
    ref = jax.jit(lambda q, k, v: attention_ref(
        jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2),
        jnp.swapaxes(v, 1, 2)))
    t_ref = _time(ref, q, k, v)
    t_k = _time(lambda q, k, v: flash_attention(q, k, v, interpret=True),
                q, k, v)
    err = float(jnp.max(jnp.abs(
        jnp.swapaxes(ref(q, k, v), 1, 2)
        - flash_attention(q, k, v, interpret=True))))
    rows.append(("flash_attention", f"B{B}S{S}H{H}", t_ref, t_k, err))

    from repro.kernels.int8_matmul.ops import int8_matmul, quantize_int8
    from repro.kernels.int8_matmul.ref import int8_matmul_ref
    M, K, N = (128, 256, 128) if quick else (512, 1024, 512)
    x = jax.random.normal(ks[3], (M, K))
    w = jax.random.normal(ks[4], (K, N)) * 0.05
    wq, sc = quantize_int8(w)
    t_ref = _time(jax.jit(int8_matmul_ref), x, wq, sc)
    t_k = _time(lambda x, wq, sc: int8_matmul(x, wq, sc, interpret=True),
                x, wq, sc)
    err = float(jnp.max(jnp.abs(int8_matmul_ref(x, wq, sc)
                                - int8_matmul(x, wq, sc, interpret=True))))
    rows.append(("int8_matmul", f"{M}x{K}x{N}", t_ref, t_k, err))

    for name, case, tr, tk, err in rows:
        print(f"kernels,{name},{case},{tr*1e6:.0f},{tk*1e6:.0f},{err:.2e}")
    return rows


if __name__ == "__main__":
    run(quick=False)
