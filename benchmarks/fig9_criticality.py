"""Fig. 9 — impact of application criticality K (0-100%): the
accuracy-MTTR trade-off curve for FailLite."""

from __future__ import annotations


def run(quick: bool = True):
    from repro.core.simulation import SimConfig, Simulation

    ks = [0.0, 0.5, 1.0] if quick else [0.0, 0.2, 0.4, 0.6, 0.8, 1.0]
    scale = dict(n_sites=4, servers_per_site=5) if quick else \
        dict(n_sites=10, servers_per_site=10)
    print("# fig9: K,recovery_rate,mttr_ms,acc_red_pct")
    rows = []
    for k in ks:
        # controller metrics only: skip the traffic plane
        cfg = SimConfig(critical_frac=k, policy="faillite", seed=0,
                        headroom=0.2, traffic_rate_scale=0.0, **scale)
        sim = Simulation(cfg).setup()
        victim = sim.rng.choice(sim.cluster.alive_servers()).id
        res = sim.inject_failure(servers=[victim])
        rows.append((k, res.recovery_rate, res.mttr_avg * 1e3,
                     res.accuracy_reduction * 100))
        print(f"fig9,{k:.1f},{res.recovery_rate:.3f},"
              f"{res.mttr_avg*1e3:.0f},{res.accuracy_reduction*100:.2f}")
    return rows


if __name__ == "__main__":
    run(quick=False)
