"""Fig. 5 — failover behavior by backup type, single application.

Warm vs cold(small) vs cold(large) vs FailLite progressive, as recovery
timelines from one `ExperimentSpec` per mode (thin client of
`repro.experiment`). Controller MTTR is reported next to the
request-level client-observed MTTR (§5.7 framing): the latter runs from
the crash instant until a client request actually succeeded again, so
it adds detection lead-in, route propagation, and arrival
discretization on top of the controller's view.

`backend="testbed"` replays the same four specs against live workers
with a real (reduced-config) model ladder — MTTRs become wall-clock
compile-bound load times.
"""

from __future__ import annotations

MODES = [
    ("warm", "faillite", True),
    ("cold-small", "full-cold", False),
    ("cold-large", "full-cold", False),
    ("progressive", "faillite", False),
]


def _ladder(backend: str):
    if backend == "testbed":
        from repro.experiment import testbed_ladder
        return testbed_ladder("qwen2.5-3b")
    from repro.core.variants import synthetic_family
    return synthetic_family("convnext", 5.0e9, n_variants=4, spread=6.0)


def run(quick: bool = True, backend: str = "sim"):
    from repro.core.variants import Application
    from repro.experiment import (ExperimentSpec, primary_kill_scenario,
                                  run_experiment)

    ladder = _ladder(backend)
    rows = []
    for mode, policy, critical in MODES:
        variants = ladder
        if mode == "cold-small":
            variants = [ladder[-1]]      # only the small model exists
        app = Application(id="app0", family=ladder[0].family,
                          variants=list(variants), critical=critical,
                          request_rate=2.0)
        spec = ExperimentSpec(
            backend=backend, policy=policy, n_sites=2,
            servers_per_site=2, headroom=0.45,
            traffic_rate_scale=100.0, client_hz=40.0, time_scale=0.25,
            settle_s=(None if backend == "sim" else 15.0),
            scenario="primary-kill",
            scenario_builder=primary_kill_scenario(), apps=[app])
        res = run_experiment(spec)
        rec = next(r for r in res.records if r.app_id == "app0")
        t = res.traffic
        client_mttr = (t.client_mttr_avg
                       if t is not None and t.n_windows else 0.0)
        dropped = t.n_dropped if t else 0
        rows.append((mode, rec.recovered, rec.mttr, client_mttr,
                     dropped, rec.variant, rec.accuracy))
    from repro.experiment.result import ms_sentinel
    print("# fig5: mode,recovered,ctl_mttr_ms,client_mttr_ms,"
          "req_dropped,variant,acc")
    for r in rows:
        print(f"fig5,{r[0]},{r[1]},{ms_sentinel(r[2]):.1f},"
              f"{ms_sentinel(r[3]):.1f},{r[4]},{r[5]},{r[6]:.4f}")
    return rows


if __name__ == "__main__":
    run(quick=False)
