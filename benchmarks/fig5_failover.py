"""Fig. 5 — failover behavior by backup type, single application.

Warm vs cold(small) vs cold(large) vs FailLite progressive, as recovery
timelines from the DES with testbed-profiled load constants.
"""

from __future__ import annotations


def run(quick: bool = True):
    from repro.core.simulation import (SimConfig, Simulation, EventQueue,
                                       SimLoadExecutor)
    from repro.core.variants import synthetic_family, Application

    ladder = synthetic_family("convnext", 5.0e9, n_variants=4, spread=6.0)
    rows = []
    for mode, policy, critical in [
        ("warm", "faillite", True),
        ("cold-small", "full-cold", False),
        ("cold-large", "full-cold", False),
        ("progressive", "faillite", False),
    ]:
        variants = ladder
        if mode == "cold-small":
            variants = [ladder[-1]]      # only the small model exists
        app = Application(id="app0", family="convnext",
                          variants=list(variants), critical=critical)
        cfg = SimConfig(n_sites=2, servers_per_site=2, policy=policy,
                        server_mem=16e9, headroom=0.45)
        sim = Simulation(cfg, apps=[app]).setup()
        victim = sim.controller.primaries["app0"]
        res = sim.inject_failure(servers=[victim])
        rec = res.records["app0"]
        rows.append((mode, rec.recovered, rec.mttr, rec.variant,
                     rec.accuracy))
    print("# fig5: mode,recovered,mttr_ms,variant,acc")
    for r in rows:
        print(f"fig5,{r[0]},{r[1]},{r[2]*1e3:.1f},{r[3]},{r[4]:.4f}")
    return rows


if __name__ == "__main__":
    run(quick=False)
