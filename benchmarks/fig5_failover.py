"""Fig. 5 — failover behavior by backup type, single application.

Warm vs cold(small) vs cold(large) vs FailLite progressive, as recovery
timelines from the DES with testbed-profiled load constants. Controller
MTTR is reported next to the request-level client-observed MTTR (§5.7
framing): the latter runs from the crash instant until a client request
actually succeeded again, so it adds detection lead-in, route
propagation, and arrival discretization on top of the controller's view.
"""

from __future__ import annotations


def run(quick: bool = True):
    from repro.core.simulation import (SimConfig, Simulation, EventQueue,
                                       SimLoadExecutor)
    from repro.core.variants import synthetic_family, Application

    ladder = synthetic_family("convnext", 5.0e9, n_variants=4, spread=6.0)
    rows = []
    for mode, policy, critical in [
        ("warm", "faillite", True),
        ("cold-small", "full-cold", False),
        ("cold-large", "full-cold", False),
        ("progressive", "faillite", False),
    ]:
        variants = ladder
        if mode == "cold-small":
            variants = [ladder[-1]]      # only the small model exists
        app = Application(id="app0", family="convnext",
                          variants=list(variants), critical=critical,
                          request_rate=2.0)
        cfg = SimConfig(n_sites=2, servers_per_site=2, policy=policy,
                        server_mem=16e9, headroom=0.45,
                        traffic_rate_scale=100.0)
        sim = Simulation(cfg, apps=[app]).setup()
        victim = sim.controller.primaries["app0"]
        res = sim.inject_failure(servers=[victim])
        rec = res.records["app0"]
        t = res.traffic
        # inf (never recovered / no windows recovered) prints as the
        # same -1.0 sentinel the controller MTTR column uses
        client_mttr = (t.client_mttr_avg
                       if t is not None and t.n_windows else 0.0)
        dropped = t.n_dropped if t else 0
        rows.append((mode, rec.recovered, rec.mttr, client_mttr,
                     dropped, rec.variant, rec.accuracy))
    print("# fig5: mode,recovered,ctl_mttr_ms,client_mttr_ms,"
          "req_dropped,variant,acc")
    import math
    for r in rows:
        ctl = r[2] * 1e3 if math.isfinite(r[2]) else -1.0
        cli = r[3] * 1e3 if math.isfinite(r[3]) else -1.0
        print(f"fig5,{r[0]},{r[1]},{ctl:.1f},{cli:.1f},"
              f"{r[4]},{r[5]},{r[6]:.4f}")
    return rows


if __name__ == "__main__":
    run(quick=False)
