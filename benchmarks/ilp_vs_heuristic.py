"""Warm-placement ILP (exact B&B) vs Algorithm 1: optimality gap and
wall time at testbed scale (the paper uses Gurobi for the proactive step
and the heuristic at simulation scale; this quantifies what the
heuristic gives up).

Both planners come from the registry and both report the Eq. 1
objective (accuracy · request_rate), so the gap compares like with
like."""

from __future__ import annotations

import random
import time


def run(quick: bool = True):
    from repro.core.cluster import make_cluster
    from repro.core.planner import PlanRequest, get_planner
    from repro.core.variants import Application, synthetic_family

    sizes = [(6, 8), (8, 12)] if quick else [(6, 8), (8, 12), (10, 20),
                                             (12, 30)]
    print("# ilp: servers,apps,ilp_obj,heur_obj,gap_pct,ilp_s,heur_s,"
          "ilp_optimal")
    rows = []
    ilp = get_planner("ilp", node_limit=300, time_limit_s=20.0)
    heur_planner = get_planner("greedy")
    for n_servers, n_apps in sizes:
        rng = random.Random(42)
        cluster = make_cluster(2, n_servers // 2, mem=12e9)
        apps = []
        for i in range(n_apps):
            lad = synthetic_family(f"f{i}", rng.uniform(1e9, 5e9),
                                   n_variants=4, spread=6.0)
            apps.append(Application(id=f"a{i}", family=f"f{i}",
                                    variants=lad, critical=True,
                                    request_rate=rng.uniform(0.5, 2.0)))
        primaries = {}
        servers = cluster.alive_servers()
        for i, a in enumerate(apps):
            sid = servers[i % len(servers)].id
            cluster.place(a.id, a.variants[-1], sid, "primary")
            primaries[a.id] = sid

        req = PlanRequest(apps=apps, cluster=cluster, primaries=primaries,
                          alpha=0.1)
        t0 = time.perf_counter()
        res = ilp.plan(req)
        t_ilp = time.perf_counter() - t0

        t0 = time.perf_counter()
        heur = heur_planner.plan(req)
        t_heur = time.perf_counter() - t0
        gap = 100.0 * (res.objective - heur.objective) \
            / max(res.objective, 1e-9)
        rows.append((n_servers, n_apps, res.objective, heur.objective,
                     gap, t_ilp, t_heur, res.optimal))
        print(f"ilp,{n_servers},{n_apps},{res.objective:.3f},"
              f"{heur.objective:.3f},{gap:.2f},{t_ilp:.2f},{t_heur:.4f},"
              f"{int(res.optimal)}")
    return rows


if __name__ == "__main__":
    run(quick=False)
