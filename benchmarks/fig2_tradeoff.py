"""Fig. 2 — accuracy-resource trade-off (a) and load time (b).

(a) every assigned arch's variant ladder: memory vs normalized accuracy.
(b) load-time model calibrated by a real measurement: host byte-copy
bandwidth (the disk->GPU analogue) + engine warmup constant.
"""

from __future__ import annotations

import time

import numpy as np


def measure_copy_bandwidth(mb: int = 256) -> float:
    src = np.random.bytes(mb * 2**20)
    t0 = time.perf_counter()
    dst = bytes(src)          # forced copy
    dt = time.perf_counter() - t0
    assert len(dst) == len(src)
    return mb * 2**20 / dt


def run(quick: bool = True):
    from repro import configs
    from repro.core.variants import build_ladder

    bw = measure_copy_bandwidth(64 if quick else 256)
    rows = []
    archs = configs.ARCHS[:4] if quick else configs.ARCHS
    for arch in archs:
        cfg = configs.get_config(arch)
        for v in build_ladder(cfg):
            rows.append((arch, v.name.split(":")[1],
                         v.mem_bytes / 2**30, v.accuracy,
                         v.load_time(bw)))
    print("# fig2: arch,variant,mem_gib,acc_norm,load_s "
          f"(measured copy bw {bw/1e9:.2f} GB/s)")
    for r in rows:
        print(f"fig2,{r[0]},{r[1]},{r[2]:.3f},{r[3]:.4f},{r[4]:.3f}")
    # headline check (paper: big memory cuts <-> small accuracy cuts)
    full = [r for r in rows if r[1] == "full"]
    small = [r for r in rows if r[1] == "w050-int8"]
    ratio = np.mean([s[2] / f[2] for s, f in zip(small, full)])
    dacc = np.mean([f[3] - s[3] for s, f in zip(small, full)])
    print(f"fig2,summary,w050-int8_vs_full,mem_ratio={ratio:.3f},"
          f"acc_drop={dacc*100:.2f}%")
    return rows


if __name__ == "__main__":
    run(quick=False)
