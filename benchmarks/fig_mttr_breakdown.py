"""MTTR breakdown — where recovery time actually goes once model-state
is explicit (beyond-paper companion to Fig. 5/7).

Replays `cold-load-storm` (site outage + degraded cloud uplink) on the
"edge" storage preset and decomposes every cold recovery's MTTR into
the model-state plane's phases:

    detect   crash -> detector declares the failure
    plan     planner wall time for the failover round
    queue    waited behind other transfers on the fetch-path links
    fetch    checkpoint byte-transfer (local disk / peer NIC / cloud)
    warmup   per-instance compile/alloc
    route    client push notification

across the policy matrix (protection policy x placement planner x
recovery scheduler). The queue column is the storm's signature: FIFO +
locality-blind placement piles transfers onto the shared uplink, while
the criticality scheduler + locality planner drain restores from local
disks first. `tools/bench_mttr.py` is the JSON/CI twin of this figure.
"""

from __future__ import annotations

CELLS = [
    ("faillite", None, "fifo"),
    ("faillite", None, "criticality"),
    ("faillite", "locality", "fifo"),
    ("faillite", "locality", "criticality"),
    ("full-cold", None, "fifo"),
]
PHASES = ("detect", "plan", "queue", "fetch", "warmup", "route")


def run(quick: bool = True):
    import math

    import numpy as np

    from repro.experiment import ExperimentSpec, run_experiment

    seeds = [0] if quick else [0, 1, 2]
    shape = (dict(n_sites=3, servers_per_site=4) if quick
             else dict(n_sites=4, servers_per_site=5))
    print("# fig_mttr_breakdown: policy,planner,scheduler,n_cold,"
          + ",".join(f"{p}_ms" for p in PHASES)
          + ",ctl_mttr_ms,client_p99_ms")
    rows = []
    for policy, planner, scheduler in CELLS:
        records, downs = [], []
        for seed in seeds:
            res = run_experiment(ExperimentSpec(
                scenario="cold-load-storm", storage="edge",
                policy=policy, planner=planner, scheduler=scheduler,
                seed=seed, headroom=0.2, **shape))
            records += list(res.records)
            downs += [w.client_downtime for w in res.traffic.windows
                      if w.recovered
                      and math.isfinite(w.client_downtime)]
        recovered = [r for r in records if r.recovered]
        cold = [r for r in recovered
                if r.mode.startswith("cold") and r.phases]
        means = {ph: (1e3 * sum(r.phases.get(ph, 0.0) for r in cold)
                      / max(len(cold), 1)) for ph in PHASES}
        ctl = 1e3 * sum(r.mttr for r in recovered) \
            / max(len(recovered), 1)
        p99 = (float(np.percentile(downs, 99)) * 1e3
               if downs else float("nan"))
        rows.append((policy, planner or "greedy", scheduler,
                     len(cold), means, ctl, p99))
        print(f"fig_mttr_breakdown,{policy},{planner or 'greedy'},"
              f"{scheduler},{len(cold)},"
              + ",".join(f"{means[p]:.1f}" for p in PHASES)
              + f",{ctl:.1f},{p99:.1f}", flush=True)

    # human-readable stacked view
    print("\npolicy/planner/scheduler        "
          + "".join(f"{p:>9s}" for p in PHASES) + "      ctl      p99")
    for policy, planner, scheduler, n, means, ctl, p99 in rows:
        label = f"{policy}/{planner}/{scheduler}"
        print(f"{label:32s}"
              + "".join(f"{means[p]:8.1f}m" for p in PHASES)
              + f"{ctl:8.1f}m{p99:8.1f}m")
    return rows


if __name__ == "__main__":
    run(quick=False)
