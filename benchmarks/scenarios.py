"""Scenario sweep — all four policies over the named failure-scenario
library (cascades, rolling rejoin, churn, flaky nodes, ...), as a thin
client of `repro.experiment`: one `ExperimentSpec` per cell, so the
same sweep runs on either backend (`--backend testbed` replays a
reduced cell matrix against live workers).

Beyond the paper's one-shot injections, every cell reports BOTH planes:

  * control plane, PER FAILURE EPOCH: recovery rate / controller MTTR /
    accuracy reduction, so repeated-failure degradation and
    re-protection recovery are visible;
  * request plane (what clients experienced, §5.7 framing): availability,
    client-observed MTTR, accuracy-weighted goodput, dropped/degraded/
    SLO-violated request counts, and latency percentiles.

Client-observed MTTR upper-bounds controller MTTR: clients keep failing
from the crash instant (before detection) until the re-route push
reaches them and a request actually succeeds.

    PYTHONPATH=src python -m benchmarks.run --only scenarios
"""

from __future__ import annotations

POLICIES = ("faillite", "full-warm", "full-cold", "full-warm-k")


def run(quick: bool = True, backend: str = "sim"):
    from repro.core.scenario import SCENARIOS
    from repro.experiment import ExperimentSpec, run_experiment
    from repro.experiment.result import ms_sentinel as _ms

    names = sorted(SCENARIOS)
    if quick:
        # keep every *required* scenario class, one representative each
        names = ["single-server", "site-outage", "cascade",
                 "rolling-with-rejoin", "churn-under-failure",
                 "tp-shard-storm"]
    if backend == "testbed":
        # live workers: compile-bound loads make the full matrix hours;
        # sweep the base case across policies at the smoke scale
        names = ["single-server"]
        base = ExperimentSpec.smoke("testbed")
    else:
        scale = (dict(n_sites=4, servers_per_site=5) if quick
                 else dict(n_sites=10, servers_per_site=10))
        base = ExperimentSpec(headroom=0.2, seed=0, **scale)

    print("# scenarios: scenario,policy,epoch,n,recovery_rate,"
          "ctl_mttr_ms,acc_red_pct,warm_cov,unplaced,"
          "req_dropped,client_mttr_ms")
    print("# scenarios-traffic: scenario,policy,req_offered,availability,"
          "client_mttr_ms,goodput,degraded,slo_viol,p50_ms,p99_ms")
    for name in names:
        for policy in POLICIES:
            res = run_experiment(base.with_(scenario=name, policy=policy))
            for ep, s in enumerate(res.per_epoch):
                mttr = (s["mttr_avg"] * 1e3
                        if s["mttr_avg"] != float("inf") else -1.0)
                te = (res.traffic.epoch_row(ep) if res.traffic
                      else {"n_dropped": 0, "client_mttr_avg": 0.0})
                print(f"scenarios,{name},{policy},{ep},{s['n']},"
                      f"{s['recovery_rate']:.3f},{mttr:.1f},"
                      f"{s['accuracy_reduction']*100:.2f},"
                      f"{res.warm_coverage:.2f},"
                      f"{res.unplaced_arrivals},"
                      f"{te['n_dropped']},"
                      f"{_ms(te['client_mttr_avg']):.1f}")
            o = res.overall
            mttr = (o["mttr_avg"] * 1e3
                    if o["mttr_avg"] != float("inf") else -1.0)
            t = res.traffic
            print(f"scenarios,{name},{policy},overall,{o['n']},"
                  f"{o['recovery_rate']:.3f},{mttr:.1f},"
                  f"{o['accuracy_reduction']*100:.2f},"
                  f"{res.warm_coverage:.2f},{res.unplaced_arrivals},"
                  f"{t.n_dropped if t else 0},"
                  f"{_ms(t.client_mttr_avg) if t else 0.0:.1f}")
            if t is not None:
                print(f"scenarios-traffic,{name},{policy},{t.n_offered},"
                      f"{t.availability:.5f},"
                      f"{_ms(t.client_mttr_avg):.1f},"
                      f"{t.goodput:.5f},{t.n_degraded},"
                      f"{t.n_slo_violated},{t.latency_p50*1e3:.1f},"
                      f"{t.latency_p99*1e3:.1f}")

    if backend != "testbed":
        # shard recovery ladder on tp-shard-storm (the tp_degree=1 sweep
        # above exercises ShardFail's monolith semantics; this cell
        # exercises the actual shard plane, core/shardgroup.py)
        print("# scenarios-shard: tp_degree,shard_policy,availability,"
              "client_mttr_ms,n_degrade,n_reshard,n_monolith")
        for policy in ("degrade", "reshard", "monolith"):
            res = run_experiment(base.with_(
                scenario="tp-shard-storm", storage="edge",
                tp_degree=2, shard_policy=policy))
            t, shard = res.traffic, res.extras.get("shard", {})
            acts = shard.get("actions", {})
            print(f"scenarios-shard,2,{policy},"
                  f"{t.availability:.5f},"
                  f"{_ms(t.client_mttr_avg):.1f},"
                  f"{acts.get('shard-degrade', 0)},"
                  f"{acts.get('shard-reshard', 0)},"
                  f"{acts.get('shard-monolith', 0)}")


if __name__ == "__main__":
    run(quick=True)
