"""Scenario sweep — all four policies over the named failure-scenario
library (cascades, rolling rejoin, churn, flaky nodes, ...).

Beyond the paper's one-shot injections: recovery-rate / MTTR / accuracy
are reported PER FAILURE EPOCH, so repeated-failure degradation and
re-protection recovery are visible.

    PYTHONPATH=src python -m benchmarks.run --only scenarios
"""

from __future__ import annotations


def run(quick: bool = True):
    from repro.core.scenario import SCENARIOS
    from repro.core.simulation import SimConfig, run_scenario_suite

    scale = (dict(n_sites=4, servers_per_site=5) if quick
             else dict(n_sites=10, servers_per_site=10))
    names = sorted(SCENARIOS)
    if quick:
        # keep every *required* scenario class, one representative each
        names = ["single-server", "site-outage", "cascade",
                 "rolling-with-rejoin", "churn-under-failure"]
    cfg = SimConfig(headroom=0.2, seed=0, **scale)

    print("# scenarios: scenario,policy,epoch,n,recovery_rate,"
          "mttr_ms,acc_red_pct,warm_cov,unplaced_arrivals")
    suite = run_scenario_suite(cfg, names=names)
    for name in names:
        for policy, res in suite[name].items():
            for ep, s in enumerate(res.per_epoch):
                mttr = (s["mttr_avg"] * 1e3
                        if s["mttr_avg"] != float("inf") else -1.0)
                print(f"scenarios,{name},{policy},{ep},{s['n']},"
                      f"{s['recovery_rate']:.3f},{mttr:.1f},"
                      f"{s['accuracy_reduction']*100:.2f},"
                      f"{res.warm_coverage:.2f},"
                      f"{res.unplaced_arrivals}")
            o = res.overall
            mttr = (o["mttr_avg"] * 1e3
                    if o["mttr_avg"] != float("inf") else -1.0)
            print(f"scenarios,{name},{policy},overall,{o['n']},"
                  f"{o['recovery_rate']:.3f},{mttr:.1f},"
                  f"{o['accuracy_reduction']*100:.2f},"
                  f"{res.warm_coverage:.2f},{res.unplaced_arrivals}")


if __name__ == "__main__":
    run(quick=True)
