#!/usr/bin/env python
"""Shard-failure ladder benchmark -> BENCH_shardfail.json.

Sweeps the shard recovery policy ladder (degrade / reshard / monolith
fallback) across tensor-parallel degrees on the tp-shard-storm scenario
— same cluster, same seeds, same ShardFail stream per cell — under the
paper-faithful "edge" storage topology (slices live on peers, monolith
variants pay the shared cloud uplink). Per (shard_policy, tp_degree)
cell it records client-observed MTTR, pooled client-downtime
percentiles, availability, goodput, and the shard plane's ladder-action
counters:

    PYTHONPATH=src python tools/bench_shardfail.py            # full
    PYTHONPATH=src python tools/bench_shardfail.py --smoke    # CI
    PYTHONPATH=src python tools/bench_shardfail.py --check-win

`--check-win` exits non-zero unless BOTH shard-aware rungs — degraded-TP
continuation AND reshard-onto-survivors — strictly beat the monolith
fallback on client-observed MTTR at EVERY swept tp_degree: the
acceptance gate for the shard plane.
"""

from __future__ import annotations

import argparse
import json
import math
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

SCENARIO = "tp-shard-storm"
POLICIES = ("degrade", "reshard", "monolith")
ACTIONS = ("shard-degrade", "shard-reshard", "shard-monolith")


def run_cell(policy, tp_degree, seeds, *, n_sites, servers_per_site,
             headroom):
    import numpy as np

    from repro.experiment import ExperimentSpec, run_experiment

    downs, n_unrec = [], 0
    client_mttr, avail, goodput, recov = [], [], [], []
    actions = {a: 0 for a in ACTIONS}
    action_mttrs = {a: [] for a in ACTIONS}
    for seed in seeds:
        spec = ExperimentSpec(
            scenario=SCENARIO, seed=seed, n_sites=n_sites,
            servers_per_site=servers_per_site, headroom=headroom,
            storage="edge", tp_degree=tp_degree, shard_policy=policy)
        res = run_experiment(spec)
        t = res.traffic
        downs += [w.client_downtime for w in t.windows
                  if w.recovered and math.isfinite(w.client_downtime)]
        n_unrec += t.n_unrecovered_windows
        if math.isfinite(t.client_mttr_avg):
            client_mttr.append(t.client_mttr_avg)
        avail.append(t.availability)
        goodput.append(t.goodput)
        recov.append(res.overall.get("recovery_rate", 1.0))
        shard = res.extras.get("shard", {})
        for a, n in shard.get("actions", {}).items():
            actions[a] = actions.get(a, 0) + n
        for a, s in shard.get("mttr_avg_s", {}).items():
            action_mttrs.setdefault(a, []).append(s)

    downs_a = np.asarray(downs, dtype=float)
    return {
        "shard_policy": policy,
        "tp_degree": tp_degree,
        "seeds": list(seeds),
        # client-observed MTTR averaged over seeds (-1 = never darkened)
        "client_mttr_ms": round(1e3 * float(np.mean(client_mttr)), 2)
        if client_mttr else -1.0,
        # pooled client-observed blackout percentiles (-1 = no windows)
        "client_p50_ms": round(float(np.percentile(downs_a, 50)) * 1e3, 2)
        if downs_a.size else -1.0,
        "client_p99_ms": round(float(np.percentile(downs_a, 99)) * 1e3, 2)
        if downs_a.size else -1.0,
        "availability": round(float(np.mean(avail)), 6),
        "goodput": round(float(np.mean(goodput)), 6),
        "recovery_rate": round(float(np.mean(recov)), 6),
        "n_windows": len(downs),
        "n_unrecovered_windows": n_unrec,
        # ladder actions taken + their control-plane MTTRs (seed-avg)
        **{f"n_{a.replace('shard-', '')}": n
           for a, n in sorted(actions.items())},
        **{f"mttr_{a.replace('shard-', '')}_ms":
           round(1e3 * float(np.mean(v)), 2) if v else -1.0
           for a, v in sorted(action_mttrs.items())},
    }


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_shardfail.json")
    ap.add_argument("--smoke", action="store_true",
                    help="one seed, tp=2 only, small cluster (CI)")
    ap.add_argument("--seeds", default=None,
                    help="comma-separated seed list")
    ap.add_argument("--check-win", action="store_true",
                    help="fail unless degrade AND reshard each strictly "
                         "beat monolith fallback on client MTTR at "
                         "every tp_degree")
    args = ap.parse_args()

    if args.seeds:
        seeds = [int(s) for s in args.seeds.split(",")]
    else:
        seeds = [0] if args.smoke else [0, 1, 2]
    shape = (dict(n_sites=3, servers_per_site=4, headroom=0.25)
             if args.smoke
             else dict(n_sites=4, servers_per_site=5, headroom=0.2))
    tp_degrees = (2,) if args.smoke else (2, 4)

    rows = []
    for tp in tp_degrees:
        for policy in POLICIES:
            row = run_cell(policy, tp, seeds, **shape)
            rows.append(row)
            print(f"shardfail,tp={tp},{policy},"
                  f"client_mttr={row['client_mttr_ms']}ms,"
                  f"p99={row['client_p99_ms']}ms,"
                  f"avail={row['availability']},"
                  f"degrade={row['n_degrade']},"
                  f"reshard={row['n_reshard']},"
                  f"monolith={row['n_monolith']}", flush=True)

    def cell(policy, tp):
        return next(r for r in rows if r["shard_policy"] == policy
                    and r["tp_degree"] == tp)

    gate = []
    for tp in tp_degrees:
        d, r, m = (cell("degrade", tp), cell("reshard", tp),
                   cell("monolith", tp))
        gate.append({
            "tp_degree": tp,
            "degrade_client_mttr_ms": d["client_mttr_ms"],
            "reshard_client_mttr_ms": r["client_mttr_ms"],
            "monolith_client_mttr_ms": m["client_mttr_ms"],
        })
    doc = {
        "bench": "shardfail",
        "description": "shard recovery ladder (core/shardgroup.py) on "
                       "tp-shard-storm under edge storage: degraded-TP "
                       "continuation vs reshard-onto-survivors vs "
                       "monolith fallback per tensor-parallel degree; "
                       "client MTTR averaged over seeds, downtime "
                       "percentiles pooled over seeds",
        "seeds": seeds,
        "cluster": shape,
        "unit": "milliseconds",
        "rows": rows,
        "gate": gate,
    }
    Path(args.out).write_text(json.dumps(doc, indent=2) + "\n")
    print(f"wrote {args.out}")
    for g in gate:
        print(f"  tp={g['tp_degree']}: "
              f"degrade {g['degrade_client_mttr_ms']}ms, "
              f"reshard {g['reshard_client_mttr_ms']}ms, "
              f"monolith {g['monolith_client_mttr_ms']}ms")

    if args.check_win:
        ok = all(
            0 <= g["degrade_client_mttr_ms"]
            < g["monolith_client_mttr_ms"]
            and 0 <= g["reshard_client_mttr_ms"]
            < g["monolith_client_mttr_ms"]
            for g in gate)
        if not ok:
            print("FAIL: a shard-aware rung did not strictly beat the "
                  "monolith fallback on client MTTR")
            return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
