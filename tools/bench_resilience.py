#!/usr/bin/env python
"""Request-plane resilience benchmark -> BENCH_resilience.json.

Runs the three resilience storms (retry-amplification,
thundering-herd-rejoin, metastable-overload) with the toolkit OFF and
ON — same cluster, same seeds, same scenario stream — and records, per
(scenario, resilience) cell, the client-observed latency percentiles,
the pooled client-downtime percentiles, availability, accuracy-weighted
goodput, and the new outcome-class counters:

    PYTHONPATH=src python tools/bench_resilience.py            # full
    PYTHONPATH=src python tools/bench_resilience.py --smoke    # CI
    PYTHONPATH=src python tools/bench_resilience.py --check-win

`--check-win` exits non-zero unless the toolkit strictly improves BOTH
the p99 latency proxy AND the accuracy-weighted goodput on the
retry-amplification storm — the acceptance gate for this layer.
"""

from __future__ import annotations

import argparse
import json
import math
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

STORMS = ("retry-amplification", "thundering-herd-rejoin",
          "metastable-overload")
GATE_STORM = "retry-amplification"


def run_cell(scenario, resilience, seeds, *, n_sites, servers_per_site,
             headroom):
    import numpy as np

    from repro.experiment import ExperimentSpec, run_experiment

    downs, n_unrec = [], 0
    lat_p50, lat_p99, avail, goodput = [], [], [], []
    counters = {"n_hedged_win": 0, "n_fast_failed": 0, "n_shed": 0,
                "n_retried": 0}
    for seed in seeds:
        spec = ExperimentSpec(
            scenario=scenario, seed=seed, n_sites=n_sites,
            servers_per_site=servers_per_site, headroom=headroom,
            resilience={"enabled": True} if resilience else None)
        t = run_experiment(spec).traffic
        downs += [w.client_downtime for w in t.windows
                  if w.recovered and math.isfinite(w.client_downtime)]
        n_unrec += t.n_unrecovered_windows
        lat_p50.append(t.latency_p50)
        lat_p99.append(t.latency_p99)
        avail.append(t.availability)
        goodput.append(t.goodput)
        for k in counters:
            counters[k] += getattr(t, k)

    downs_a = np.asarray(downs, dtype=float)
    return {
        "scenario": scenario,
        "resilience": "on" if resilience else "off",
        "seeds": list(seeds),
        # latency proxy over served requests, averaged over seeds
        "latency_p50_ms": round(1e3 * float(np.mean(lat_p50)), 3),
        "latency_p99_ms": round(1e3 * float(np.mean(lat_p99)), 3),
        # pooled client-observed blackout percentiles (-1 = no windows)
        "client_p50_ms": round(float(np.percentile(downs_a, 50)) * 1e3, 2)
        if downs_a.size else -1.0,
        "client_p99_ms": round(float(np.percentile(downs_a, 99)) * 1e3, 2)
        if downs_a.size else -1.0,
        "availability": round(float(np.mean(avail)), 6),
        "goodput": round(float(np.mean(goodput)), 6),
        "n_windows": len(downs),
        "n_unrecovered_windows": n_unrec,
        **counters,
    }


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_resilience.json")
    ap.add_argument("--smoke", action="store_true",
                    help="one seed, small cluster (CI)")
    ap.add_argument("--seeds", default=None,
                    help="comma-separated seed list")
    ap.add_argument("--check-win", action="store_true",
                    help="fail unless the toolkit strictly improves "
                         "p99 latency AND goodput on "
                         f"{GATE_STORM}")
    args = ap.parse_args()

    if args.seeds:
        seeds = [int(s) for s in args.seeds.split(",")]
    else:
        seeds = [0] if args.smoke else [0, 1, 2]
    shape = (dict(n_sites=3, servers_per_site=4, headroom=0.25)
             if args.smoke
             else dict(n_sites=4, servers_per_site=5, headroom=0.2))

    rows = []
    for scenario in STORMS:
        for resilience in (False, True):
            row = run_cell(scenario, resilience, seeds, **shape)
            rows.append(row)
            print(f"resilience,{scenario},{row['resilience']},"
                  f"p99={row['latency_p99_ms']}ms,"
                  f"goodput={row['goodput']},"
                  f"avail={row['availability']},"
                  f"hedged={row['n_hedged_win']},"
                  f"shed={row['n_shed']}", flush=True)

    def cell(scenario, resilience):
        return next(r for r in rows if r["scenario"] == scenario
                    and r["resilience"] == resilience)

    off, on = cell(GATE_STORM, "off"), cell(GATE_STORM, "on")
    doc = {
        "bench": "resilience",
        "description": "request-plane resilience toolkit "
                       "(core/resilience.py) on vs off across the "
                       "three resilience storms: latency percentiles "
                       "averaged over seeds, client-downtime "
                       "percentiles pooled over seeds",
        "seeds": seeds,
        "cluster": shape,
        "unit": "milliseconds",
        "rows": rows,
        "gate": {
            "scenario": GATE_STORM,
            "p99_off_ms": off["latency_p99_ms"],
            "p99_on_ms": on["latency_p99_ms"],
            "goodput_off": off["goodput"],
            "goodput_on": on["goodput"],
        },
    }
    Path(args.out).write_text(json.dumps(doc, indent=2) + "\n")
    print(f"wrote {args.out} "
          f"(p99 {off['latency_p99_ms']} -> {on['latency_p99_ms']} ms, "
          f"goodput {off['goodput']} -> {on['goodput']})")

    if args.check_win:
        ok = (on["latency_p99_ms"] < off["latency_p99_ms"]
              and on["goodput"] > off["goodput"])
        if not ok:
            print(f"FAIL: toolkit did not strictly win on {GATE_STORM} "
                  f"(p99 {off['latency_p99_ms']} -> "
                  f"{on['latency_p99_ms']} ms, goodput "
                  f"{off['goodput']} -> {on['goodput']})")
            return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
