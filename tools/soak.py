#!/usr/bin/env python
"""Randomized chaos soak -> BENCH_soak.json trend file.

Runs the seeded "chaos" scenario stream (core/chaos.py) through the
experiment API across N seeds, once per controller flavor — ``static``
(the fixed criticality rule) and ``autopilot`` (the adaptive-protection
loop, core/autopilot.py) — on the "edge" storage preset with diurnal
traffic, and folds each `RunResult.to_json_dict()` into one JSON
document: per-seed rows plus pooled p50/p99 client-MTTR, availability,
accuracy-weighted goodput, and warm-replica headroom aggregates.

    PYTHONPATH=src python tools/soak.py --seeds 0:20   # refresh trend
    PYTHONPATH=src python tools/soak.py --seeds 0:4 \
        --out soak_ci.json --dump-dir soak_dumps       # CI subset
    PYTHONPATH=src python tools/soak.py --seeds 0:20 --check-win

The sim is deterministic and machine-independent, so per-seed rows are
exactly reproducible anywhere — `tools/check_trend.py` compares a CI
run's rows against the committed trend inside tolerance bands.
`--check-win` exits non-zero unless the autopilot beats the static
policy on pooled p99 client MTTR or goodput at equal-or-lower mean warm
headroom — the tentpole's acceptance gate.
"""

from __future__ import annotations

import argparse
import json
import math
import sys
from pathlib import Path
from typing import List, Optional, Tuple

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

CONTROLLERS = ("static", "autopilot")

# one soak cell: a mid-size edge fleet under diurnal traffic on the
# constrained storage preset, recovery drained by criticality
SOAK_SPEC = dict(
    scenario="chaos", policy="faillite", storage="edge",
    scheduler="criticality", n_sites=3, servers_per_site=4,
    headroom=0.2, traffic_diurnal_amplitude=0.5,
    traffic_diurnal_period=120.0, settle_s=20.0)


def parse_seeds(text: str) -> List[int]:
    """"0:20" (half-open range) or "0,3,7" (explicit list)."""
    if ":" in text:
        lo, hi = (int(x) for x in text.split(":", 1))
        return list(range(lo, hi))
    return [int(s) for s in text.split(",") if s.strip()]


def run_one(seed: int, controller: str,
            dump_dir: Optional[Path] = None) -> Tuple[dict, List[float]]:
    from repro.experiment import ExperimentSpec, run_experiment

    spec = ExperimentSpec(seed=seed, autopilot=(controller == "autopilot"),
                          **SOAK_SPEC)
    res = run_experiment(spec)
    if dump_dir is not None:
        dump_dir.mkdir(parents=True, exist_ok=True)
        doc = {"spec": spec.to_dict(), **res.to_json_dict()}
        (dump_dir / f"soak_s{seed}_{controller}.json").write_text(
            json.dumps(doc, indent=1) + "\n")

    t = res.traffic
    downs = [w.client_downtime for w in t.windows
             if w.recovered and math.isfinite(w.client_downtime)]
    prot = res.extras.get("protection", {})
    row = {
        "seed": seed,
        "controller": controller,
        "recovery_rate": round(res.overall.get("recovery_rate", 1.0), 4),
        "availability": round(t.availability, 6),
        "goodput": round(t.goodput, 6),
        "n_offered": t.n_offered,
        "n_windows": t.n_windows,
        "n_unrecovered": t.n_unrecovered_windows,
        "client_p50_ms": _pct_ms(downs, 50),
        "client_p99_ms": _pct_ms(downs, 99),
        "warm_bytes_mean": round(prot.get("warm_bytes_mean", 0.0), 1),
        "n_warm_mean": round(prot.get("n_warm_mean", 0.0), 3),
    }
    return row, downs


def _pct_ms(vals: List[float], q: float) -> float:
    import numpy as np

    if not vals:
        return -1.0                      # repo-wide no-data sentinel
    return round(float(np.percentile(np.asarray(vals), q)) * 1e3, 3)


def _mean(vals: List[float]) -> float:
    return sum(vals) / len(vals) if vals else 0.0


def aggregate(rows: List[dict], downs: List[float]) -> dict:
    """Pooled percentiles + mean per-seed metrics for one controller."""
    return {
        "n_seeds": len(rows),
        "client_p50_ms": _pct_ms(downs, 50),
        "client_p99_ms": _pct_ms(downs, 99),
        "availability_mean": round(_mean([r["availability"]
                                          for r in rows]), 6),
        "goodput_mean": round(_mean([r["goodput"] for r in rows]), 6),
        "recovery_rate_mean": round(_mean([r["recovery_rate"]
                                           for r in rows]), 4),
        "warm_bytes_mean": round(_mean([r["warm_bytes_mean"]
                                        for r in rows]), 1),
        "n_windows": sum(r["n_windows"] for r in rows),
        "n_unrecovered": sum(r["n_unrecovered"] for r in rows),
    }


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_soak.json")
    ap.add_argument("--seeds", default="0:20",
                    help='"lo:hi" half-open range or comma list')
    ap.add_argument("--dump-dir", default=None, metavar="DIR",
                    help="write every RunResult JSON dump here "
                         "(CI uploads them as artifacts)")
    ap.add_argument("--check-win", action="store_true",
                    help="fail unless autopilot beats static on p99 "
                         "client MTTR or goodput at <= warm headroom")
    args = ap.parse_args()

    seeds = parse_seeds(args.seeds)
    dump_dir = Path(args.dump_dir) if args.dump_dir else None

    per_seed: List[dict] = []
    pooled = {c: [] for c in CONTROLLERS}
    for seed in seeds:
        for controller in CONTROLLERS:
            row, downs = run_one(seed, controller, dump_dir)
            per_seed.append(row)
            pooled[controller] += downs
            print(f"soak,seed={seed},{controller},"
                  f"p99={row['client_p99_ms']}ms,"
                  f"avail={row['availability']:.4f},"
                  f"goodput={row['goodput']:.4f},"
                  f"warm={row['warm_bytes_mean']/1e9:.1f}GB", flush=True)

    cells = {c: aggregate([r for r in per_seed if r["controller"] == c],
                          pooled[c]) for c in CONTROLLERS}
    st, ap_ = cells["static"], cells["autopilot"]
    comparison = {
        "p99_ratio_static_over_autopilot": (
            round(st["client_p99_ms"] / ap_["client_p99_ms"], 3)
            if ap_["client_p99_ms"] > 0 else -1.0),
        "goodput_delta": round(ap_["goodput_mean"] - st["goodput_mean"],
                               6),
        "availability_delta": round(ap_["availability_mean"]
                                    - st["availability_mean"], 6),
        "warm_bytes_ratio": (
            round(ap_["warm_bytes_mean"] / st["warm_bytes_mean"], 4)
            if st["warm_bytes_mean"] > 0 else -1.0),
    }
    doc = {
        "bench": "soak",
        "description": "seeded chaos-stream soak: static vs autopilot "
                       "protection on the 'edge' preset with diurnal "
                       "traffic; per-seed rows are exactly reproducible "
                       "(deterministic sim), pooled percentiles over "
                       "all client downtime windows",
        "config": SOAK_SPEC,
        "seeds": seeds,
        "unit": "milliseconds",
        "per_seed": per_seed,
        "cells": cells,
        "autopilot_vs_static": comparison,
    }
    Path(args.out).write_text(json.dumps(doc, indent=2) + "\n")
    print(f"wrote {args.out} (p99 ratio "
          f"{comparison['p99_ratio_static_over_autopilot']}x, "
          f"goodput delta {comparison['goodput_delta']:+.4f}, "
          f"warm ratio {comparison['warm_bytes_ratio']}x)")

    if args.check_win:
        wins = (comparison["p99_ratio_static_over_autopilot"] > 1.0
                or comparison["goodput_delta"] > 0.0)
        cheaper = (comparison["warm_bytes_ratio"] >= 0
                   and comparison["warm_bytes_ratio"] <= 1.0)
        if not (wins and cheaper):
            print(f"FAIL: autopilot must win on p99 or goodput at "
                  f"equal-or-lower warm headroom; got {comparison}")
            return 1
        print("ok: autopilot wins at equal-or-lower warm headroom")
    return 0


if __name__ == "__main__":
    sys.exit(main())
