#!/usr/bin/env python
"""MTTR-breakdown benchmark -> BENCH_mttr.json.

Runs the `cold-load-storm` scenario (site outage + degraded cloud
uplink) on the "edge" storage preset across the model-state plane's
policy matrix — protection policy x placement planner x recovery
scheduler — and records, per cell, the controller MTTR, the pooled
client-observed downtime percentiles, and the mean MTTR phase
decomposition (detect / plan / queue / fetch / warmup / route):

    PYTHONPATH=src python tools/bench_mttr.py                 # full
    PYTHONPATH=src python tools/bench_mttr.py --smoke         # CI
    PYTHONPATH=src python tools/bench_mttr.py --check-p99-ratio 2.0

`--check-p99-ratio X` exits non-zero unless the criticality scheduler +
locality planner beat the FIFO + greedy baseline by an X-fold p99
client-observed MTTR — the acceptance gate for the model-state plane.
"""

from __future__ import annotations

import argparse
import json
import math
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

# (policy, planner, scheduler); None planner = the policy default
CELLS = [
    ("faillite", None, "fifo"),            # baseline
    ("faillite", None, "criticality"),
    ("faillite", "locality", "fifo"),
    ("faillite", "locality", "criticality"),
    ("full-cold", None, "fifo"),
]
BASELINE = ("faillite", None, "fifo")
TUNED = ("faillite", "locality", "criticality")

PHASES = ("detect", "plan", "queue", "fetch", "warmup", "route")


def run_cell(policy, planner, scheduler, seeds, *, n_sites,
             servers_per_site):
    import numpy as np

    from repro.experiment import ExperimentSpec, run_experiment

    records, downs, n_unrec = [], [], 0
    for seed in seeds:
        spec = ExperimentSpec(
            scenario="cold-load-storm", storage="edge", policy=policy,
            planner=planner, scheduler=scheduler, seed=seed,
            n_sites=n_sites, servers_per_site=servers_per_site,
            headroom=0.2)
        res = run_experiment(spec)
        records += list(res.records)
        downs += [w.client_downtime for w in res.traffic.windows
                  if w.recovered and math.isfinite(w.client_downtime)]
        n_unrec += res.traffic.n_unrecovered_windows

    recovered = [r for r in records if r.recovered]
    cold = [r for r in recovered if r.mode.startswith("cold")]
    phase_ms = {}
    for ph in PHASES:
        vals = [r.phases.get(ph, 0.0) for r in cold if r.phases]
        phase_ms[ph] = round(1e3 * sum(vals) / len(vals), 3) if vals \
            else 0.0
    sources = {}
    for r in cold:
        if r.source:
            sources[r.source] = sources.get(r.source, 0) + 1
    downs_a = np.asarray(downs, dtype=float)
    return {
        "policy": policy,
        "planner": planner or "greedy",
        "scheduler": scheduler,
        "n": len(records),
        "recovery_rate": round(len(recovered) / max(len(records), 1), 4),
        "ctl_mttr_ms": round(1e3 * sum(r.mttr for r in recovered)
                             / max(len(recovered), 1), 2),
        "client_p50_ms": round(float(np.percentile(downs_a, 50)) * 1e3, 2)
        if downs_a.size else -1.0,
        "client_p99_ms": round(float(np.percentile(downs_a, 99)) * 1e3, 2)
        if downs_a.size else -1.0,
        "n_windows": len(downs),
        "n_unrecovered_windows": n_unrec,
        "phase_ms": phase_ms,
        "sources": sources,
    }


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_mttr.json")
    ap.add_argument("--smoke", action="store_true",
                    help="one seed, small cluster (CI)")
    ap.add_argument("--seeds", default=None,
                    help="comma-separated seed list")
    ap.add_argument("--check-p99-ratio", type=float, default=None,
                    help="fail unless criticality+locality beats "
                         "fifo+greedy by this p99 client-MTTR factor")
    args = ap.parse_args()

    if args.seeds:
        seeds = [int(s) for s in args.seeds.split(",")]
    else:
        seeds = [0] if args.smoke else [0, 1, 2]
    shape = dict(n_sites=3, servers_per_site=4) if args.smoke \
        else dict(n_sites=4, servers_per_site=5)

    cells = []
    for policy, planner, scheduler in CELLS:
        row = run_cell(policy, planner, scheduler, seeds, **shape)
        cells.append(row)
        print(f"mttr,{policy},{row['planner']},{scheduler},"
              f"rec={row['recovery_rate']},"
              f"ctl={row['ctl_mttr_ms']}ms,"
              f"p99={row['client_p99_ms']}ms,"
              f"fetch={row['phase_ms']['fetch']}ms,"
              f"queue={row['phase_ms']['queue']}ms", flush=True)

    def cell(key):
        policy, planner, scheduler = key
        return next(c for c in cells if c["policy"] == policy
                    and c["planner"] == (planner or "greedy")
                    and c["scheduler"] == scheduler)

    base, tuned = cell(BASELINE), cell(TUNED)
    # -1.0 is the no-recovered-windows sentinel: a cell with no data is
    # a FAILURE of the gate, never a vacuous pass
    if base["client_p99_ms"] <= 0 or tuned["client_p99_ms"] <= 0:
        ratio = float("nan")
    else:
        ratio = base["client_p99_ms"] / tuned["client_p99_ms"]
    doc = {
        "bench": "mttr",
        "description": "cold-load-storm MTTR breakdown on the 'edge' "
                       "storage preset: protection policy x planner x "
                       "recovery scheduler; client percentiles pooled "
                       "over seeds, phases averaged over cold "
                       "recoveries",
        "scenario": "cold-load-storm",
        "storage": "edge",
        "seeds": seeds,
        "cluster": shape,
        "unit": "milliseconds",
        "cells": cells,
        "p99_speedup_fifo_greedy_vs_criticality_locality": round(ratio, 2),
    }
    Path(args.out).write_text(json.dumps(doc, indent=2) + "\n")
    print(f"wrote {args.out} (p99 speedup {ratio:.2f}x)")

    if args.check_p99_ratio is not None \
            and not ratio >= args.check_p99_ratio:
        print(f"FAIL: p99 speedup {ratio:.2f}x < "
              f"{args.check_p99_ratio}x")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
