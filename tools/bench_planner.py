#!/usr/bin/env python
"""Planner micro-benchmark -> BENCH_planner.json.

Times the legacy loop implementation of Algorithm 1 against the
vectorized planner (and the load-aware policy) across fleet sizes, plus
the Eq. 1-7 B&B ILP at testbed scale, and writes one JSON document the
perf trajectory can track:

    PYTHONPATH=src python tools/bench_planner.py                # full
    PYTHONPATH=src python tools/bench_planner.py --smoke        # CI
    PYTHONPATH=src python tools/bench_planner.py \
        --scales 1000:100 --check-speedup 5.0

Each scale point reports legacy/vectorized/load-aware wall time, the
legacy->vectorized speedup, placements, and the (identical) Eq. 1
objective. `--check-speedup X` exits non-zero unless the LARGEST scale
point reaches an X-fold speedup — the acceptance gate for the
array-backed planner refactor.
"""

from __future__ import annotations

import argparse
import json
import random
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

FULL_SCALES = [(100, 20), (250, 50), (500, 50), (1000, 100), (2000, 150)]
SMOKE_SCALES = [(50, 10), (200, 20)]
ILP_SIZES = [(6, 8), (8, 12)]           # (servers, apps), testbed scale


def make_instance(n_apps: int, n_servers: int, n_variants: int = 6,
                  seed: int = 0):
    from repro.core.cluster import make_cluster
    from repro.core.variants import Application, synthetic_family

    rng = random.Random(seed)
    cluster = make_cluster(max(1, n_servers // 10), min(n_servers, 10),
                           mem=64e9)
    apps = []
    for i in range(n_apps):
        lad = synthetic_family(f"f{i}", rng.uniform(1e9, 4e9),
                               n_variants=n_variants)
        apps.append(Application(id=f"a{i}", family=f"f{i}", variants=lad,
                                request_rate=rng.uniform(0.5, 2.0),
                                critical=rng.random() < 0.5))
    return apps, cluster


def time_planner(name: str, apps, cluster, repeats: int = 1,
                 **planner_kw) -> dict:
    from repro.core.planner import PlanRequest, get_planner

    planner = get_planner(name, **planner_kw)
    best, res = float("inf"), None
    for _ in range(repeats):
        t0 = time.perf_counter()
        res = planner.plan(PlanRequest(apps=apps, cluster=cluster,
                                       alpha=0.1))
        best = min(best, time.perf_counter() - t0)
    return {"wall_s": best, "placed": len(res.assignment),
            "objective": round(res.objective, 6)}


def bench_heuristics(scales, repeats: int) -> list:
    points = []
    for n_apps, n_servers in scales:
        apps, cluster = make_instance(n_apps, n_servers)
        row = {"n_apps": n_apps, "n_servers": n_servers}
        for name in ("legacy-greedy", "greedy", "load-aware"):
            r = time_planner(name, apps, cluster,
                             repeats=1 if name == "legacy-greedy"
                             else repeats)
            key = {"legacy-greedy": "legacy", "greedy": "vectorized",
                   "load-aware": "load_aware"}[name]
            row[f"{key}_s"] = round(r["wall_s"], 6)
            row[f"{key}_placed"] = r["placed"]
            if key in ("legacy", "vectorized"):
                row[f"{key}_objective"] = r["objective"]
        row["speedup"] = round(row["legacy_s"]
                               / max(row["vectorized_s"], 1e-12), 2)
        row["parity"] = (row["legacy_objective"]
                         == row["vectorized_objective"]
                         and row["legacy_placed"]
                         == row["vectorized_placed"])
        points.append(row)
        print(f"planner,{n_apps},{n_servers},"
              f"legacy={row['legacy_s']:.4f}s,"
              f"vectorized={row['vectorized_s']:.4f}s,"
              f"speedup={row['speedup']:.1f}x,"
              f"parity={int(row['parity'])}", flush=True)
    return points


def bench_backends(scales, repeats: int) -> list:
    """numpy vs jax planner backend, same instances as the heuristic
    sweep. Best-of-N repeats on one persistent planner, so the jax
    number excludes one-time kernel compilation (the failover-round
    steady state — a proactive round pays the compile in production;
    see docs/PLANNER.md)."""
    from repro.core.planner import have_jax

    if not have_jax():
        print("backend sweep skipped: jax not importable", flush=True)
        return []
    points = []
    for n_apps, n_servers in scales:
        apps, cluster = make_instance(n_apps, n_servers)
        r_np = time_planner("greedy", apps, cluster, repeats=repeats,
                            backend="numpy")
        r_jx = time_planner("greedy", apps, cluster,
                            repeats=max(repeats, 2), backend="jax")
        row = {"n_apps": n_apps, "n_servers": n_servers,
               "numpy_s": round(r_np["wall_s"], 6),
               "jax_s": round(r_jx["wall_s"], 6),
               "speedup": round(r_np["wall_s"]
                                / max(r_jx["wall_s"], 1e-12), 2),
               "parity": (r_np["objective"] == r_jx["objective"]
                          and r_np["placed"] == r_jx["placed"])}
        points.append(row)
        print(f"backend,{n_apps},{n_servers},"
              f"numpy={row['numpy_s']:.4f}s,jax={row['jax_s']:.4f}s,"
              f"speedup={row['speedup']:.1f}x,"
              f"parity={int(row['parity'])}", flush=True)
    return points


def bench_ilp(sizes) -> list:
    from repro.core.planner import PlanRequest, get_planner

    out = []
    for n_servers, n_apps in sizes:
        apps, cluster = make_instance(n_apps, n_servers, n_variants=4,
                                      seed=42)
        primaries = {}
        servers = cluster.alive_servers()
        for i, a in enumerate(apps):
            sid = servers[i % len(servers)].id
            cluster.place(a.id, a.variants[-1], sid, "primary")
            primaries[a.id] = sid
        req = PlanRequest(apps=apps, cluster=cluster, primaries=primaries,
                          alpha=0.1)
        t0 = time.perf_counter()
        ilp = get_planner("ilp", node_limit=300, time_limit_s=20.0).plan(req)
        t_ilp = time.perf_counter() - t0
        t0 = time.perf_counter()
        heur = get_planner("greedy").plan(req)
        t_heur = time.perf_counter() - t0
        gap = 100.0 * (ilp.objective - heur.objective) \
            / max(ilp.objective, 1e-9)
        out.append({"n_servers": n_servers, "n_apps": n_apps,
                    "ilp_s": round(t_ilp, 4), "heur_s": round(t_heur, 6),
                    "ilp_objective": round(ilp.objective, 6),
                    "heur_objective": round(heur.objective, 6),
                    "gap_pct": round(gap, 3),
                    "optimal": bool(ilp.optimal)})
        print(f"ilp,{n_servers},{n_apps},ilp={t_ilp:.2f}s,"
              f"heur={t_heur:.4f}s,gap={gap:.2f}%", flush=True)
    return out


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_planner.json")
    ap.add_argument("--smoke", action="store_true",
                    help="small scales for CI (no ILP beyond smallest)")
    ap.add_argument("--scales", default=None,
                    help="comma-separated apps:servers pairs")
    ap.add_argument("--repeats", type=int, default=3,
                    help="best-of repeats for the fast planners")
    ap.add_argument("--check-speedup", type=float, default=None,
                    help="fail unless the largest point reaches this "
                         "legacy->vectorized speedup")
    ap.add_argument("--backend", action="store_true", dest="backend_sweep",
                    default=None,
                    help="force the numpy-vs-jax backend sweep (default: "
                         "run it when jax imports; this flag makes a "
                         "missing jax a hard error)")
    ap.add_argument("--no-backend", action="store_false",
                    dest="backend_sweep", help="skip the backend sweep")
    args = ap.parse_args()

    if args.scales:
        scales = [tuple(int(x) for x in s.split(":"))
                  for s in args.scales.split(",")]
    else:
        scales = SMOKE_SCALES if args.smoke else FULL_SCALES
    ilp_sizes = ILP_SIZES[:1] if args.smoke else ILP_SIZES

    points = bench_heuristics(scales, args.repeats)
    backend = []
    if args.backend_sweep is not False:
        if args.backend_sweep:
            from repro.core.planner import have_jax
            assert have_jax(), "--backend requires jax"
        backend = bench_backends(scales, args.repeats)
    ilp = bench_ilp(ilp_sizes)

    doc = {
        "bench": "planner",
        "description": "Algorithm 1 legacy loop vs vectorized planner "
                       "wall time by fleet size; numpy vs jax planner "
                       "backend on the same instances; Eq. 1-7 B&B ILP "
                       "at testbed scale",
        "unit": "seconds",
        "heuristic": points,
        "backend": backend,
        "ilp": ilp,
    }
    Path(args.out).write_text(json.dumps(doc, indent=2) + "\n")
    print(f"wrote {args.out}")

    if not all(p["parity"] for p in points):
        print("FAIL: vectorized planner diverged from legacy", flush=True)
        return 1
    if not all(p["parity"] for p in backend):
        print("FAIL: jax planner backend diverged from numpy", flush=True)
        return 1
    if args.check_speedup is not None:
        top = max(points, key=lambda p: p["n_apps"])
        if top["speedup"] < args.check_speedup:
            print(f"FAIL: speedup {top['speedup']}x at "
                  f"{top['n_apps']} apps < {args.check_speedup}x")
            return 1
        print(f"ok: {top['speedup']}x >= {args.check_speedup}x at "
              f"{top['n_apps']} apps")
    return 0


if __name__ == "__main__":
    sys.exit(main())
