#!/usr/bin/env python
"""Tolerance-band trend gate: current benchmark vs committed trend.

CI runs a benchmark (tools/soak.py, tools/bench_mttr.py,
tools/bench_planner.py), then compares its JSON output against the
trend file committed in the repo:

    PYTHONPATH=src python tools/check_trend.py \
        --trend BENCH_soak.json --current soak_ci.json

Rows are matched by identity keys (seed+controller for soak, the
policy/planner/scheduler cell for mttr, the scale point for planner);
each matched row's metrics are compared directionally inside a
tolerance band — a HIGHER-is-better metric fails when the current
value drops below ``ref - max(abs_tol, rel_tol * |ref|)``, a
LOWER-is-better metric fails when it climbs above the mirrored bound,
an EQUAL metric fails on any difference. The repo-wide ``-1.0``
no-data sentinel is honored: sentinel->sentinel passes,
data->sentinel is a regression (the benchmark lost its signal),
sentinel->data is an improvement. Wall-clock fields are either
excluded or given very loose bands (machine-dependent); the sim
metrics themselves are deterministic and machine-independent, so CI
rows match the committed trend exactly until a code change moves them.

Exit status: 0 = inside every band, 1 = regression (or nothing
matched — a gate that compares zero rows must not pass vacuously).
"""

from __future__ import annotations

import argparse
import json
import sys
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, List, Tuple

SENTINEL = -1.0


@dataclass(frozen=True)
class Metric:
    """One gated column: direction + tolerance band."""
    key: str
    direction: str                 # "higher" | "lower" | "equal"
    rel_tol: float = 0.0
    abs_tol: float = 0.0


@dataclass(frozen=True)
class BenchSpec:
    """How to gate one benchmark document."""
    rows_key: str                  # where the row list lives in the doc
    id_keys: Tuple[str, ...]       # row identity (match key)
    metrics: Tuple[Metric, ...]


SPECS: Dict[str, BenchSpec] = {
    # per-seed soak rows: deterministic sim, so bands only absorb
    # intentional code-change drift reviewed alongside the trend update
    "soak": BenchSpec(
        rows_key="per_seed",
        id_keys=("seed", "controller"),
        metrics=(
            Metric("goodput", "higher", rel_tol=0.02, abs_tol=0.005),
            Metric("availability", "higher", abs_tol=0.005),
            Metric("client_p99_ms", "lower", rel_tol=0.25, abs_tol=50.0),
            Metric("recovery_rate", "higher", abs_tol=0.02),
            Metric("warm_bytes_mean", "lower", rel_tol=0.10,
                   abs_tol=0.5e9),
        )),
    # bench_mttr cells (policy x planner x scheduler)
    "mttr": BenchSpec(
        rows_key="cells",
        id_keys=("policy", "planner", "scheduler"),
        metrics=(
            Metric("recovery_rate", "higher", abs_tol=0.02),
            Metric("ctl_mttr_ms", "lower", rel_tol=0.15, abs_tol=10.0),
            Metric("client_p99_ms", "lower", rel_tol=0.20, abs_tol=25.0),
        )),
    # bench_resilience rows (storm x toolkit on/off): deterministic sim
    # metrics; latency/goodput bands absorb reviewed drift only
    "resilience": BenchSpec(
        rows_key="rows",
        id_keys=("scenario", "resilience"),
        metrics=(
            Metric("goodput", "higher", rel_tol=0.02, abs_tol=0.005),
            Metric("availability", "higher", abs_tol=0.01),
            Metric("latency_p99_ms", "lower", rel_tol=0.20, abs_tol=25.0),
            Metric("client_p99_ms", "lower", rel_tol=0.25, abs_tol=50.0),
        )),
    # bench_shardfail rows (shard_policy x tp_degree): deterministic
    # sim under edge storage; MTTR bands absorb reviewed drift only
    "shardfail": BenchSpec(
        rows_key="rows",
        id_keys=("shard_policy", "tp_degree"),
        metrics=(
            Metric("client_mttr_ms", "lower", rel_tol=0.20, abs_tol=25.0),
            Metric("client_p99_ms", "lower", rel_tol=0.25, abs_tol=50.0),
            Metric("availability", "higher", abs_tol=0.01),
            Metric("goodput", "higher", rel_tol=0.02, abs_tol=0.005),
            Metric("recovery_rate", "higher", abs_tol=0.02),
        )),
    # bench_scale cells (servers x apps): placements/recoveries are
    # deterministic and exact; throughput + planning wall are
    # wall-clock and machine-dependent -> very loose bands
    "scale": BenchSpec(
        rows_key="cells",
        id_keys=("n_servers", "n_apps"),
        metrics=(
            Metric("n_apps_placed", "equal"),
            Metric("recovery_rate", "higher", abs_tol=0.02),
            Metric("events_per_sec", "higher", rel_tol=0.8),
            Metric("speedup", "higher", rel_tol=0.8),
            Metric("plan_wall_peak_s", "lower", rel_tol=2.0,
                   abs_tol=0.05),
            # jax planner backend columns (absent in pre-backend trend
            # files — compare_rows skips missing metrics)
            Metric("plan_wall_peak_jax_s", "lower", rel_tol=2.0,
                   abs_tol=0.05),
            Metric("jax_plan_speedup", "higher", rel_tol=0.8),
        )),
    # bench_planner heuristic points: parity/placements are exact;
    # speedup is wall-clock and machine-dependent -> very loose band
    "planner": BenchSpec(
        rows_key="heuristic",
        id_keys=("n_apps", "n_servers"),
        metrics=(
            Metric("parity", "equal"),
            Metric("vectorized_placed", "equal"),
            Metric("vectorized_objective", "higher", rel_tol=1e-9,
                   abs_tol=1e-6),
            Metric("speedup", "higher", rel_tol=0.8),
        )),
    # bench_planner numpy-vs-jax backend rows (same document as
    # "planner" — gate it a second time with --spec planner-backend):
    # parity is exact by the bit-identical contract; the backend
    # speedup is wall-clock -> very loose band
    "planner-backend": BenchSpec(
        rows_key="backend",
        id_keys=("n_apps", "n_servers"),
        metrics=(
            Metric("parity", "equal"),
            Metric("speedup", "higher", rel_tol=0.8),
        )),
}


def compare_rows(ref: dict, cur: dict, spec: BenchSpec,
                 label: str) -> List[str]:
    fails: List[str] = []
    for m in spec.metrics:
        if m.key not in ref or m.key not in cur:
            continue                   # metric absent on either side
        r, c = ref[m.key], cur[m.key]
        if m.direction == "equal":
            if r != c:
                fails.append(f"{label}: {m.key} changed {r!r} -> {c!r}")
            continue
        r, c = float(r), float(c)
        if r == SENTINEL and c == SENTINEL:
            continue
        if r != SENTINEL and c == SENTINEL:
            fails.append(f"{label}: {m.key} lost its data "
                         f"({r} -> no-data sentinel)")
            continue
        if r == SENTINEL:
            continue                   # data appeared: an improvement
        band = max(m.abs_tol, m.rel_tol * abs(r))
        if m.direction == "higher" and c < r - band:
            fails.append(f"{label}: {m.key} regressed {r} -> {c} "
                         f"(band -{band:g})")
        elif m.direction == "lower" and c > r + band:
            fails.append(f"{label}: {m.key} regressed {r} -> {c} "
                         f"(band +{band:g})")
    return fails


def compare(trend: dict, current: dict,
            spec_name: str = None) -> Tuple[List[str], int]:
    """(failures, n_matched). Zero matched rows is itself a failure.

    ``spec_name`` overrides the spec lookup (default: the documents'
    own "bench" field) so one benchmark document can be gated under
    several row sets — the planner doc under both "planner" and
    "planner-backend"."""
    bench = trend.get("bench")
    if bench != current.get("bench"):
        return ([f"bench mismatch: trend={bench!r} "
                 f"current={current.get('bench')!r}"], 0)
    name = spec_name or bench
    if name not in SPECS:
        return ([f"no gate spec for bench {name!r}; "
                 f"have {sorted(SPECS)}"], 0)
    spec = SPECS[name]

    def index(doc):
        rows = doc.get(spec.rows_key, [])
        return {tuple(row.get(k) for k in spec.id_keys): row
                for row in rows}

    ref_rows, cur_rows = index(trend), index(current)
    fails: List[str] = []
    matched = 0
    for key, cur in sorted(cur_rows.items(), key=lambda kv: str(kv[0])):
        ref = ref_rows.get(key)
        label = f"{name}[" + ",".join(f"{k}={v}" for k, v
                                      in zip(spec.id_keys, key)) + "]"
        if ref is None:
            print(f"note {label}: new row, no trend baseline")
            continue
        matched += 1
        fails += compare_rows(ref, cur, spec, label)
    if matched == 0:
        fails.append(f"no {name!r} rows matched the trend — "
                     f"the gate compared nothing")
    return fails, matched


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--trend", required=True,
                    help="committed trend JSON (the baseline)")
    ap.add_argument("--current", required=True,
                    help="freshly produced benchmark JSON")
    ap.add_argument("--spec", default=None, choices=sorted(SPECS),
                    help="gate spec override (default: the documents' "
                         "own 'bench' field) — lets one benchmark doc "
                         "be gated under several row sets")
    args = ap.parse_args()

    trend = json.loads(Path(args.trend).read_text())
    current = json.loads(Path(args.current).read_text())
    fails, matched = compare(trend, current, args.spec)
    if fails:
        print(f"\nTREND GATE FAILED ({len(fails)} regression(s), "
              f"{matched} row(s) compared):")
        for f in fails:
            print(f"  {f}")
        return 1
    print(f"trend gate ok: {matched} row(s) inside every band "
          f"({args.current} vs {args.trend})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
