#!/usr/bin/env python
"""Profile any ExperimentSpec — the hot-loop hunting harness.

Runs one experiment under cProfile (or pyinstrument when available and
requested), prints the top-N functions by cumulative time, and dumps a
binary ``.prof`` stats file that flamegraph tooling understands
(``snakeviz out.prof`` / ``flameprof out.prof > flame.svg``):

    PYTHONPATH=src python tools/profile_sim.py --smoke
    PYTHONPATH=src python tools/profile_sim.py \
        --scenario cascade --set n_sites=10 --set servers_per_site=20 \
        --set event_mode=per-event -n 30 --out perevent.prof

Any ExperimentSpec field is reachable via ``--set key=value`` (values
parse as JSON first, then fall back to plain strings), so the harness
profiles exactly what `repro run` would execute — this is how the
per-event hot loops (per-chunk demand-vector rebuilds, per-app dict
scans, per-request classification) were found and killed for the
epoch-batched engine (docs/SCALE.md).
"""

from __future__ import annotations

import argparse
import cProfile
import json
import pstats
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))


def parse_sets(pairs):
    """``--set key=value`` -> {key: parsed}; values are JSON when they
    parse (ints, floats, bools, lists, dicts), raw strings otherwise."""
    out = {}
    for pair in pairs:
        key, sep, val = pair.partition("=")
        if not sep:
            raise SystemExit(f"--set needs key=value, got {pair!r}")
        try:
            out[key] = json.loads(val)
        except json.JSONDecodeError:
            out[key] = val
    return out


def build_spec(args):
    from repro.experiment.spec import ExperimentSpec

    spec = (ExperimentSpec.smoke(args.backend or "sim") if args.smoke
            else ExperimentSpec(backend=args.backend or "sim"))
    overrides = parse_sets(args.set)
    if args.scenario:
        overrides["scenario"] = args.scenario
    if args.seed is not None:
        overrides["seed"] = args.seed
    return spec.with_(**overrides)


def profile_cprofile(spec, top_n: int, sort: str, out: str,
                     planner_only: bool = False):
    from repro.experiment.backends import run_experiment

    prof = cProfile.Profile()
    t0 = time.perf_counter()
    prof.enable()
    res = run_experiment(spec)
    prof.disable()
    wall = time.perf_counter() - t0

    stats = pstats.Stats(prof, stream=sys.stdout)
    if planner_only:
        # restrict the table to the planner package (state sync, the
        # vectorized/sharded/jax paths, kernels) + the plan-wall summary
        stats.sort_stats(sort).print_stats(
            r"repro[/\\](core[/\\]planner|kernels)", top_n)
        planner = (res.extras or {}).get("planner", {})
        if planner:
            print("planner: " + ", ".join(f"{k}={v}" for k, v
                                          in sorted(planner.items())))
    else:
        stats.sort_stats(sort).print_stats(top_n)
    if out:
        stats.dump_stats(out)
        print(f"wrote {out} (snakeviz/flameprof-compatible)")
    return res, wall


def profile_pyinstrument(spec, out: str):
    from pyinstrument import Profiler

    from repro.experiment.backends import run_experiment

    profiler = Profiler()
    t0 = time.perf_counter()
    profiler.start()
    res = run_experiment(spec)
    profiler.stop()
    wall = time.perf_counter() - t0
    print(profiler.output_text(unicode=True, color=False))
    if out:
        Path(out).write_text(profiler.output_html())
        print(f"wrote {out} (open in a browser)")
    return res, wall


def main() -> int:
    ap = argparse.ArgumentParser(
        description="profile one ExperimentSpec run")
    ap.add_argument("--backend", default=None, choices=["sim", "testbed"])
    ap.add_argument("--scenario", default=None)
    ap.add_argument("--seed", type=int, default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="start from the reduced CI preset")
    ap.add_argument("--set", action="append", default=[],
                    metavar="KEY=VALUE",
                    help="override any ExperimentSpec field (repeatable)")
    ap.add_argument("-n", "--top", type=int, default=25,
                    help="rows of the stats table to print")
    ap.add_argument("--sort", default="cumulative",
                    choices=["cumulative", "tottime", "ncalls"],
                    help="pstats sort column")
    ap.add_argument("--out", default="profile_sim.prof",
                    help="stats dump path ('' disables); .prof for "
                         "cProfile, .html for --pyinstrument")
    ap.add_argument("--pyinstrument", action="store_true",
                    help="use pyinstrument's sampling tree when the "
                         "package is importable (falls back to cProfile)")
    ap.add_argument("--planner-only", action="store_true",
                    help="restrict the cProfile table to the planner "
                         "package and print the run's planner stats "
                         "(backend, rounds, fallbacks)")
    args = ap.parse_args()

    spec = build_spec(args)
    print(f"profiling: backend={spec.backend} scenario={spec.scenario} "
          f"event_mode={spec.event_mode} seed={spec.seed}")

    if args.pyinstrument and not args.planner_only:
        try:
            res, wall = profile_pyinstrument(spec, args.out)
        except ImportError:
            print("pyinstrument not installed; falling back to cProfile")
            res, wall = profile_cprofile(spec, args.top, args.sort,
                                         args.out)
    else:
        res, wall = profile_cprofile(spec, args.top, args.sort, args.out,
                                     planner_only=args.planner_only)

    t = res.traffic
    n_req = t.n_offered if t is not None else 0
    print(f"run: {wall:.2f}s wall, {n_req} requests, "
          f"{len(res.records)} recovery record(s)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
