#!/usr/bin/env python
"""Docs health check: smoke-execute ```python fences and verify
intra-repo markdown links.

Used by the CI docs job and by tests/test_docs.py:

    PYTHONPATH=src python tools/check_docs.py            # both checks
    PYTHONPATH=src python tools/check_docs.py --links    # links only

Every fenced ```python block in README.md and docs/*.md is executed in
its own namespace (with src/ on sys.path) unless the fence is preceded
by an HTML comment containing `no-run` within the two lines above it.
Keep snippets small-scale (tiny clusters) — they run on every CI push.

Link checking covers relative links `[text](path)` in all tracked
markdown files: the target (ignoring any #fragment) must exist relative
to the file. External schemes (http/https/mailto) are skipped.
"""

from __future__ import annotations

import argparse
import re
import sys
from pathlib import Path
from typing import List, Tuple

ROOT = Path(__file__).resolve().parents[1]

SNIPPET_FILES = ["README.md", "docs/ARCHITECTURE.md", "docs/SCENARIOS.md",
                 "docs/PLANNER.md", "docs/EXPERIMENTS.md", "docs/CI.md",
                 "docs/RESILIENCE.md", "docs/SCALE.md",
                 "docs/SHARDING_FAILOVER.md"]
LINK_FILES_GLOB = ["*.md", "docs/*.md"]

FENCE_RE = re.compile(r"^```python\s*$")
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def iter_snippets(path: Path) -> List[Tuple[int, str]]:
    """Yield (start_line, source) for runnable ```python fences."""
    lines = path.read_text().splitlines()
    out: List[Tuple[int, str]] = []
    i = 0
    while i < len(lines):
        if FENCE_RE.match(lines[i]):
            context = " ".join(lines[max(0, i - 2):i])
            skip = "no-run" in context
            block: List[str] = []
            j = i + 1
            while j < len(lines) and not lines[j].startswith("```"):
                block.append(lines[j])
                j += 1
            if not skip:
                out.append((i + 1, "\n".join(block)))
            i = j
        i += 1
    return out


def run_snippets(paths: List[Path]) -> List[str]:
    errors: List[str] = []
    sys.path.insert(0, str(ROOT / "src"))
    for path in paths:
        for lineno, src in iter_snippets(path):
            label = f"{path.relative_to(ROOT)}:{lineno}"
            try:
                code = compile(src, label, "exec")
                exec(code, {"__name__": "__docsnippet__"})
            except Exception as e:                     # noqa: BLE001
                errors.append(f"{label}: {type(e).__name__}: {e}")
            else:
                print(f"ok   snippet {label}")
    return errors


def check_links(paths: List[Path]) -> List[str]:
    errors: List[str] = []
    for path in paths:
        file_errors: List[str] = []
        for m in LINK_RE.finditer(path.read_text()):
            target = m.group(1)
            if re.match(r"^[a-z][a-z0-9+.-]*:", target):   # scheme
                continue
            if target.startswith("#"):                     # same-page
                continue
            rel = target.split("#", 1)[0]
            resolved = (path.parent / rel).resolve()
            # bytecode caches are build litter, never a valid doc
            # target — a link "satisfied" by one is still broken
            if not resolved.exists() \
                    or "__pycache__" in resolved.parts:
                file_errors.append(f"{path.relative_to(ROOT)}: broken "
                                   f"link -> {target}")
        status = "ok  " if not file_errors else "FAIL"
        print(f"{status} links   {path.relative_to(ROOT)}")
        errors += file_errors
    return errors


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--links", action="store_true",
                    help="only check markdown links")
    ap.add_argument("--snippets", action="store_true",
                    help="only execute doc snippets")
    args = ap.parse_args()
    do_links = args.links or not args.snippets
    do_snippets = args.snippets or not args.links

    link_paths = sorted({p for g in LINK_FILES_GLOB
                         for p in ROOT.glob(g)
                         if p.is_file() and "__pycache__" not in p.parts})
    snippet_paths = [ROOT / f for f in SNIPPET_FILES if (ROOT / f).exists()]

    errors: List[str] = []
    if do_links:
        errors += check_links(link_paths)
    if do_snippets:
        errors += run_snippets(snippet_paths)
    if errors:
        print("\nFAILED:")
        for e in errors:
            print(f"  {e}")
        return 1
    print("\ndocs check passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
