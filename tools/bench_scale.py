#!/usr/bin/env python
"""Planet-scale simulation benchmark -> BENCH_scale.json.

Sweeps the discrete-event engine over a {servers} x {apps} grid —
up to 10k servers / 100k apps in the full run — replaying the same
deterministic site-outage scenario per cell and recording:

  * sim throughput: (heap events drained + requests generated) per
    wall-clock second of the scenario replay, for the epoch-batched
    drain AND the historical per-event compat path (the speedup
    column is the acceptance gate for the epoch engine);
  * failover planning wall time: the peak per-epoch "plan" phase over
    every recovery record (sub-second at the top of the sweep is the
    sharded-planner acceptance gate) plus the controller's cumulative
    planner wall;
  * peak RSS per cell (each cell runs in a fresh subprocess so
    `ru_maxrss` is not contaminated by earlier cells).

    PYTHONPATH=src python tools/bench_scale.py                # full sweep
    PYTHONPATH=src python tools/bench_scale.py --smoke        # CI cells
    PYTHONPATH=src python tools/bench_scale.py \
        --check-speedup 4.0 --check-plan-wall 1.0 \
        --check-jax-plan-speedup 3.0

Cluster sizing inverts the simulator's budget rule: `synthetic_apps`
emits ~one app per 2.3 GB of `primary_util * total_mem`, so
``server_mem = n_apps * 2.3e9 / (n_servers * 0.5)`` yields the target
app count (the row reports the exact placed count). The per-event
mode is skipped (no-data sentinel -1.0) at the 10k x 100k cell — the
whole point of the epoch engine is that the compat path does not
finish there in reasonable time. docs/SCALE.md walks the design.
"""

from __future__ import annotations

import argparse
import json
import subprocess
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

SENTINEL = -1.0
AVG_FULL_MEM = 2.3e9          # mean full-variant bytes of the 9-family mix
PRIMARY_UTIL = 0.5
SCENARIO = "site-outage"


def _have_jax() -> bool:
    from repro.core.planner.kernels import have_jax
    return have_jax()

# (n_servers, n_apps target, servers/site, rate_scale, chunk_s, per-event?)
FULL_CELLS = [
    dict(n_servers=1000, n_apps=10000, per_site=50,
         rate_scale=2.0, chunk_s=0.5, per_event=True),
    dict(n_servers=1000, n_apps=100000, per_site=50,
         rate_scale=0.2, chunk_s=2.0, per_event=True),
    dict(n_servers=10000, n_apps=10000, per_site=50,
         rate_scale=2.0, chunk_s=2.0, per_event=True),
    dict(n_servers=10000, n_apps=100000, per_site=50,
         rate_scale=0.1, chunk_s=5.0, per_event=False),
]
SMOKE_CELLS = [
    dict(n_servers=20, n_apps=100, per_site=5,
         rate_scale=20.0, chunk_s=0.5, per_event=True),
    dict(n_servers=40, n_apps=200, per_site=5,
         rate_scale=10.0, chunk_s=0.5, per_event=True),
]


def run_cell(cell: dict, mode: str, seed: int = 0,
             backend: str = "numpy") -> dict:
    """One (cell, event_mode, planner_backend) measurement — meant to
    run in its own process so peak RSS is per-cell."""
    import resource

    from repro.core.simulation import SimConfig, Simulation

    n_servers, n_apps = cell["n_servers"], cell["n_apps"]
    per_site = cell["per_site"]
    dtype = "float32" if n_servers >= 10000 else "float64"
    cfg = SimConfig(
        n_sites=max(1, n_servers // per_site), servers_per_site=per_site,
        server_mem=n_apps * AVG_FULL_MEM / (n_servers * PRIMARY_UTIL),
        headroom=0.2, seed=seed, planner="sharded", planner_dtype=dtype,
        planner_backend=backend,
        traffic_rate_scale=cell["rate_scale"],
        traffic_chunk_s=cell["chunk_s"], event_mode=mode)

    t0 = time.perf_counter()
    sim = Simulation(cfg).setup()
    setup_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    res = sim.run_named_scenario(SCENARIO)
    run_s = time.perf_counter() - t0

    n_events = sim.events.n_processed
    n_requests = sim.traffic.n_generated if sim.traffic is not None else 0
    plan_peak = max((r.phases.get("plan", 0.0) for r in res.records),
                    default=0.0)
    rss_mb = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0
    return {
        "mode": mode, "n_sites": cfg.n_sites,
        "n_apps_placed": res.n_apps_final,
        "planner": "sharded", "planner_dtype": dtype,
        "planner_backend": backend,
        "setup_wall_s": round(setup_s, 3),
        "run_wall_s": round(run_s, 3),
        "n_events": n_events, "n_requests": n_requests,
        "events_per_sec": round((n_events + n_requests)
                                / max(run_s, 1e-9), 1),
        "plan_wall_peak_s": round(plan_peak, 6),
        "plan_wall_total_s": round(sim.controller.plan_wall_s, 6),
        "recovery_rate": res.overall["recovery_rate"],
        "n_recovery_records": len(res.records),
        "peak_rss_mb": round(rss_mb, 1),
    }


def run_cell_subprocess(cell: dict, mode: str, seed: int,
                        backend: str = "numpy") -> dict:
    """Fork a fresh interpreter for the measurement; falls back to
    in-process when the spawn itself fails."""
    payload = json.dumps({"cell": cell, "mode": mode, "seed": seed,
                          "backend": backend})
    proc = subprocess.run(
        [sys.executable, str(Path(__file__).resolve()),
         "--cell-json", payload],
        capture_output=True, text=True)
    for line in proc.stdout.splitlines():
        if line.startswith("RESULT "):
            return json.loads(line[len("RESULT "):])
    raise RuntimeError(
        f"cell subprocess produced no result (rc={proc.returncode}):\n"
        f"{proc.stdout[-2000:]}\n{proc.stderr[-2000:]}")


def sweep(cells, seed: int, in_process: bool) -> list:
    rows = []
    for cell in cells:
        key = f"{cell['n_servers']}x{cell['n_apps']}"
        modes = ["epoch"] + (["per-event"] if cell["per_event"] else [])
        per_mode = {}
        for mode in modes:
            print(f"scale,{key},{mode}: running...", flush=True)
            r = (run_cell(cell, mode, seed) if in_process
                 else run_cell_subprocess(cell, mode, seed))
            per_mode[mode] = r
            print(f"scale,{key},{mode},events/s={r['events_per_sec']:.0f},"
                  f"run={r['run_wall_s']:.2f}s,"
                  f"plan_peak={r['plan_wall_peak_s']*1e3:.1f}ms,"
                  f"rss={r['peak_rss_mb']:.0f}MB", flush=True)

        ep = per_mode["epoch"]
        pe = per_mode.get("per-event")
        row = {"n_servers": cell["n_servers"], "n_apps": cell["n_apps"],
               **{k: v for k, v in ep.items() if k != "mode"}}

        # jax planner backend on the epoch drain: same deterministic
        # replay, compiled planner inner loops — the plan-wall columns
        # are the jax-backend acceptance gate (docs/PLANNER.md)
        jx = None
        if _have_jax():
            print(f"scale,{key},epoch+jax: running...", flush=True)
            jx = (run_cell(cell, "epoch", seed, backend="jax")
                  if in_process
                  else run_cell_subprocess(cell, "epoch", seed,
                                           backend="jax"))
            print(f"scale,{key},epoch+jax,"
                  f"plan_peak={jx['plan_wall_peak_s']*1e3:.1f}ms,"
                  f"run={jx['run_wall_s']:.2f}s", flush=True)
            # the compiled backend must replay the identical control
            # plane: same placements, same recoveries, same rate
            for k in ("n_apps_placed", "recovery_rate",
                      "n_recovery_records"):
                assert jx[k] == ep[k], (k, jx[k], ep[k])
        if jx is not None:
            row["plan_wall_peak_jax_s"] = jx["plan_wall_peak_s"]
            row["plan_wall_total_jax_s"] = jx["plan_wall_total_s"]
            row["run_wall_jax_s"] = jx["run_wall_s"]
            row["jax_plan_speedup"] = round(
                ep["plan_wall_peak_s"]
                / max(jx["plan_wall_peak_s"], 1e-9), 2)
        else:
            row["plan_wall_peak_jax_s"] = SENTINEL
            row["plan_wall_total_jax_s"] = SENTINEL
            row["run_wall_jax_s"] = SENTINEL
            row["jax_plan_speedup"] = SENTINEL
        if pe is not None:
            row["events_per_sec_per_event"] = pe["events_per_sec"]
            row["run_wall_per_event_s"] = pe["run_wall_s"]
            row["speedup"] = round(ep["events_per_sec"]
                                   / max(pe["events_per_sec"], 1e-9), 2)
            # same deterministic replay on both drains, or the speedup
            # compares two different workloads; control-plane outcomes
            # must match exactly, request counts only statistically
            # above the bulk-stream threshold (docs/SCALE.md)
            for k in ("n_apps_placed", "recovery_rate"):
                assert pe[k] == ep[k], (k, pe[k], ep[k])
            rel = abs(pe["n_requests"] - ep["n_requests"]) \
                / max(pe["n_requests"], 1)
            assert rel < 0.01, ("n_requests", pe["n_requests"],
                                ep["n_requests"])
        else:
            row["events_per_sec_per_event"] = SENTINEL
            row["run_wall_per_event_s"] = SENTINEL
            row["speedup"] = SENTINEL
        rows.append(row)
    return rows


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_scale.json")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced CI cells")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--in-process", action="store_true",
                    help="skip the per-cell subprocess isolation "
                         "(peak RSS becomes cumulative)")
    ap.add_argument("--check-speedup", type=float, default=None,
                    help="fail unless the 1k-server/10k-app cell (or "
                         "the largest cell with both modes) reaches "
                         "this epoch-vs-per-event speedup")
    ap.add_argument("--check-plan-wall", type=float, default=None,
                    help="fail unless the largest cell's peak failover "
                         "plan phase stays under this many seconds")
    ap.add_argument("--check-jax-plan-speedup", type=float, default=None,
                    help="fail unless the largest cell's jax planner "
                         "backend beats the numpy peak failover plan "
                         "wall by this factor")
    ap.add_argument("--cell-json", default=None, help=argparse.SUPPRESS)
    args = ap.parse_args()

    if args.cell_json:                     # subprocess worker entry
        req = json.loads(args.cell_json)
        row = run_cell(req["cell"], req["mode"], req["seed"],
                       req.get("backend", "numpy"))
        print("RESULT " + json.dumps(row))
        return 0

    cells = SMOKE_CELLS if args.smoke else FULL_CELLS
    t0 = time.perf_counter()
    rows = sweep(cells, args.seed, args.in_process)
    doc = {
        "bench": "scale",
        "description": "epoch-batched vs per-event sim throughput, "
                       "sharded failover planning wall, and peak RSS "
                       "over a servers x apps grid (site-outage replay)",
        "scenario": SCENARIO,
        "smoke": bool(args.smoke),
        "sweep_wall_s": round(time.perf_counter() - t0, 1),
        "cells": rows,
    }
    Path(args.out).write_text(json.dumps(doc, indent=2) + "\n")
    print(f"wrote {args.out}")

    rc = 0
    if args.check_speedup is not None:
        with_both = [r for r in rows if r["speedup"] != SENTINEL]
        gate = next((r for r in with_both
                     if r["n_servers"] == 1000 and r["n_apps"] == 10000),
                    max(with_both,
                        key=lambda r: r["n_servers"] * r["n_apps"]))
        if gate["speedup"] < args.check_speedup:
            print(f"FAIL: epoch speedup {gate['speedup']}x at "
                  f"{gate['n_servers']}x{gate['n_apps']} "
                  f"< {args.check_speedup}x")
            rc = 1
        else:
            print(f"ok: {gate['speedup']}x >= {args.check_speedup}x at "
                  f"{gate['n_servers']} servers / {gate['n_apps']} apps")
    if args.check_plan_wall is not None:
        top = max(rows, key=lambda r: r["n_servers"] * r["n_apps"])
        if top["plan_wall_peak_s"] >= args.check_plan_wall:
            print(f"FAIL: peak failover plan {top['plan_wall_peak_s']}s "
                  f"at {top['n_servers']}x{top['n_apps']} "
                  f">= {args.check_plan_wall}s")
            rc = 1
        else:
            print(f"ok: peak failover plan {top['plan_wall_peak_s']}s "
                  f"< {args.check_plan_wall}s at {top['n_servers']} "
                  f"servers / {top['n_apps']} apps")
    if args.check_jax_plan_speedup is not None:
        top = max(rows, key=lambda r: r["n_servers"] * r["n_apps"])
        if top["jax_plan_speedup"] < args.check_jax_plan_speedup:
            print(f"FAIL: jax plan speedup {top['jax_plan_speedup']}x "
                  f"at {top['n_servers']}x{top['n_apps']} "
                  f"< {args.check_jax_plan_speedup}x")
            rc = 1
        else:
            print(f"ok: jax plan speedup {top['jax_plan_speedup']}x >= "
                  f"{args.check_jax_plan_speedup}x at {top['n_servers']} "
                  f"servers / {top['n_apps']} apps")
    return rc


if __name__ == "__main__":
    sys.exit(main())
