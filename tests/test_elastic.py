"""Elastic restart: a checkpoint written under one mesh restores onto a
different device count with re-sharding — the training-side analogue of
FailLite's progressive failover after pod loss."""

import os
import subprocess
import sys

import pytest


@pytest.mark.slow
def test_checkpoint_restores_onto_different_mesh(tmp_path):
    code = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import jax.numpy as jnp
import numpy as np
from repro import configs
from repro.models import model as MDL
from repro.parallel import sharding as SH
from repro.training import checkpoint as CKPT
from repro.training.optimizer import AdamW

cfg = configs.get_smoke("qwen2.5-3b")
params = MDL.init_params(jax.random.PRNGKey(0), cfg)
opt = AdamW()
opt_state = opt.init(params)
CKPT.save_checkpoint(r"{tmp_path}", 7, params, opt_state)

# restore onto a 2x4 mesh (as if 8 of 16 hosts survived a pod loss)
mesh = jax.make_mesh((2, 4), ("data", "model"))
tmpl_p = MDL.param_shapes(cfg)
tmpl_o = opt.state_shapes(tmpl_p)
shard_p = SH.param_shardings(tmpl_p, mesh)
step, params_r, opt_r, _ = CKPT.restore_checkpoint(
    r"{tmp_path}", 7, tmpl_p, tmpl_o, shardings=shard_p)
assert step == 7
a = jax.tree_util.tree_leaves(params)[0]
b = jax.tree_util.tree_leaves(params_r)[0]
np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
# restored leaves actually live on the new mesh
leaf = jax.tree_util.tree_leaves(params_r)[0]
assert len(leaf.devices()) >= 1
print("ELASTIC-RESTORE-OK")
"""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        timeout=300, cwd=root,
        # sanitized env; JAX_PLATFORMS=cpu keeps a locally-installed TPU
        # plugin from probing cloud metadata (hangs in sandboxes)
        env={"PYTHONPATH": os.path.join(root, "src"),
             "PATH": os.environ.get("PATH", "/usr/bin:/bin"),
             "HOME": os.environ.get("HOME", "/tmp"),
             "JAX_PLATFORMS": "cpu"})
    assert "ELASTIC-RESTORE-OK" in out.stdout, out.stderr[-2000:]
