"""End-to-end failover behaviour in the discrete-event simulator:
FailLite vs baselines, progressive upgrade, site failures, reclamation."""



from repro.core.simulation import SimConfig, Simulation


def _run(policy, **kw):
    cfg = SimConfig(n_sites=4, servers_per_site=5, policy=policy, seed=0,
                    **kw)
    sim = Simulation(cfg).setup()
    victim = sim.rng.choice(sim.cluster.alive_servers()).id
    return sim, sim.inject_failure(servers=[victim])


def test_faillite_full_recovery_at_low_headroom():
    _, res = _run("faillite", headroom=0.1)
    assert res.n_affected > 0
    assert res.recovery_rate == 1.0
    assert res.accuracy_reduction < 0.10


def test_baselines_degrade_at_low_headroom():
    _, cold = _run("full-cold", headroom=0.1)
    _, fl = _run("faillite", headroom=0.1)
    assert fl.recovery_rate >= cold.recovery_rate


def test_warm_faster_than_cold():
    cfg = SimConfig(n_sites=4, servers_per_site=5, policy="faillite",
                    seed=0, critical_frac=1.0, headroom=0.4)
    sim = Simulation(cfg).setup()
    victim = sim.rng.choice(sim.cluster.alive_servers()).id
    res_warm = sim.inject_failure(servers=[victim])
    warm_recs = [r for r in res_warm.records.values()
                 if r.recovered and r.mode == "warm"]

    cfg2 = SimConfig(n_sites=4, servers_per_site=5, policy="full-cold",
                     seed=0, headroom=0.4)
    sim2 = Simulation(cfg2).setup()
    victim2 = sim2.rng.choice(sim2.cluster.alive_servers()).id
    res_cold = sim2.inject_failure(servers=[victim2])
    cold_recs = [r for r in res_cold.records.values()
                 if r.recovered and r.mode == "cold"]
    if warm_recs and cold_recs:
        assert max(r.mttr for r in warm_recs) < min(r.mttr
                                                    for r in cold_recs)


def test_progressive_upgrades_to_selected():
    """Progressive failover recovers on the smallest variant, then
    hot-swaps to the (larger) selected variant."""
    _, res = _run("faillite", headroom=0.4, critical_frac=0.0)
    prog = [r for r in res.records.values()
            if r.recovered and r.mode == "cold-progressive"]
    assert prog, "expected at least one progressive recovery"
    for r in prog:
        assert r.upgraded_to is not None
        assert r.variant == r.upgraded_to     # final variant after upgrade


def test_progressive_mttr_below_full_cold():
    _, fl = _run("faillite", headroom=0.3, critical_frac=0.0)
    _, cold = _run("full-cold", headroom=0.3, critical_frac=0.0)
    if fl.recovery_rate > 0 and cold.recovery_rate > 0:
        assert fl.mttr_avg <= cold.mttr_avg + 1e-9


def test_site_failure_with_independence():
    cfg = SimConfig(n_sites=10, servers_per_site=3, policy="faillite",
                    seed=1, site_independence=True, headroom=0.3)
    sim = Simulation(cfg).setup()
    # warm backups never share the primary's site
    for app_id, (v, sid, _) in sim.controller.warm.items():
        p = sim.controller.primaries[app_id]
        assert (sim.cluster.servers[sid].site
                != sim.cluster.servers[p].site)
    res = sim.inject_failure(sites=[list(sim.cluster.sites)[0]])
    assert res.recovery_rate > 0.9


def test_warm_reclamation_on_widespread_failure():
    cfg = SimConfig(n_sites=10, servers_per_site=3, policy="faillite",
                    seed=0, site_independence=True, headroom=0.2)
    sim = Simulation(cfg).setup()
    n_warm_before = len(sim.controller.warm)
    sites = list(sim.cluster.sites)[:5]
    res = sim.inject_failure(sites=sites)
    # widespread failure should trigger reclamation or full placement
    assert res.recovery_rate > 0.4
    assert len(sim.controller.warm) <= n_warm_before


def test_reclamation_evicts_warm_but_keeps_cold_protection():
    """_reclaim_and_assign under site-scale failure: stranded warm
    backups of unaffected apps are evicted to make room, and the evicted
    apps are demoted to cold protection — still recoverable."""
    cfg = SimConfig(n_sites=10, servers_per_site=3, policy="faillite",
                    seed=0, site_independence=True, headroom=0.2)
    sim = Simulation(cfg).setup()
    ctl = sim.controller
    res = sim.inject_failure(sites=list(sim.cluster.sites)[:5])
    assert res.recovery_rate > 0.9
    assert ctl.cold_protected, "expected warm-backup eviction"
    for app_id in ctl.cold_protected:
        assert app_id not in ctl.warm
        assert ctl.ds.get(f"warm/{app_id}") is None
        assert ctl.ds.get(f"cold/{app_id}") is not None
    # cold protection is real: kill an evicted app's primary and it
    # still comes back via the progressive cold path (second epoch)
    victim = next(a for a in sorted(ctl.cold_protected)
                  if ctl.primaries.get(a)
                  and sim.cluster.servers[ctl.primaries[a]].alive)
    t = sim.clock.now()
    ctl.handle_failures([ctl.primaries[victim]], t)
    sim.events.run_until(t + 30.0)
    rec = ctl.records[victim]
    assert rec.epoch == 1
    assert rec.recovered
    assert rec.mode in ("cold", "cold-progressive")


def test_mttr_accounting_includes_detection_and_notify():
    _, res = _run("faillite", headroom=0.4, critical_frac=1.0)
    for r in res.records.values():
        if r.recovered and r.mode == "warm":
            # detection (~65ms) + notify (10ms)
            assert 0.04 < r.mttr < 0.2


def test_replan_lost_backups():
    cfg = SimConfig(n_sites=4, servers_per_site=5, policy="faillite",
                    seed=0, headroom=0.4, critical_frac=1.0)
    sim = Simulation(cfg).setup()
    # kill a server hosting only warm backups if one exists; else any
    warm_srvs = {sid for (_, sid, _) in sim.controller.warm.values()}
    victim = next(iter(warm_srvs))
    sim.inject_failure(servers=[victim])
    sim.controller.replan_lost_backups()
    # every critical app with a live primary has warm protection again
    for app in sim.apps:
        p = sim.controller.primaries.get(app.id)
        if (app.critical and p and sim.cluster.servers[p].alive):
            assert app.id in sim.controller.warm
