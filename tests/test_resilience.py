"""Storm battery for the request-plane resilience toolkit.

Covers every primitive in core/resilience.py deterministically (fake
clocks, manual-completion executors — no sleeps where avoidable), pins
the six pre-resilience scenarios bit-exact with the toolkit off, and
proves end-to-end that the toolkit beats the bare request plane on the
retry-amplification storm.
"""

import hashlib

import numpy as np
import pytest

import test_modelstate as golden
from repro.core.controller import LoadExecutor, RecoveryScheduler
from repro.core.resilience import (CLOSED, HALF_OPEN, OPEN, Bulkhead,
                                   CircuitBreaker, ResilienceConfig,
                                   RetryBudget, active, admit_mask,
                                   hedged_call)
from repro.core.simulation import SimConfig, Simulation
from repro.core.variants import Application, synthetic_family

# ---------------------------------------------------------------------------
# config plumbing
# ---------------------------------------------------------------------------


def test_config_round_trip():
    cfg = ResilienceConfig(enabled=True, breaker_window=5)
    assert ResilienceConfig.from_dict(cfg.to_dict()) == cfg


def test_config_rejects_unknown_fields():
    with pytest.raises(ValueError, match="unknown ResilienceConfig"):
        ResilienceConfig.from_dict({"enabled": True, "bogus": 1})


def test_coerce_dict_defaults_to_enabled():
    # passing a dict at all expresses intent to turn the layer on
    assert ResilienceConfig.coerce({}).enabled
    assert ResilienceConfig.coerce({"enabled": False}).enabled is False
    assert ResilienceConfig.coerce(None) is None


def test_active_gates_on_enabled():
    assert active(None) is None
    assert active({"enabled": False}) is None
    cfg = active({"breaker_window": 3})
    assert cfg is not None and cfg.breaker_window == 3


# ---------------------------------------------------------------------------
# circuit breaker state machine (fake clock, no sleeping)
# ---------------------------------------------------------------------------

def _breaker(**kw):
    clock = {"t": 0.0}
    cfg = ResilienceConfig(enabled=True, **kw)
    return CircuitBreaker(cfg, clock=lambda: clock["t"]), clock


def test_breaker_trips_on_failure_rate():
    br, _ = _breaker(breaker_window=8, breaker_min_failures=4,
                     breaker_failure_rate=0.5)
    for _ in range(3):
        br.record(False)
    assert br.state == CLOSED              # below min_failures
    br.record(True)
    br.record(False)                       # 4 fails / 5 outcomes >= 0.5
    assert br.state == OPEN
    assert not br.allow()


def test_breaker_failure_rate_guard():
    # plenty of absolute failures but diluted by successes: stays closed
    br, _ = _breaker(breaker_window=16, breaker_min_failures=4,
                     breaker_failure_rate=0.5)
    for _ in range(4):
        br.record(True)
        br.record(True)
        br.record(True)
        br.record(False)                   # 25% failure rate
    assert br.state == CLOSED


def test_breaker_half_open_probe_closes_on_success():
    br, clock = _breaker(breaker_min_failures=2, breaker_failure_rate=0.5,
                         breaker_open_s=0.5, breaker_probes=1)
    br.record(False), br.record(False)
    assert br.state == OPEN
    assert not br.allow()                  # still inside the open window
    clock["t"] = 0.6
    assert br.allow()                      # the probe
    assert br.state == HALF_OPEN
    assert not br.allow()                  # only breaker_probes granted
    br.record(True)
    assert br.state == CLOSED
    assert br.allow()


def test_breaker_half_open_probe_reopens_on_failure():
    br, clock = _breaker(breaker_min_failures=2, breaker_failure_rate=0.5,
                         breaker_open_s=0.5)
    br.record(False), br.record(False)
    clock["t"] = 0.6
    assert br.allow()
    br.record(False)                       # probe failed
    assert br.state == OPEN
    assert not br.allow()                  # open window restarted at 0.6
    clock["t"] = 1.2
    assert br.allow()                      # ...and reopens for probing


# ---------------------------------------------------------------------------
# bulkhead + retry budget
# ---------------------------------------------------------------------------

def test_bulkhead_rejects_at_capacity_and_releases():
    bh = Bulkhead(2)
    assert bh.try_acquire() and bh.try_acquire()
    assert not bh.try_acquire()            # full
    assert bh.in_flight == 2
    bh.release()
    assert bh.try_acquire()                # slot freed
    assert bh.in_flight == 2


def test_bulkhead_floor_is_one_slot():
    assert Bulkhead(0).slots == 1


def test_retry_budget_accrues_and_exhausts():
    budget = RetryBudget(ResilienceConfig(enabled=True, retry_budget=0.5))
    assert not budget.try_spend()          # empty bucket
    budget.on_request()
    budget.on_request()                    # 2 * 0.5 = 1 token
    assert budget.try_spend()
    assert not budget.try_spend()          # exhausted again
    for _ in range(100):
        budget.on_request()
    assert budget.tokens == pytest.approx(8.0)   # capped


# ---------------------------------------------------------------------------
# hedged call
# ---------------------------------------------------------------------------

def test_hedge_primary_fast_win_cancels_backup():
    backup_cancel = {}

    def primary(cancel):
        return "p"

    def backup(cancel):
        backup_cancel["ev"] = cancel
        cancel.wait(1.0)
        return "b"

    value, winner = hedged_call(primary, backup, delay_s=0.0)
    assert (value, winner) == ("p", "primary")
    # backup may not even have started (primary settled first); if it
    # did, its cancel event must be set
    ev = backup_cancel.get("ev")
    assert ev is None or ev.wait(1.0)


def test_hedge_backup_wins_when_primary_fails():
    # primary fails immediately -> backup engages BEFORE the hedge
    # delay elapses (no point waiting out the delay on a dead primary)
    import time as _time
    t0 = _time.monotonic()
    value, winner = hedged_call(lambda c: None, lambda c: "b",
                                delay_s=5.0)
    assert (value, winner) == ("b", "backup")
    assert _time.monotonic() - t0 < 2.0


def test_hedge_backup_wins_after_delay_on_slow_primary():
    def primary(cancel):
        cancel.wait(5.0)
        return None

    value, winner = hedged_call(primary, lambda c: "b", delay_s=0.01)
    assert (value, winner) == ("b", "backup")


def test_hedge_both_fail():
    assert hedged_call(lambda c: None, lambda c: None,
                       delay_s=0.0) == (None, None)


def test_hedge_no_backup():
    assert hedged_call(lambda c: "p", None, delay_s=0.0) == \
        ("p", "primary")
    assert hedged_call(lambda c: None, None, delay_s=0.0) == (None, None)


# ---------------------------------------------------------------------------
# deterministic admission thinning
# ---------------------------------------------------------------------------

def test_admit_mask_fraction_and_determinism():
    p = np.full(1000, 0.75)
    keep = admit_mask(p)
    assert keep.sum() == 750
    assert np.array_equal(keep, admit_mask(p))     # pure function
    # maximal spacing: no run of more than ceil(1/(1-p)) rejections
    assert not np.any(~keep[:-1] & ~keep[1:])      # p=0.75 -> isolated


def test_admit_mask_admits_everything_at_one():
    assert admit_mask(np.ones(10)).all()


# ---------------------------------------------------------------------------
# recovery-drain observer (feeds admission control)
# ---------------------------------------------------------------------------

class _ManualExecutor(LoadExecutor):
    def __init__(self):
        self._cbs = []

    def load(self, app, variant, server_id, on_ready):
        self._cbs.append(on_ready)

    def complete(self, i=0, t=1.0):
        self._cbs.pop(i)(t)


def _app(i):
    return Application(id=f"a{i}", family="f", request_rate=1.0,
                       variants=synthetic_family(f"g{i}", 1e9))


def test_drain_observer_start_end_pairing():
    ex = _ManualExecutor()
    sched = RecoveryScheduler(ex, mode="fifo")
    events = []
    sched.drain_observer = lambda kind, t: events.append(kind)
    sched.submit(_app(0), _app(0).full, "s0", lambda t: None)
    sched.submit(_app(1), _app(1).full, "s0", lambda t: None)
    assert events == ["start"]             # nested drains fold into one
    ex.complete()
    assert events == ["start"]
    ex.complete()
    assert events == ["start", "end"]      # ends only at depth zero


def test_drain_observer_survives_dead_server_queue_drop():
    # criticality mode queues loads; dropping a dead server's queue must
    # release the drain counter for never-dispatched items (no leak)
    ex = _ManualExecutor()
    alive = {"s0": True}
    sched = RecoveryScheduler(ex, mode="criticality",
                              alive_fn=lambda sid: alive[sid])
    events = []
    sched.drain_observer = lambda kind, t: events.append(kind)
    sched.submit(_app(0), _app(0).full, "s0", lambda t: None)
    sched.submit(_app(1), _app(1).full, "s0", lambda t: None)  # queued
    alive["s0"] = False
    sched.reset_server("s0")               # drops the queued item
    ex.complete()                          # in-flight item still lands
    assert events == ["start", "end"]


# ---------------------------------------------------------------------------
# golden pinning: resilience OFF is bit-exact with the pre-toolkit plane
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(golden.GOLDEN_FINGERPRINTS))
def test_goldens_bit_exact_with_resilience_off(name):
    sim = Simulation(SimConfig(resilience={"enabled": False},
                               **golden.GOLDEN_CFG)).setup()
    res = sim.run_named_scenario(name)
    got = hashlib.sha256(repr(res.fingerprint()).encode()).hexdigest()
    assert got == golden.GOLDEN_FINGERPRINTS[name], (
        f"{name}: resilience={{enabled: False}} must leave the request "
        f"plane bit-identical to the pre-toolkit behavior")


# ---------------------------------------------------------------------------
# end-to-end: toolkit on beats off on the retry-amplification storm
# ---------------------------------------------------------------------------

_STORM_CFG = dict(n_sites=3, servers_per_site=4, headroom=0.25,
                  policy="faillite", seed=0)


def _run_storm(resilience):
    sim = Simulation(SimConfig(resilience=resilience,
                               **_STORM_CFG)).setup()
    return sim.run_named_scenario("retry-amplification")


def test_retry_amplification_toolkit_beats_bare_plane():
    off = _run_storm(None).traffic
    on = _run_storm({"enabled": True}).traffic
    assert on.n_hedged_win + on.n_shed + on.n_fast_failed \
        + on.n_retried > 0                 # the toolkit actually engaged
    assert off.n_hedged_win == off.n_shed == 0   # ...and only when on
    # the gated claims: tail latency AND client MTTR AND
    # accuracy-weighted goodput all improve under the storm
    assert on.latency_p99 < off.latency_p99
    assert on.client_mttr_avg < off.client_mttr_avg
    assert on.goodput > off.goodput
    assert on.availability >= off.availability


def test_storm_scenarios_registered():
    from repro.core.scenario import SCENARIOS
    for name in ("retry-amplification", "thundering-herd-rejoin",
                 "metastable-overload"):
        assert name in SCENARIOS
