"""Request-level traffic plane: vectorized arrival generation, per-seed
determinism of the per-request trace, downtime-window accounting,
client-observed vs controller MTTR, LoadSpike request pressure, and
degraded/goodput bookkeeping."""

import math

import numpy as np
import pytest

from repro.core.scenario import LoadSpike, Scenario, ServerFail
from repro.core.simulation import SimConfig, Simulation
from repro.core.traffic import (
    diurnal_arrival_times, diurnal_factor, poisson_arrival_times)
from repro.core.variants import Application, synthetic_family


def _sim(**kw):
    base = dict(n_sites=4, servers_per_site=5, headroom=0.2,
                policy="faillite", seed=0)
    base.update(kw)
    return Simulation(SimConfig(**base)).setup()


# ---------------------------------------------------------------------------
# vectorized generators
# ---------------------------------------------------------------------------

def test_poisson_arrival_times_statistics_and_bounds():
    rng = np.random.default_rng(0)
    arr = poisson_arrival_times(rng, 200.0, 2.0, 12.0)
    assert arr.size > 0
    assert np.all(arr >= 2.0) and np.all(arr < 12.0)
    assert np.all(np.diff(arr) >= 0)          # sorted
    # count concentrates around rate * duration = 2000
    assert 1700 < arr.size < 2300
    assert poisson_arrival_times(rng, 0.0, 0.0, 10.0).size == 0
    assert poisson_arrival_times(rng, 5.0, 3.0, 3.0).size == 0


def test_diurnal_arrivals_modulate_rate():
    rng = np.random.default_rng(1)
    period = 100.0
    # peak half vs trough half of one period, amplitude 1
    peak = diurnal_arrival_times(rng, 100.0, 0.0, 50.0, period=period,
                                 amplitude=1.0)
    trough = diurnal_arrival_times(rng, 100.0, 50.0, 100.0, period=period,
                                   amplitude=1.0)
    assert peak.size > 2 * trough.size
    assert diurnal_factor(75.0, period=period, amplitude=1.0) < 0.1


def test_serving_workload_shares_vectorized_layer():
    import random
    from repro.serving.workload import poisson_arrivals
    rng = random.Random(0)
    out = poisson_arrivals(rng, 50.0, 10.0)
    assert isinstance(out, list)
    assert all(0.0 <= t < 10.0 for t in out)
    assert out == sorted(out)
    assert 350 < len(out) < 650
    # same seed => same schedule
    assert poisson_arrivals(random.Random(7), 5.0, 5.0) \
        == poisson_arrivals(random.Random(7), 5.0, 5.0)


# ---------------------------------------------------------------------------
# per-seed determinism of the request-level numbers (acceptance criterion)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["single-server", "cascade",
                                  "churn-under-failure"])
def test_request_level_numbers_identical_across_runs(name):
    a = _sim(seed=5).run_named_scenario(name)
    b = _sim(seed=5).run_named_scenario(name)
    assert a.traffic is not None and b.traffic is not None
    assert a.traffic.fingerprint() == b.traffic.fingerprint()
    assert a.traffic.to_dict() == b.traffic.to_dict()
    assert a.fingerprint() == b.fingerprint()


def test_different_seed_changes_request_trace():
    a = _sim(seed=0).run_named_scenario("single-server")
    b = _sim(seed=1).run_named_scenario("single-server")
    assert a.traffic.fingerprint() != b.traffic.fingerprint()


# ---------------------------------------------------------------------------
# downtime windows + client-observed MTTR
# ---------------------------------------------------------------------------

def test_windows_open_per_affected_app_and_close_on_recovery():
    sim = _sim()
    victim = sim.controller.primaries[sim.apps[0].id]
    n_primaries = sum(1 for i in
                      sim.cluster.servers[victim].instances.values()
                      if i.role == "primary" and i.app_id != "_reserved")
    res = sim.run_scenario(Scenario(
        name="one", horizon=30.0,
        events=[ServerFail(t=1.0, server=victim)]))
    t = res.traffic
    assert t.n_windows == n_primaries
    assert t.n_unrecovered_windows == 0
    for w in t.windows:
        assert w.epoch == 0
        assert w.t_start == pytest.approx(1.0)
        assert w.recovered and w.duration > 0
        assert w.client_downtime >= w.duration - 1e-9


def test_client_mttr_upper_bounds_controller_mttr():
    """Clients pay crash->detection lead-in + notify + arrival
    discretization on top of what the controller records."""
    sim = _sim(traffic_rate_scale=80.0)
    victim = sim.controller.primaries[sim.apps[0].id]
    res = sim.inject_failure(servers=[victim])
    assert res.traffic.n_windows > 0
    assert res.traffic.client_mttr_avg > res.mttr_avg
    # ...but not by much more than notify + one inter-arrival gap
    assert res.traffic.client_mttr_avg < res.mttr_avg + 0.5


def test_unrecovered_window_stays_open():
    """An app that never recovers keeps a censored (inf) window, and its
    requests keep dropping until the end of the run."""
    ladder = synthetic_family("big", 6.0e9, n_variants=2, spread=1.2)
    app = Application(id="app0", family="big", variants=ladder,
                      request_rate=2.0)
    cfg = SimConfig(n_sites=1, servers_per_site=2, headroom=0.1,
                    policy="faillite")
    sim = Simulation(cfg, apps=[app]).setup()
    victim = sim.controller.primaries["app0"]
    res = sim.inject_failure(servers=[victim], run_for=10.0)
    assert not res.records["app0"].recovered
    t = res.traffic
    assert t.n_unrecovered_windows == 1
    assert t.availability < 1.0
    w = t.windows[0]
    assert not w.recovered and math.isinf(w.client_downtime)
    assert w.n_dropped > 0
    # a permanent blackout is the worst outcome, not zero downtime
    assert math.isinf(t.client_mttr_avg)
    # unrecovered windows are censored at the horizon, not dropped
    assert t.downtime_total_s > 5.0


# ---------------------------------------------------------------------------
# LoadSpike / degraded / goodput
# ---------------------------------------------------------------------------

def test_load_spike_generates_extra_requests():
    base = Scenario(name="calm", horizon=20.0, events=[])
    spiky = Scenario(name="spiky", horizon=20.0, events=[
        LoadSpike(t=2.0, factor=4.0, duration=10.0)])
    r_base = _sim().run_scenario(base)
    r_spiky = _sim().run_scenario(spiky)
    assert r_spiky.traffic.n_offered > 1.5 * r_base.traffic.n_offered
    # queueing pressure from the spike shows up in tail latency
    assert r_spiky.traffic.latency_p99 > r_base.traffic.latency_p99
    assert r_spiky.traffic.n_slo_violated > r_base.traffic.n_slo_violated


def test_progressive_failover_serves_degraded_requests():
    """Between small-variant-up and full-variant-upgrade the traffic is
    served degraded; goodput accounts for the accuracy loss."""
    ladder = synthetic_family("fam", 4.0e9, n_variants=4, spread=6.0)
    app = Application(id="app0", family="fam", variants=ladder,
                      request_rate=2.0, critical=False)
    cfg = SimConfig(n_sites=2, servers_per_site=2, headroom=0.45,
                    policy="faillite", traffic_rate_scale=100.0)
    sim = Simulation(cfg, apps=[app]).setup()
    victim = sim.controller.primaries["app0"]
    res = sim.inject_failure(servers=[victim])
    assert res.records["app0"].mode == "cold-progressive"
    t = res.traffic
    assert t.n_degraded > 0
    assert t.goodput < t.availability       # degradation costs goodput


def test_second_crash_during_progressive_upgrade_opens_window():
    """An app serving from a 'loading'-role instance (small variant up,
    selected variant still loading) must black out when that server
    crashes: the route pointed there, even though no 'primary'-role
    instance did."""
    def build():
        ladder = synthetic_family("fam", 4.0e9, n_variants=4, spread=6.0)
        app = Application(id="app0", family="fam", variants=ladder,
                          request_rate=2.0, critical=False)
        cfg = SimConfig(n_sites=2, servers_per_site=2, headroom=0.45,
                        policy="faillite", traffic_rate_scale=100.0)
        return Simulation(cfg, apps=[app]).setup()

    # throwaway run to learn (deterministically) where app0 recovers
    probe = build()
    victim = probe.controller.primaries["app0"]
    probe.inject_failure(servers=[victim])
    target = probe.controller.primaries["app0"]
    assert target != victim

    sim = build()
    assert sim.controller.primaries["app0"] == victim
    res = sim.run_scenario(Scenario(name="double", horizon=30.0, events=[
        ServerFail(t=1.0, server=victim),
        # small variant is serving from ~1.2s; the full variant is still
        # loading (role stays "loading" until the hot-swap completes)
        ServerFail(t=1.35, server=target),
    ]))
    t = res.traffic
    assert t.n_windows == 2
    assert {w.epoch for w in t.windows} == {0, 1}
    assert all(w.recovered for w in t.windows)


def test_departed_app_requests_not_offered():
    """Traffic generated for an app after its departure is excluded from
    the offered count instead of polluting availability."""
    sim = _sim()
    aid = sim.apps[0].id
    from repro.core.scenario import AppDeparture
    res = sim.run_scenario(Scenario(
        name="bye", horizon=20.0,
        events=[AppDeparture(t=5.0, app_id=aid)]))
    t = res.traffic
    assert t.n_offered > 0
    assert t.availability == pytest.approx(1.0)
    assert t.n_dropped == 0


# ---------------------------------------------------------------------------
# scenario-suite integration: every named scenario reports the plane
# ---------------------------------------------------------------------------

def test_every_named_scenario_reports_request_metrics():
    from repro.core.scenario import SCENARIOS
    from repro.core.simulation import run_scenario_suite
    cfg = SimConfig(n_sites=3, servers_per_site=3, headroom=0.25, seed=0)
    suite = run_scenario_suite(cfg, names=sorted(SCENARIOS),
                               policies=("faillite",))
    for name, by_policy in suite.items():
        t = by_policy["faillite"].traffic
        assert t is not None, name
        assert t.n_offered > 0
        assert 0.0 <= t.availability <= 1.0
        assert 0.0 <= t.goodput <= t.availability + 1e-9
        for row in t.per_epoch:
            assert set(row) == {"epoch", "n_windows", "n_dropped",
                                "client_mttr_avg", "n_unrecovered"}


def test_traffic_plane_disabled_by_zero_scale():
    sim = _sim(traffic_rate_scale=0.0)
    assert sim.traffic is None
    res = sim.run_named_scenario("single-server")
    assert res.traffic is None
    assert isinstance(res.fingerprint(), tuple)
