"""Per-kernel validation: shape/dtype sweeps, interpret mode vs the
pure-jnp oracle in ref.py."""

import jax
import jax.numpy as jnp
import pytest

pytestmark = pytest.mark.slow  # JAX compile-heavy: full CI tier only

ATOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,Sq,Skv,H,KVH,hd,causal,window", [
    (2, 64, 64, 4, 2, 32, True, 0),
    (1, 100, 100, 4, 1, 64, True, 16),       # MQA + window + ragged pad
    (2, 96, 96, 8, 8, 32, False, 0),         # MHA bidirectional
    (1, 33, 33, 2, 2, 128, True, 8),         # hd=128 MXU-width
])
def test_flash_attention(dtype, B, Sq, Skv, H, KVH, hd, causal, window):
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.flash_attention.ref import attention_ref
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = _rand(ks[0], (B, Sq, H, hd), dtype)
    k = _rand(ks[1], (B, Skv, KVH, hd), dtype)
    v = _rand(ks[2], (B, Skv, KVH, hd), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=32, block_k=32, interpret=True)
    ref = attention_ref(jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2),
                        jnp.swapaxes(v, 1, 2), causal=causal,
                        window=window)
    err = jnp.max(jnp.abs(out.astype(jnp.float32)
                          - jnp.swapaxes(ref, 1, 2).astype(jnp.float32)))
    assert float(err) < ATOL[dtype], float(err)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,KVH,hd,Smax,window", [
    (3, 8, 2, 64, 200, 0),
    (2, 4, 1, 32, 64, 16),
    (1, 16, 16, 128, 300, 0),
])
def test_decode_attention(dtype, B, H, KVH, hd, Smax, window):
    from repro.kernels.decode_attention.ops import decode_attention
    from repro.kernels.decode_attention.ref import decode_attention_ref
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    q = _rand(ks[0], (B, 1, H, hd), dtype)
    kc = _rand(ks[1], (B, Smax, KVH, hd), dtype)
    vc = _rand(ks[2], (B, Smax, KVH, hd), dtype)
    lens = jax.random.randint(ks[3], (B,), 1, Smax + 1)
    out = decode_attention(q, kc, vc, lens, window=window, block_k=64,
                           interpret=True)
    ref = decode_attention_ref(q[:, 0], jnp.swapaxes(kc, 1, 2),
                               jnp.swapaxes(vc, 1, 2), lens, window=window)
    err = jnp.max(jnp.abs(out[:, 0].astype(jnp.float32)
                          - ref.astype(jnp.float32)))
    assert float(err) < ATOL[dtype], float(err)


@pytest.mark.parametrize("B,S,W,bs,bw", [
    (2, 100, 256, 32, 128),
    (1, 64, 128, 64, 128),
    (3, 17, 256, 8, 256),
])
def test_rglru_scan(B, S, W, bs, bw):
    from repro.kernels.rglru_scan.ops import rglru_scan
    from repro.kernels.rglru_scan.ref import rglru_scan_ref
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (B, S, W))) * 0.2 + 0.8
    b = jax.random.normal(ks[1], (B, S, W)) * 0.1
    h0 = jax.random.normal(ks[2], (B, W))
    h1, hl1 = rglru_scan(a, b, h0, block_s=bs, block_w=bw, interpret=True)
    h2, hl2 = rglru_scan_ref(a, b, h0)
    assert float(jnp.max(jnp.abs(h1 - h2))) < 1e-5
    assert float(jnp.max(jnp.abs(hl1 - hl2))) < 1e-5


@pytest.mark.parametrize("B,NH,S,hs,chunk", [
    (2, 3, 70, 16, 16),
    (1, 2, 64, 32, 32),
    (2, 1, 33, 8, 8),
])
def test_wkv6(B, NH, S, hs, chunk):
    from repro.kernels.rwkv6_scan.ops import wkv6
    from repro.kernels.rwkv6_scan.ref import wkv6_ref
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    r = jax.random.normal(ks[0], (B, NH, S, hs))
    k = jax.random.normal(ks[1], (B, NH, S, hs))
    v = jax.random.normal(ks[2], (B, NH, S, hs))
    lw = -jnp.exp(jax.random.normal(ks[3], (B, NH, S, hs)) * 0.5 - 2.0)
    u = jax.random.normal(ks[4], (NH, hs)) * 0.3
    y1, s1 = wkv6(r, k, v, lw, u, chunk=chunk, interpret=True)
    y2, s2 = wkv6_ref(r, k, v, lw, u)
    assert float(jnp.max(jnp.abs(y1 - y2))) < 5e-4
    assert float(jnp.max(jnp.abs(s1 - s2))) < 5e-4


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("M,K,N", [(100, 300, 200), (64, 128, 64),
                                   (33, 65, 130)])
def test_int8_matmul(dtype, M, K, N):
    from repro.kernels.int8_matmul.ops import int8_matmul, quantize_int8
    from repro.kernels.int8_matmul.ref import int8_matmul_ref
    ks = jax.random.split(jax.random.PRNGKey(4), 2)
    x = _rand(ks[0], (M, K), dtype)
    w = jax.random.normal(ks[1], (K, N)) * 0.05
    wq, sc = quantize_int8(w)
    out = int8_matmul(x, wq, sc, block_m=32, block_n=64, block_k=128,
                      interpret=True)
    ref = int8_matmul_ref(x, wq, sc)
    err = jnp.max(jnp.abs(out.astype(jnp.float32)
                          - ref.astype(jnp.float32)))
    assert float(err) < ATOL[dtype] * 10, float(err)


def test_quantize_int8_roundtrip_quality():
    from repro.kernels.int8_matmul.int8_matmul import quantize_int8
    w = jax.random.normal(jax.random.PRNGKey(5), (256, 128)) * 0.1
    wq, sc = quantize_int8(w)
    rel = float(jnp.linalg.norm(wq.astype(jnp.float32) * sc - w)
                / jnp.linalg.norm(w))
    assert rel < 0.01
    assert wq.dtype == jnp.int8
