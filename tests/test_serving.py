"""Serving runtime: engine consistency, router semantics, and a compact
real-failure testbed integration test."""


import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow  # JAX compile-heavy: full CI tier only

from repro import configs
from repro.models import model as MDL
from repro.serving.engine import InferenceEngine, Request
from repro.serving.router import Router


def test_engine_matches_forward():
    cfg = configs.get_smoke("qwen2.5-3b")
    params = MDL.init_params(jax.random.PRNGKey(0), cfg)
    eng = InferenceEngine(cfg, params, batch_slots=2, max_len=48)
    prompt = np.arange(1, 9, dtype=np.int32)
    req = Request(id="r", prompt=prompt, max_new_tokens=3)
    assert eng.try_admit(req)
    while eng.active_count():
        eng.step()
    assert len(req.tokens) == 1 + 3
    # greedy decode must match the model's own prefill+decode
    cache = MDL.init_cache(cfg, 1, 48)
    logits, cache = MDL.prefill(params, cfg, jnp.asarray(prompt)[None],
                                cache)
    toks = [int(jnp.argmax(logits[0]))]
    for _ in range(3):
        logits, cache = MDL.decode_step(
            params, cfg, jnp.asarray([toks[-1]], jnp.int32), cache)
        toks.append(int(jnp.argmax(logits[0])))
    assert req.tokens == toks


def test_engine_slot_reuse_and_concurrency():
    cfg = configs.get_smoke("qwen2.5-3b")
    params = MDL.init_params(jax.random.PRNGKey(0), cfg)
    eng = InferenceEngine(cfg, params, batch_slots=2, max_len=48)
    reqs = [Request(id=f"r{i}", prompt=np.arange(1, 9, dtype=np.int32),
                    max_new_tokens=2) for i in range(4)]
    assert eng.try_admit(reqs[0])
    assert eng.try_admit(reqs[1])
    assert not eng.try_admit(reqs[2])       # slots full
    while eng.active_count():
        eng.step()
    assert eng.try_admit(reqs[2])           # slot freed
    assert eng.try_admit(reqs[3])
    while eng.active_count():
        eng.step()
    for r in reqs:
        assert len(r.tokens) == 3
    # same prompt, same params -> identical greedy outputs across slots
    assert reqs[0].tokens == reqs[1].tokens == reqs[2].tokens


def test_router_epoch_and_push():
    r = Router()
    seen = []
    r.subscribe(lambda a, s, v: seen.append((a, s, v)))
    r.set_route("app1", "s1", "m:full")
    assert r.lookup("app1") == ("s1", "m:full")
    e0 = r.epoch
    r.set_route("app1", "s2", "m:w050")
    assert r.epoch == e0 + 1
    assert seen[-1] == ("app1", "s2", "m:w050")


@pytest.mark.slow
def test_mini_testbed_failover_end_to_end():
    from repro.serving.testbed import MiniTestbed
    tb = MiniTestbed(apps_per_arch=1, archs=["qwen2.5-3b", "rwkv6-3b"],
                     seed=3, headroom=0.35)
    try:
        tb.deploy()
        res = tb.run_failure_experiment(observe_s=25.0, client_hz=10.0)
        assert res["detect_latency_s"] < 0.5
        s = res["summary"]
        assert s["n"] >= 1
        assert s["recovery_rate"] == 1.0
        # clients of unaffected apps kept being served
        healthy = [st for app_id, st in res["client_stats"].items()
                   if app_id not in res["records"]]
        assert all(st.ok > 0 for st in healthy)
    finally:
        tb.shutdown()
