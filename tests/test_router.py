"""Router epoch/push semantics under concurrency: strictly monotonic
epochs, exactly-once in-order delivery to subscribers during a simulated
failover storm, and consistent snapshots."""

import threading

from repro.serving.router import Router

N_THREADS = 8
N_SETS = 50


def _hammer(router, results, tid, barrier):
    barrier.wait()
    for i in range(N_SETS):
        ep = router.set_route(f"app{tid}", f"s{i % 4}", f"m:v{i % 3}")
        results[tid].append(ep)


def test_concurrent_set_route_epochs_strictly_monotonic():
    r = Router()
    results = [[] for _ in range(N_THREADS)]
    barrier = threading.Barrier(N_THREADS)
    threads = [threading.Thread(target=_hammer,
                                args=(r, results, t, barrier))
               for t in range(N_THREADS)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    all_epochs = [ep for per in results for ep in per]
    # every change got a unique epoch, no skips, no reuse
    assert sorted(all_epochs) == list(range(1, N_THREADS * N_SETS + 1))
    # per-thread view is strictly increasing (no reordering)
    for per in results:
        assert all(a < b for a, b in zip(per, per[1:]))
    assert r.epoch == N_THREADS * N_SETS


def test_subscribers_see_every_change_exactly_once_in_order():
    r = Router()
    seen = []                      # appended under the router lock
    r.subscribe_versioned(lambda ep, a, s, v: seen.append((ep, a, s, v)))
    legacy = []
    r.subscribe(lambda a, s, v: legacy.append((a, s, v)))

    results = [[] for _ in range(N_THREADS)]
    barrier = threading.Barrier(N_THREADS)
    threads = [threading.Thread(target=_hammer,
                                args=(r, results, t, barrier))
               for t in range(N_THREADS)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()

    total = N_THREADS * N_SETS
    # exactly once per change, for both subscription flavors
    assert len(seen) == total
    assert len(legacy) == total
    # in epoch order, covering every epoch
    assert [ep for ep, *_ in seen] == list(range(1, total + 1))
    # the payload delivered at epoch e matches what set_route(e) installed
    by_epoch = {ep: (a, s, v) for ep, a, s, v in seen}
    for tid, per in enumerate(results):
        for i, ep in enumerate(per):
            assert by_epoch[ep] == (f"app{tid}", f"s{i % 4}", f"m:v{i % 3}")


def test_snapshot_is_internally_consistent_under_writes():
    r = Router()
    stop = threading.Event()

    def writer():
        i = 0
        while not stop.is_set():
            r.set_route("app0", f"s{i}", f"m:v{i}")
            i += 1

    w = threading.Thread(target=writer)
    w.start()
    try:
        for _ in range(200):
            epoch, routes = r.snapshot()
            if "app0" in routes:
                sid, var = routes["app0"]
                # server and variant were written by the same set_route
                assert sid[1:] == var[3:]
            # epoch never goes backwards across snapshots
            epoch2, _ = r.snapshot()
            assert epoch2 >= epoch
    finally:
        stop.set()
        w.join()


def test_drop_route_bumps_epoch_and_clears_lookup():
    r = Router()
    e1 = r.set_route("app0", "s0", "m:full")
    assert r.lookup("app0") == ("s0", "m:full")
    e2 = r.drop_route("app0")
    assert e2 == e1 + 1
    assert r.lookup("app0") is None
    assert r.drop_route("app0") is None       # idempotent: no bump
    assert r.epoch == e2


def test_drop_route_is_pushed_so_epochs_have_no_gaps():
    """A subscriber tracking epochs must be able to tell 'route dropped'
    from 'I missed a push': drops are delivered with server=None."""
    r = Router()
    seen = []
    r.subscribe_versioned(lambda ep, a, s, v: seen.append((ep, a, s, v)))
    r.set_route("app0", "s0", "m:full")
    r.drop_route("app0")
    r.set_route("app1", "s1", "m:full")
    assert [ep for ep, *_ in seen] == [1, 2, 3]     # no gaps
    assert seen[1] == (2, "app0", None, None)


def test_late_subscriber_misses_nothing_after_subscription():
    r = Router()
    r.set_route("app0", "s0", "m:full")       # before subscription
    seen = []
    r.subscribe_versioned(lambda ep, a, s, v: seen.append(ep))
    r.set_route("app0", "s1", "m:w050")
    r.set_route("app1", "s2", "m:full")
    assert seen == [2, 3]
