"""Per-architecture smoke tests: reduced config, one forward + one train
step on CPU; asserts output shapes and absence of NaNs.

Also checks prefill+decode consistency against the full forward for every
family, which exercises all cache paths (ring-buffer local KV, recurrent
state, encoder-decoder cross-KV).
"""

import jax
import jax.numpy as jnp
import pytest

pytestmark = pytest.mark.slow  # JAX compile-heavy: full CI tier only

from repro import configs
from repro.configs.shapes import SHAPES, applicable_cells
from repro.launch.steps import make_train_step
from repro.models import model as MDL
from repro.training.optimizer import AdamW

S = 24
B = 2


def _batch(cfg, key):
    ks = jax.random.split(key, 3)
    if cfg.is_encoder_decoder:
        return {
            "frame_embeds": jax.random.normal(
                ks[0], (B, cfg.encoder_seq_len, cfg.d_model), jnp.float32),
            "tokens": jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size),
            "labels": jax.random.randint(ks[2], (B, S), 0, cfg.vocab_size),
        }
    if cfg.num_patch_tokens:
        return {
            "patch_embeds": jax.random.normal(
                ks[0], (B, cfg.num_patch_tokens, cfg.d_model), jnp.float32),
            "tokens": jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size),
            "labels": jax.random.randint(ks[2], (B, S), 0, cfg.vocab_size),
        }
    return {
        "tokens": jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(ks[2], (B, S), 0, cfg.vocab_size),
    }


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_smoke_forward_shapes(arch):
    cfg = configs.get_smoke(arch)
    params = MDL.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg, jax.random.PRNGKey(1))
    if cfg.is_encoder_decoder:
        logits, aux = MDL.forward(params, cfg, batch["tokens"],
                                  batch["frame_embeds"])
        assert logits.shape == (B, S, cfg.vocab_size)
    else:
        logits, aux = MDL.forward(params, cfg, batch["tokens"],
                                  batch.get("patch_embeds"))
        exp = S + cfg.num_patch_tokens
        assert logits.shape == (B, exp, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits)))
    assert not bool(jnp.isnan(aux))


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_smoke_train_step(arch):
    cfg = configs.get_smoke(arch)
    params = MDL.init_params(jax.random.PRNGKey(0), cfg)
    opt = AdamW(lr=1e-3, warmup_steps=1, total_steps=10)
    opt_state = opt.init(params)
    batch = _batch(cfg, jax.random.PRNGKey(1))
    step = jax.jit(make_train_step(cfg, opt))
    params2, opt_state2, metrics = step(params, opt_state, batch)
    assert jnp.isfinite(metrics["loss"])
    assert jnp.isfinite(metrics["grad_norm"])
    # parameters actually moved
    moved = jax.tree_util.tree_map(
        lambda a, b: bool(jnp.any(a != b)), params, params2)
    assert any(jax.tree_util.tree_leaves(moved))
    # second step: loss finite again (state threading is consistent)
    _, _, m2 = step(params2, opt_state2, batch)
    assert jnp.isfinite(m2["loss"])


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_smoke_prefill_decode_matches_forward(arch):
    cfg = configs.get_smoke(arch)
    params = MDL.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg, jax.random.PRNGKey(1))
    toks = batch["tokens"]
    if cfg.is_encoder_decoder:
        logits, _ = MDL.forward(params, cfg, toks, batch["frame_embeds"])
        cache = MDL.init_cache(cfg, B, S + 4)
        lp, cache = MDL.prefill(params, cfg, toks[:, :S - 1], cache,
                                batch["frame_embeds"])
        ld, cache = MDL.decode_step(params, cfg, toks[:, S - 1], cache)
    else:
        logits, _ = MDL.forward(params, cfg, toks,
                                batch.get("patch_embeds"))
        cache = MDL.init_cache(cfg, B, S + 4 + cfg.num_patch_tokens)
        lp, cache = MDL.prefill(params, cfg, toks[:, :S - 1], cache,
                                batch.get("patch_embeds"))
        ld, cache = MDL.decode_step(params, cfg, toks[:, S - 1], cache)
    assert jnp.allclose(lp, logits[:, -2], atol=2e-4), (
        float(jnp.max(jnp.abs(lp - logits[:, -2]))))
    assert jnp.allclose(ld, logits[:, -1], atol=2e-4), (
        float(jnp.max(jnp.abs(ld - logits[:, -1]))))


def test_shape_cells_cover_40():
    cells = [(a, s) for a in configs.ARCHS for s in SHAPES]
    assert len(cells) == 40
    runnable = [(a, s) for a in configs.ARCHS for s in applicable_cells(a)]
    # 3 archs run long_500k; 7 skip it
    assert len(runnable) == 33


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_param_count_sane(arch):
    cfg = configs.get_config(arch)
    n = cfg.param_count()
    assert n > 1e8, f"{arch}: {n}"
    a = cfg.active_param_count()
    assert a <= n
    if cfg.num_experts:
        assert a < n
