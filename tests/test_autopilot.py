"""SLO autopilot + chaos stream: decision-engine unit tests, seeded
chaos determinism, scheduler boost reordering, and end-to-end autopilot
run reproducibility."""

from repro.core.autopilot import (AppSignal, AutopilotConfig,
                                  AutopilotPolicy, AutopilotView)
from repro.core.chaos import build_chaos, chaos_events
from repro.core.controller import RecoveryScheduler
from repro.core.scenario import (ServerFail, ServerRejoin, SiteFail,
                                 build_scenario)
from repro.core.simulation import SimConfig, Simulation
from repro.core.variants import Application, synthetic_family


def _sim(**kw):
    base = dict(n_sites=3, servers_per_site=4, headroom=0.2,
                policy="faillite", seed=0)
    base.update(kw)
    return Simulation(SimConfig(**base)).setup()


def _app(aid, rate, critical=False):
    return Application(id=aid, family=f"fam-{aid}",
                       variants=synthetic_family(f"fam-{aid}", 2e9),
                       request_rate=rate, critical=critical)


def _view(apps, rates, *, now=0.0, warm=None, fails=(), pilot=None):
    prot = (pilot.protected if pilot is not None
            and pilot.protected is not None else None)
    return AutopilotView(
        now=now, apps={a.id: a for a in apps},
        warm_ids=set(warm if warm is not None
                     else (prot or [a.id for a in apps if a.critical])),
        signals={aid: AppSignal(rate=r) for aid, r in rates.items()},
        fail_times=list(fails))


# ---------------------------------------------------------------------------
# chaos stream
# ---------------------------------------------------------------------------

def test_chaos_scenario_deterministic_and_valid():
    sim = _sim()
    a = build_scenario("chaos", sim.cluster, sim.apps, seed=3)
    b = build_scenario("chaos", sim.cluster, sim.apps, seed=3)
    assert [repr(e) for e in a.events] == [repr(e) for e in b.events]
    assert a.horizon == b.horizon
    a.validate(sim.cluster)
    c = build_scenario("chaos", sim.cluster, sim.apps, seed=4)
    assert [repr(e) for e in a.events] != [repr(e) for e in c.events]


def test_chaos_every_crash_gets_a_rejoin():
    sim = _sim()
    import random
    for seed in range(5):
        sc = build_chaos(sim.cluster, random.Random(seed))
        downs = [e for e in sc.events
                 if isinstance(e, (ServerFail, SiteFail))]
        rejoins = [e for e in sc.events if isinstance(e, ServerRejoin)]
        assert downs, f"seed {seed}: stream must contain a failure"
        n_crashed = sum(
            len(sim.cluster.sites[e.site]) if isinstance(e, SiteFail)
            else 1 for e in downs)
        assert len(rejoins) == n_crashed
        assert sc.horizon >= max(e.t for e in sc.events)


def test_chaos_respects_max_down_fraction():
    sim = _sim()
    import random
    from repro.core.chaos import ChaosConfig
    cfg = ChaosConfig(duration=300.0, mean_gap_s=1.0)
    n = len(sim.cluster.servers)
    for seed in range(3):
        events = chaos_events(sim.cluster, random.Random(seed), cfg)
        down_until = {sid: 0.0 for sid in sim.cluster.servers}
        for e in sorted(events, key=lambda e: e.t):
            if isinstance(e, ServerFail):
                down_until[e.server] = float("inf")
            elif isinstance(e, SiteFail):
                for sid in sim.cluster.sites[e.site]:
                    down_until[sid] = float("inf")
            elif isinstance(e, ServerRejoin):
                down_until[e.server] = 0.0
            n_down = sum(1 for v in down_until.values() if v > e.t)
            assert n_down <= cfg.max_down_frac * n + 1e-9


# ---------------------------------------------------------------------------
# decision engine
# ---------------------------------------------------------------------------

def test_autopilot_promotes_hot_app_within_static_budget():
    apps = [_app("crit", 5.0, critical=True), _app("hot", 1.0),
            _app("cold", 0.5)]
    pilot = AutopilotPolicy()
    # observed traffic inverts the configured picture: "hot" dominates
    dec = pilot.decide(_view(apps, {"crit": 0.1, "hot": 50.0,
                                    "cold": 0.2}))
    assert dec.budget == 1                 # one critical app = one slot
    assert dec.protected == ["hot"]
    assert dec.promote == ["hot"] and dec.demote == ["crit"]


def test_autopilot_hysteresis_keeps_incumbent_on_small_edge():
    apps = [_app("a", 5.0, critical=True), _app("b", 1.0)]
    pilot = AutopilotPolicy(AutopilotConfig(rate_ewma=1.0))
    pilot.decide(_view(apps, {"a": 10.0, "b": 1.0}))
    # challenger 5% ahead: inside the 15% swap margin -> no move
    dec = pilot.decide(_view(apps, {"a": 10.0, "b": 10.5}))
    assert dec.protected == ["a"] and not dec.demote


def test_autopilot_move_cap_limits_swaps_per_sweep():
    apps = ([_app(f"c{i}", 1.0, critical=True) for i in range(4)]
            + [_app(f"n{i}", 1.0) for i in range(4)])
    pilot = AutopilotPolicy(AutopilotConfig(rate_ewma=1.0, max_moves=2))
    rates = {f"c{i}": 1.0 for i in range(4)}
    rates.update({f"n{i}": 100.0 for i in range(4)})
    dec = pilot.decide(_view(apps, rates))
    assert len(dec.promote) == 2           # capped despite 4 challengers
    assert len(dec.protected) == 4         # budget still filled


def test_autopilot_replication_bumps_with_hazard():
    apps = [_app("a", 1.0, critical=True)]
    pilot = AutopilotPolicy()
    calm = pilot.decide(_view(apps, {"a": 1.0}, now=100.0))
    assert calm.hazard == 0 and calm.replication == 2
    hot = pilot.decide(_view(apps, {"a": 1.0}, now=100.0,
                             fails=[80.0, 85.0, 95.0]))
    assert hot.hazard == 3 and hot.replication == 4
    mild = pilot.decide(_view(apps, {"a": 1.0}, now=100.0,
                              fails=[95.0]))
    assert mild.replication == 3


def test_autopilot_trough_shrinks_budget_and_snaps_back():
    cfg = AutopilotConfig(diurnal_amplitude=0.5, diurnal_period=100.0,
                          lead_s=5.0, calm_frac=0.5)
    apps = [_app(f"c{i}", 1.0, critical=True) for i in range(4)]
    pilot = AutopilotPolicy(cfg)
    rates = {a.id: 1.0 for a in apps}
    # find a trough instant and a peak instant of the diurnal model
    trough_t = min((pilot._factor(t), t)
                   for t in range(0, 100, 5))[1]
    peak_t = max((pilot._factor(t), t) for t in range(0, 100, 5))[1]
    assert pilot.in_trough(trough_t) and not pilot.in_trough(peak_t)
    low = pilot.decide(_view(apps, rates, now=trough_t))
    assert low.budget == 2                 # ceil(4 * 0.5)
    full = pilot.decide(_view(apps, rates, now=peak_t))
    assert full.budget == 4
    # hazard overrides the trough: never shed protection mid-incident
    risky = pilot.decide(_view(apps, rates, now=trough_t,
                               fails=[trough_t - 1.0]))
    assert risky.budget == 4


# ---------------------------------------------------------------------------
# scheduler boosts
# ---------------------------------------------------------------------------

class _RecordingExecutor:
    """Stub executor: records dispatch order, completes on demand."""

    def __init__(self):
        self.order = []
        self.pending = []

    def load(self, app, variant, server_id, on_ready):
        self.order.append(app.id)
        self.pending.append(on_ready)
        return None


def test_scheduler_boosts_reorder_criticality_drain():
    ex = _RecordingExecutor()
    sched = RecoveryScheduler(ex, mode="criticality")
    sched.set_boosts({"slow": 100.0})
    apps = [_app("first", 9.0), _app("fast", 5.0), _app("slow", 1.0)]
    for a in apps:
        sched.submit(a, a.smallest, "s0", lambda t: None)
    # "first" dispatched immediately; completing it must drain the
    # boosted low-rate app before the higher-rate unboosted one
    assert ex.order == ["first"]
    ex.pending[0](1.0)
    ex.pending[1](2.0)
    assert ex.order == ["first", "slow", "fast"]


def test_scheduler_without_boosts_keeps_rate_order():
    ex = _RecordingExecutor()
    sched = RecoveryScheduler(ex, mode="criticality")
    apps = [_app("first", 9.0), _app("fast", 5.0), _app("slow", 1.0)]
    for a in apps:
        sched.submit(a, a.smallest, "s0", lambda t: None)
    ex.pending[0](1.0)
    ex.pending[1](2.0)
    assert ex.order == ["first", "fast", "slow"]


# ---------------------------------------------------------------------------
# end to end
# ---------------------------------------------------------------------------

def test_autopilot_chaos_run_is_deterministic():
    def run():
        sim = _sim(autopilot=True, traffic_diurnal_amplitude=0.5,
                   traffic_diurnal_period=120.0)
        return sim.run_named_scenario("chaos").fingerprint()

    assert run() == run()


def test_autopilot_off_path_has_no_policy_attached():
    sim = _sim()
    assert sim.controller.autopilot is None
    on = _sim(autopilot=True)
    assert on.controller.autopilot is not None
    # before the first sweep the static criticality rule still applies
    assert on.controller.autopilot.protected is None
