"""Training substrate: optimizer convergence, checkpoint roundtrip +
elastic restore, deterministic seekable data."""

import jax
import numpy as np
import pytest

from repro import configs
from repro.launch.steps import make_train_step
from repro.models import model as MDL
from repro.training import checkpoint as CKPT
from repro.training.data import DataConfig, SyntheticTokenStream
from repro.training.optimizer import AdamW


def _setup(arch="qwen2.5-3b"):
    cfg = configs.get_smoke(arch)
    params = MDL.init_params(jax.random.PRNGKey(0), cfg)
    opt = AdamW(lr=1e-3, warmup_steps=2, total_steps=50)
    return cfg, params, opt


def test_loss_decreases():
    cfg, params, opt = _setup()
    opt_state = opt.init(params)
    step = jax.jit(make_train_step(cfg, opt))
    data = SyntheticTokenStream(DataConfig(cfg.vocab_size, 4, 32))
    first = last = None
    for i in range(25):
        params, opt_state, m = step(params, opt_state, data.batch(0))
        if first is None:
            first = float(m["loss"])
        last = float(m["loss"])
    assert last < first * 0.9, (first, last)


def test_checkpoint_roundtrip(tmp_path):
    cfg, params, opt = _setup()
    opt_state = opt.init(params)
    step = jax.jit(make_train_step(cfg, opt))
    data = SyntheticTokenStream(DataConfig(cfg.vocab_size, 2, 16))
    for i in range(3):
        params, opt_state, _ = step(params, opt_state, data.batch(i))
    CKPT.save_checkpoint(tmp_path, 3, params, opt_state)
    assert CKPT.latest_step(tmp_path) == 3

    tmpl_p = MDL.init_params(jax.random.PRNGKey(0), cfg)
    tmpl_o = opt.init(tmpl_p)
    step_r, params_r, opt_r, _ = CKPT.restore_checkpoint(
        tmp_path, 3, tmpl_p, tmpl_o)
    assert step_r == 3
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(params_r)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # training continues identically from the restored state
    p1, _, m1 = step(params, opt_state, data.batch(3))
    p2, _, m2 = step(params_r, opt_r, data.batch(3))
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-6)


def test_checkpoint_atomicity(tmp_path):
    cfg, params, opt = _setup()
    CKPT.save_checkpoint(tmp_path, 1, params)
    CKPT.save_checkpoint(tmp_path, 2, params)
    assert CKPT.latest_step(tmp_path) == 2
    # a partially-written (tmp) dir is never visible as a checkpoint
    stray = tmp_path / ".tmp_partial"
    stray.mkdir()
    assert CKPT.latest_step(tmp_path) == 2


def test_data_deterministic_and_seekable():
    d = SyntheticTokenStream(DataConfig(1000, 8, 32, seed=7))
    b1 = d.batch(5)
    b2 = d.batch(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = d.batch(6)
    assert not np.array_equal(b1["tokens"], b3["tokens"])


def test_data_host_sharding_partitions_batch():
    d = SyntheticTokenStream(DataConfig(1000, 8, 16, seed=1))
    full = d.batch(0)
    parts = [d.host_shard(0, i, 4) for i in range(4)]
    glued = np.concatenate([p["tokens"] for p in parts], axis=0)
    np.testing.assert_array_equal(full["tokens"], glued)


def test_train_driver_resume(tmp_path):
    from repro.launch.train import train
    r1 = train(arch="qwen2.5-3b", scale="toy", steps=6, batch=2, seq=16,
               ckpt_every=3, ckpt_dir=str(tmp_path),
               simulate_failure_at=4)
    assert r1["crashed_at"] == 4
    r2 = train(arch="qwen2.5-3b", scale="toy", steps=6, batch=2, seq=16,
               ckpt_every=3, ckpt_dir=str(tmp_path), resume=True)
    assert len(r2["losses"]) == 3      # resumed from step 3
