"""Sharding rules: spec filtering properties, param-spec coverage, and a
small-mesh dry-run (subprocess — device count must be set pre-jax-init)."""

import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.parallel import sharding as SH

# The filter_spec divisibility property test lives in
# tests/test_properties.py (hypothesis-based, skips without the dep).


class FakeMesh:
    def __init__(self, sizes):
        self.axis_names = tuple(sizes)
        self.devices = np.empty(tuple(sizes.values()))
        self.axis_sizes = tuple(sizes.values())


def test_current_mesh_abstract_path():
    """The non-deprecated abstract-mesh discovery is probed FIRST and
    wins without touching the legacy pxla fallback."""
    assert SH.current_mesh() is None
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    if hasattr(jax.sharding, "use_mesh"):          # newer jax
        ctx = jax.sharding.use_mesh(mesh)
    else:                                          # pre-public-export jax
        from jax._src import mesh as mesh_lib
        ctx = mesh_lib.set_abstract_mesh(mesh.abstract_mesh)
    with ctx:
        am = SH._mesh_from_abstract()
        assert am is not None
        assert tuple(am.axis_names) == ("data", "model")
        # the pxla probe sees nothing here: only the abstract path hits
        got = SH.current_mesh()
        assert got is not None
        assert tuple(got.axis_names) == ("data", "model")
    assert SH._mesh_from_abstract() is None
    assert SH.current_mesh() is None


def test_current_mesh_pxla_fallback_path():
    """The legacy `with Mesh(...):` context still resolves, through the
    fallback probe."""
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with mesh:
        pm = SH._mesh_from_pxla()
        assert pm is not None and not pm.empty
        assert tuple(pm.axis_names) == ("data", "model")
        got = SH.current_mesh()
        assert got is not None
        assert tuple(got.axis_names) == ("data", "model")
    assert SH._mesh_from_pxla() is None
    assert SH.current_mesh() is None


def test_param_specs_cover_all_archs():
    """Every parameter of every full config gets a valid spec and the
    big tensors are actually sharded on the production mesh."""
    from repro import configs
    from repro.models import model as MDL
    for arch in ["qwen2.5-3b", "rwkv6-3b", "recurrentgemma-2b",
                 "whisper-medium", "qwen3-moe-30b-a3b"]:
        cfg = configs.get_smoke(arch)
        shapes = MDL.param_shapes(cfg)
        specs = SH.param_specs(shapes)
        n_leaves = len(jax.tree_util.tree_leaves(
            shapes, is_leaf=lambda x: hasattr(x, "shape")))
        n_specs = len(jax.tree_util.tree_leaves(
            specs, is_leaf=lambda x: isinstance(x, P)))
        assert n_leaves == n_specs, arch


def test_decode_cache_shardings_long_context():
    """Batch-1 long-context caches shard the sequence dim instead."""
    from repro.parallel.sharding import decode_cache_shardings
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cache_shapes = {
        "pos": jax.ShapeDtypeStruct((1,), jnp.int32),
        "cycles": [{"k": jax.ShapeDtypeStruct((4, 1, 1024, 2, 64),
                                              jnp.bfloat16),
                    "v": jax.ShapeDtypeStruct((4, 1, 1024, 2, 64),
                                              jnp.bfloat16)}],
        "tail": [],
    }
    sh = decode_cache_shardings(cache_shapes, mesh)
    # on the 1x1 mesh everything degrades to replicated — just structural
    assert sh["cycles"][0]["k"] is not None


@pytest.mark.slow
def test_dryrun_small_mesh_subprocess():
    """Lower+compile a smoke config on 8 fake devices (fresh process)."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
from repro import configs
from repro.launch.dryrun import _lower_one
from repro.configs.shapes import ShapeCell
from repro.training.optimizer import AdamW

mesh = jax.make_mesh((2, 4), ("data", "model"))
cfg = configs.get_smoke("qwen2.5-3b").replace(
    param_dtype="bfloat16", remat=True)
shape = ShapeCell("t", "train", 64, 8)
lowered, compiled = _lower_one(cfg, shape, mesh, AdamW())
assert compiled.memory_analysis().temp_size_in_bytes >= 0
cost = compiled.cost_analysis()
if isinstance(cost, list):      # older jaxlib: one dict per computation
    cost = cost[0] if cost else {}
assert cost.get("flops", 0) > 0
print("SMALL-MESH-DRYRUN-OK")
"""
    import os
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        timeout=300, cwd=root,
        # sanitized env; JAX_PLATFORMS=cpu keeps a locally-installed TPU
        # plugin from probing cloud metadata (hangs in sandboxes)
        env={"PYTHONPATH": os.path.join(root, "src"),
             "PATH": os.environ.get("PATH", "/usr/bin:/bin"),
             "HOME": os.environ.get("HOME", "/tmp"),
             "JAX_PLATFORMS": "cpu"})
    assert "SMALL-MESH-DRYRUN-OK" in out.stdout, out.stderr[-2000:]
