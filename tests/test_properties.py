"""Hypothesis property tests (placement invariants, sharding specs).

Kept in their own module so environments without `hypothesis` still run
the full deterministic tier-1 suite; here the whole module skips.
"""

import random

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.cluster import RESOURCES, make_cluster  # noqa: E402
from repro.core.metrics import (DOWN, UP, DowntimeWindow,  # noqa: E402
                                classify_app)
from repro.core.planner import faillite_heuristic, match  # noqa: E402
from repro.core.resilience import (CLOSED, CircuitBreaker,  # noqa: E402
                                   ResilienceConfig, shape_app_log)
from repro.core.variants import Application, synthetic_family  # noqa: E402


def _apps(rng, n, mem_range=(0.5e9, 4e9), spread=6.0, critical_frac=0.5):
    out = []
    for i in range(n):
        lad = synthetic_family(f"f{i}", rng.uniform(*mem_range),
                               n_variants=4, spread=spread)
        out.append(Application(id=f"a{i}", family=f"f{i}", variants=lad,
                               request_rate=rng.uniform(0.5, 2.0),
                               critical=rng.random() < critical_frac))
    return out


# ---------------------------------------------------------------------------
# Algorithm 1 properties
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000),
       n_apps=st.integers(1, 20),
       n_servers=st.integers(2, 12),
       alpha=st.floats(0.0, 0.5))
def test_heuristic_feasible(seed, n_apps, n_servers, alpha):
    """Placements never exceed per-server free capacity nor the α budget,
    and never use excluded servers."""
    rng = random.Random(seed)
    cluster = make_cluster(1, n_servers, mem=16e9)
    apps = _apps(rng, n_apps)
    exclude = {a.id: {f"s0-{rng.randrange(n_servers)}"} for a in apps}
    res = faillite_heuristic(apps, cluster, exclude=exclude, alpha=alpha)

    used = {s.id: {r: 0.0 for r in RESOURCES}
            for s in cluster.alive_servers()}
    total = {r: 0.0 for r in RESOURCES}
    for app_id, (v, sid) in res.assignment.items():
        assert sid not in exclude[app_id]
        for r in RESOURCES:
            used[sid][r] += v.demand[r]
            total[r] += v.demand[r]
    for s in cluster.alive_servers():
        for r in RESOURCES:
            assert used[s.id][r] <= s.free(r) + 1e-6
    free_total = cluster.total_free()
    for r in RESOURCES:
        assert total[r] <= (1 - alpha) * free_total[r] + 1e-6
    # every app is either assigned or reported unplaced
    assert (set(res.assignment) | set(res.unplaced)
            == {a.id for a in apps})


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), delta=st.floats(0.01, 2.0))
def test_match_selects_within_delta(seed, delta):
    rng = random.Random(seed)
    lad = synthetic_family("f", rng.uniform(1e9, 8e9), n_variants=5,
                           spread=8.0)
    j = match(lad, delta)
    assert 0 <= j < len(lad)
    if delta >= 1.0:
        assert j == 0
    elif j < len(lad) - 1:
        # chosen variant obeys the δ bound (unless only smallest remains)
        assert all(lad[j].demand[r] <= delta * lad[0].demand[r] + 1e-6
                   for r in RESOURCES)


# ---------------------------------------------------------------------------
# sharding-spec properties
# ---------------------------------------------------------------------------

class FakeMesh:
    def __init__(self, sizes):
        self.axis_names = tuple(sizes)
        self.devices = np.empty(tuple(sizes.values()))
        self.axis_sizes = tuple(sizes.values())


@settings(max_examples=50, deadline=None)
@given(d0=st.sampled_from([1, 2, 3, 8, 16, 64, 256]),
       d1=st.sampled_from([1, 2, 5, 16, 128, 151936]),
       data=st.sampled_from([1, 2, 4, 16]),
       model=st.sampled_from([1, 2, 4, 16]))
def test_filter_spec_always_divisible(d0, d1, data, model):
    from jax.sharding import PartitionSpec as P

    from repro.parallel import sharding as SH

    mesh = FakeMesh({"data": data, "model": model})
    spec = SH.filter_spec(P(("pod", "data"), "model"), mesh, (d0, d1))
    sizes = {"data": data, "model": model}
    for dim, entry in zip((d0, d1), spec):
        if entry is None:
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        f = int(np.prod([sizes[a] for a in axes]))
        assert dim % f == 0
        assert "pod" not in axes            # absent axes dropped


# ---------------------------------------------------------------------------
# shard-group conservation (core/shardgroup.py)
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000),
       policy=st.sampled_from(["auto", "degrade", "reshard", "monolith"]),
       kills=st.lists(st.integers(0, 7), min_size=1, max_size=5),
       rejoin=st.booleans())
def test_shard_group_conservation_under_arbitrary_failures(
        seed, policy, kills, rejoin):
    """Whatever sequence of ShardFail/ServerFail/rejoin events hits a
    tensor-parallel deployment, every shard group ends the run in a
    coherent state: member count matches the group state machine (live
    = k members, degraded/resharding = 1..k-1, fallen-back = 0), no
    member sits on a dead server, and pending reshard placements exist
    exactly in the resharding state — check_conservation() holds."""
    from repro.core.scenario import (Scenario, ServerFail, ServerRejoin,
                                     ShardFail)
    from repro.core.simulation import SimConfig, Simulation

    rng = random.Random(seed)

    def build(cluster, _rng):
        sids = sorted(s.id for s in cluster.alive_servers())
        events, t = [], 1.0
        for i, k in enumerate(kills):
            sid = sids[k % len(sids)]
            ev = (ShardFail if i % 2 == 0 else ServerFail)
            events.append(ev(t=t, server=sid))
            if rejoin and i == 0:
                events.append(ServerRejoin(t=t + 4.0, server=sid))
            t += 3.0
        return Scenario(name="prop-shard", events=events, horizon=t + 20.0)

    sim = Simulation(SimConfig(
        seed=rng.randrange(1 << 30), n_sites=3, servers_per_site=3,
        headroom=0.25, tp_degree=2, shard_policy=policy,
        traffic_rate_scale=0.0))
    sim.run_scenario(build(sim.cluster, rng))
    assert sim.shards is not None
    sim.shards.check_conservation()
    dead = {s.id for s in sim.cluster.servers.values() if not s.alive}
    for g in sim.shards.groups.values():
        for m in g.members.values():
            assert m.server_id not in dead, (g.app_id, m.server_id)


# ---------------------------------------------------------------------------
# resilience-layer properties (core/resilience.py)
# ---------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 10_000),
       n=st.integers(0, 300),
       has_backup=st.booleans(),
       recovered=st.booleans(),
       drain=st.booleans(),
       retry_budget=st.floats(0.0, 1.0),
       admit_util=st.floats(0.3, 0.95))
def test_shaping_classifies_every_request_exactly_once(
        seed, n, has_backup, recovered, drain, retry_budget, admit_util):
    """Conservation invariant: after the vectorized resilience shaping,
    every offered request lands in EXACTLY one terminal class of
    {served-plain, hedged-win, retried, dropped, fast-failed, shed},
    and hedged/retried/degraded/SLO-violated stay subsets of served."""
    rng = np.random.default_rng(seed)
    arrivals = np.sort(rng.uniform(0.0, 10.0, n))
    rates = rng.uniform(0.2, 6.0, n)
    # one blackout [2, 4) on an otherwise-UP timeline
    times = np.array([0.0, 2.0] + ([4.0] if recovered else []))
    states = np.array([UP, DOWN] + ([UP] if recovered else []))
    accs = np.full(len(times), 0.9)
    svcs = np.full(len(times), 0.01)
    log = classify_app("a", arrivals, rates, times, states, accs, svcs,
                       full_accuracy=0.9, slo=0.2,
                       jitter_rng=np.random.default_rng(seed + 1))
    w = DowntimeWindow("a", epoch=0, t_start=2.0,
                       t_end=4.0 if recovered else np.inf,
                       backup=(0.8, 0.02) if has_backup else None)
    cfg = ResilienceConfig(enabled=True, retry_budget=retry_budget,
                           admit_util=admit_util)
    out = shape_app_log(log, rates, times=times, states=states,
                        accs=accs, svcs=svcs, windows=[w],
                        drains=[(3.0, 7.0)] if drain else [],
                        full_accuracy=0.9, slo=0.2,
                        util_k=2.0, util_cap=0.9, rcfg=cfg)
    classes = np.stack([out.served & ~out.hedged & ~out.retried,
                        out.hedged, out.retried, out.dropped,
                        out.fast_failed, out.shed])
    assert np.array_equal(classes.sum(axis=0),
                          out.offered.astype(int))
    assert not np.any(out.hedged & ~out.served)
    assert not np.any(out.retried & ~out.served)
    assert not np.any(out.degraded & ~out.served)
    assert not np.any(out.slo_violated & ~out.served)
    # served requests carry finite accuracy/latency; shed carry neither
    assert np.all(np.isfinite(out.accuracy[out.served]))
    assert np.all(np.isfinite(out.latency[out.served]))
    assert not np.any(np.isfinite(out.latency[out.shed]))


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 10_000),
       outcomes=st.lists(st.booleans(), max_size=40),
       open_s=st.floats(0.05, 2.0))
def test_breaker_never_stays_open_against_healthy_backend(
        seed, outcomes, open_s):
    """Liveness: whatever outcome history tripped (or didn't trip) the
    breaker, once the backend is healthy the open window expires, a
    probe is granted, and one probe success closes the breaker."""
    clock = {"t": 0.0}
    br = CircuitBreaker(ResilienceConfig(enabled=True,
                                         breaker_open_s=open_s),
                        clock=lambda: clock["t"])
    rng = random.Random(seed)
    for ok in outcomes:
        clock["t"] += rng.uniform(0.0, 0.2)
        if br.allow():
            br.record(ok)
    clock["t"] += open_s + 1e-9            # any open window expires
    assert br.allow()                      # probe (or plain closed pass)
    br.record(True)                        # healthy backend answers
    assert br.state == CLOSED
    assert br.allow()
