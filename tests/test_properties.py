"""Hypothesis property tests (placement invariants, sharding specs).

Kept in their own module so environments without `hypothesis` still run
the full deterministic tier-1 suite; here the whole module skips.
"""

import random

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.cluster import RESOURCES, make_cluster  # noqa: E402
from repro.core.planner import faillite_heuristic, match  # noqa: E402
from repro.core.variants import Application, synthetic_family  # noqa: E402


def _apps(rng, n, mem_range=(0.5e9, 4e9), spread=6.0, critical_frac=0.5):
    out = []
    for i in range(n):
        lad = synthetic_family(f"f{i}", rng.uniform(*mem_range),
                               n_variants=4, spread=spread)
        out.append(Application(id=f"a{i}", family=f"f{i}", variants=lad,
                               request_rate=rng.uniform(0.5, 2.0),
                               critical=rng.random() < critical_frac))
    return out


# ---------------------------------------------------------------------------
# Algorithm 1 properties
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000),
       n_apps=st.integers(1, 20),
       n_servers=st.integers(2, 12),
       alpha=st.floats(0.0, 0.5))
def test_heuristic_feasible(seed, n_apps, n_servers, alpha):
    """Placements never exceed per-server free capacity nor the α budget,
    and never use excluded servers."""
    rng = random.Random(seed)
    cluster = make_cluster(1, n_servers, mem=16e9)
    apps = _apps(rng, n_apps)
    exclude = {a.id: {f"s0-{rng.randrange(n_servers)}"} for a in apps}
    res = faillite_heuristic(apps, cluster, exclude=exclude, alpha=alpha)

    used = {s.id: {r: 0.0 for r in RESOURCES}
            for s in cluster.alive_servers()}
    total = {r: 0.0 for r in RESOURCES}
    for app_id, (v, sid) in res.assignment.items():
        assert sid not in exclude[app_id]
        for r in RESOURCES:
            used[sid][r] += v.demand[r]
            total[r] += v.demand[r]
    for s in cluster.alive_servers():
        for r in RESOURCES:
            assert used[s.id][r] <= s.free(r) + 1e-6
    free_total = cluster.total_free()
    for r in RESOURCES:
        assert total[r] <= (1 - alpha) * free_total[r] + 1e-6
    # every app is either assigned or reported unplaced
    assert (set(res.assignment) | set(res.unplaced)
            == {a.id for a in apps})


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), delta=st.floats(0.01, 2.0))
def test_match_selects_within_delta(seed, delta):
    rng = random.Random(seed)
    lad = synthetic_family("f", rng.uniform(1e9, 8e9), n_variants=5,
                           spread=8.0)
    j = match(lad, delta)
    assert 0 <= j < len(lad)
    if delta >= 1.0:
        assert j == 0
    elif j < len(lad) - 1:
        # chosen variant obeys the δ bound (unless only smallest remains)
        assert all(lad[j].demand[r] <= delta * lad[0].demand[r] + 1e-6
                   for r in RESOURCES)


# ---------------------------------------------------------------------------
# sharding-spec properties
# ---------------------------------------------------------------------------

class FakeMesh:
    def __init__(self, sizes):
        self.axis_names = tuple(sizes)
        self.devices = np.empty(tuple(sizes.values()))
        self.axis_sizes = tuple(sizes.values())


@settings(max_examples=50, deadline=None)
@given(d0=st.sampled_from([1, 2, 3, 8, 16, 64, 256]),
       d1=st.sampled_from([1, 2, 5, 16, 128, 151936]),
       data=st.sampled_from([1, 2, 4, 16]),
       model=st.sampled_from([1, 2, 4, 16]))
def test_filter_spec_always_divisible(d0, d1, data, model):
    from jax.sharding import PartitionSpec as P

    from repro.parallel import sharding as SH

    mesh = FakeMesh({"data": data, "model": model})
    spec = SH.filter_spec(P(("pod", "data"), "model"), mesh, (d0, d1))
    sizes = {"data": data, "model": model}
    for dim, entry in zip((d0, d1), spec):
        if entry is None:
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        f = int(np.prod([sizes[a] for a in axes]))
        assert dim % f == 0
        assert "pod" not in axes            # absent axes dropped
