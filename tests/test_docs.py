"""Docs stay healthy: intra-repo markdown links resolve and the
runnable snippets in docs/ + README execute (same machinery as the CI
docs job, tools/check_docs.py)."""

import sys
from pathlib import Path


ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "tools"))

import check_docs  # noqa: E402


def test_docs_exist():
    assert (ROOT / "docs" / "ARCHITECTURE.md").is_file()
    assert (ROOT / "docs" / "SCENARIOS.md").is_file()


def test_intra_repo_markdown_links_resolve():
    paths = sorted({p for g in check_docs.LINK_FILES_GLOB
                    for p in ROOT.glob(g) if p.is_file()})
    assert paths
    errors = check_docs.check_links(paths)
    assert errors == []


def test_docs_reference_the_traffic_plane():
    arch = (ROOT / "docs" / "ARCHITECTURE.md").read_text()
    scen = (ROOT / "docs" / "SCENARIOS.md").read_text()
    for needle in ("traffic.py", "metrics.py", "client-observed"):
        assert needle in arch
    for needle in ("client-observed MTTR", "goodput", "LoadSpike"):
        assert needle in scen


def test_doc_snippets_execute():
    paths = [ROOT / f for f in check_docs.SNIPPET_FILES]
    snippets = [s for p in paths for s in check_docs.iter_snippets(p)]
    assert len(snippets) >= 5, "docs lost their runnable snippets"
    errors = check_docs.run_snippets(paths)
    assert errors == []
