"""Weight-stationary serving layout + engine slot-cache helpers."""

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.parallel.sharding import _drop_axes


def test_drop_axes_variants():
    assert _drop_axes(P("data", "model"), {"data"}) == P(None, "model")
    assert _drop_axes(P(("pod", "data"), None), {"data", "pod"}) == \
        P(None, None)
    assert _drop_axes(P(("pod", "model"), "data"), {"pod", "data"}) == \
        P("model", None)
    assert _drop_axes(P("model", None, "data"), {"data"}) == \
        P("model", None, None)


def test_serving_param_shardings_drop_fsdp():
    from repro import configs
    from repro.models import model as MDL
    from repro.parallel import sharding as SH

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = configs.get_smoke("qwen2.5-3b")
    shapes = MDL.param_shapes(cfg)
    sh_serve = SH.param_shardings(shapes, mesh, serving=True)

    def specs(tree):
        return [s.spec for s in jax.tree_util.tree_leaves(
            tree, is_leaf=lambda x: hasattr(x, "spec"))]
    for sp in specs(sh_serve):
        for entry in sp:
            axes = entry if isinstance(entry, tuple) else (entry,)
            assert "data" not in axes and "pod" not in axes


def test_cache_slot_roundtrip():
    from repro import configs
    from repro.models import model as MDL
    cfg = configs.get_smoke("recurrentgemma-2b")   # mixed kv + rnn caches
    cache = MDL.init_cache(cfg, 3, 16)
    # write a distinguishable value into slot 1, read it back
    sub = MDL.cache_take_slot(cache, 1)
    sub = jax.tree_util.tree_map(lambda t: jnp.ones_like(t), sub)
    cache2 = MDL.cache_put_slot(cache, 1, sub)
    back = MDL.cache_take_slot(cache2, 1)
    for leaf in jax.tree_util.tree_leaves(back):
        np.testing.assert_allclose(np.asarray(leaf, np.float32), 1.0)
    other = MDL.cache_take_slot(cache2, 0)
    for leaf in jax.tree_util.tree_leaves(other):
        np.testing.assert_allclose(np.asarray(leaf, np.float32), 0.0)
