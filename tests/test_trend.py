"""Trend gate + RunResult JSON schema: the CI regression net.

Loads tools/check_trend.py by path (tools/ is not a package) and
exercises the comparator's contract: an identical trend passes,
an injected p99 regression fails, in-band noise is tolerated, the
no-data sentinel rules hold, and a gate that matches zero rows fails
rather than passing vacuously. Also pins the RunResult JSON schema the
soak rows are built from."""

import copy
import importlib.util
import json
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "src"))


def _load_tool(name):
    spec = importlib.util.spec_from_file_location(
        name, ROOT / "tools" / f"{name}.py")
    mod = importlib.util.module_from_spec(spec)
    sys.modules[name] = mod    # dataclasses resolve via sys.modules
    spec.loader.exec_module(mod)
    return mod


CT = _load_tool("check_trend")


def _soak_doc(**overrides):
    rows = [
        {"seed": 0, "controller": "static", "goodput": 0.95,
         "availability": 0.99, "client_p99_ms": 800.0,
         "recovery_rate": 1.0, "warm_bytes_mean": 4.0e9},
        {"seed": 0, "controller": "autopilot", "goodput": 0.96,
         "availability": 0.992, "client_p99_ms": 500.0,
         "recovery_rate": 1.0, "warm_bytes_mean": 3.5e9},
    ]
    doc = {"bench": "soak", "per_seed": rows}
    doc.update(overrides)
    return doc


# ---------------------------------------------------------------------------
# comparator contract
# ---------------------------------------------------------------------------

def test_identical_trend_passes():
    doc = _soak_doc()
    fails, matched = CT.compare(doc, copy.deepcopy(doc))
    assert not fails and matched == 2


def test_injected_p99_regression_fails():
    cur = _soak_doc()
    cur["per_seed"][1]["client_p99_ms"] *= 2.0   # way past the 25% band
    fails, _ = CT.compare(_soak_doc(), cur)
    assert any("client_p99_ms" in f for f in fails)


def test_in_band_noise_is_tolerated():
    cur = _soak_doc()
    cur["per_seed"][1]["client_p99_ms"] *= 1.05  # inside the 25% band
    cur["per_seed"][0]["goodput"] *= 0.99        # inside the 2% band
    fails, matched = CT.compare(_soak_doc(), cur)
    assert not fails and matched == 2


def test_improvements_always_pass():
    cur = _soak_doc()
    cur["per_seed"][1]["client_p99_ms"] = 100.0
    cur["per_seed"][0]["goodput"] = 0.999
    fails, _ = CT.compare(_soak_doc(), cur)
    assert not fails


def test_sentinel_rules():
    # sentinel -> sentinel: fine (metric had no data in either run)
    ref, cur = _soak_doc(), _soak_doc()
    ref["per_seed"][0]["client_p99_ms"] = -1.0
    cur["per_seed"][0]["client_p99_ms"] = -1.0
    fails, _ = CT.compare(ref, cur)
    assert not fails
    # data -> sentinel: the benchmark lost its signal = regression
    cur["per_seed"][1]["client_p99_ms"] = -1.0
    fails, _ = CT.compare(ref, cur)
    assert any("lost its data" in f for f in fails)
    # sentinel -> data: an improvement, never a failure
    ref2, cur2 = _soak_doc(), _soak_doc()
    ref2["per_seed"][0]["client_p99_ms"] = -1.0
    cur2["per_seed"][0]["client_p99_ms"] = 9999.0
    fails, _ = CT.compare(ref2, cur2)
    assert not fails


def test_zero_matched_rows_is_a_failure():
    cur = _soak_doc()
    for row in cur["per_seed"]:
        row["seed"] = 77                   # no identity overlap
    fails, matched = CT.compare(_soak_doc(), cur)
    assert matched == 0 and fails


def test_bench_kind_mismatch_fails():
    cur = _soak_doc(bench="mttr")
    fails, _ = CT.compare(_soak_doc(), cur)
    assert any("mismatch" in f for f in fails)


def test_committed_trend_files_self_compare_green():
    for name in ("BENCH_soak.json", "BENCH_mttr_smoke.json",
                 "BENCH_planner_smoke.json", "BENCH_resilience.json",
                 "BENCH_resilience_smoke.json", "BENCH_scale.json",
                 "BENCH_scale_smoke.json", "BENCH_shardfail.json",
                 "BENCH_shardfail_smoke.json"):
        doc = json.loads((ROOT / name).read_text())
        fails, matched = CT.compare(doc, copy.deepcopy(doc))
        assert not fails and matched > 0, (name, fails)


# ---------------------------------------------------------------------------
# RunResult JSON schema
# ---------------------------------------------------------------------------

def test_runresult_json_roundtrip_schema():
    from repro.experiment import ExperimentSpec, run_experiment

    spec = ExperimentSpec.smoke("sim")
    doc = run_experiment(spec).to_json_dict()
    # the document must survive a strict JSON round-trip unchanged
    assert json.loads(json.dumps(doc)) == doc
    for key in ("row", "per_epoch", "overall", "records", "traffic",
                "traffic_per_epoch", "protection"):
        assert key in doc, key
    for key in ("availability", "goodput", "n_offered"):
        assert key in doc["traffic"], key
    for key in ("warm_bytes_mean", "warm_bytes_final", "n_warm_mean",
                "n_warm_final"):
        assert key in doc["protection"], key


def test_soak_rows_carry_every_gated_metric():
    """Every metric the soak trend gate checks must exist in the rows
    tools/soak.py emits — a renamed key would silently skip the gate."""
    soak = _load_tool("soak")
    row, _ = soak.run_one(0, "static")
    gated = {m.key for m in CT.SPECS["soak"].metrics}
    assert gated <= set(row), gated - set(row)
    assert set(CT.SPECS["soak"].id_keys) <= set(row)


def test_resilience_rows_carry_every_gated_metric():
    """Same key-coherence check for the resilience gate: the committed
    trend rows (produced by tools/bench_resilience.py) must carry every
    metric AND identity key the 'resilience' spec gates on."""
    doc = json.loads((ROOT / "BENCH_resilience_smoke.json").read_text())
    spec = CT.SPECS["resilience"]
    assert doc["bench"] == "resilience"
    rows = doc[spec.rows_key]
    assert rows
    gated = {m.key for m in spec.metrics}
    for row in rows:
        assert gated <= set(row), gated - set(row)
        assert set(spec.id_keys) <= set(row)
    # both arms of the on/off comparison are present for every storm
    arms = {(r["scenario"], r["resilience"]) for r in rows}
    for scenario in {r["scenario"] for r in rows}:
        assert (scenario, "on") in arms and (scenario, "off") in arms


def test_shardfail_rows_carry_every_gated_metric():
    """Key coherence for the shardfail gate: every committed shardfail
    trend row (tools/bench_shardfail.py) must carry every metric and
    identity key the 'shardfail' spec gates on, all three ladder rungs
    must be present per tp_degree, and the committed gate evidence —
    degrade AND reshard each beating the monolith fallback on client
    MTTR — must actually hold in the committed rows."""
    spec = CT.SPECS["shardfail"]
    for name in ("BENCH_shardfail.json", "BENCH_shardfail_smoke.json"):
        doc = json.loads((ROOT / name).read_text())
        assert doc["bench"] == "shardfail"
        rows = doc[spec.rows_key]
        assert rows
        gated = {m.key for m in spec.metrics}
        for row in rows:
            assert gated <= set(row), (name, gated - set(row))
            assert set(spec.id_keys) <= set(row)
        cells = {(r["shard_policy"], r["tp_degree"]): r for r in rows}
        for tp in {r["tp_degree"] for r in rows}:
            for policy in ("degrade", "reshard", "monolith"):
                assert (policy, tp) in cells, (name, policy, tp)
            mono = cells[("monolith", tp)]["client_mttr_ms"]
            for policy in ("degrade", "reshard"):
                won = cells[(policy, tp)]["client_mttr_ms"]
                assert 0 <= won < mono, (name, policy, tp, won, mono)


def test_scale_rows_carry_every_gated_metric():
    """Key coherence for the scale gate: every committed scale trend
    row (tools/bench_scale.py) must carry every metric and identity
    key the 'scale' spec gates on — including the sentinel-bearing
    speedup column on epoch-only cells."""
    spec = CT.SPECS["scale"]
    for name in ("BENCH_scale.json", "BENCH_scale_smoke.json"):
        doc = json.loads((ROOT / name).read_text())
        assert doc["bench"] == "scale"
        rows = doc[spec.rows_key]
        assert rows
        gated = {m.key for m in spec.metrics}
        for row in rows:
            assert gated <= set(row), (name, gated - set(row))
            assert set(spec.id_keys) <= set(row)
