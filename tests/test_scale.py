"""Planet-scale engine: epoch-batched event drain, sharded planner,
and the hot-loop caches — the bit-exactness property suite.

Three claims are load-bearing for docs/SCALE.md and proven here:

  1. the epoch-batched drain ("epoch", the default) reproduces the
     per-event compat path's scenario fingerprints bit-for-bit — for
     every named golden scenario AND for randomized chaos streams;
  2. site-sharded worst-fit selection (planner/sharded.py) returns the
     same assignment, unplaced set, and Eq. 1 objective as the dense
     vectorized planner;
  3. the demand-vector/demand-matrix caches agree with the RESOURCES
     layout every planner array assumes.
"""

import hashlib
import random

import numpy as np
import pytest

from repro.core.cluster import RESOURCES, make_cluster
from repro.core.planner import (PlannerState, PlanRequest, SiteIndex,
                                get_planner, plan_greedy)
from repro.core.simulation import EventQueue, SimConfig, Simulation
from repro.core.variants import Application, synthetic_family

GOLDEN_CFG = dict(n_sites=4, servers_per_site=5, headroom=0.2,
                  policy="faillite", seed=0)
GOLDEN_SCENARIOS = ("cascade", "churn-under-failure", "flaky-node",
                    "rolling-with-rejoin", "single-server", "site-outage")


def _fingerprint(name, *, event_mode, seed=0, **cfg_over):
    cfg = dict(GOLDEN_CFG, event_mode=event_mode, seed=seed, **cfg_over)
    sim = Simulation(SimConfig(**cfg)).setup()
    res = sim.run_named_scenario(name)
    return hashlib.sha256(repr(res.fingerprint()).encode()).hexdigest()


# ---------------------------------------------------------------------------
# 1. epoch drain == per-event drain, bit for bit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", GOLDEN_SCENARIOS)
def test_epoch_drain_matches_per_event_goldens(name):
    """The six pinned scenarios (tests/test_modelstate.py) replay to the
    same fingerprint under both drain strategies — the epoch engine
    folds event-free chunk spans without moving a single RNG draw."""
    assert _fingerprint(name, event_mode="epoch") \
        == _fingerprint(name, event_mode="per-event")


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_epoch_drain_matches_per_event_chaos(seed):
    """Randomized churn (core/chaos.py) schedules events at arbitrary
    times, exercising every fold/stop boundary of the epoch drain."""
    assert _fingerprint("chaos", event_mode="epoch", seed=seed) \
        == _fingerprint("chaos", event_mode="per-event", seed=seed)


def test_epoch_drain_matches_with_diurnal_modulation():
    # diurnal q depends on chunk start times — folding must keep them
    over = dict(traffic_diurnal_amplitude=0.4,
                traffic_diurnal_period=30.0)
    assert _fingerprint("cascade", event_mode="epoch", **over) \
        == _fingerprint("cascade", event_mode="per-event", **over)


def test_bulk_stream_preserves_control_plane_and_volume():
    """Above ``bulk_min_apps`` the epoch drain switches to vectorized
    Poisson draws — a different RNG stream order, same traffic law.
    Control-plane outcomes must stay identical (the traffic plane is
    pure observation with resilience off), request volume must agree
    statistically, and the bulk path must be deterministic per seed."""
    def run(mode, bulk):
        sim = Simulation(SimConfig(**dict(GOLDEN_CFG, event_mode=mode)))
        if bulk:
            sim.traffic.bulk_min_apps = 1      # force the bulk branch
        sim.setup()
        res = sim.run_named_scenario("site-outage")
        return sim, res

    sim_b, res_b = run("epoch", bulk=True)
    sim_p, res_p = run("per-event", bulk=False)
    assert res_b.overall["recovery_rate"] == res_p.overall["recovery_rate"]
    assert len(res_b.records) == len(res_p.records)
    assert res_b.n_apps_final == res_p.n_apps_final
    nb, npe = sim_b.traffic.n_generated, sim_p.traffic.n_generated
    assert nb > 0 and abs(nb - npe) / npe < 0.05
    _, res_b2 = run("epoch", bulk=True)
    assert res_b2.fingerprint() == res_b.fingerprint()


def test_unknown_event_mode_rejected():
    with pytest.raises(ValueError, match="event_mode"):
        Simulation(SimConfig(event_mode="warp"))


def test_event_queue_counts_processed_events():
    from repro.core.simulation import SimClock

    q = EventQueue(SimClock())
    hits = []
    q.at(1.0, lambda: hits.append(1))
    q.at(2.0, lambda: hits.append(2))
    assert q.next_time() == 1.0
    q.run_until(5.0)
    assert q.n_processed == 2 and hits == [1, 2]
    assert q.next_time() is None


def test_float32_planner_runs_end_to_end():
    """Not fingerprint-preserving by design — but the scale dtype must
    still recover everything the float64 run recovers."""
    cfg = dict(GOLDEN_CFG, planner_dtype="float32")
    sim = Simulation(SimConfig(**cfg)).setup()
    assert sim.controller.state.capacity.dtype == np.float32
    res = sim.run_named_scenario("single-server")
    assert res.overall["recovery_rate"] == 1.0


# ---------------------------------------------------------------------------
# 2. sharded selection == dense selection
# ---------------------------------------------------------------------------

def _instance(n_apps, n_sites, per_site, seed):
    rng = random.Random(seed)
    cluster = make_cluster(n_sites, per_site, mem=48e9)
    apps = []
    for i in range(n_apps):
        lad = synthetic_family(f"f{i}", rng.uniform(0.5e9, 4e9),
                               n_variants=4)
        apps.append(Application(id=f"a{i}", family=f"f{i}", variants=lad,
                                request_rate=rng.uniform(0.5, 2.0),
                                critical=rng.random() < 0.5))
    return apps, cluster


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_sharded_planner_matches_greedy_bit_for_bit(seed):
    apps, cluster = _instance(120, 6, 8, seed)
    dense = get_planner("greedy").plan(
        PlanRequest(apps=apps, cluster=cluster, alpha=0.1))
    sharded = get_planner("sharded").plan(
        PlanRequest(apps=apps, cluster=cluster, alpha=0.1))
    assert sharded.assignment == dense.assignment
    assert sharded.unplaced == dense.unplaced
    assert sharded.objective == dense.objective


def test_sharded_matches_under_exclusions():
    apps, cluster = _instance(60, 4, 6, seed=7)
    exclude = {a.id: {cluster.alive_servers()[i % 4].id}
               for i, a in enumerate(apps)}
    site_exclude = {apps[0].id: {cluster.alive_servers()[0].site}}
    kw = dict(exclude=exclude, site_exclude=site_exclude, alpha=0.1)
    dense = plan_greedy(apps, cluster, **kw)
    sharded = plan_greedy(apps, cluster, site_index=SiteIndex, **kw)
    assert sharded.assignment == dense.assignment
    assert sharded.unplaced == dense.unplaced
    assert sharded.objective == dense.objective


def test_sharded_matches_with_dead_servers_and_degenerate_sites():
    apps, cluster = _instance(50, 5, 4, seed=3)
    for s in cluster.alive_servers()[::3]:
        cluster.fail_server(s.id)
    dense = plan_greedy(apps, cluster, alpha=0.1)
    sharded = plan_greedy(apps, cluster, site_index=SiteIndex, alpha=0.1)
    assert sharded.assignment == dense.assignment
    assert sharded.objective == dense.objective


def test_site_index_select_equals_masked_argmax():
    """Direct unit check of the selection invariant: first-maximum in
    row order, under random feasibility/exclusion patterns."""
    rng = np.random.default_rng(42)
    for _ in range(50):
        n = int(rng.integers(1, 40))
        site_of = np.sort(rng.integers(0, 6, n))
        free = rng.random((n, 2)) * 4.0
        head = rng.random(n)
        d = rng.random(2)
        excl = np.flatnonzero(rng.random(n) < 0.2).astype(np.int64)
        idx = SiteIndex(site_of, head)
        got = idx.select(free, head, d, excl if excl.size else None)
        feas = (free >= d - 1e-9).all(axis=1)
        feas[excl] = False
        want = (int(np.argmax(np.where(feas, head, -np.inf)))
                if feas.any() else -1)
        assert got == want


def test_sharded_planner_registered_and_realtime():
    p = get_planner("sharded")
    assert p.realtime


def test_full_scale_sim_runs_with_sharded_planner():
    cfg = dict(GOLDEN_CFG, planner="sharded")
    sim = Simulation(SimConfig(**cfg)).setup()
    res = sim.run_named_scenario("single-server")
    base = Simulation(SimConfig(**GOLDEN_CFG)).setup() \
        .run_named_scenario("single-server")
    assert res.fingerprint() == base.fingerprint()


# ---------------------------------------------------------------------------
# 3. cached demand layouts
# ---------------------------------------------------------------------------

def test_resources_layout_pinned():
    # every cached demand vector hardcodes this order — fail loudly if
    # the resource axes ever move
    assert RESOURCES == ("mem", "compute")


def test_variant_demand_vec_matches_resources_order():
    lad = synthetic_family("f", 2e9, n_variants=3)
    for v in lad:
        vec = v.demand_vec
        assert vec.dtype == np.float64
        assert vec[RESOURCES.index("mem")] == v.mem_bytes
        assert vec[RESOURCES.index("compute")] == v.compute
        assert v.demand_vec is vec          # cached, not rebuilt


def test_application_demand_matrix_cached_and_correct():
    lad = synthetic_family("f", 2e9, n_variants=4)
    app = Application(id="a", family="f", variants=lad)
    M = app.demand_matrix()
    assert M is app.demand_matrix()
    assert M.shape == (4, len(RESOURCES))
    for i, v in enumerate(app.variants):
        assert M[i, 0] == v.mem_bytes and M[i, 1] == v.compute


def test_worst_fit_accepts_vector_and_dict_identically():
    _, cluster = _instance(0, 3, 4, seed=0)
    st = PlannerState(cluster)
    d = {"mem": 1e9, "compute": 0.05}
    vec = np.array([1e9, 0.05])
    assert st.worst_fit(d) == st.worst_fit(vec)
    sid = st.worst_fit(vec)
    assert sid in cluster.servers


# ---------------------------------------------------------------------------
# spec/CLI plumbing
# ---------------------------------------------------------------------------

def test_cli_plumbs_event_mode_and_planner_dtype():
    from repro.experiment.cli import _build_parser, _spec_from_args

    args = _build_parser().parse_args(
        ["run", "--event-mode", "per-event",
         "--planner-dtype", "float32"])
    spec = _spec_from_args(args)
    assert spec.event_mode == "per-event"
    assert spec.planner_dtype == "float32"
    # defaults survive when the flags are absent
    args = _build_parser().parse_args(["run"])
    spec = _spec_from_args(args)
    assert spec.event_mode == "epoch"
    assert spec.planner_dtype == "float64"
