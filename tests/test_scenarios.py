"""Failure-scenario engine: deterministic replay, rejoin re-fill,
repeated-failure (multi-epoch) bookkeeping, churn, and the continuous
re-protection loop."""

import pytest

from repro.core.scenario import (
    SCENARIOS, AppArrival, AppDeparture, Scenario, ServerFail, ServerRejoin, build_scenario)
from repro.core.simulation import SimConfig, Simulation, run_scenario_suite

REQUIRED = ["single-server", "site-outage", "cascade",
            "rolling-with-rejoin", "churn-under-failure"]


def _sim(**kw):
    base = dict(n_sites=4, servers_per_site=5, headroom=0.2,
                policy="faillite", seed=0)
    base.update(kw)
    return Simulation(SimConfig(**base)).setup()


# ---------------------------------------------------------------------------
# library + determinism
# ---------------------------------------------------------------------------

def test_scenario_library_covers_required_classes():
    assert set(REQUIRED) <= set(SCENARIOS)
    assert len(SCENARIOS) >= 5
    sim = _sim()
    for name in SCENARIOS:
        sc = build_scenario(name, sim.cluster, sim.apps, seed=0)
        assert sc.events, name
        sc.validate(sim.cluster)


def test_scenario_build_deterministic_from_seed():
    sim = _sim()
    for name in SCENARIOS:
        a = build_scenario(name, sim.cluster, sim.apps, seed=7)
        b = build_scenario(name, sim.cluster, sim.apps, seed=7)
        assert a.sorted_events() == b.sorted_events(), name


@pytest.mark.parametrize("name", ["cascade", "rolling-with-rejoin",
                                  "churn-under-failure"])
def test_scenario_replay_deterministic(name):
    res_a = _sim(seed=3).run_named_scenario(name)
    res_b = _sim(seed=3).run_named_scenario(name)
    assert res_a.fingerprint() == res_b.fingerprint()
    assert res_a.per_epoch == res_b.per_epoch
    assert res_a.warm_coverage == res_b.warm_coverage


# ---------------------------------------------------------------------------
# rejoin re-fill
# ---------------------------------------------------------------------------

def test_rejoin_refills_returned_servers():
    sim = _sim()
    sc = build_scenario("rolling-with-rejoin", sim.cluster, sim.apps,
                        seed=0)
    rejoined = {e.server for e in sc.events
                if isinstance(e, ServerRejoin)}
    assert rejoined
    res = sim.run_scenario(sc)
    # every server is back alive
    assert all(s.alive for s in sim.cluster.servers.values())
    # re-protection converged: every critical app warm-protected again
    assert res.warm_coverage == 1.0
    assert res.overall["recovery_rate"] == 1.0
    # at least one rejoined (empty) server was re-filled with real work
    refilled = [sid for sid in rejoined
                if any(i.app_id != "_reserved"
                       for i in sim.cluster.servers[sid].instances.values())]
    assert refilled
    # the other-tenant share got re-blocked on rejoin
    for sid in rejoined:
        assert any(i.app_id == "_reserved"
                   for i in sim.cluster.servers[sid].instances.values())


def test_rejoin_within_detection_window():
    """A node that bounces back faster than failure detection (~65ms)
    must still end up alive, and the apps whose state died in the crash
    must still be recovered (their instances are gone either way)."""
    sim = _sim()
    victim = sim.controller.primaries[sim.apps[0].id]
    n_primaries = sum(1 for i in
                      sim.cluster.servers[victim].instances.values()
                      if i.role == "primary" and i.app_id != "_reserved")
    sc = Scenario(name="fast-bounce", horizon=20.0, events=[
        ServerFail(t=1.0, server=victim),
        ServerRejoin(t=1.03, server=victim),   # before detection fires
    ])
    res = sim.run_scenario(sc)
    assert sim.cluster.servers[victim].alive
    assert res.n_epochs == 1
    assert res.overall["n"] == n_primaries
    assert res.overall["recovery_rate"] == 1.0


def test_rejected_arrival_leaves_no_state():
    """deploy_primary must not leak an unplaceable app into controller
    bookkeeping."""
    sim = _sim(n_sites=1, servers_per_site=2, headroom=0.05)
    from repro.core.variants import Application, synthetic_family
    ladder = synthetic_family("huge", 64e9, n_variants=2, spread=1.5)
    app = Application(id="huge0", family="huge", variants=ladder)
    with pytest.raises(ValueError):
        sim.controller.deploy_primary(app)
    assert "huge0" not in sim.controller.apps
    assert "huge0" not in sim.controller.primaries
    assert not sim.cluster.instances_of("huge0")


def test_unrecovered_apps_retry_after_rejoin():
    """Capacity-starved failure: apps that cannot place stay down until
    servers rejoin, then the re-protection loop recovers them with MTTR
    counted from the ORIGINAL failure."""
    sim = _sim(n_sites=2, servers_per_site=2, headroom=0.15,
               critical_frac=0.0)
    sids = sorted(sim.cluster.servers)
    sc = Scenario(name="starve", horizon=30.0, events=[
        ServerFail(t=1.0, server=sids[0]),
        ServerFail(t=1.2, server=sids[1]),
        ServerFail(t=1.4, server=sids[2]),
        ServerRejoin(t=10.0, server=sids[0]),
        ServerRejoin(t=12.0, server=sids[1]),
    ])
    res = sim.run_scenario(sc)
    assert res.n_epochs == 3
    late = [r for r in res.records if r.recovered and r.mttr > 5.0]
    assert late, "expected retried recoveries after the rejoins"
    for r in late:
        assert r.mode in ("cold", "cold-progressive")
        assert r.epoch < res.n_epochs


# ---------------------------------------------------------------------------
# repeated failures / epochs
# ---------------------------------------------------------------------------

def test_flaky_node_produces_one_epoch_per_crash():
    sim = _sim()
    res = sim.run_named_scenario("flaky-node")
    assert res.n_epochs == 3           # three crash cycles
    assert len(sim.controller.epoch_records) == 3
    for ep, recs in enumerate(sim.controller.epoch_records):
        for rec in recs.values():
            assert rec.epoch == ep
    assert res.overall["recovery_rate"] == 1.0


def test_cascade_multi_epoch_bookkeeping():
    sim = _sim()
    res = sim.run_named_scenario("cascade")
    assert res.n_epochs >= 3           # one epoch per wave at least
    # per-epoch records are disjoint snapshots; the legacy flat view
    # keeps only the latest record per app
    flat_ids = [r.app_id for ep in sim.controller.epoch_records
                for r in ep.values()]
    assert len(flat_ids) == len(res.records)
    assert set(sim.controller.records) == set(flat_ids)
    assert res.per_epoch == sim.controller.summarize_epochs()


def test_double_failure_of_same_server_is_idempotent():
    sim = _sim()
    sid = sorted(sim.cluster.servers)[0]
    sc = Scenario(name="dup", horizon=20.0, events=[
        ServerFail(t=1.0, server=sid),
        ServerFail(t=5.0, server=sid),      # already dead: no-op epoch
    ])
    res = sim.run_scenario(sc)
    assert res.n_epochs == 2
    assert len(sim.controller.epoch_records[0]) > 0
    assert len(sim.controller.epoch_records[1]) == 0


# ---------------------------------------------------------------------------
# churn
# ---------------------------------------------------------------------------

def test_churn_under_failure_bookkeeping():
    sim = _sim()
    n0 = len(sim.apps)
    rates0 = {a.id: a.request_rate for a in sim.apps}
    sc = build_scenario("churn-under-failure", sim.cluster, sim.apps,
                        seed=0)
    arrivals = [e for e in sc.events if isinstance(e, AppArrival)]
    departures = [e for e in sc.events if isinstance(e, AppDeparture)]
    assert arrivals and departures
    res = sim.run_scenario(sc)

    ctl = sim.controller
    for e in departures:
        assert e.app_id not in ctl.apps
        assert not sim.cluster.instances_of(e.app_id)
    placed_late = [e.app.id for e in arrivals if e.app.id in ctl.apps]
    assert len(placed_late) + res.unplaced_arrivals == len(arrivals)
    assert res.n_apps_final == n0 + len(placed_late) - len(departures)
    # load-spike multiplier was restored after its duration
    for a in sim.apps:
        if a.id in rates0:
            assert a.request_rate == pytest.approx(rates0[a.id])
    # new critical arrivals got warm protection from the reprotect loop
    for e in arrivals:
        if e.app.critical and e.app.id in ctl.apps:
            assert e.app.id in ctl.warm


# ---------------------------------------------------------------------------
# policy sweep (the CI-smoke entry point)
# ---------------------------------------------------------------------------

def test_scenario_suite_sweeps_policies():
    cfg = SimConfig(n_sites=3, servers_per_site=3, headroom=0.25, seed=0)
    suite = run_scenario_suite(cfg, names=["single-server", "flaky-node"],
                               policies=("faillite", "full-cold"))
    for name, by_policy in suite.items():
        assert set(by_policy) == {"faillite", "full-cold"}
        for res in by_policy.values():
            assert res.n_epochs >= 1
            assert len(res.per_epoch) == res.n_epochs
