"""Planner subsystem: vectorized-vs-legacy parity, array-state
incremental sync, policy registry, and controller integration.

The parity test is the load-bearing one: the vectorized Algorithm 1
(planner/vectorized.py) must reproduce the legacy loop implementation
(planner/legacy.py) EXACTLY — same assignments, same unplaced list,
bit-identical Eq. 1 objective — across seeded random clusters,
exclusions, α values, and latency SLOs."""

import math
import random

import numpy as np
import pytest

from repro.core.cluster import Cluster, RESOURCES, Server, make_cluster
from repro.core.planner import (PlanRequest, PlannerState,
                                available_planners, eq1_objective,
                                faillite_heuristic,
                                faillite_heuristic_legacy, get_planner)
from repro.core.variants import Application, synthetic_family


def _rand_cluster(rng: random.Random) -> Cluster:
    """Heterogeneous cluster: 1-3 sites, uneven per-server capacity."""
    servers = []
    n_sites = rng.randint(1, 3)
    for si in range(n_sites):
        for sj in range(rng.randint(2, 5)):
            servers.append(Server(
                id=f"s{si}-{sj}", site=f"site{si}",
                capacity={"mem": rng.uniform(6e9, 24e9),
                          "compute": rng.uniform(0.5, 2.0)}))
    return Cluster(servers)


def _rand_apps(rng: random.Random, n: int):
    out = []
    for i in range(n):
        lad = synthetic_family(f"f{i}", rng.uniform(0.3e9, 6e9),
                               n_variants=rng.randint(2, 6),
                               spread=rng.uniform(1.5, 12.0))
        out.append(Application(
            id=f"a{i}", family=f"f{i}", variants=lad,
            request_rate=rng.uniform(0.2, 3.0),
            latency_slo=(rng.uniform(0.005, 0.05)
                         if rng.random() < 0.5 else math.inf),
            critical=rng.random() < 0.5))
    return out


def _lat_fn(app, variant, server):
    """Deterministic synthetic latency: per-server distance + size term."""
    return (0.002 * (sum(map(ord, server.id)) % 7)
            + variant.mem_bytes / 1e12 + 0.001)


def _norm(res):
    return ({k: (v.name, s) for k, (v, s) in res.assignment.items()},
            list(res.unplaced))


@pytest.mark.parametrize("seed", range(20))
def test_vectorized_matches_legacy(seed):
    """Seeded property test: identical assignments AND identical Eq. 1
    objective bits across random instances (tentpole acceptance)."""
    rng = random.Random(seed * 1009 + 7)
    cluster = _rand_cluster(rng)
    apps = _rand_apps(rng, rng.randint(1, 25))
    sids = list(cluster.servers)
    exclude = {a.id: {rng.choice(sids)} for a in apps
               if rng.random() < 0.7}
    site_exclude = {a.id: {f"site{rng.randrange(3)}"} for a in apps
                    if rng.random() < 0.3}
    alpha = rng.choice([0.0, 0.1, 0.25, 0.5])
    latency_fn = _lat_fn if rng.random() < 0.5 else None
    # make some instances capacity-starved: pre-place primaries
    for a in apps[::3]:
        sid = rng.choice(sids)
        if cluster.servers[sid].fits(a.variants[-1].demand):
            cluster.place(a.id, a.variants[-1], sid, "primary")

    old = faillite_heuristic_legacy(apps, cluster, exclude=exclude,
                                    site_exclude=site_exclude,
                                    alpha=alpha, latency_fn=latency_fn)
    new = faillite_heuristic(apps, cluster, exclude=exclude,
                             site_exclude=site_exclude,
                             alpha=alpha, latency_fn=latency_fn)
    assert _norm(old) == _norm(new)
    assert old.objective == new.objective      # bit-identical


def test_parity_with_dead_servers_and_empty_edge_cases():
    rng = random.Random(42)
    cluster = _rand_cluster(rng)
    apps = _rand_apps(rng, 8)
    for sid in list(cluster.servers)[::2]:
        cluster.fail_server(sid)
    old = faillite_heuristic_legacy(apps, cluster, alpha=0.1)
    new = faillite_heuristic(apps, cluster, alpha=0.1)
    assert _norm(old) == _norm(new)
    assert old.objective == new.objective
    # no apps
    assert _norm(faillite_heuristic([], cluster)) == ({}, [])
    # no alive servers
    for sid in cluster.servers:
        cluster.fail_server(sid)
    res = faillite_heuristic(apps, cluster)
    ref = faillite_heuristic_legacy(apps, cluster)
    assert _norm(res) == _norm(ref)
    assert res.assignment == {}


def test_objective_is_eq1():
    """Satellite: heuristic reports Σ accuracy·rate (Eq. 1), not raw
    accuracy, so ILP and heuristic compare like with like."""
    rng = random.Random(0)
    cluster = make_cluster(1, 4, mem=32e9)
    apps = _rand_apps(rng, 5)
    res = faillite_heuristic(apps, cluster)
    rate = {a.id: a.request_rate for a in apps}
    want = sum(v.accuracy * rate[aid] for aid, (v, _) in
               res.assignment.items())
    assert res.objective == pytest.approx(want, abs=1e-12)
    assert res.objective == eq1_objective(res.assignment, apps)


# ---------------------------------------------------------------------------
# PlannerState incremental sync
# ---------------------------------------------------------------------------

def _fresh(cluster):
    st = PlannerState(cluster, subscribe=False)
    st.sync()
    return st


def test_state_incremental_matches_rebuild():
    """Place / fail / revive / remove feed per-server deltas; the synced
    persistent state must equal a from-scratch rebuild exactly."""
    rng = random.Random(1)
    cluster = make_cluster(2, 3, mem=16e9)
    state = PlannerState(cluster)          # subscribes to cluster
    state.sync()
    apps = _rand_apps(rng, 6)
    keys = {}
    for i, a in enumerate(apps):
        sid = list(cluster.servers)[i % 6]
        keys[a.id] = cluster.place(a.id, a.full, sid, "primary")
    assert state.n_dirty > 0               # deltas were observed
    state.sync()
    ref = _fresh(cluster)
    assert np.array_equal(state.free, ref.free)
    assert np.array_equal(state.alive, ref.alive)

    cluster.fail_server("s0-0")
    cluster.remove(keys[apps[1].id], list(cluster.servers)[1])
    cluster.revive_server("s0-0")          # returns empty
    cluster.remove_app(apps[2].id)
    state.sync()
    ref = _fresh(cluster)
    assert np.array_equal(state.free, ref.free)
    assert np.array_equal(state.alive, ref.alive)
    # dirty set is now empty: a no-op sync touches nothing
    assert state.sync() == 0


def test_state_worst_fit_matches_legacy_freeview():
    from repro.core.planner.legacy import _FreeView, worst_fit
    rng = random.Random(5)
    for _ in range(10):
        cluster = _rand_cluster(rng)
        if rng.random() < 0.5:
            cluster.fail_server(rng.choice(list(cluster.servers)))
        state = PlannerState(cluster)
        demand = {"mem": rng.uniform(1e9, 20e9),
                  "compute": rng.uniform(0.1, 1.5)}
        excl = ({rng.choice(list(cluster.servers))}
                if rng.random() < 0.5 else set())
        view = _FreeView(cluster.alive_servers())
        assert (state.worst_fit(demand, excl)
                == worst_fit(view, demand, excl))


# ---------------------------------------------------------------------------
# registry + controller integration
# ---------------------------------------------------------------------------

def test_registry_contents_and_errors():
    names = available_planners()
    for want in ("greedy", "ilp", "legacy-greedy", "load-aware"):
        assert want in names
    with pytest.raises(KeyError, match="unknown planner"):
        get_planner("no-such-policy")
    assert get_planner("ilp").realtime is False
    assert get_planner("greedy").realtime is True


def test_load_aware_is_feasible_and_placed():
    rng = random.Random(9)
    cluster = make_cluster(2, 4, mem=24e9)
    apps = _rand_apps(rng, 10)
    res = get_planner("load-aware").plan(
        PlanRequest(apps=apps, cluster=cluster, alpha=0.1))
    used = {s.id: {r: 0.0 for r in RESOURCES} for s in cluster.servers.values()}
    for aid, (v, sid) in res.assignment.items():
        for r in RESOURCES:
            used[sid][r] += v.demand[r]
    for s in cluster.alive_servers():
        for r in RESOURCES:
            assert used[s.id][r] <= s.free(r) + 1e-6
    assert set(res.assignment) | set(res.unplaced) == {a.id for a in apps}


@pytest.mark.parametrize("name", ["greedy", "load-aware", "legacy-greedy"])
def test_controller_runs_with_any_registered_planner(name):
    """Acceptance: FailLiteController selects planners by name without
    importing planner internals."""
    from repro.core.simulation import SimConfig, Simulation
    cfg = SimConfig(n_sites=2, servers_per_site=3, server_mem=24e9,
                    planner=name, traffic_rate_scale=0.0, seed=3)
    sim = Simulation(cfg).setup()
    assert sim.controller.planner.name == name
    victim = sim.controller.primaries[next(iter(sim.controller.apps))]
    res = sim.inject_failure(servers=[victim], run_for=30.0)
    assert res.n_affected > 0
    assert res.recovery_rate > 0.0
    assert sim.controller.plan_wall_s > 0.0


def test_controller_has_no_private_freeview_dependency():
    """Satellite: the underscore import is gone for good."""
    import inspect
    import repro.core.controller as ctl
    src = inspect.getsource(ctl)
    assert "_FreeView" not in src
    assert "from repro.core.heuristic import" not in src


def test_ilp_planner_via_registry_dominates_greedy():
    rng = random.Random(11)
    cluster = make_cluster(2, 3, mem=8e9)
    apps = _rand_apps(rng, 6)
    primaries = {}
    for i, a in enumerate(apps):
        sid = cluster.alive_servers()[i % 6].id
        cluster.place(a.id, a.variants[-1], sid, "primary")
        primaries[a.id] = sid
    req = PlanRequest(apps=apps, cluster=cluster, primaries=primaries,
                      alpha=0.1)
    ilp = get_planner("ilp").plan(req)
    greedy = get_planner("greedy").plan(req)
    assert ilp.objective >= greedy.objective - 1e-6
    for aid, (v, sid) in ilp.assignment.items():
        assert sid != primaries[aid]


# ---------------------------------------------------------------------------
# jax planner backend: bit-identical compiled path
# ---------------------------------------------------------------------------

try:                                       # dev extra — shim to seeded
    from hypothesis import given, settings  # sweeps when not installed
    from hypothesis import strategies as hst
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def _fixed_cluster(rng: random.Random) -> Cluster:
    """Random capacities on a FIXED 2x3 shape: the jax kernels compile
    per (S, R, V, E, dtype) signature, so the property sweep keeps S
    pinned and varies everything else."""
    servers = []
    for si in range(2):
        for sj in range(3):
            servers.append(Server(
                id=f"s{si}-{sj}", site=f"site{si}",
                capacity={"mem": rng.uniform(6e9, 24e9),
                          "compute": rng.uniform(0.5, 2.0)}))
    return Cluster(servers)


def _jax_apps(rng: random.Random, n: int):
    """Like _rand_apps but <= 4 variants so the V bucket stays at 4."""
    out = []
    for i in range(n):
        lad = synthetic_family(f"f{i}", rng.uniform(0.3e9, 6e9),
                               n_variants=rng.randint(2, 4),
                               spread=rng.uniform(1.5, 12.0))
        out.append(Application(
            id=f"a{i}", family=f"f{i}", variants=lad,
            request_rate=rng.uniform(0.2, 3.0),
            critical=rng.random() < 0.5))
    return out


def _check_jax_parity(seed: int, dtype: str) -> None:
    from repro.core.planner.jax_backend import (JaxPlanContext,
                                                plan_greedy_jax)
    from repro.core.planner.vectorized import plan_greedy

    rng = random.Random(seed)
    cluster = _fixed_cluster(rng)
    apps = _jax_apps(rng, rng.randint(1, 20))
    sids = list(cluster.servers)
    exclude = {a.id: {rng.choice(sids)} for a in apps
               if rng.random() < 0.6}
    site_exclude = {a.id: {f"site{rng.randrange(3)}"} for a in apps
                    if rng.random() < 0.3}
    alpha = rng.choice([0.0, 0.1, 0.4])
    if rng.random() < 0.3:
        cluster.fail_server(rng.choice(sids))
    for a in apps[::4]:
        sid = rng.choice(sids)
        if cluster.servers[sid].fits(a.variants[-1].demand):
            cluster.place(a.id, a.variants[-1], sid, "primary")

    st_np = PlannerState(cluster, subscribe=False, dtype=dtype)
    st_jx = PlannerState(cluster, subscribe=False, dtype=dtype)
    r_np = plan_greedy(apps, cluster, state=st_np, exclude=exclude,
                       site_exclude=site_exclude, alpha=alpha)
    r_jx = plan_greedy_jax(apps, cluster, state=st_jx, exclude=exclude,
                           site_exclude=site_exclude, alpha=alpha,
                           ctx=JaxPlanContext())
    assert _norm(r_np) == _norm(r_jx)
    assert list(r_np.assignment) == list(r_jx.assignment)
    assert r_np.objective == r_jx.objective          # bit-identical


@pytest.mark.slow
@pytest.mark.parametrize("dtype", ["float64", "float32"])
def test_jax_backend_matches_numpy_random_instances(dtype):
    """Tentpole acceptance: the compiled planner is bit-identical to
    the numpy path across random clusters, exclusions, alphas, dead
    servers, and capacity-starved instances — property-style via
    hypothesis when installed, a seeded sweep otherwise."""
    pytest.importorskip("jax")
    if HAVE_HYPOTHESIS:
        @settings(max_examples=20, deadline=None)
        @given(hst.integers(min_value=0, max_value=2**31 - 1))
        def check(seed):
            _check_jax_parity(seed, dtype)
        check()
    else:
        for seed in range(10):
            _check_jax_parity(seed * 7919 + 13, dtype)


@pytest.mark.slow
def test_jax_dirty_row_sync_sequence_matches_numpy():
    """Incremental rounds: two identically mutated clusters, one
    planned by numpy and one by jax with a persistent DeviceMirror —
    every round must stay bit-identical, and the mirror must move
    dirty rows through the donated scatter, not full re-uploads."""
    pytest.importorskip("jax")
    from repro.core.planner.jax_backend import (JaxPlanContext,
                                                plan_greedy_jax)
    from repro.core.planner.vectorized import plan_greedy

    cl_np = _fixed_cluster(random.Random(5))
    cl_jx = _fixed_cluster(random.Random(5))
    apps = _jax_apps(random.Random(6), 12)
    st_np = PlannerState(cl_np, dtype="float32")
    st_jx = PlannerState(cl_jx, dtype="float32")
    ctx = JaxPlanContext()
    mirror = ctx.mirror(st_jx)
    mut = random.Random(7)
    downed = []
    for rnd in range(5):
        subset = [a for a in apps if mut.random() < 0.7] or apps[:1]
        r_np = plan_greedy(subset, cl_np, state=st_np, alpha=0.1)
        r_jx = plan_greedy_jax(subset, cl_jx, state=st_jx, alpha=0.1,
                               ctx=ctx)
        assert _norm(r_np) == _norm(r_jx)
        assert r_np.objective == r_jx.objective
        for aid, (v, sid) in list(r_np.assignment.items())[:3]:
            cl_np.place(f"{aid}-r{rnd}", v, sid, "backup")
            cl_jx.place(f"{aid}-r{rnd}", v, sid, "backup")
        if downed and rnd % 2:
            sid = downed.pop()
            cl_np.revive_server(sid)
            cl_jx.revive_server(sid)
        else:
            alive = [s.id for s in cl_np.alive_servers()]
            if len(alive) > 2:
                sid = mut.choice(alive)
                cl_np.fail_server(sid)
                cl_jx.fail_server(sid)
                downed.append(sid)
    assert mirror.full_uploads == 1
    assert mirror.rows_scattered > 0


def test_masked_argmax_jnp_matches_ref():
    """The jnp reduction (max + first-index min over iota) must keep
    numpy's first-maximum tie rule, including heavy ties and the
    empty mask."""
    pytest.importorskip("jax")
    import jax.numpy as jnp
    from repro.kernels.planner_argmax.ops import masked_argmax
    from repro.kernels.planner_argmax.ref import masked_argmax_ref

    rng = np.random.default_rng(3)
    for n in (1, 7, 512, 1000):
        for _ in range(5):
            vals = rng.standard_normal(n).astype(np.float32)
            for mask in (rng.random(n) < 0.5,
                         np.zeros(n, bool), np.ones(n, bool)):
                for v in (vals, np.round(vals)):     # round -> ties
                    wi, wv = masked_argmax_ref(v, mask)
                    gi, gv = masked_argmax(jnp.asarray(v),
                                           jnp.asarray(mask))
                    assert (int(gi), float(gv)) == (int(wi), float(wv))


@pytest.mark.slow
def test_masked_argmax_pallas_interpret_matches_ref():
    """The Pallas tiled kernel, run in interpret mode on CPU, is
    bit-identical to the numpy ref — ties, empty mask, non-multiple
    -of-block lengths."""
    pytest.importorskip("jax")
    import jax.numpy as jnp
    from repro.kernels.planner_argmax.ops import masked_argmax
    from repro.kernels.planner_argmax.ref import masked_argmax_ref

    rng = np.random.default_rng(9)
    for n in (128, 300, 512):
        vals = np.round(rng.standard_normal(n)).astype(np.float32)
        for mask in (rng.random(n) < 0.5, np.zeros(n, bool),
                     np.ones(n, bool)):
            wi, wv = masked_argmax_ref(vals, mask)
            gi, gv = masked_argmax(jnp.asarray(vals),
                                   jnp.asarray(mask),
                                   impl="pallas", block=128,
                                   interpret=True)
            assert (int(gi), float(gv)) == (int(wi), float(wv))


def test_ilp_branch_frac_pinned_to_float64():
    """Satellite regression: branching-variable selection must compare
    fractionalities in float64 — two LP values 1e-8 apart tie in
    float32 (argmax falls back to index 0) but have a strict winner in
    float64."""
    from repro.core.planner.ilp import _branch_frac

    x = np.array([0.50000002, 0.50000001])
    f = _branch_frac(x)
    assert f.dtype == np.float64
    assert int(np.argmax(f)) == 1
    # the float32 computation this pins away: both round to 0.5, the
    # fracs tie at 0.5, and argmax flips to index 0
    f32 = np.abs(x.astype(np.float32) - np.round(x.astype(np.float32)))
    assert int(np.argmax(f32)) == 0
    assert _branch_frac(x.astype(np.float32)).dtype == np.float64


def test_sharded_dense_fallback_warns_once_and_counts(caplog):
    """Satellite: a latency_fn request on the sharded planner falls
    back to the dense path — logged ONCE per planner instance, counted
    per round in stats["fallback_dense"]."""
    import logging

    rng = random.Random(21)
    cluster = _rand_cluster(rng)
    apps = _rand_apps(rng, 8)
    planner = get_planner("sharded")
    req = PlanRequest(apps=apps, cluster=cluster, alpha=0.1,
                      latency_fn=_lat_fn)
    with caplog.at_level(logging.WARNING, "repro.planner.sharded"):
        r1 = planner.plan(req)
        planner.plan(req)
    assert planner.stats["fallback_dense"] == 2
    warns = [r for r in caplog.records
             if "dense" in r.getMessage().lower()]
    assert len(warns) == 1                  # log-once, counted twice
    dense = get_planner("greedy").plan(req)
    assert _norm(r1) == _norm(dense)


@pytest.mark.parametrize("coordinators", [2, 3])
def test_multi_coordinator_sharded_matches_single(coordinators):
    """Tentpole: row-group coordinators planning concurrently must
    reproduce the single-coordinator sharded selection exactly (the
    deterministic ceiling-ordered merge)."""
    for seed in range(6):
        rng = random.Random(seed * 131 + 17)
        cluster = _rand_cluster(rng)
        apps = _rand_apps(rng, rng.randint(4, 18))
        req = PlanRequest(apps=apps, cluster=cluster, alpha=0.1)
        base = get_planner("sharded").plan(req)
        multi = get_planner("sharded", coordinators=coordinators)
        got = multi.plan(req)
        assert multi.stats["coordinators"] == coordinators
        assert _norm(base) == _norm(got)
        assert base.objective == got.objective


def test_planner_backend_registry_and_validation():
    from repro.core.planner import have_jax

    assert get_planner("greedy", backend="numpy").stats["backend"] \
        == "numpy"
    with pytest.raises(ValueError, match="unknown planner backend"):
        get_planner("greedy", backend="tpu")
    if have_jax():
        assert get_planner("sharded", backend="jax").stats["backend"] \
            == "jax"
    else:
        with pytest.raises(RuntimeError, match="requires jax"):
            get_planner("greedy", backend="jax")


@pytest.mark.slow
def test_simulation_jax_backend_matches_numpy():
    """End-to-end: the same failure scenario under planner_backend
    "jax" and "numpy" recovers identically, and the run surfaces the
    backend + round counters through planner_stats."""
    pytest.importorskip("jax")
    from repro.core.simulation import SimConfig, Simulation

    def run(backend):
        cfg = SimConfig(n_sites=2, servers_per_site=3, server_mem=24e9,
                        planner="greedy", planner_backend=backend,
                        traffic_rate_scale=0.0, seed=11)
        sim = Simulation(cfg).setup()
        victim = sim.controller.primaries[
            next(iter(sim.controller.apps))]
        res = sim.inject_failure(servers=[victim], run_for=30.0)
        return sim, res

    sim_np, res_np = run("numpy")
    sim_jx, res_jx = run("jax")
    assert res_np.recovery_rate == res_jx.recovery_rate
    assert res_np.n_affected == res_jx.n_affected
    stats = sim_jx.controller.planner_stats()
    assert stats["backend"] == "jax"
    assert stats["jax_rounds"] > 0
    assert sim_np.controller.planner_stats()["backend"] == "numpy"
