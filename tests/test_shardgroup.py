"""Shard plane (core/shardgroup.py + serving/shard.py): TP-k groups,
the ShardFail recovery ladder, and the real sharded testbed engine.

The off-path contract (tp_degree=1 keeps every golden fingerprint
bit-exact) is enforced by tests/test_modelstate.py; here we pin the
on-path behavior of each ladder rung plus the tp=1 ShardFail ==
ServerFail equivalence."""

import math

import pytest

from repro.core.scenario import Scenario, ServerFail, ShardFail
from repro.core.simulation import SimConfig, Simulation


def _sim(policy, **kw):
    cfg = dict(n_sites=3, servers_per_site=3, seed=0, headroom=0.25,
               tp_degree=2, shard_policy=policy, storage="edge")
    cfg.update(kw)
    return Simulation(SimConfig(**cfg)).setup()


def _kill_member(sim, app_id, *, lead=False):
    g = sim.shards.groups[app_id]
    rank = min(g.members) if lead else max(g.members)
    victim = g.members[rank].server_id
    return sim.run_scenario(Scenario(
        name="one-shard", horizon=25.0,
        events=[ShardFail(t=1.0, server=victim)]))


# ---------------------------------------------------------------------------
# deployment
# ---------------------------------------------------------------------------

def test_deploy_group_spans_distinct_servers():
    sim = _sim("auto")
    assert sim.shards is not None and sim.shards.groups
    for app in sim.apps:
        g = sim.shards.groups[app.id]
        sids = [m.server_id for m in g.members.values()]
        assert len(g.members) == 2 and len(set(sids)) == 2
        assert g.state == "live"
        # route answers on the rank-0 lead with the FULL variant name
        srv, vname = sim.controller.routing.routes[app.id]
        assert srv == g.lead.server_id and vname == app.full.name
        # each slice checkpoint has its own residency entry
        for rank, m in g.members.items():
            sv = sim.shards.slice_variant(app.full, rank)
            assert sv.mem_bytes == pytest.approx(app.full.mem_bytes / 2)
    sim.shards.check_conservation()


def test_auto_policy_resolves_by_criticality():
    sim = _sim("auto")
    for gid, g in sim.shards.groups.items():
        app = sim.controller.apps[gid]
        assert g.policy == ("degrade" if app.critical else "reshard")


def test_tp1_keeps_shardfail_identical_to_serverfail():
    """With no shard plane a ShardFail IS a ServerFail — bit-exact."""
    def run(ev_cls):
        sim = Simulation(SimConfig(n_sites=2, servers_per_site=3,
                                   seed=0)).setup()
        assert sim.shards is None
        victim = sim.controller.primaries[sim.apps[0].id]
        return sim.run_scenario(Scenario(
            name="x", horizon=20.0,
            events=[ev_cls(t=1.0, server=victim)])).fingerprint()
    assert run(ShardFail) == run(ServerFail)


# ---------------------------------------------------------------------------
# the recovery ladder, rung by rung
# ---------------------------------------------------------------------------

def test_degrade_continuation():
    sim = _sim("degrade")
    app = sim.apps[0]
    res = _kill_member(sim, app.id)
    g = sim.shards.groups[app.id]
    assert g.state == "degraded" and len(g.members) == 1
    rec = next(r for r in res.records if r.app_id == app.id)
    assert rec.mode == "shard-degrade" and rec.recovered
    assert rec.phases["repartition"] > 0 and "fetch" not in rec.phases
    # the synthetic variant lives in the side table, NOT app.variants
    # (appending would corrupt app.smallest / cached demand matrices)
    dv = sim.shards.lookup_variant(rec.variant)
    assert dv is not None and dv.name.endswith("::tp1of2")
    assert all(v.name != dv.name for v in app.variants)
    assert dv.accuracy < app.full.accuracy
    assert dv.compute > app.full.compute / 2       # k/k_alive service x
    sim.shards.check_conservation()


def test_degrade_of_nonlead_is_seamless_lead_is_not():
    sim = _sim("degrade")
    app = sim.apps[0]
    g = sim.shards.groups[app.id]
    lead_sid = g.lead.server_id
    other_sid = g.members[max(g.members)].server_id
    # non-lead loss: survivors keep answering -> no darkened app
    assert app.id not in sim.shards.darkened_by({other_sid})
    # lead loss: clients see the gap until the route flips
    assert app.id in sim.shards.darkened_by({lead_sid})


def test_reshard_restores_full_tp():
    sim = _sim("reshard")
    app = sim.apps[0]
    res = _kill_member(sim, app.id)
    g = sim.shards.groups[app.id]
    assert g.state == "live" and len(g.members) == 2
    assert g.pending is None
    sids = {m.server_id for m in g.members.values()}
    assert len(sids) == 2
    rec = next(r for r in res.records if r.app_id == app.id)
    assert rec.mode == "shard-reshard" and rec.recovered
    # slice refetch + explicit repartition phase, priced as slice bytes
    assert rec.phases["fetch"] > 0 and rec.phases["repartition"] > 0
    assert rec.mttr > sim.shards.repartition_seconds(
        sim.shards.slice_variant(app.full, 0), 1)
    sim.shards.check_conservation()


def test_monolith_fallback_dissolves_group():
    sim = _sim("monolith")
    app = sim.apps[0]
    res = _kill_member(sim, app.id)
    g = sim.shards.groups[app.id]
    assert g.state == "fallen-back" and not g.members
    assert not sim.shards.is_grouped(app.id)
    rec = next(r for r in res.records if r.app_id == app.id)
    assert rec.recovered                  # ordinary progressive failover
    assert "shard-monolith" in sim.shards.summary()["actions"]
    sim.shards.check_conservation()


def test_ladder_client_mttr_ordering():
    """The acceptance ordering behind BENCH_shardfail.json: degraded-TP
    continuation answers fastest, reshard pays the slice fetch but
    beats re-fetching whole monoliths through the cloud uplink."""
    mttr = {}
    for policy in ("degrade", "reshard", "monolith"):
        sim = _sim(policy, servers_per_site=4)   # the bench smoke shape
        t = sim.run_named_scenario("tp-shard-storm").traffic
        assert math.isfinite(t.client_mttr_avg), policy
        mttr[policy] = t.client_mttr_avg
    assert mttr["degrade"] < mttr["reshard"] < mttr["monolith"]


def test_second_loss_of_degraded_group_falls_back():
    sim = _sim("degrade")
    app = sim.apps[0]
    g = sim.shards.groups[app.id]
    s1 = g.members[max(g.members)].server_id
    s2 = g.lead.server_id
    sim.run_scenario(Scenario(name="double", horizon=30.0, events=[
        ShardFail(t=1.0, server=s1),
        ShardFail(t=8.0, server=s2),
    ]))
    assert g.state == "fallen-back"
    summary = sim.shards.summary()
    assert summary["actions"]["shard-degrade"] >= 1
    assert summary["actions"].get("shard-monolith", 0) >= 1
    sim.shards.check_conservation()


# ---------------------------------------------------------------------------
# testbed: a REAL sharded JAX engine surviving a shard-host kill
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_testbed_reshard_measures_real_mttr():
    from repro.serving.testbed import MiniTestbed
    tb = MiniTestbed(n_sites=3, servers_per_site=1,
                     archs=["rwkv6-3b"], apps_per_arch=1, seed=3,
                     headroom=0.35, tp_degree=2, shard_policy="reshard")
    try:
        tb.deploy()
        app = tb.apps[0]
        g = tb.shards.groups[app.id]
        victim = g.members[max(g.members)].server_id
        res = tb.run_scenario(Scenario(
            name="tb-shard", horizon=8.0,
            events=[ShardFail(t=1.0, server=victim)]),
            settle_s=30.0, client_hz=10.0)
        assert g.state == "live" and len(g.members) == 2
        assert victim not in {m.server_id for m in g.members.values()}
        shard = res["shard"]
        meas = shard["measured"]
        # a real slice re-materialize + re-gather + recompile happened
        assert meas["slice_fetch_s"]["n"] >= 1
        assert meas["reshard_mttr_s"]["n"] >= 1
        assert meas["reshard_mttr_s"]["avg_s"] > 0
        # the measured repartition calibrated the sim's cost model
        assert shard["repartition_scale"] != 1.0
        # and the lead is serving the gathered full engine again
        lead = tb.workers[g.lead.server_id]
        assert lead.has(app.full.name)
    finally:
        tb.shutdown()
