"""Experiment API: spec round-trip, backend registry, sim-path
equivalence with the legacy entry point, CLI, and (slow) cross-backend
parity — the same spec must make the same failover choices on the
simulator and on the live thread testbed."""

import json
import math

import pytest

from repro.experiment import (BACKENDS, ExperimentSpec, RunResult,
                              get_backend, primary_kill_scenario,
                              run_experiment)

TINY = dict(n_sites=2, servers_per_site=2, headroom=0.3,
            traffic_rate_scale=5.0, settle_s=10.0, seed=0)


# ---------------------------------------------------------------------------
# spec + registry
# ---------------------------------------------------------------------------

def test_spec_roundtrip():
    spec = ExperimentSpec(scenario="cascade", policy="full-warm",
                          seed=7, n_sites=3, archs=["qwen2.5-3b"],
                          app_mix="arch")
    again = ExperimentSpec.from_dict(spec.to_dict())
    assert again == spec
    assert json.loads(json.dumps(spec.to_dict())) == spec.to_dict()


def test_spec_rejects_unknown_fields_and_mixes():
    with pytest.raises(ValueError):
        ExperimentSpec.from_dict({"no_such_field": 1})
    with pytest.raises(ValueError):
        ExperimentSpec(app_mix="bogus")


def test_spec_testbed_forces_arch_mix():
    # synthetic ladders carry no ModelConfig -> not servable
    assert ExperimentSpec(backend="testbed").app_mix == "arch"
    assert ExperimentSpec(backend="sim").app_mix == "synthetic"


def test_backend_registry():
    assert {"sim", "testbed"} <= set(BACKENDS)
    assert get_backend("sim").name == "sim"
    with pytest.raises(KeyError):
        get_backend("quantum")


# ---------------------------------------------------------------------------
# sim backend
# ---------------------------------------------------------------------------

def test_sim_run_result_schema():
    res = run_experiment(ExperimentSpec(scenario="single-server", **TINY))
    assert isinstance(res, RunResult)
    assert res.backend == "sim"
    assert res.n_epochs >= 1
    assert res.overall["recovery_rate"] == 1.0
    assert res.traffic is not None and res.traffic.n_offered > 0
    assert res.plan_wall_s > 0.0
    assert math.isnan(res.detect_latency_s)     # sim models detection
    by_app = res.recovery_by_app()
    assert by_app and all(len(v) == 3 for v in by_app.values())
    assert set(res.to_row()) >= {"backend", "scenario", "recovery_rate",
                                 "client_mttr_ms", "availability"}


def test_sim_path_identical_to_legacy_entry_point():
    """The API wrapper must not perturb the deterministic sim path:
    same fingerprint as driving Simulation directly."""
    from repro.core.simulation import SimConfig, Simulation

    res = run_experiment(ExperimentSpec(scenario="site-outage", **TINY))
    sim = Simulation(SimConfig(n_sites=2, servers_per_site=2,
                               headroom=0.3, traffic_rate_scale=5.0,
                               seed=0)).setup()
    legacy = sim.run_named_scenario("site-outage", settle=10.0)
    assert res.fingerprint() == legacy.fingerprint()


def test_scenario_builder_hook():
    res = run_experiment(ExperimentSpec(
        scenario="primary-kill",
        scenario_builder=primary_kill_scenario(), **TINY))
    assert res.scenario == "primary-kill"
    # the victim hosted app0's primary, so app0 must appear
    assert any(r.app_id == "app0" for r in res.records)


def test_arch_mix_runs_on_sim():
    res = run_experiment(ExperimentSpec(
        scenario="single-server", app_mix="arch",
        archs=["qwen2.5-3b", "rwkv6-3b"], n_sites=2, servers_per_site=1,
        headroom=0.35, traffic_rate_scale=5.0, settle_s=10.0, seed=3))
    assert res.overall["recovery_rate"] == 1.0
    # arch ladders really were used
    fams = {r.variant.split(":")[0] for r in res.records}
    assert fams <= {"qwen2.5-3b", "rwkv6-3b"}


def test_fingerprint_raises_on_non_deterministic_backend():
    res = run_experiment(ExperimentSpec(scenario="single-server", **TINY))
    res.sim_result = None                 # simulate a testbed result
    with pytest.raises(ValueError):
        res.fingerprint()


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def test_cli_list(capsys):
    from repro.experiment.cli import main
    assert main(["list"]) == 0
    out = capsys.readouterr().out
    assert "sim" in out and "testbed" in out and "single-server" in out


def test_cli_run_smoke_json(capsys):
    from repro.experiment.cli import main
    assert main(["run", "--smoke", "--backend", "sim", "--json"]) == 0
    row = json.loads(capsys.readouterr().out)
    assert row["backend"] == "sim"
    assert row["recovery_rate"] == 1.0


def test_cli_run_out_dumps_runresult(tmp_path, capsys):
    from repro.experiment.cli import main
    out = tmp_path / "result.json"
    assert main(["run", "--smoke", "--backend", "sim",
                 "--out", str(out)]) == 0
    capsys.readouterr()
    doc = json.loads(out.read_text())
    assert doc["spec"]["backend"] == "sim"
    assert doc["row"]["recovery_rate"] == 1.0
    assert doc["records"] and {"app_id", "mttr_ms", "phases"} \
        <= set(doc["records"][0])
    # the whole dump must already be JSON-clean (no inf/nan leaked)
    json.dumps(doc)
    # spec round-trips back into an executable ExperimentSpec
    assert ExperimentSpec.from_dict(doc["spec"]).backend == "sim"


def test_load_bw_sweeps_without_monkeypatching():
    """The Fig. 2b constants are SimConfig/ExperimentSpec fields now:
    doubling the disk bandwidth shrinks cold-recovery MTTR."""
    slow = run_experiment(ExperimentSpec(**TINY, policy="full-cold",
                                         load_bw=4e9))
    fast = run_experiment(ExperimentSpec(**TINY, policy="full-cold",
                                         load_bw=16e9))
    assert fast.overall["mttr_avg"] < slow.overall["mttr_avg"]


def test_storage_and_scheduler_fields_reach_backend():
    res = run_experiment(ExperimentSpec(**TINY, scenario="cold-load-storm",
                                        storage="edge",
                                        scheduler="criticality",
                                        planner="locality"))
    assert res.overall["recovery_rate"] > 0.0
    srcs = {r.source for r in res.records if r.source}
    assert srcs <= {"local", "peer", "cloud"} and srcs


# ---------------------------------------------------------------------------
# testbed backend (slow: real JAX engines)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_testbed_rejects_profile_only_apps():
    from repro.core.variants import Application, synthetic_family
    from repro.serving.testbed import MiniTestbed
    ladder = synthetic_family("x", 1e9)
    with pytest.raises(ValueError):
        MiniTestbed(apps=[Application(id="x0", family="x",
                                      variants=ladder)])


@pytest.mark.slow
def test_cross_backend_parity():
    """Same spec, same scenario, same seed -> the same failover variant
    choices on both backends (wall-clock MTTRs may differ)."""
    spec = ExperimentSpec(
        backend="testbed", scenario="single-server", app_mix="arch",
        archs=["qwen2.5-3b", "rwkv6-3b", "recurrentgemma-2b"],
        n_sites=3, servers_per_site=2, headroom=0.35, client_hz=20.0,
        time_scale=0.25, settle_s=25.0, seed=1)
    sim = run_experiment(spec.with_(backend="sim"))
    tb = run_experiment(spec)

    assert sim.recovery_by_app() == tb.recovery_by_app()
    assert tb.overall["recovery_rate"] == 1.0
    # unified schema: both sides expose the same summary keys
    assert set(sim.to_row()) == set(tb.to_row())
    # real detection + real client-observed downtime on the testbed
    assert 0.0 < tb.detect_latency_s < 1.0
    t = tb.traffic
    assert t.n_windows >= 1
    assert t.n_offered > 0
    assert math.isfinite(t.client_mttr_avg) and t.client_mttr_avg > 0.0


@pytest.mark.slow
def test_cross_backend_parity_with_resilience():
    """The resilience toolkit is request-plane only: with it on, both
    backends must still make the SAME control-plane failover choices,
    and both must report through the new outcome classes."""
    spec = ExperimentSpec(
        backend="testbed", scenario="single-server", app_mix="arch",
        archs=["qwen2.5-3b", "rwkv6-3b", "recurrentgemma-2b"],
        n_sites=3, servers_per_site=2, headroom=0.35, client_hz=20.0,
        time_scale=0.25, settle_s=25.0, seed=1,
        resilience={"enabled": True})
    sim = run_experiment(spec.with_(backend="sim"))
    tb = run_experiment(spec)

    # control plane untouched by the request-plane layer: identical
    # failover decisions on both engines, and identical to the
    # resilience-off sim path
    assert sim.recovery_by_app() == tb.recovery_by_app()
    off = run_experiment(spec.with_(backend="sim", resilience=None))
    assert sim.recovery_by_app() == off.recovery_by_app()
    assert tb.overall["recovery_rate"] == 1.0
    # both sides fold the new outcome classes into the same schema
    for t in (sim.traffic, tb.traffic):
        d = t.to_dict()
        assert {"n_hedged_win", "n_fast_failed",
                "n_shed", "n_retried"} <= set(d)
    # the toolkit visibly engaged on at least one backend: warm-backed
    # apps hedge, unprotected ones fast-fail or shed under the blackout
    engaged = sum(sim.traffic.to_dict()[k] + tb.traffic.to_dict()[k]
                  for k in ("n_hedged_win", "n_fast_failed",
                            "n_shed", "n_retried"))
    assert engaged > 0
