"""FailLite core: unit + hypothesis property tests for the placement
invariants (capacity feasibility, anti-affinity, α-reserve, ILP vs
heuristic dominance)."""

import random

import pytest

from repro.core.cluster import make_cluster
from repro.core.planner import faillite_heuristic, solve_warm_placement
from repro.core.variants import (Application, Variant, build_ladder,
                                 synthetic_family)


def _apps(rng, n, mem_range=(0.5e9, 4e9), spread=6.0, critical_frac=0.5):
    out = []
    for i in range(n):
        lad = synthetic_family(f"f{i}", rng.uniform(*mem_range),
                               n_variants=4, spread=spread)
        out.append(Application(id=f"a{i}", family=f"f{i}", variants=lad,
                               request_rate=rng.uniform(0.5, 2.0),
                               critical=rng.random() < critical_frac))
    return out


# ---------------------------------------------------------------------------
# variant ladders
# ---------------------------------------------------------------------------

def test_ladder_monotone_all_archs():
    from repro import configs
    for arch in configs.ARCHS:
        lad = build_ladder(configs.get_config(arch))
        mems = [v.mem_bytes for v in lad]
        assert mems == sorted(mems, reverse=True), arch
        assert all(0.0 < v.accuracy <= 1.0 + 1e-9 for v in lad), arch
        assert lad[0].accuracy == max(v.accuracy for v in lad), arch
        # Fig 2a shape: halving capacity costs only a few percent accuracy
        small = next(v for v in lad if v.name.endswith("w050"))
        assert lad[0].accuracy - small.accuracy < 0.05, arch


def test_int8_variant_halves_memory():
    from repro import configs
    lad = build_ladder(configs.get_config("qwen2.5-3b"))
    full = next(v for v in lad if v.name.endswith(":full"))
    int8 = next(v for v in lad if v.name.endswith(":int8"))
    assert abs(int8.mem_bytes / full.mem_bytes - 0.5) < 0.05


# ---------------------------------------------------------------------------
# Algorithm 1 properties (hypothesis-based invariants for the heuristic
# live in tests/test_properties.py, which skips without `hypothesis`)
# ---------------------------------------------------------------------------

def test_heuristic_prefers_larger_when_space():
    """upgrade_model: with abundant capacity every app gets its full model."""
    rng = random.Random(0)
    cluster = make_cluster(1, 8, mem=64e9)
    apps = _apps(rng, 4, mem_range=(0.5e9, 1e9))
    res = faillite_heuristic(apps, cluster)
    for app in apps:
        v, _ = res.assignment[app.id]
        assert v.name == app.variants[0].name


# ---------------------------------------------------------------------------
# ILP (exact B&B) vs heuristic
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 1, 2])
def test_ilp_dominates_heuristic(seed):
    rng = random.Random(seed)
    cluster = make_cluster(2, 3, mem=8e9)
    apps = _apps(rng, 6, mem_range=(1e9, 5e9), critical_frac=1.0)
    primaries = {}
    for i, a in enumerate(apps):
        sid = cluster.alive_servers()[i % 6].id
        cluster.place(a.id, a.variants[-1], sid, "primary")
        primaries[a.id] = sid
    res = solve_warm_placement(apps, cluster, primaries, alpha=0.1)
    greedy = faillite_heuristic(
        apps, cluster, exclude={a.id: {primaries[a.id]} for a in apps},
        alpha=0.1)
    obj_h = sum(v.accuracy * a.request_rate
                for a in apps
                for v, _ in [greedy.assignment.get(a.id, (None, None))]
                if v is not None)
    assert res.objective >= obj_h - 1e-6

    # ILP respects anti-affinity + per-server capacity
    used = {}
    for app_id, (v, sid) in res.assignment.items():
        assert sid != primaries[app_id]
        used.setdefault(sid, 0.0)
        used[sid] += v.demand["mem"]
    for sid, u in used.items():
        assert u <= cluster.servers[sid].free("mem") + 1e-3


def test_ilp_alpha_reserve_respected():
    rng = random.Random(3)
    cluster = make_cluster(1, 4, mem=8e9)
    apps = _apps(rng, 5, mem_range=(2e9, 5e9), critical_frac=1.0)
    primaries = {a.id: "s0-0" for a in apps}
    alpha = 0.5
    res = solve_warm_placement(apps, cluster, primaries, alpha=alpha)
    total = sum(v.demand["mem"] for v, _ in res.assignment.values())
    assert total <= (1 - alpha) * cluster.total_free()["mem"] + 1e-3


# ---------------------------------------------------------------------------
# cluster / datastore
# ---------------------------------------------------------------------------

def test_cluster_capacity_accounting():
    cluster = make_cluster(1, 1, mem=10e9)
    v = Variant("m:full", "m", 4e9, 0.1, 1.0)
    key = cluster.place("a", v, "s0-0", "primary")
    assert cluster.servers["s0-0"].free("mem") == pytest.approx(6e9)
    # cold replicas don't consume accelerator memory
    cluster.place("b", v, "s0-0", "cold")
    assert cluster.servers["s0-0"].free("mem") == pytest.approx(6e9)
    cluster.remove(key, "s0-0")
    assert cluster.servers["s0-0"].free("mem") == pytest.approx(10e9)
    with pytest.raises(ValueError):
        cluster.place("c", Variant("m:x", "m", 11e9, 0.1, 1.0), "s0-0",
                      "warm")


def test_datastore_replication_and_checkpoint(tmp_path):
    from repro.core.datastore import DataStore
    ds = DataStore("primary")
    replica = DataStore("replica")
    ds.put("a", {"x": 1})
    ds.add_replica(replica)
    ds.put("b", [1, 2, 3])
    ds.delete("a")
    assert replica.get("b") == [1, 2, 3]
    assert replica.get("a") is None
    p = tmp_path / "snap.json"
    ds.checkpoint_to(p)
    ds2 = DataStore.from_checkpoint(p)
    assert ds2.get("b") == [1, 2, 3]
    assert ds2.version == ds.version


def test_failure_detector_sim_clock():
    from repro.core.heartbeat import FailureDetector, SimClock
    clock = SimClock()
    det = FailureDetector(clock, interval=0.02, miss_count=2)
    det.beat("s1")
    det.beat("s2")
    clock.advance(0.03)
    det.beat("s2")               # s2 keeps beating
    assert det.sweep() == []
    clock.advance(0.02)          # s1 now 50ms stale (> 2*20ms)
    assert det.sweep() == ["s1"]
    assert det.sweep() == []     # reported once
